"""Fault-tolerance showcase: failure injection + bit-identical recovery +
elastic restart (the checkpointed run resumes with a different data-shard
layout, as after a pod loss).

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""
import shutil
import tempfile

import jax
import numpy as np

from repro.configs import get_arch
from repro.data import DataConfig, SyntheticLM
from repro.ft import FailureInjector, RunnerConfig, TrainingRunner
from repro.models import RunConfig, init_lm
from repro.optim import OptConfig
from repro.train import TrainConfig, init_train_state, make_train_step

cfg = get_arch("granite-moe-1b-a400m").reduced()
run = RunConfig(remat="none")
tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=24))
data = SyntheticLM(DataConfig(seed=3, seq_len=32, global_batch=4,
                              vocab=cfg.vocab))
key = jax.random.PRNGKey(0)
step = jax.jit(make_train_step(cfg, run, tcfg))

def fresh():
    return init_train_state(cfg, init_lm(cfg, key), tcfg)

d_ok = tempfile.mkdtemp()
d_ft = tempfile.mkdtemp()

print("run A: 24 steps, no failures")
out_a = TrainingRunner(step, data, fresh(), d_ok,
                       RunnerConfig(total_steps=24, ckpt_every=6)).run()

print("run B: failures injected at steps 8 and 17 → auto-restart from ckpt")
out_b = TrainingRunner(step, data, fresh(), d_ft,
                       RunnerConfig(total_steps=24, ckpt_every=6),
                       injector=FailureInjector(fail_at=(8, 17))).run()

diff = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
           for a, b in zip(jax.tree.leaves(out_a["state"]["params"]),
                           jax.tree.leaves(out_b["state"]["params"])))
print(f"restarts: {out_b['restarts']}, max param divergence: {diff:.2e} "
      f"({'bit-identical ✓' if diff == 0 else 'MISMATCH'})")

shutil.rmtree(d_ok), shutil.rmtree(d_ft)
