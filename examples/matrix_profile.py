"""Matrix profile: motif and discord discovery in one long recording.

Builds a synthetic series with a planted repeated pattern (the motif)
and a planted one-off anomaly (the discord), then:
  1. batch   — `matrix_profile` over the finished series;
  2. stream  — `StreamProfile` fed block by block, polled live;
  3. matsa   — the paper-facing `matsa(mode="self_join")` front door,
               which routes through the profile.

Run:  PYTHONPATH=src python examples/matrix_profile.py
"""
import numpy as np

from repro.core import matsa, synthetic_timeseries
from repro.search import matrix_profile
from repro.stream import StreamProfile

rng = np.random.default_rng(11)
W = 32

# --- a recording with a planted motif and a planted discord ---------------
series = synthetic_timeseries(rng, 2048, anomaly_rate=0.0)
motif = (200 * np.sin(np.linspace(0, 4 * np.pi, W))).astype(series.dtype)
series[300:300 + W] = motif + rng.integers(-3, 4, W).astype(series.dtype)
series[1500:1500 + W] = motif + rng.integers(-3, 4, W).astype(series.dtype)
series[900:900 + W] = rng.integers(-2000, 2000, W).astype(series.dtype)

# --- 1. batch profile -----------------------------------------------------
# (A homogeneous periodic series defeats the envelope bounds, so expect
# pruned=0 here — heterogeneous level-shifted data prunes; see
# benchmarks/profile_bench.py.)
prof = matrix_profile(series, W, stride=8, k=3)
print(f"[batch] {prof.starts.shape[0]} windows, "
      f"pruned {prof.chunks_pruned}/{prof.chunks_total} chunks")
for a, b, d in prof.motifs:
    print(f"  motif: windows at samples {prof.starts[a]} and "
          f"{prof.starts[b]} (distance {d:.0f})")
for i, d in prof.discords:
    print(f"  discord: window at sample {prof.starts[i]} "
          f"(nearest neighbor {d:.0f} away)")

# --- 2. streaming: same answer, fed in blocks -----------------------------
sp = StreamProfile(W, stride=8, k=3, chunk=256)
for block in np.array_split(series, 7):
    sp.feed(block)
live = sp.results()
assert np.array_equal(live.nn_dist, matrix_profile(
    series, W, stride=8, prune=False, chunk=256).nn_dist)
print(f"[stream] {live.starts.shape[0]} windows admitted live; "
      f"top discord at sample {live.starts[live.discords[0][0]]}")

# --- 3. the paper-facing front door ---------------------------------------
res = matsa(series, mode="self_join", window=W, stride=8,
            anomaly_threshold=float(np.percentile(
                np.asarray(matrix_profile(series, W, stride=8).nn_dist), 99)))
print(f"[matsa]  {int(np.asarray(res.anomalies).sum())} windows over the "
      f"99th-percentile threshold; profile attached: "
      f"{res.profile is not None}")
