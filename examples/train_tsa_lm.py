"""End-to-end training driver: TSA-filtered data → LM training.

The paper's Fig. 2 pipeline, productionised: a synthetic sensor stream is
filtered by sDTW (only anomalous windows survive — the interesting 10%),
quantised to tokens, and used to train a language model with the full
framework stack (AdamW, remat, checkpointing, fault-tolerant runner).

Default is a CPU-friendly model; ``--full-100m`` trains a ~100M-param
llama3.2-1b-derived config (a few hundred steps; expect hours on this
container's single CPU core — it exists to satisfy the end-to-end-driver
contract, and on a real mesh the same flags + --mesh run it distributed).

Run:  PYTHONPATH=src python examples/train_tsa_lm.py --steps 30
"""
import argparse
import dataclasses

import jax

from repro.configs import get_arch
from repro.data import DataConfig, TSAFilteredLM
from repro.ft import RunnerConfig, TrainingRunner
from repro.models import RunConfig, init_lm
from repro.optim import OptConfig
from repro.train import TrainConfig, init_train_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=30)
ap.add_argument("--seq-len", type=int, default=64)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--ckpt", default="/tmp/tsa_lm_ckpt")
ap.add_argument("--full-100m", action="store_true",
                help="~100M-param config instead of the reduced one")
args = ap.parse_args()

cfg = get_arch("llama3.2-1b")
if args.full_100m:
    cfg = dataclasses.replace(cfg, n_layers=8, d_model=768, n_heads=12,
                              n_kv_heads=4, d_ff=2048, head_dim=64,
                              vocab=8192)   # ≈100M params
else:
    cfg = dataclasses.replace(cfg.reduced(), vocab=512, d_model=128,
                              n_layers=4, d_ff=256)

run = RunConfig(remat="none" if not args.full_100m else "full")
tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=5,
                                 total_steps=args.steps))
data = TSAFilteredLM(DataConfig(seed=11, seq_len=args.seq_len,
                                global_batch=args.batch, vocab=cfg.vocab),
                     window=args.seq_len + 1)

params = init_lm(cfg, jax.random.PRNGKey(0))
n = sum(x.size for x in jax.tree.leaves(params))
print(f"model: {cfg.name}-derived, {n/1e6:.1f}M params; "
      f"TSA filter feeding tokens")

state = init_train_state(cfg, params, tcfg)
step = jax.jit(make_train_step(cfg, run, tcfg))
runner = TrainingRunner(step, data, state, args.ckpt,
                        RunnerConfig(total_steps=args.steps, ckpt_every=10))
out = runner.run()

losses = [m["loss"] for m in out["metrics"]]
print(f"TSA filter stats: kept {data.filter_stats['kept']} / "
      f"{data.filter_stats['seen']} windows")
print(f"loss: {losses[0]:.3f} → {losses[-1]:.3f} over {len(losses)} steps "
      f"({'decreasing ✓' if losses[-1] < losses[0] else 'check config'})")
