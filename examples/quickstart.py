"""Quickstart: sDTW time-series analysis with the MATSA API (paper Listing 1).

Detects anomalies in a synthetic ECG-like stream two ways:
  1. query_filtering — compare incoming windows against a clean reference.
  2. self_join      — discord discovery inside the reference itself.
Then projects the same workload onto the three MATSA hardware versions with
the paper's performance/energy model.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (MATSA_EMBEDDED, MATSA_HPC, MATSA_PORTABLE, Workload,
                        matsa, simulate, synthetic_timeseries)

rng = np.random.default_rng(7)

# --- a clean reference and a stream with injected anomalies ---------------
reference = synthetic_timeseries(rng, 4096, anomaly_rate=0.0)
stream = synthetic_timeseries(rng, 64 * 128, anomaly_rate=0.3)
windows = stream.reshape(128, 64)

# --- 1. query filtering (the paper's Fig. 2 deployment) -------------------
res = matsa(reference, windows, dist_metric="abs_diff",
            anomaly_threshold=None)
d = np.asarray(res.distances)
thr = float(np.percentile(d, 80))
res = matsa(reference, windows, dist_metric="abs_diff", anomaly_threshold=thr)
n_anom = int(np.asarray(res.anomalies).sum())
print(f"[query_filtering] {len(windows)} windows, "
      f"{n_anom} anomalies above threshold {thr:.0f}")
print(f"  distance range: {d.min():.0f} .. {d.max():.0f}")

# --- 2. self-join discord discovery ---------------------------------------
sj = matsa(reference.astype(np.float32), mode="self_join", window=128,
           stride=64)
sd = np.asarray(sj.distances)
top = np.asarray(sj.window_starts)[np.argsort(-sd)[:3]]
print(f"[self_join] top-3 discord windows start at {sorted(int(t) for t in top)}")

# --- 3. what would this cost on MATSA hardware? ----------------------------
w = Workload(ref_size=len(reference), query_size=64,
             num_queries=len(windows))
for v in (MATSA_EMBEDDED, MATSA_PORTABLE, MATSA_HPC):
    r = simulate(w, v.compute_columns)
    print(f"[{v.name:15s}] exec={r.exec_time_s*1e6:9.1f} µs   "
          f"energy={r.energy_j*1e3:8.3f} mJ   "
          f"({r.throughput_cells_per_s/1e9:.1f} GCells/s)")
