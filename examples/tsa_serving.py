"""End-to-end serving driver (the paper's kind: a TSA inference service).

Serves batched sDTW queries against a long reference — the MATSA deployment
scenario — using all three execution schemes, verifying they agree, and
reporting throughput. The sDTW "model" here plays the role a transformer
plays in the LM examples: batched requests in, per-request results out.

Run:  PYTHONPATH=src python examples/tsa_serving.py [--queries 64]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import matsa, sdtw_batch, synthetic_timeseries
from repro.kernels.sdtw import sdtw_pallas

ap = argparse.ArgumentParser()
ap.add_argument("--queries", type=int, default=32)
ap.add_argument("--query-len", type=int, default=48)
ap.add_argument("--ref-len", type=int, default=2048)
args = ap.parse_args()

rng = np.random.default_rng(0)
reference = jnp.asarray(synthetic_timeseries(rng, args.ref_len,
                                             anomaly_rate=0.05))
queries = jnp.asarray(
    synthetic_timeseries(rng, args.queries * args.query_len, anomaly_rate=0.4)
    .reshape(args.queries, args.query_len))

print(f"serving {args.queries} queries (len {args.query_len}) against "
      f"a {args.ref_len}-point reference")

results = {}
for name, fn in {
    "rowscan": lambda: sdtw_batch(queries, reference, impl="rowscan"),
    "wavefront": lambda: sdtw_batch(queries, reference, impl="wavefront"),
    "pallas": lambda: sdtw_pallas(queries, reference),
}.items():
    out = jax.block_until_ready(fn())          # compile
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn())
    dt = time.perf_counter() - t0
    results[name] = np.asarray(out)
    print(f"  [{name:9s}] {dt*1e3:8.2f} ms  "
          f"({args.queries/dt:,.0f} queries/s)")

assert np.allclose(results["rowscan"], results["wavefront"])
assert np.allclose(results["rowscan"], results["pallas"])
print("all three schemes agree ✓")

d = results["rowscan"]
thr = float(np.percentile(d, 75))
flagged = np.where(d > thr)[0]
print(f"{len(flagged)} queries flagged as anomalous (thr={thr:.0f}): "
      f"{flagged[:10].tolist()}{'…' if len(flagged) > 10 else ''}")
