"""End-to-end serving driver (the paper's kind: a TSA inference service).

Phase 1 verifies the execution schemes agree on a batch of queries
(rowscan / wavefront / pallas — the correctness gate every deployment
runs at startup). Phase 2 is a *streaming monitor*: the reference
arrives as a live feed (``engine.stream``), the query batch stands as
persistent monitors whose top-K matches and threshold alerts update as
samples arrive, the session snapshots mid-stream and restores
(fault-tolerant serving), the per-tile envelope lands in the shared
``EnvelopeCache`` for later offline requests, and the end-of-stream
state is asserted bitwise against the offline engine and search
answers. Phase 3 is anomaly localization: the most anomalous queries
get their matched *span* and full warping path via ``engine.align()`` —
where in the recording the nearest normal event lies and how the query
warps onto it — with the replayed path cost checked against the
reported distance. Phase 4 puts the whole thing behind the serving
router (``repro.serve``): concurrent tenants submit through the
admission queue, the microbatcher coalesces them into one bucketed
engine dispatch per window, and every served answer is asserted bitwise
against the tenant's own offline call.

Run:  PYTHONPATH=src python examples/tsa_serving.py [--queries 64]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import align, path_cost, sdtw_batch, stream, \
    synthetic_timeseries
from repro.core.sdtw import sdtw_chunked
from repro.kernels.sdtw import sdtw_pallas
from repro.search import EnvelopeCache, search_topk
from repro.stream import StreamSession

ap = argparse.ArgumentParser()
ap.add_argument("--queries", type=int, default=32)
ap.add_argument("--query-len", type=int, default=48)
ap.add_argument("--ref-len", type=int, default=4096)
ap.add_argument("--arrival", type=int, default=160,
                help="streaming arrival size (samples per feed)")
ap.add_argument("--top-k", type=int, default=3)
args = ap.parse_args()

rng = np.random.default_rng(0)
reference = jnp.asarray(synthetic_timeseries(rng, args.ref_len,
                                             anomaly_rate=0.05))
queries = jnp.asarray(
    synthetic_timeseries(rng, args.queries * args.query_len, anomaly_rate=0.4)
    .reshape(args.queries, args.query_len))

print(f"serving {args.queries} queries (len {args.query_len}) against "
      f"a {args.ref_len}-point reference")

# --- phase 1: the execution schemes agree (startup correctness gate) -----
results = {}
for name, fn in {
    "rowscan": lambda: sdtw_batch(queries, reference, impl="rowscan"),
    "wavefront": lambda: sdtw_batch(queries, reference, impl="wavefront"),
    "pallas": lambda: sdtw_pallas(queries, reference),
}.items():
    out = jax.block_until_ready(fn())          # compile
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn())
    dt = time.perf_counter() - t0
    results[name] = np.asarray(out)
    print(f"  [{name:9s}] {dt*1e3:8.2f} ms  "
          f"({args.queries/dt:,.0f} queries/s)")

assert np.allclose(results["rowscan"], results["wavefront"])
assert np.allclose(results["rowscan"], results["pallas"])
print("all three schemes agree ✓")

d = results["rowscan"]
thr = float(np.percentile(d, 75))
flagged = np.where(d > thr)[0]
print(f"{len(flagged)} queries flagged as anomalous (thr={thr:.0f}): "
      f"{flagged[:10].tolist()}{'…' if len(flagged) > 10 else ''}")

# --- phase 2: streaming monitor (the reference arrives as a live feed) ----
# The query batch becomes a set of standing monitors; the recording
# streams in --arrival-sized feeds. The session keeps every query's
# top-K matches current, fires threshold alerts as matching events
# arrive, survives a mid-stream snapshot/restore, and shares its
# incrementally-built envelope with the offline search path.
tile = 512
alert_thr = float(np.percentile(d, 5))
alerts = []
cache = EnvelopeCache()
print(f"\nstreaming monitor: {args.ref_len} samples arriving "
      f"{args.arrival} at a time (DP tile {tile}, alert at d<="
      f"{alert_thr:.0f})")
session = stream(queries, chunk=tile, top_k=args.top_k, return_spans=True,
                 alert_threshold=alert_thr, on_alert=alerts.append)
# A pruned sibling session builds the shared envelope cache online.
pruned = stream(queries, chunk=tile, top_k=args.top_k, return_spans=True,
                prune=True, cache=cache, ref_key="live")
feed_np = np.asarray(reference)
t0 = time.perf_counter()
for off in range(0, args.ref_len, args.arrival):
    arrival = feed_np[off:off + args.arrival]
    session.feed(arrival)
    pruned.feed(arrival)
    if off == (args.ref_len // (2 * args.arrival)) * args.arrival:
        # Fault-tolerance drill: serialize, drop, restore, keep feeding.
        session = StreamSession.restore(session.snapshot(),
                                        on_alert=alerts.append)
dt = time.perf_counter() - t0
res = session.results()
rate = args.ref_len / dt / 1e3
print(f"  streamed {args.ref_len} samples in {dt*1e3:.1f} ms "
      f"({rate:,.0f} Ksamples/s incl. snapshot/restore), "
      f"{len(alerts)} alerts")
for ev in alerts[:3]:
    print(f"    alert: query {ev.query} matched d={ev.distance:.0f} "
          f"@ ref[{ev.start}:{ev.end}]")

# End-of-stream state == the offline answers, bitwise.
kd, ks, ke = sdtw_chunked(queries, reference, chunk=tile,
                          top_k=args.top_k, return_spans=True)
assert np.array_equal(np.asarray(res.distances), np.asarray(kd)), \
    "streamed heap diverged from offline engine"
assert np.array_equal(np.asarray(res.starts), np.asarray(ks))
assert np.array_equal(np.asarray(res.positions), np.asarray(ke))
pres = pruned.results()
assert np.array_equal(np.asarray(pres.distances), np.asarray(kd)), \
    "pruned stream diverged from offline engine"
print(f"streamed top-{args.top_k} == offline engine bitwise ✓ "
      f"(pruned sibling skipped "
      f"{pres.tiles_pruned}/{pres.tiles_total} tiles, same answer)")

# The streamed envelope now serves offline requests: a pruned search
# against the materialized recording hits the cache entry the stream
# built tile by tile (exact top-1 gate runs prune=False, cache-free).
pruned.flush()
check = search_topk(queries, reference, k=1, chunk=tile, cache=cache,
                    ref_key="live")
assert cache.hits >= 1, "offline search missed the streamed envelope"
exact = search_topk(queries, reference, k=1, chunk=tile, prune=False)
assert np.array_equal(np.asarray(exact.distances)[:, 0], d), \
    "search_topk top-1 diverged from engine"
assert np.array_equal(np.asarray(check.distances)[:, 0], d), \
    "pruned search top-1 diverged from engine"
print(f"offline search after the stream: top-1 == engine ✓ "
      f"(envelope cache: {cache.hits} hit(s), built online by the stream)")

# --- phase 3: anomaly localization (spans + warping paths) ----------------
# For the most anomalous queries, report *where* the nearest normal event
# sits in the reference and how the query warps onto it — the traceback
# re-runs the DP only inside each [start, end] window (O(N·chunk) memory).
worst = np.argsort(d)[-3:][::-1]
print(f"\nlocalizing the {len(worst)} most anomalous queries")
t0 = time.perf_counter()
located = align(jnp.asarray(np.asarray(queries)[worst]), reference)
dt = time.perf_counter() - t0
for qi, ar in zip(worst, located):
    assert ar.path is not None
    replay = path_cost(np.asarray(queries)[qi], np.asarray(reference),
                       ar.path)
    # Exact compare is valid here because the stream is int32 (saturating
    # adds are order-independent); general float32 data replays to ULPs
    # only — use np.isclose there.
    assert replay == np.asarray(ar.distance), (replay, ar.distance)
    stretch = len(ar.path) / args.query_len
    print(f"  query {qi}: d={float(ar.distance):.0f}  "
          f"span ref[{ar.start}:{ar.end}] "
          f"({ar.end - ar.start + 1} samples)  "
          f"path len {len(ar.path)} ({stretch:.2f}x warp)")
print(f"alignment paths replay their distances bitwise ✓ "
      f"({dt*1e3:.1f} ms for {len(worst)} tracebacks)")

# --- phase 4: multi-tenant serving through the router ---------------------
# Four tenants (disjoint slices of the monitor batch) submit concurrently;
# the router coalesces the window into ONE ragged engine dispatch and
# each tenant's slice equals its own offline call bitwise.
from repro.serve import Router, RouterConfig  # noqa: E402

q_np = np.asarray(queries)
tenants = np.array_split(np.arange(args.queries), 4)
router = Router(RouterConfig(auto_dispatch=False))
futs = [router.submit(queries=q_np[idx], reference=reference, chunk=tile,
                      top_k=args.top_k, return_spans=True)
        for idx in tenants if len(idx)]
t0 = time.perf_counter()
router.drain()
dt = time.perf_counter() - t0
stats = router.stats()
for idx, fut in zip(tenants, futs):
    sd, ss, se = (np.asarray(x) for x in fut.result(timeout=0))
    assert np.array_equal(sd, np.asarray(kd)[idx]), \
        "served top-K diverged from offline engine"
    assert np.array_equal(ss, np.asarray(ks)[idx])
    assert np.array_equal(se, np.asarray(ke)[idx])
router.close()
print(f"\nserved {len(futs)} tenants in {stats.dispatches} coalesced "
      f"dispatch(es) ({dt*1e3:.1f} ms, occupancy "
      f"{stats.mean_batch_requests:.1f} requests/dispatch); "
      f"served == offline bitwise ✓")
