"""End-to-end serving driver (the paper's kind: a TSA inference service).

Phase 1 verifies the execution schemes agree on a batch of queries
(rowscan / wavefront / pallas — the correctness gate every deployment
runs at startup). Phase 2 is the actual serving loop: batched requests →
top-K match positions via ``repro.search.search_topk``, with the
per-reference envelope cached across requests (the reference is
long-lived; queries stream in) and the LB cascade pruning chunks that
cannot beat each request's running matches. Phase 3 is anomaly
localization: the most anomalous queries get their matched *span* and
full warping path via ``engine.align()`` — where in the recording the
nearest normal event lies and how the query warps onto it — with the
replayed path cost checked against the reported distance.

Run:  PYTHONPATH=src python examples/tsa_serving.py [--queries 64]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import align, path_cost, sdtw_batch, synthetic_timeseries
from repro.kernels.sdtw import sdtw_pallas
from repro.search import EnvelopeCache, search_topk

ap = argparse.ArgumentParser()
ap.add_argument("--queries", type=int, default=32)
ap.add_argument("--query-len", type=int, default=48)
ap.add_argument("--ref-len", type=int, default=4096)
ap.add_argument("--requests", type=int, default=4,
                help="serving-loop request batches")
ap.add_argument("--top-k", type=int, default=3)
args = ap.parse_args()

rng = np.random.default_rng(0)
reference = jnp.asarray(synthetic_timeseries(rng, args.ref_len,
                                             anomaly_rate=0.05))
queries = jnp.asarray(
    synthetic_timeseries(rng, args.queries * args.query_len, anomaly_rate=0.4)
    .reshape(args.queries, args.query_len))

print(f"serving {args.queries} queries (len {args.query_len}) against "
      f"a {args.ref_len}-point reference")

# --- phase 1: the execution schemes agree (startup correctness gate) -----
results = {}
for name, fn in {
    "rowscan": lambda: sdtw_batch(queries, reference, impl="rowscan"),
    "wavefront": lambda: sdtw_batch(queries, reference, impl="wavefront"),
    "pallas": lambda: sdtw_pallas(queries, reference),
}.items():
    out = jax.block_until_ready(fn())          # compile
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn())
    dt = time.perf_counter() - t0
    results[name] = np.asarray(out)
    print(f"  [{name:9s}] {dt*1e3:8.2f} ms  "
          f"({args.queries/dt:,.0f} queries/s)")

assert np.allclose(results["rowscan"], results["wavefront"])
assert np.allclose(results["rowscan"], results["pallas"])
print("all three schemes agree ✓")

d = results["rowscan"]
thr = float(np.percentile(d, 75))
flagged = np.where(d > thr)[0]
print(f"{len(flagged)} queries flagged as anomalous (thr={thr:.0f}): "
      f"{flagged[:10].tolist()}{'…' if len(flagged) > 10 else ''}")

# --- phase 2: request → top-K matches loop (the search front door) -------
print(f"\nserving loop: {args.requests} request batches → top-{args.top_k} "
      "matches each")
cache = EnvelopeCache()
per_batch = max(1, args.queries // args.requests)
for req in range(args.requests):
    # Each "request" carries a fresh batch of queries from the stream.
    batch = jnp.asarray(synthetic_timeseries(
        rng, per_batch * args.query_len, anomaly_rate=0.4)
        .reshape(per_batch, args.query_len))
    t0 = time.perf_counter()
    res = search_topk(batch, reference, k=args.top_k, cache=cache,
                      ref_key="stream")
    jax.block_until_ready(res.distances)
    dt = time.perf_counter() - t0
    best_d = np.asarray(res.distances)[:, 0]
    best_p = np.asarray(res.positions)[:, 0]
    best_s = np.asarray(res.starts)[:, 0]
    top = best_d.argmin()
    print(f"  req {req}: {dt*1e3:7.2f} ms  "
          f"pruned {res.chunks_pruned}/{res.chunks_total} chunks "
          f"(envelope cache {cache.hits} hits)  "
          f"best match d={best_d.min()} "
          f"@ ref[{best_s[top]}:{best_p[top]}]")

# The engine and the search front door agree on the best distance.
# (prune=False: the exact streaming path — unconditional, so the gate
# holds for any --ref-len/--query-len, not just spans within span_cap.)
check = np.asarray(search_topk(queries, reference, k=1, cache=cache,
                               ref_key="stream",
                               prune=False).distances)[:, 0]
assert np.array_equal(check, d), "search_topk top-1 diverged from engine"
print(f"search top-1 == engine distances ✓ "
      f"(envelope computed {cache.misses}×, reused {cache.hits}×)")

# --- phase 3: anomaly localization (spans + warping paths) ----------------
# For the most anomalous queries, report *where* the nearest normal event
# sits in the reference and how the query warps onto it — the traceback
# re-runs the DP only inside each [start, end] window (O(N·chunk) memory).
worst = np.argsort(d)[-3:][::-1]
print(f"\nlocalizing the {len(worst)} most anomalous queries")
t0 = time.perf_counter()
located = align(jnp.asarray(np.asarray(queries)[worst]), reference)
dt = time.perf_counter() - t0
for qi, ar in zip(worst, located):
    assert ar.path is not None
    replay = path_cost(np.asarray(queries)[qi], np.asarray(reference),
                       ar.path)
    # Exact compare is valid here because the stream is int32 (saturating
    # adds are order-independent); general float32 data replays to ULPs
    # only — use np.isclose there.
    assert replay == np.asarray(ar.distance), (replay, ar.distance)
    stretch = len(ar.path) / args.query_len
    print(f"  query {qi}: d={float(ar.distance):.0f}  "
          f"span ref[{ar.start}:{ar.end}] "
          f"({ar.end - ar.start + 1} samples)  "
          f"path len {len(ar.path)} ({stretch:.2f}x warp)")
print(f"alignment paths replay their distances bitwise ✓ "
      f"({dt*1e3:.1f} ms for {len(worst)} tracebacks)")
