"""sDTW implementation shoot-out on this host (CPU wall-times).

Compares the paper-faithful wavefront schedule against the beyond-paper
tropical row-scan, the Pallas kernel (interpret mode on CPU — its TPU
performance is projected by the roofline, not measured here), and the
unified engine's chunked-streaming path on a long reference (the regime of
the paper's Seismology/Power/ECG workloads, M ≈ 1.7–1.8M). Feeds
EXPERIMENTS.md §Perf (paper-faithful baseline vs optimized, measured).

``smoke=True`` shrinks every shape so the bench-smoke CI job exercises the
full code path in seconds.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sdtw, sdtw_batch
from repro.kernels.sdtw import sdtw_pallas, sdtw_ref_jnp

from .common import emit, print_rows, time_call


def main(smoke: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    b, n, m = (2, 16, 256) if smoke else (8, 64, 4096)
    q = jnp.asarray(rng.integers(-100, 100, (b, n)).astype(np.int32))
    r = jnp.asarray(rng.integers(-100, 100, m).astype(np.int32))

    fns = {
        "naive_scan_oracle": lambda: sdtw_ref_jnp(q, r),
        "wavefront_paper_faithful": functools.partial(
            sdtw_batch, q, r, impl="wavefront"),
        "rowscan_tropical": functools.partial(
            sdtw_batch, q, r, impl="rowscan"),
        "pallas_interpret": functools.partial(
            sdtw_pallas, q, r, block_q=8, block_m=128 if smoke else 512),
        "engine_auto": functools.partial(sdtw, q, r),
    }
    base = None
    for name, fn in fns.items():
        us = time_call(fn, repeats=3, warmup=1)
        cells = b * n * m
        rate = cells / (us * 1e-6) / 1e6
        speedup = "" if base is None else f";speedup_vs_naive={base/us:.1f}x"
        rows.append(emit(f"sdtw_kernel/{name}_b{b}_n{n}_m{m}", us,
                         f"Mcells_per_s={rate:.1f}{speedup}"))
        if base is None:
            base = us

    # Long-reference sweep: engine chunked streaming, M ≥ 256K in bounded
    # memory (only the (b, N) boundary column crosses chunk boundaries).
    bl, nl, ml = (2, 8, 4096) if smoke else (4, 32, 1 << 18)
    ql = jnp.asarray(rng.integers(-100, 100, (bl, nl)).astype(np.int32))
    rl = jnp.asarray(rng.integers(-100, 100, ml).astype(np.int32))
    chunks = (512, 1024) if smoke else (8192, 32768)
    for chunk in chunks:
        fn = functools.partial(sdtw, ql, rl, impl="chunked", chunk=chunk)
        us = time_call(fn, repeats=3, warmup=1)
        cells = bl * nl * ml
        rate = cells / (us * 1e-6) / 1e6
        rows.append(emit(
            f"sdtw_kernel/engine_chunked_b{bl}_n{nl}_m{ml}_c{chunk}", us,
            f"Mcells_per_s={rate:.1f}"))
    return rows


if __name__ == "__main__":
    print_rows(main())
