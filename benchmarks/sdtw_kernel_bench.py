"""sDTW implementation shoot-out on this host (CPU wall-times).

Compares the paper-faithful wavefront schedule against the beyond-paper
tropical row-scan, the Pallas kernel (interpret mode on CPU — its TPU
performance is projected by the roofline, not measured here), and the
unified engine's chunked-streaming path on a long reference (the regime of
the paper's Seismology/Power/ECG workloads, M ≈ 1.7–1.8M). Feeds
EXPERIMENTS.md §Perf (paper-faithful baseline vs optimized, measured).

Also measures what the span/traceback features cost: the start-pointer
lane (``return_spans=True``) against the plain distance call, and the
full ``engine.align()`` path recovery (span search + windowed replay).

``smoke=True`` shrinks every shape so the bench-smoke CI job exercises the
full code path in seconds.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import align, sdtw, sdtw_batch, stream
from repro.core.distances import accum_dtype, big, pointwise_distance, sat_add
from repro.core.sdtw import sdtw_chunked
from repro.kernels.sdtw import sdtw_pallas

from .common import emit, print_rows, time_call


@functools.partial(jax.jit, static_argnames=("metric",))
def _naive_scan_baseline(queries, reference, metric: str = "abs_diff"):
    """The simplest possible jnp formulation — sequential scan over rows
    with a sequential scan over columns. Benchmark baseline only; the test
    oracle lives in ``tests/oracle.py``."""
    acc = accum_dtype(jnp.result_type(queries, reference))
    BIG = big(acc)
    b, n = queries.shape

    def one(query):
        d_row0 = pointwise_distance(query[0], reference, metric)
        best0 = jnp.where(n == 1, jnp.min(d_row0), BIG)

        def row(carry, qi):
            prev, best, i = carry
            d = pointwise_distance(qi, reference, metric)

            def col(s_left, xs):
                dj, p_diag, p_up = xs
                s = sat_add(dj, jnp.minimum(jnp.minimum(p_diag, p_up),
                                            s_left))
                return s, s

            s0 = sat_add(prev[0], d[0])
            _, s_rest = lax.scan(col, s0, (d[1:], prev[:-1], prev[1:]))
            s = jnp.concatenate([s0[None], s_rest])
            best = jnp.where(i == n - 1, jnp.minimum(best, jnp.min(s)),
                             best)
            return (s, best, i + 1), None

        (_, best, _), _ = lax.scan(row, (d_row0, best0, jnp.int32(1)),
                                   query[1:])
        return best

    return jax.vmap(one)(queries)


def main(smoke: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    b, n, m = (2, 16, 256) if smoke else (8, 64, 4096)
    q = jnp.asarray(rng.integers(-100, 100, (b, n)).astype(np.int32))
    r = jnp.asarray(rng.integers(-100, 100, m).astype(np.int32))

    fns = {
        "naive_scan_baseline": lambda: _naive_scan_baseline(q, r),
        "wavefront_paper_faithful": functools.partial(
            sdtw_batch, q, r, impl="wavefront"),
        "rowscan_tropical": functools.partial(
            sdtw_batch, q, r, impl="rowscan"),
        "pallas_interpret": functools.partial(
            sdtw_pallas, q, r, block_q=8, block_m=128 if smoke else 512),
        "engine_auto": functools.partial(sdtw, q, r),
    }
    base = None
    engine_us = None
    for name, fn in fns.items():
        us = time_call(fn, repeats=3, warmup=1)
        cells = b * n * m
        rate = cells / (us * 1e-6) / 1e6
        speedup = "" if base is None else f";speedup_vs_naive={base/us:.1f}x"
        rows.append(emit(f"sdtw_kernel/{name}_b{b}_n{n}_m{m}", us,
                         f"Mcells_per_s={rate:.1f}{speedup}"))
        if base is None:
            base = us
        if name == "engine_auto":
            engine_us = us

    # Span / traceback overhead: the start-pointer lane doubles every DP
    # lane (value + int32 start), align() adds the windowed path replay.
    us_spans = time_call(functools.partial(sdtw, q, r, return_spans=True),
                         repeats=3, warmup=1)
    rows.append(emit(f"sdtw_kernel/engine_spans_b{b}_n{n}_m{m}", us_spans,
                     f"span_overhead_vs_plain={us_spans/engine_us:.2f}x"))
    us_align = time_call(functools.partial(align, q, r), repeats=3,
                         warmup=1)
    rows.append(emit(f"sdtw_kernel/engine_align_b{b}_n{n}_m{m}", us_align,
                     f"traceback_overhead_vs_plain={us_align/engine_us:.2f}x"))

    # Long-reference sweep: engine chunked streaming, M ≥ 256K in bounded
    # memory (only the (b, N) boundary column crosses chunk boundaries).
    bl, nl, ml = (2, 8, 4096) if smoke else (4, 32, 1 << 18)
    ql = jnp.asarray(rng.integers(-100, 100, (bl, nl)).astype(np.int32))
    rl = jnp.asarray(rng.integers(-100, 100, ml).astype(np.int32))
    chunks = (512, 1024) if smoke else (8192, 32768)
    cells_l = bl * nl * ml

    # Long-reference Pallas rows: the kernel path end to end, single launch
    # (impl='pallas') and chunk-streamed (impl='pallas' + chunk=). Off-TPU
    # these run in interpret mode — the absolute numbers are a *relative*
    # measurement (the regression gate and the README table compare them
    # against BENCH_baseline.json recorded on the same class of host).
    want_l = np.asarray(sdtw(ql, rl, impl="chunked", chunk=chunks[-1]))
    fnp = functools.partial(sdtw, ql, rl, impl="pallas")
    us = time_call(fnp, repeats=3, warmup=1)
    eq = np.array_equal(np.asarray(fnp()), want_l)
    rows.append(emit(
        f"sdtw_kernel/pallas_long_b{bl}_n{nl}_m{ml}", us,
        f"Mcells_per_s={cells_l / (us * 1e-6) / 1e6:.1f};"
        f"vs_chunked={'equal' if eq else 'DIFFERS'}"))
    pc = chunks[-1]
    fnpc = functools.partial(sdtw, ql, rl, impl="pallas", chunk=pc)
    us = time_call(fnpc, repeats=3, warmup=1)
    eq = np.array_equal(np.asarray(fnpc()), want_l)
    rows.append(emit(
        f"sdtw_kernel/pallas_chunk_b{bl}_n{nl}_m{ml}_c{pc}", us,
        f"Mcells_per_s={cells_l / (us * 1e-6) / 1e6:.1f};"
        f"vs_chunked={'equal' if eq else 'DIFFERS'}"))

    us_plain = None
    for chunk in chunks:
        fn = functools.partial(sdtw, ql, rl, impl="chunked", chunk=chunk)
        us = time_call(fn, repeats=3, warmup=1)
        cells = bl * nl * ml
        rate = cells / (us * 1e-6) / 1e6
        rows.append(emit(
            f"sdtw_kernel/engine_chunked_b{bl}_n{nl}_m{ml}_c{chunk}", us,
            f"Mcells_per_s={rate:.1f}"))
        us_plain = us
    # Streamed span lane on the same long reference (last chunk size).
    fn = functools.partial(sdtw, ql, rl, impl="chunked", chunk=chunks[-1],
                           return_spans=True)
    us = time_call(fn, repeats=3, warmup=1)
    rows.append(emit(
        f"sdtw_kernel/engine_chunked_spans_b{bl}_n{nl}_m{ml}_c{chunks[-1]}",
        us, f"span_overhead_vs_plain={us/us_plain:.2f}x"))

    # Streaming sessions on the same long reference: the online monitor
    # (feed loop + per-feed host hops) vs the offline chunked call, with a
    # bitwise streamed-vs-offline gate baked into the derived column.
    tile = chunks[-1]
    feed = tile // 2            # unaligned arrivals: exercises buffering
    rl_np = np.asarray(rl)
    cells = bl * nl * ml

    def run_stream(**kw):
        s = stream(ql, chunk=tile, **kw)
        for off in range(0, ml, feed):
            s.feed(rl_np[off:off + feed])
        return s.results()

    us = time_call(lambda: run_stream().distances, repeats=3, warmup=1)
    eq = np.array_equal(np.asarray(run_stream().distances),
                        np.asarray(sdtw(ql, rl, impl="chunked",
                                        chunk=tile)))
    rate = cells / (us * 1e-6) / 1e6
    rows.append(emit(
        f"sdtw_kernel/stream_feed_b{bl}_n{nl}_m{ml}_c{tile}", us,
        f"Mcells_per_s={rate:.1f};offline_ratio={us/us_plain:.2f}x;"
        f"streamed_vs_offline={'equal' if eq else 'DIFFERS'}"))

    us_offk = time_call(functools.partial(sdtw_chunked, ql, rl, chunk=tile,
                                          top_k=3), repeats=3, warmup=1)
    us_k = time_call(lambda: run_stream(top_k=3).distances, repeats=3,
                     warmup=1)
    sres = run_stream(top_k=3)
    kd, kp = sdtw_chunked(ql, rl, chunk=tile, top_k=3)
    eq = (np.array_equal(np.asarray(sres.distances), np.asarray(kd))
          and np.array_equal(np.asarray(sres.positions), np.asarray(kp)))
    rate = cells / (us_k * 1e-6) / 1e6
    rows.append(emit(
        f"sdtw_kernel/stream_topk_b{bl}_n{nl}_m{ml}_c{tile}", us_k,
        f"Mcells_per_s={rate:.1f};offline_ratio={us_k/us_offk:.2f}x;"
        f"streamed_vs_offline={'equal' if eq else 'DIFFERS'}"))

    # Sharded scaling: cells/s vs device count at FIXED work, each row
    # bitwise-gated against the single-device engine. One device means one
    # row — the CI bench-smoke job forces 8 fake CPU devices
    # (XLA_FLAGS=--xla_force_host_platform_device_count=8) so the sweep
    # covers 1/2/4/8-way systolic meshes plus a 2D (dp, mp) mesh.
    from repro.distributed import get_mesh
    devs = jax.devices()
    bs, ns, ms = (4, 8, 2048) if smoke else (8, 32, 1 << 16)
    qsh = jnp.asarray(rng.integers(-100, 100, (bs, ns)).astype(np.int32))
    rsh = jnp.asarray(rng.integers(-100, 100, ms).astype(np.int32))
    csh = 256 if smoke else 8192
    want_sh = np.asarray(sdtw(qsh, rsh, impl="chunked", chunk=csh))
    cells_s = bs * ns * ms
    shapes = [(c,) for c in (1, 2, 4, 8) if c <= len(devs)]
    if len(devs) >= 4:
        shapes.append((2, len(devs) // 2))   # 2D: dp rows x systolic mp
    for shape in shapes:
        nd = int(np.prod(shape))
        mesh = get_mesh(shape, devices=devs[:nd])
        fn = functools.partial(sdtw, qsh, rsh, mesh=mesh, chunk=csh)
        us = time_call(fn, repeats=3, warmup=1)
        eq = np.array_equal(np.asarray(fn()), want_sh)
        tag = "x".join(str(s) for s in shape)
        rows.append(emit(
            f"sdtw_kernel/sharded_scaling_b{bs}_n{ns}_m{ms}_mesh{tag}", us,
            f"Mcells_per_s={cells_s / (us * 1e-6) / 1e6:.1f};ndev={nd};"
            f"sharded_vs_engine={'equal' if eq else 'DIFFERS'}"))
    return rows


if __name__ == "__main__":
    print_rows(main())
