"""Tuned vs default: the autotuner's measured wins, with proof of safety.

Each pair of rows runs the SAME computation twice — once with the legacy
hand-tuned constants (``tune='off'``), once through the ``repro.tune``
oracle (``tune='model'``, the engine default) — and the tuned row's
derived field carries the two tokens CI gates on:

  * ``tuned_vs_default=equal`` — the int32 results are bitwise identical
    (tuning changes speed, never answers; ``DIFFERS`` fails the smoke
    assertion),
  * ``tuned_speedup=<ratio>`` — default µs / tuned µs (>1 means the
    oracle beat the hand constants; the smoke gate only enforces a
    generous noise floor, the real ranking validation is
    ``repro.tune.validate`` against the committed baseline).

Tuned rows also carry the dispatch ``decision`` token
(``source:impl`` from ``engine.sdtw(..., explain=True)``) so the
trajectory records *why* each configuration ran.

Pairs: engine auto-dispatch (where the model picks the wavefront past
the legacy ``M < 2N`` line), the pallas kernel's block shape, and (full
mode only) the chunked path's tile size.
"""
from __future__ import annotations

import numpy as np

from .common import emit, print_rows, time_call


def _data(nq, n, m, seed=0):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(-100, 100, (nq, n)).astype(np.int32))
    r = jnp.asarray(rng.integers(-100, 100, (m,)).astype(np.int32))
    return q, r


def _pair(rows, name, default_fn, tuned_fn, decision):
    """Time both variants, assert bitwise equality, emit the row pair."""
    a = np.asarray(default_fn())
    b = np.asarray(tuned_fn())
    equal = a.shape == b.shape and bool((a == b).all())
    us_d = time_call(default_fn)
    us_t = time_call(tuned_fn)
    rows.append(emit(f"{name}_default", us_d))
    rows.append(emit(
        f"{name}_tuned", us_t,
        f"tuned_vs_default={'equal' if equal else 'DIFFERS'};"
        f"tuned_speedup={us_d / us_t:.2f}", decision=decision))


def main(smoke: bool = False):
    from repro.core.engine import sdtw
    from repro.kernels.sdtw import sdtw_pallas

    rows = []

    # Engine auto-dispatch: legacy rules vs cost-model ranking.
    nq, n, m = (4, 32, 1024) if smoke else (8, 64, 4096)
    q, r = _data(nq, n, m)
    _, dec = sdtw(q, r, explain=True)
    _pair(rows, f"tuning_bench/dispatch_b{nq}_n{n}_m{m}",
          lambda: sdtw(q, r, tune="off"),
          lambda: sdtw(q, r),
          dec.token())

    # Pallas kernel block shape: legacy cover-the-reference tile vs the
    # oracle's (table/model) block config.
    nq, n, m = (2, 16, 2048) if smoke else (4, 32, 16384)
    q, r = _data(nq, n, m, seed=1)
    _, dec = sdtw(q, r, impl="pallas", explain=True)
    _pair(rows, f"tuning_bench/pallas_blocks_b{nq}_n{n}_m{m}",
          lambda: sdtw_pallas(q, r),
          lambda: sdtw_pallas(q, r, tune="model"),
          dec.token())

    if not smoke:
        # Chunked streaming tile size: DEFAULT_CHUNK vs the tuned chunk.
        nq, n, m = 4, 32, 1 << 18
        q, r = _data(nq, n, m, seed=2)
        _, dec = sdtw(q, r, explain=True)
        _pair(rows, f"tuning_bench/chunk_b{nq}_n{n}_m{m}",
              lambda: sdtw(q, r, tune="off"),
              lambda: sdtw(q, r),
              dec.token())

    return rows


if __name__ == "__main__":
    print_rows(main())
