"""§Roofline: render the per-(arch × shape) table from dry-run artifacts.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and prints
the single-pod roofline table: three terms, dominant bottleneck, MODEL_FLOPS
ratio, and the what-would-move-it suggestion. Markdown written to
experiments/roofline_table.md for EXPERIMENTS.md inclusion.
"""
import glob
import json
import os

from .common import emit, print_rows

SUGGEST = {
    ("compute",): "raise MXU occupancy: larger per-chip tiles, fewer pads",
    ("memory",): "cut HBM traffic: fuse/remat less, wider blocks, bf16/fp8",
    ("collective",): "reshard: fewer weight gathers, overlap a2a, pod-local",
}


def suggestion(rec):
    r = rec["roofline"]
    dom = r["dominant"]
    if dom == "memory" and r["useful_flops_ratio"] < 0.5:
        return ("memory-bound with low useful-flop ratio — remove remat/"
                "masked-half recompute first")
    if dom == "collective":
        cb = rec["collectives"]["bytes"]
        top = max(cb, key=cb.get)
        return f"collective-bound ({top}): reshard to cut {top} volume"
    return SUGGEST[(dom,)]


def main(write_md: bool = True):
    csv_rows = []
    rows = []
    for fn in sorted(glob.glob("experiments/dryrun/16x16__*.json")):
        rec = json.load(open(fn))
        if rec["status"] != "ok":
            if rec["status"] == "skipped":
                rows.append((rec["arch"], rec["shape"], None, rec["reason"]))
            continue
        rows.append((rec["arch"], rec["shape"], rec["roofline"],
                     suggestion(rec)))
        r = rec["roofline"]
        csv_rows.append(emit(
            f"roofline/{rec['arch']}/{rec['shape']}",
            r["bound_time_s"] * 1e6,
            f"dom={r['dominant']};c={r['compute_s']:.3e};"
            f"m={r['memory_s']:.3e};x={r['collective_s']:.3e};"
            f"useful={r['useful_flops_ratio']:.2f};"
            f"roofline_frac={r['roofline_fraction']:.3f}"))

    if write_md and rows:
        lines = [
            "| arch | shape | compute s | memory s | collective s | "
            "dominant | MODEL/HLO flops | roofline frac | next lever |",
            "|---|---|---|---|---|---|---|---|---|",
        ]
        for arch, shape, r, note in rows:
            if r is None:
                lines.append(f"| {arch} | {shape} | — | — | — | skipped | — "
                             f"| — | {note} |")
            else:
                lines.append(
                    f"| {arch} | {shape} | {r['compute_s']:.2e} | "
                    f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
                    f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
                    f"{r['roofline_fraction']:.3f} | {note} |")
        os.makedirs("experiments", exist_ok=True)
        with open("experiments/roofline_table.md", "w") as f:
            f.write("\n".join(lines) + "\n")
    return csv_rows


if __name__ == "__main__":
    print_rows(main())
