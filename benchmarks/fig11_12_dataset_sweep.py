"""Paper Figs. 11/12: execution time & energy vs dataset sizes
(num_queries=8K, cols=128K) + Key Obs 5 (proportional to ref×query)."""
from repro.core import Workload, simulate

from .common import emit, print_rows

COLS = 131072


def main():
    rows = []
    for ref in (65536, 131072, 262144, 524288):
        for q in (4096, 8192, 16384, 32768):
            r = simulate(Workload(ref, q, 8192), COLS)
            rows.append(emit(
                f"fig11_12/ref_{ref//1024}K_q_{q//1024}K", 0.0,
                f"time_s={r.exec_time_s:.2f};energy_j={r.energy_j:.2f}"))
    a = simulate(Workload(65536, 4096, 8192), COLS)
    b = simulate(Workload(262144, 16384, 8192), COLS)   # 16× the cells
    rows.append(emit(
        "fig11_12/key5_16x_cells", 0.0,
        f"time_ratio={b.exec_time_s/a.exec_time_s:.2f};"
        f"energy_ratio={b.energy_j/a.energy_j:.2f};expected=16"))
    return rows


if __name__ == "__main__":
    print_rows(main())
