"""Paper §IV-B endurance estimate: writes/cell over a decade of 24/7 use vs
NVM endurance limits (Table II)."""
from repro.core import endurance_writes_per_cell

from .common import emit, print_rows

ENDURANCE = {"SOT-MRAM": 1e15, "STT-MRAM": 1e15, "FRAM": 1e15,
             "PCM": 1e7, "ReRAM": 1e5, "NAND": 1e5}


def main():
    rows = []
    w10 = endurance_writes_per_cell(years=10)
    per_s = w10 / (10 * 365.25 * 24 * 3600)
    rows.append(emit(
        "endurance/writes_per_cell_10yr", 0.0,
        f"model={w10:.2e};paper~4e9 (stricter hot-slice accounting)"))
    for tech, limit in ENDURANCE.items():
        life_s = limit / per_s
        unit = (f"{life_s/3.156e7:.1f}yr" if life_s > 3.156e7
                else f"{life_s/3600:.2f}h")
        rows.append(emit(f"endurance/{tech}", 0.0, f"lifetime={unit}"))
    return rows


if __name__ == "__main__":
    print_rows(main())
