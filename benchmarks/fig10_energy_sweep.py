"""Paper Fig. 10: execution energy vs MRAM read/write energy + Key Obs 4
(read 45% / write 55% split at the 50/70 pJ operating point)."""
from repro.core import MramParams, Workload, simulate

from .common import emit, print_rows

W = Workload(ref_size=131072, query_size=8192, num_queries=8192)
COLS = 131072


def main():
    rows = []
    for rd_pj in (20, 50, 100):
        r = simulate(W, COLS, MramParams(read_pj=rd_pj))
        rows.append(emit(f"fig10/rd_{rd_pj}pJ", 0.0,
                         f"energy_j={r.energy_j:.3f}"))
    for wr_pj in (30, 70, 400):
        r = simulate(W, COLS, MramParams(write_pj=wr_pj))
        rows.append(emit(f"fig10/wr_{wr_pj}pJ", 0.0,
                         f"energy_j={r.energy_j:.3f}"))
    base = simulate(W, COLS)
    rows.append(emit("fig10/key4_read_frac", 0.0,
                     f"model={base.read_energy_frac:.3f} paper=0.45"))
    return rows


if __name__ == "__main__":
    print_rows(main())
