"""Paper Fig. 13: execution time vs MATSA size (compute columns) + Key Obs 6
(near-ideal scaling)."""
from repro.core import Workload, simulate
from repro.core.pum_model import CROSSBAR_DIM, SWEEP

from .common import emit, print_rows

W = Workload(ref_size=131072, query_size=8192, num_queries=8192)


def main():
    rows = []
    prev = None
    for xbars in SWEEP["num_crossbars"]:
        cols = xbars * CROSSBAR_DIM
        r = simulate(W, cols)
        speedup = "" if prev is None else f"step_speedup={prev/r.exec_time_s:.2f}"
        rows.append(emit(f"fig13/{xbars}xbars_{cols//1024}Kcols", 0.0,
                         f"time_s={r.exec_time_s:.2f};{speedup}"))
        prev = r.exec_time_s
    return rows


if __name__ == "__main__":
    print_rows(main())
