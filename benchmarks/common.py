"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time

import numpy as np


def time_call(fn, *args, repeats: int = 3, warmup: int = 1, **kw):
    """Median wall-time per call in µs (after jit warmup)."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        _block(r)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        _block(r)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _block(x):
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass


def emit(name: str, us_per_call: float, derived: str = ""):
    """CSV row per the harness contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.2f},{derived}")
