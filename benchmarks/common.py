"""Shared helpers for the benchmark harness.

Harness contract: every benchmark module's ``main()`` *returns* a list of
``(name, us_per_call, derived)`` rows — optionally
``(name, us_per_call, derived, decision)`` where ``decision`` is the
engine's ``DispatchDecision.token()`` (``source:impl``, e.g.
``model:wavefront``) for rows that went through ``impl='auto'`` dispatch;
``benchmarks.run`` owns all printing (and the ``--json`` trajectory
dump, where the 4th element lands as a ``decision`` key). Run
standalone, a module prints its own rows via ``print_rows``.
"""
from __future__ import annotations

import time

import numpy as np

HEADER = "name,us_per_call,derived"


def time_call(fn, *args, repeats: int = 3, warmup: int = 1, **kw):
    """Median wall-time per call in µs (after jit warmup)."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        _block(r)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        _block(r)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _block(x):
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass


def emit(name: str, us_per_call: float, derived: str = "",
         decision: str | None = None):
    """Build one CSV row per the harness contract: name,us_per_call,derived
    — plus the optional dispatch-decision token (``source:impl``)."""
    if decision is None:
        return (name, float(us_per_call), derived)
    return (name, float(us_per_call), derived, decision)


def format_row(row) -> str:
    name, us, derived = row[0], row[1], row[2]
    line = f"{name},{us:.2f},{derived}"
    if len(row) > 3:
        line += f",{row[3]}"
    return line


def print_rows(rows):
    print(HEADER)
    for row in rows:
        print(format_row(row))


def rows_to_json(rows):
    out = []
    for row in rows:
        d = {"name": row[0], "us_per_call": row[1], "derived": row[2]}
        if len(row) > 3:
            d["decision"] = row[3]
        out.append(d)
    return out
