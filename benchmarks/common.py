"""Shared helpers for the benchmark harness.

Harness contract: every benchmark module's ``main()`` *returns* a list of
``(name, us_per_call, derived)`` rows; ``benchmarks.run`` owns all
printing (and the ``--json`` trajectory dump). Run standalone, a module
prints its own rows via ``print_rows``.
"""
from __future__ import annotations

import time

import numpy as np

HEADER = "name,us_per_call,derived"


def time_call(fn, *args, repeats: int = 3, warmup: int = 1, **kw):
    """Median wall-time per call in µs (after jit warmup)."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        _block(r)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        _block(r)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _block(x):
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass


def emit(name: str, us_per_call: float, derived: str = ""):
    """Build one CSV row per the harness contract: name,us_per_call,derived."""
    return (name, float(us_per_call), derived)


def format_row(row) -> str:
    name, us, derived = row
    return f"{name},{us:.2f},{derived}"


def print_rows(rows):
    print(HEADER)
    for row in rows:
        print(format_row(row))


def rows_to_json(rows):
    return [{"name": n, "us_per_call": us, "derived": d}
            for n, us, d in rows]
