"""Paper Figs. 14/15 + Table VI: MATSA versions vs CPU/GPU/FPGA/UPMEM on the
six real-world datasets. Prints per-pair geomean speedup/energy ratios next
to the paper's claims."""
import statistics

from repro.core import (PAPER_TABLE6, PLATFORMS, VERSIONS, Workload,
                        load_real_workload_shapes, simulate)

from .common import emit, print_rows


def main():
    rows = []
    shapes = load_real_workload_shapes()
    for (ver, plat), (want_sp, want_en) in sorted(PAPER_TABLE6.items()):
        v, p = VERSIONS[ver], PLATFORMS[plat]
        sp, en = [], []
        for name, s in shapes.items():
            w = Workload(s["ref_size"], s["query_size"], s["num_queries"])
            r = simulate(w, v.compute_columns)
            sp.append(p.exec_time_s(w) / r.exec_time_s)
            en.append(p.energy_j(w) / r.energy_j)
            rows.append(emit(
                f"table6/{ver}/{plat}/{name}", r.exec_time_s * 1e6,
                f"speedup={sp[-1]:.2f};energy_x={en[-1]:.2f}"))
        gsp = statistics.geometric_mean(sp)
        gen = statistics.geometric_mean(en)
        rows.append(emit(
            f"table6/{ver}/{plat}/GEOMEAN", 0.0,
            f"speedup={gsp:.2f} (paper {want_sp});"
            f"energy_x={gen:.2f} (paper {want_en});"
            f"dev={100*(gsp/want_sp-1):+.1f}%/{100*(gen/want_en-1):+.1f}%"))
    return rows


if __name__ == "__main__":
    print_rows(main())
