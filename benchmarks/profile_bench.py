"""Matrix-profile benchmark: the self-join at paper scale, plus the
correctness gates CI runs on every push.

Timing: a pruned ``matrix_profile`` over a heterogeneous (piecewise
level-shifted) series — the regime the envelope cascade targets, and the
paper's headline workload shape (§V: seismology-length records). The
non-smoke row runs M = 2^20 samples under bounded memory: the window
batch is the only O(batch · window) allocation, so the series itself
dominates.

Gates (smoke rows, asserted by the bench-smoke CI job):

  * ``profile_vs_oracle=equal`` — the unpruned profile is int32-bitwise
    equal (distance AND span) to an inline brute-force banned-column DP,
    batch and streaming both;
  * ``stream_vs_batch=equal`` — ``StreamProfile`` fed in ragged pieces
    (with a mid-stream flush) reproduces the batch profile bitwise;
  * ``pruned=<kim+keogh>/<total>`` — the cascade actually prunes on the
    heterogeneous series.
"""
import functools

import numpy as np

from repro.search.profile import matrix_profile
from repro.stream import StreamProfile

from .common import emit, time_call


def _heterogeneous_series(rng, m: int, seg: int):
    levels = rng.integers(-1500, 1500, -(-m // seg))
    return np.concatenate([
        lvl + rng.normal(0, 40, seg) for lvl in levels])[:m].astype(np.int32)


def _oracle_nn(series, window, stride, zone):
    """Brute-force per-window nearest neighbor under banned columns:
    full float64 DP per window, leftmost-argmin end, smallest-start tie
    break — the same contract as tests/oracle.py, inlined so the bench
    is self-contained."""
    series = np.asarray(series, np.float64)
    m = len(series)
    starts = np.arange(0, m - window + 1, stride)
    out = []
    for s in starts:
        q = series[s:s + window]
        d0 = np.abs(q[0] - series)
        d0[max(s - zone, 0):s + window + zone] = np.inf
        S, T = d0, np.arange(m)
        for i in range(1, window):
            di = np.abs(q[i] - series)
            di[max(s - zone, 0):s + window + zone] = np.inf
            S2 = np.empty(m)
            T2 = np.empty(m, np.int64)
            S2[0] = S[0] + di[0]
            T2[0] = T[0]
            for j in range(1, m):
                cands = ((S[j - 1], T[j - 1]), (S2[j - 1], T2[j - 1]),
                         (S[j], T[j]))
                v = min(c[0] for c in cands)
                S2[j] = di[j] + v
                T2[j] = min(c[1] for c in cands if c[0] == v)
            S, T = S2, T2
        j = int(np.argmin(S))
        out.append((S[j], int(T[j]), j) if np.isfinite(S[j])
                   else (np.inf, -1, -1))
    return out


def _gate_oracle(rows, rng):
    """Batch AND streaming bitwise against the brute-force DP."""
    m, w, chunk = 97, 8, 16
    series = rng.integers(-30, 30, m).astype(np.int32)
    want = _oracle_nn(series, w, 1, w // 2)
    prof = matrix_profile(series, w, prune=False, chunk=chunk)
    sp = StreamProfile(w, chunk=chunk)
    cuts = [0, 13, 14, 40, 41, 90, m]
    for a, b in zip(cuts[:-1], cuts[1:]):
        sp.feed(series[a:b])
    sprof = sp.results()
    for p, label in ((prof, "batch"), (sprof, "stream")):
        for i, (d, s, e) in enumerate(want):
            if np.isfinite(d):
                got = (float(p.nn_dist[i]), int(p.nn_start[i]),
                       int(p.nn_end[i]))
                if got != (d, s, e):
                    raise AssertionError(
                        f"{label} profile diverged from oracle at window "
                        f"{i}: {got} vs {(d, s, e)}")
            elif p.valid[i]:
                raise AssertionError(
                    f"{label} window {i} should be invalid")
    rows.append(emit(f"profile/oracle_m{m}_w{w}", 0.0,
                     "profile_vs_oracle=equal"))


def _gate_stream(rows, rng):
    """Ragged feeds + a mid-stream flush reproduce the batch bitwise."""
    m, w, chunk = 211, 12, 32
    series = _heterogeneous_series(rng, m, 40)
    want = matrix_profile(series, w, prune=False, chunk=chunk, k=3)
    sp = StreamProfile(w, chunk=chunk, k=3)
    sp.feed(series[:55])
    sp.flush()
    sp.feed(series[55:60])
    sp.feed(series[60:])
    got = sp.results()
    for field in ("nn_dist", "nn_start", "nn_end", "motif_a", "motif_b",
                  "discord_idx"):
        if not np.array_equal(getattr(got, field), getattr(want, field)):
            raise AssertionError(
                f"streamed profile diverged from batch on {field}")
    rows.append(emit(f"profile/stream_m{m}_w{w}", 0.0,
                     "stream_vs_batch=equal"))


def main(smoke: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    _gate_oracle(rows, rng)
    _gate_stream(rows, rng)

    # Timed self-join: pruned profile over the heterogeneous series.
    # Non-smoke is the paper-scale point: M = 2^20 samples, bounded
    # memory (nothing O(M^2); the batch slab is 256 x 64 samples).
    # Chunk dispatch is per batch (a chunk runs if any batchmate needs
    # it), so the batch is kept small enough that its windows stay
    # localized — distant level-shifted chunks then prune for the whole
    # batch.
    m, w, stride, chunk, batch = ((4096, 32, 16, 128, 16) if smoke
                                  else (1 << 20, 64, 1 << 16, 4096, 8))
    series = _heterogeneous_series(rng, m, 8 * chunk // 4)
    call = functools.partial(matrix_profile, series, w, stride=stride,
                             chunk=chunk, k=3, batch=batch)
    prof = call()                      # warms the envelope + compile
    us = time_call(call, repeats=1, warmup=0)
    nw = prof.starts.shape[0]
    rows.append(emit(
        f"profile/selfjoin_m{m}_w{w}_s{stride}", us,
        f"nw={nw};pruned={prof.chunks_pruned}/{prof.chunks_total};"
        f"kim={prof.chunks_pruned_kim};keogh={prof.chunks_pruned_keogh};"
        f"motifs={len(prof.motifs)};discords={len(prof.discords)}"))

    # Pruned distances must still be bitwise-exact vs the unpruned
    # engine path (spans may legally differ on exact ties). Smoke
    # verifies every window; non-smoke subsamples every 4th window (a
    # 4x-stride profile lands on the same starts and the same exclusion
    # bands) so the gate costs a quarter pass, not a full one.
    sub = 1 if smoke else 4
    exact = matrix_profile(series, w, stride=stride * sub, chunk=chunk,
                           prune=False)
    if not np.array_equal(prof.nn_dist[::sub], exact.nn_dist):
        raise AssertionError("pruned profile distances diverged from "
                             "the exact engine path")
    rows.append(emit(f"profile/pruned_vs_exact_m{m}", 0.0,
                     "bitwise_equal=yes"))
    return rows
