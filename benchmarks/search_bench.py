"""Top-K pruned search benchmark: the LB cascade vs exhaustive streaming.

Reference: piecewise level-shifted noise — the heterogeneous regime the
envelope bounds are built for (quiet vs active segments; a homogeneous
periodic reference defeats interval bounds and is served by the exact
path). Queries are planted matches, so the pruned top-1 is checked
bitwise against the exhaustive engine answer inside the bench — CI fails
on divergence, not just on slowness.

Derived fields include ``pruned=<kim+keogh>/<total>`` — the bench-smoke CI
job asserts at least one row prunes at least one chunk.
"""
import functools

import jax.numpy as jnp
import numpy as np

from repro.core import sdtw
from repro.search import EnvelopeCache, search_topk

from .common import emit, print_rows, time_call


def _heterogeneous_reference(rng, m: int, seg: int):
    levels = rng.integers(-1500, 1500, -(-m // seg))
    ref = np.concatenate([
        lvl + rng.normal(0, 40, seg) for lvl in levels])[:m]
    return ref.astype(np.int32)


def main(smoke: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    m, n, nq, chunk = (2048, 32, 2, 128) if smoke else (65536, 128, 8, 2048)
    ref = _heterogeneous_reference(rng, m, 8 * chunk // 4)
    starts = rng.integers(0, m - n, nq)
    queries = np.stack([
        ref[s:s + n] + rng.integers(-2, 3, n).astype(np.int32)
        for s in starts])
    refj, qj = jnp.asarray(ref), jnp.asarray(queries)
    k = 3
    cache = EnvelopeCache()

    # Exhaustive baseline (engine streaming top-K, no bounds).
    us_full = time_call(functools.partial(
        search_topk, qj, refj, k, chunk=chunk, prune=False))
    rows.append(emit(f"search/exhaustive_nq{nq}_n{n}_m{m}_k{k}", us_full,
                     f"pruned=0/{-(-m // chunk)}"))

    # Pruned cascade (envelope cached across repeats, as in serving).
    res = search_topk(qj, refj, k, chunk=chunk, cache=cache, ref_key="bench")
    us_pruned = time_call(functools.partial(
        search_topk, qj, refj, k, chunk=chunk, cache=cache,
        ref_key="bench"))
    rows.append(emit(
        f"search/pruned_nq{nq}_n{n}_m{m}_k{k}", us_pruned,
        f"pruned={res.chunks_pruned}/{res.chunks_total};"
        f"kim={res.chunks_pruned_kim};keogh={res.chunks_pruned_keogh};"
        f"speedup_vs_exhaustive={us_full / us_pruned:.2f}x"))

    # Correctness gate: pruned top-1 must equal the engine bitwise.
    want = np.asarray(sdtw(qj, refj))
    got = np.asarray(res.distances)[:, 0]
    if not np.array_equal(got, want):
        raise AssertionError(
            f"pruned top-1 diverged from engine: {got} vs {want}")
    rows.append(emit(f"search/pruned_top1_oracle_nq{nq}", 0.0,
                     "bitwise_equal=yes"))

    # Single-query latency (the serving hot path; per-query thresholds
    # prune hardest with a batch of one).
    res1 = search_topk(qj[0], refj, k, chunk=chunk, cache=cache,
                       ref_key="bench")
    us1 = time_call(functools.partial(
        search_topk, qj[0], refj, k, chunk=chunk, cache=cache,
        ref_key="bench"))
    rows.append(emit(
        f"search/pruned_single_n{n}_m{m}_k{k}", us1,
        f"pruned={res1.chunks_pruned}/{res1.chunks_total}"))
    return rows


if __name__ == "__main__":
    print_rows(main())
