"""Paper Fig. 9: execution time vs MRAM read/write latency
(ref=128K, query=8K, n_q=8K, cols=128K)."""
from repro.core import MramParams, OpCounts, Workload, simulate

from .common import emit, print_rows

W = Workload(ref_size=131072, query_size=8192, num_queries=8192)
COLS = 131072


def main():
    rows = []
    for preset in ("first_principles", "fig9_calibrated"):
        counts = OpCounts.derive(preset=preset)
        base = simulate(W, COLS, MramParams(read_ns=1, write_ns=1),
                        counts).exec_time_s
        for rd in (1, 3, 5, 10, 20):
            t = simulate(W, COLS, MramParams(read_ns=rd, write_ns=1),
                         counts).exec_time_s
            rows.append(emit(f"fig09/{preset}/rd_{rd}ns", t * 1e6,
                             f"ratio_vs_1ns={t / base:.2f}"))
        for wr in (1, 3, 5, 10, 20):
            t = simulate(W, COLS, MramParams(read_ns=1, write_ns=wr),
                         counts).exec_time_s
            rows.append(emit(f"fig09/{preset}/wr_{wr}ns", t * 1e6,
                             f"ratio_vs_1ns={t / base:.2f}"))
    # Paper Key Obs 3 endpoints: 10× rd → 4.7×, 10× wr → 6.5×.
    c = OpCounts.derive(preset="fig9_calibrated")
    r10 = simulate(W, COLS, MramParams(10, 1), c).exec_time_s / \
        simulate(W, COLS, MramParams(1, 1), c).exec_time_s
    w10 = simulate(W, COLS, MramParams(1, 10), c).exec_time_s / \
        simulate(W, COLS, MramParams(1, 1), c).exec_time_s
    rows.append(emit("fig09/key3_rd10x", 0.0, f"model={r10:.2f} paper=4.7"))
    rows.append(emit("fig09/key3_wr10x", 0.0, f"model={w10:.2f} paper=6.5"))
    return rows


if __name__ == '__main__':
    print_rows(main())
