"""Closed-loop offered-load sweep over the serving router.

For each client count, N closed-loop threads hammer one ``Router``
(submit → wait → repeat); rows report the client-observed latency split
and goodput, plus the router correctness gate: every served answer is
compared bitwise against the client's own offline ``engine.sdtw`` call
(int32 inputs, so equality is exact) and the row carries
``served_vs_offline=equal`` only if every comparison passed — CI pins
that token.

Rows:
    serve_bench/closed_loop_c{N}   us_per_call = p50 client latency
        derived: p99_us, goodput_rps (completed requests / wall s),
                 occupancy (requests per engine dispatch),
                 served_vs_offline
"""
from __future__ import annotations

import threading
import time

import numpy as np

from .common import emit, print_rows


def _closed_loop(*, clients, requests, nq, qlen, reflen, window_ms, seed=0):
    import repro.core.engine as engine
    from repro.serve import Router, RouterConfig

    rng = np.random.default_rng(seed)
    reference = rng.integers(-40, 40, reflen).astype(np.int32)
    queries = [rng.integers(-40, 40, (nq, qlen)).astype(np.int32)
               for _ in range(clients)]
    offline = [np.asarray(engine.sdtw(q, reference)) for q in queries]

    flags = [True] * clients
    config = RouterConfig(window_ms=window_ms, max_queue=4 * clients)
    with Router(config) as router:
        def client(ci):
            for _ in range(requests):
                got = np.asarray(router.sdtw(queries[ci], reference))
                if not np.array_equal(got, offline[ci]):
                    flags[ci] = False

        threads = [threading.Thread(target=client, args=(ci,))
                   for ci in range(clients)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        stats = router.stats()
    goodput = stats.completed / wall if wall > 0 else float("nan")
    return stats, goodput, all(flags)


def main(smoke: bool = False):
    if smoke:
        sweep, requests, nq, qlen, reflen = (1, 4), 3, 2, 32, 512
    else:
        sweep, requests, nq, qlen, reflen = (1, 4, 16), 8, 4, 128, 4096

    rows = []
    for clients in sweep:
        # Warm the jit cache at the same fan-in so the measured window
        # times serving, not the coalesced bucket shape's first compile.
        _closed_loop(clients=clients, requests=1, nq=nq, qlen=qlen,
                     reflen=reflen, window_ms=2.0)
        stats, goodput, equal = _closed_loop(
            clients=clients, requests=requests, nq=nq, qlen=qlen,
            reflen=reflen, window_ms=2.0)
        rows.append(emit(
            f"serve_bench/closed_loop_c{clients}",
            stats.p50_latency_us,
            f"p99_us={stats.p99_latency_us:.0f};"
            f"goodput_rps={goodput:.1f};"
            f"occupancy={stats.mean_batch_requests:.2f};"
            f"served_vs_offline={'equal' if equal else 'DIFF'}"))
    return rows


if __name__ == "__main__":
    print_rows(main())
