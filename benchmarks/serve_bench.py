"""Closed-loop offered-load sweep over the serving router.

For each client count, N closed-loop threads hammer one ``Router``
(submit → wait → repeat) configured the way production would be: the
full device pool (``devices='all'``), a ``router.warmup`` sweep
pre-compiling every pow-2 bucket on every device before traffic lands,
the adaptive coalescing window, in-window dedup, and clients spread
over two priority classes. Rows
report the client-observed latency split and goodput, plus the router
correctness gate: every served answer is compared bitwise against the
client's own offline ``engine.sdtw`` call (int32 inputs, so equality is
exact) and the row carries ``served_vs_offline=equal`` only if every
comparison passed — CI pins that token. To exercise the dedup path,
client pairs share query content (c ≥ 2 rows report ``dedup>0`` when
twins landed in one window — opportunistic, so only the count is
reported, not gated).

Rows:
    serve_bench/closed_loop_c{N}   us_per_call = p50 client latency
        derived: p99_us, goodput_rps (completed requests / wall s),
                 occupancy (requests per engine dispatch),
                 dedup (requests answered from a twin's call),
                 served_vs_offline

The non-smoke sweep doubles as the latency-SLO regression gate: CI
replays it with ``--only serve_bench --compare BENCH_baseline.json``,
where the compare gate bounds BOTH us_per_call (p50) and the parsed
``p99_us`` against the committed baseline rows.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from .common import emit, print_rows


def _closed_loop(*, clients, requests, nq, qlen, reflen, window_ms, seed=0):
    import repro.core.engine as engine
    from repro.serve import Router, RouterConfig

    rng = np.random.default_rng(seed)
    reference = rng.integers(-40, 40, reflen).astype(np.int32)
    # Client pairs share query CONTENT (distinct arrays) so concurrent
    # twins can dedup inside a window; odd client counts keep one solo.
    base = [rng.integers(-40, 40, (nq, qlen)).astype(np.int32)
            for _ in range((clients + 1) // 2)]
    queries = [base[ci // 2].copy() for ci in range(clients)]
    offline = [np.asarray(engine.sdtw(q, reference)) for q in queries]

    flags = [True] * clients
    # Close the window once a 4-request bucket fills: high client counts
    # then produce a steady stream of same-shape groups (spread over the
    # pool's warm devices) instead of timer-cut windows of every size —
    # each novel size is a never-compiled bucket shape, i.e. a
    # multi-second XLA stall in the latency tail.
    config = RouterConfig(window_ms=window_ms, max_queue=4 * clients,
                          devices="all",
                          window_full_queries=max(8, 4 * nq))
    with Router(config) as router:
        # Production protocol: pre-compile every pow-2 bucket a window
        # can form, on every device, before traffic lands — the jit
        # cache is process-global, so across the sweep each bucket
        # compiles exactly once per device.
        bucket = 1 << max(0, nq - 1).bit_length()
        while True:
            router.warmup(queries=[np.zeros(qlen, np.int32)] * bucket,
                          reference=reference)
            if bucket >= nq * clients:
                break
            bucket *= 2
        def client(ci):
            for _ in range(requests):
                got = np.asarray(router.sdtw(
                    queries[ci], reference,
                    tenant=f"client{ci}", priority=ci % 2))
                if not np.array_equal(got, offline[ci]):
                    flags[ci] = False

        threads = [threading.Thread(target=client, args=(ci,))
                   for ci in range(clients)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        stats = router.stats()
    goodput = stats.completed / wall if wall > 0 else float("nan")
    return stats, goodput, all(flags)


def main(smoke: bool = False):
    if smoke:
        sweep, requests, nq, qlen, reflen = (1, 4), 3, 2, 32, 512
    else:
        sweep, requests, nq, qlen, reflen = (1, 4, 16), 8, 4, 128, 4096

    rows = []
    for clients in sweep:
        # Shake out the serving plumbing at the same fan-in; executable
        # compiles are handled by the in-loop ``router.warmup`` sweep
        # (every pow-2 bucket x every device, before traffic).
        _closed_loop(clients=clients, requests=1, nq=nq, qlen=qlen,
                     reflen=reflen, window_ms=2.0)
        stats, goodput, equal = _closed_loop(
            clients=clients, requests=requests, nq=nq, qlen=qlen,
            reflen=reflen, window_ms=2.0)
        rows.append(emit(
            f"serve_bench/closed_loop_c{clients}",
            stats.p50_latency_us,
            f"p99_us={stats.p99_latency_us:.0f};"
            f"goodput_rps={goodput:.1f};"
            f"occupancy={stats.mean_batch_requests:.2f};"
            f"dedup={stats.deduped};"
            f"served_vs_offline={'equal' if equal else 'DIFF'}"))
    return rows


if __name__ == "__main__":
    print_rows(main())
