"""Benchmark harness entry point: one function per paper table/figure.

Every sub-benchmark's ``main()`` returns ``(name, us_per_call, derived)``
rows; this driver prints the single CSV stream and optionally dumps the
same rows as JSON (``--json out.json``) so bench trajectories
(``BENCH_*.json``) can be recorded per commit. ``--smoke`` shrinks the
compute-heavy benches to tiny shapes for the CI bench-smoke job;
``--only`` selects a comma-separated subset by module name.
"""
import argparse
import json


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", dest="json_path", default=None,
                    help="also write rows as JSON to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for the compute-heavy benches")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names (e.g. "
                         "'search_bench,sdtw_kernel_bench')")
    args = ap.parse_args(argv)

    from . import (common, endurance, fig09_latency_sweep, fig10_energy_sweep,
                   fig11_12_dataset_sweep, fig13_scaling, roofline_table,
                   sdtw_kernel_bench, search_bench, table6_speedups)
    mods = [
        ("fig09_latency_sweep", fig09_latency_sweep.main),
        ("fig10_energy_sweep", fig10_energy_sweep.main),
        ("fig11_12_dataset_sweep", fig11_12_dataset_sweep.main),
        ("fig13_scaling", fig13_scaling.main),
        ("table6_speedups", table6_speedups.main),
        ("endurance", endurance.main),
        ("sdtw_kernel_bench",
         lambda: sdtw_kernel_bench.main(smoke=args.smoke)),
        ("search_bench", lambda: search_bench.main(smoke=args.smoke)),
        ("roofline_table", roofline_table.main),
    ]
    if args.only:
        wanted = {w.strip() for w in args.only.split(",")}
        unknown = wanted - {name for name, _ in mods}
        if unknown:
            raise SystemExit(f"unknown benchmarks: {sorted(unknown)}")
        mods = [(n, f) for n, f in mods if n in wanted]

    rows = []
    print(common.HEADER)
    for _, fn in mods:
        for row in fn():
            print(common.format_row(row))
            rows.append(row)

    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(common.rows_to_json(rows), f, indent=1)
    return rows


if __name__ == '__main__':
    main()
