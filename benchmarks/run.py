"""Benchmark harness entry point: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows."""


def main() -> None:
    from . import (fig09_latency_sweep, fig10_energy_sweep,
                   fig11_12_dataset_sweep, fig13_scaling, table6_speedups,
                   sdtw_kernel_bench, roofline_table, endurance)
    print("name,us_per_call,derived")
    fig09_latency_sweep.main()
    fig10_energy_sweep.main()
    fig11_12_dataset_sweep.main()
    fig13_scaling.main()
    table6_speedups.main()
    endurance.main()
    sdtw_kernel_bench.main()
    roofline_table.main()


if __name__ == '__main__':
    main()
