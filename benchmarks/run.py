"""Benchmark harness entry point: one function per paper table/figure.

Every sub-benchmark's ``main()`` returns ``(name, us_per_call, derived)``
rows; this driver prints the single CSV stream and optionally dumps the
same rows as JSON (``--json out.json``) so bench trajectories
(``BENCH_*.json``) can be recorded per commit. ``--smoke`` shrinks the
compute-heavy benches to tiny shapes for the CI bench-smoke job;
``--only`` selects a comma-separated subset by module name.

Regression gate: ``--compare BASELINE.json`` checks every fresh row
against the committed baseline by name and exits non-zero when a row got
more than ``--tolerance`` slower (ratio-tolerant: CI runners and dev
hosts differ in absolute speed, so the gate is meant to catch
order-of-magnitude path regressions, not µs jitter — rows faster than
``--min-us`` in the baseline are skipped as noise, and baseline rows
whose benchmark module did not run this invocation are ignored so
``--only``/``--smoke`` subsets stay comparable).
"""
import argparse
import json


def _derived_value(row, key: str):
    """Parse a ``key=<float>`` token out of a row's derived field
    (``None`` when absent or non-numeric)."""
    for token in (row.get("derived") or "").split(";"):
        if token.startswith(key + "="):
            try:
                return float(token[len(key) + 1:])
            except ValueError:
                return None
    return None


def compare_rows(rows, baseline_rows, tolerance: float, min_us: float):
    """Compare fresh rows against a recorded baseline.

    Returns ``(report_lines, regressions, missing)`` where regressions are
    rows slower than ``baseline * (1 + tolerance)`` and missing are
    baseline rows whose module ran but which the fresh run no longer
    produces (a silently dropped benchmark is a coverage regression).

    Latency-SLO gate: rows that carry a ``p99_us=`` derived token in
    BOTH the baseline and the fresh run (the ``serve_bench`` offered-load
    sweep) are additionally bounded at the tail — the fresh p99 must not
    exceed ``baseline_p99 * (1 + tolerance)`` (same noise floor), so a
    serving change that keeps the median but blows up the per-concurrency
    tail still fails the gate.
    """
    fresh = {r["name"]: r for r in rows}
    prefixes_run = {name.split("/")[0] for name in fresh}
    report, regressions, missing = [], [], []
    for brow in baseline_rows:
        name = brow["name"]
        if name.split("/")[0] not in prefixes_run:
            continue                      # that module did not run
        crow = fresh.get(name)
        if crow is None:
            missing.append(name)
            report.append(f"MISSING  {name}")
            continue
        if brow["us_per_call"] < min_us:
            report.append(f"skip     {name} (baseline {brow['us_per_call']:.0f}us "
                          f"< {min_us:.0f}us noise floor)")
            continue
        ratio = crow["us_per_call"] / brow["us_per_call"]
        ok = ratio <= 1.0 + tolerance
        tag = "ok      " if ok else "REGRESSED"
        report.append(f"{tag} {name} {brow['us_per_call']:.0f}us -> "
                      f"{crow['us_per_call']:.0f}us ({ratio:.2f}x)")
        if not ok:
            regressions.append(name)
        b99, c99 = _derived_value(brow, "p99_us"), _derived_value(crow,
                                                                  "p99_us")
        if b99 is not None and c99 is not None and b99 >= min_us:
            ratio99 = c99 / b99
            ok99 = ratio99 <= 1.0 + tolerance
            tag = "ok      " if ok99 else "REGRESSED"
            report.append(f"{tag} {name} [p99 SLO] {b99:.0f}us -> "
                          f"{c99:.0f}us ({ratio99:.2f}x)")
            if not ok99:
                regressions.append(name + ":p99")
    return report, regressions, missing


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", dest="json_path", default=None,
                    help="also write rows as JSON to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for the compute-heavy benches")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names (e.g. "
                         "'search_bench,sdtw_kernel_bench')")
    ap.add_argument("--compare", default=None, metavar="BASELINE.json",
                    help="fail if any row regressed vs this recorded "
                         "baseline (see module docstring)")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="allowed slowdown ratio above 1.0 for --compare "
                         "(0.5 = fail beyond 1.5x the baseline)")
    ap.add_argument("--min-us", type=float, default=200.0,
                    help="baseline rows faster than this are skipped by "
                         "--compare (timer noise)")
    args = ap.parse_args(argv)

    from . import (common, endurance, fig09_latency_sweep, fig10_energy_sweep,
                   fig11_12_dataset_sweep, fig13_scaling, profile_bench,
                   roofline_table, sdtw_kernel_bench, search_bench,
                   serve_bench, table6_speedups, tuning_bench)
    mods = [
        ("fig09_latency_sweep", fig09_latency_sweep.main),
        ("fig10_energy_sweep", fig10_energy_sweep.main),
        ("fig11_12_dataset_sweep", fig11_12_dataset_sweep.main),
        ("fig13_scaling", fig13_scaling.main),
        ("table6_speedups", table6_speedups.main),
        ("endurance", endurance.main),
        ("sdtw_kernel_bench",
         lambda: sdtw_kernel_bench.main(smoke=args.smoke)),
        ("search_bench", lambda: search_bench.main(smoke=args.smoke)),
        ("profile_bench", lambda: profile_bench.main(smoke=args.smoke)),
        ("serve_bench", lambda: serve_bench.main(smoke=args.smoke)),
        ("tuning_bench", lambda: tuning_bench.main(smoke=args.smoke)),
        ("roofline_table", roofline_table.main),
    ]
    if args.only:
        wanted = {w.strip() for w in args.only.split(",")}
        unknown = wanted - {name for name, _ in mods}
        if unknown:
            raise SystemExit(f"unknown benchmarks: {sorted(unknown)}")
        mods = [(n, f) for n, f in mods if n in wanted]

    rows = []
    print(common.HEADER)
    for _, fn in mods:
        for row in fn():
            print(common.format_row(row))
            rows.append(row)

    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(common.rows_to_json(rows), f, indent=1)

    if args.compare:
        with open(args.compare) as f:
            baseline = json.load(f)
        report, regressions, missing = compare_rows(
            common.rows_to_json(rows), baseline, args.tolerance, args.min_us)
        print(f"\n--compare {args.compare} (tolerance {args.tolerance:.2f}, "
              f"noise floor {args.min_us:.0f}us)")
        for line in report:
            print("  " + line)
        if regressions or missing:
            raise SystemExit(
                f"bench regression gate failed: {len(regressions)} regressed "
                f"{regressions}, {len(missing)} missing {missing}")
        print("bench regression gate passed")
    return rows


if __name__ == '__main__':
    main()
