"""Pure-jnp oracle for the sDTW Pallas kernel.

Deliberately the *simplest possible* JAX formulation: a sequential scan over
rows with a sequential scan over columns (exactly Algorithm 1 plus the
standard free-start row). No wavefront, no associative scan, no tiling —
this is the ground truth the kernel is verified against (which is itself
cross-checked against the numpy oracle in ``repro.core.sdtw_ref``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.distances import accum_dtype, big, pointwise_distance, sat_add


@functools.partial(jax.jit, static_argnames=("metric",))
def sdtw_ref_jnp(queries, reference, qlens=None, metric: str = "abs_diff"):
    """Batched sDTW oracle. queries: (B, N), reference: (M,) → (B,)."""
    acc = accum_dtype(jnp.result_type(queries, reference))
    BIG = big(acc)
    b, n = queries.shape
    if qlens is None:
        qlens = jnp.full((b,), n, jnp.int32)

    def one(query, qlen):
        d_row0 = pointwise_distance(query[0], reference, metric)
        best0 = jnp.where(qlen == 1, jnp.min(d_row0), BIG)

        def row(carry, qi):
            prev, best, i = carry
            d = pointwise_distance(qi, reference, metric)

            def col(s_left, xs):
                dj, p_diag, p_up = xs
                s = sat_add(dj, jnp.minimum(jnp.minimum(p_diag, p_up), s_left))
                return s, s

            s0 = sat_add(prev[0], d[0])
            p_diag = prev[:-1]
            p_up = prev[1:]
            _, s_rest = lax.scan(col, s0, (d[1:], p_diag, p_up))
            s = jnp.concatenate([s0[None], s_rest])
            best = jnp.where(i == qlen - 1, jnp.minimum(best, jnp.min(s)), best)
            return (s, best, i + 1), None

        (_, best, _), _ = lax.scan(row, (d_row0, best0, jnp.int32(1)), query[1:])
        return best

    return jax.vmap(one)(queries, qlens)
