"""Jitted wrapper around the sDTW Pallas kernel.

Handles padding/alignment, BlockSpec plumbing, dtype promotion, and the
interpret-mode fallback (this container is CPU-only; TPU is the target —
``interpret=None`` auto-selects interpret mode off-TPU, per the validation
protocol)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.distances import accum_dtype, big
from .sdtw import _sdtw_kernel

DEFAULT_BLOCK_Q = 8     # sublane-aligned query block
DEFAULT_BLOCK_M = 512   # lane-aligned reference tile (multiple of 128)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(
    jax.jit,
    static_argnames=("metric", "block_q", "block_m", "interpret"))
def sdtw_pallas(queries, reference, qlens=None, metric: str = "abs_diff",
                block_q: int = DEFAULT_BLOCK_Q,
                block_m: int = DEFAULT_BLOCK_M,
                interpret: bool | None = None):
    """Batched sDTW on TPU via Pallas. queries (B, N), reference (M,) → (B,).

    VMEM working set per grid cell ≈ block_q·(2·block_m + 2·N) accumulator
    words — block shapes must be chosen so this fits (~16 MB VMEM on v5e);
    the defaults handle N ≤ 64K comfortably.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, n = queries.shape
    m = reference.shape[0]
    acc = accum_dtype(jnp.result_type(queries, reference))
    BIG = big(acc)

    if qlens is None:
        qlens = jnp.full((b,), n, jnp.int32)
    bp = _ceil_to(b, block_q)
    mp = _ceil_to(max(m, block_m), block_m)

    q_pad = jnp.zeros((bp, n), queries.dtype).at[:b].set(queries)
    r_pad = jnp.zeros((1, mp), reference.dtype).at[0, :m].set(reference)
    qlen_pad = jnp.ones((bp, 1), jnp.int32).at[:b, 0].set(qlens)
    rlen = jnp.full((1, 1), m, jnp.int32)

    grid = (bp // block_q, mp // block_m)
    kernel = functools.partial(_sdtw_kernel, metric, n, block_m)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, n), lambda qb, t: (qb, 0)),
            pl.BlockSpec((1, block_m), lambda qb, t: (0, t)),
            pl.BlockSpec((block_q, 1), lambda qb, t: (qb, 0)),
            pl.BlockSpec((1, 1), lambda qb, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, 1), lambda qb, t: (qb, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, 1), acc),
        scratch_shapes=[pltpu.VMEM((block_q, n), acc)],
        interpret=interpret,
    )(q_pad, r_pad, qlen_pad, rlen)
    return out[:b, 0]
