"""Jitted wrapper around the sDTW Pallas kernel.

Handles padding/alignment, BlockSpec plumbing, dtype promotion, the
interpret-mode fallback (this container is CPU-only; TPU is the target —
``interpret=None`` auto-selects interpret mode off-TPU, per the validation
protocol), and the chunk-carry protocol: a call may start from a
(boundary-column, best) carry produced by a previous call over an earlier
reference slice and return the carry for the next slice, so an arbitrarily
long reference can be streamed through fixed-shape kernel launches — the
same O(N) boundary-column hand-off MATSA performs between subarrays
(§III-B), lifted to the call boundary."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.distances import accum_dtype, big
from .sdtw import _sdtw_kernel

DEFAULT_BLOCK_Q = 8     # sublane-aligned query block
DEFAULT_BLOCK_M = 512   # lane-aligned reference tile (multiple of 128)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(
    jax.jit,
    static_argnames=("metric", "block_q", "block_m", "interpret",
                     "return_carry", "return_positions"))
def sdtw_pallas(queries, reference, qlens=None, metric: str = "abs_diff",
                block_q: int = DEFAULT_BLOCK_Q,
                block_m: int = DEFAULT_BLOCK_M,
                interpret: bool | None = None,
                carry=None,
                return_carry: bool = False,
                ref_offset=0,
                return_positions: bool = False):
    """Batched sDTW on TPU via Pallas. queries (B, N), reference (M,) → (B,).

    VMEM working set per grid cell ≈ block_q·(2·block_m + 3·N) accumulator
    words (queries + carry-in column + boundary column) — block shapes must
    be chosen so this fits (~16 MB VMEM on v5e); the defaults handle
    N ≤ 48K comfortably.

    Chunk-carry protocol: ``carry`` is an optional
    ``(bcol (B, N), best (B,), pos (B,))`` triple — the DP boundary column
    S[:, -1] of the reference slice processed so far, the running per-query
    best, and the global end position of that best (the kernel tracks the
    match end position in the carry so streamed slices report positions
    exactly; a legacy ``(bcol, best)`` pair is accepted and seeds positions
    at -1). Passing the carry returned by a previous call
    (``return_carry=True``) continues the recurrence as if the two
    reference slices had been one array. ``ref_offset`` is the global
    column index of ``reference[0]`` (traced; no recompile per slice) so
    reported positions are global.

    With ``return_positions=True`` the primary result is a
    ``(dists (B,), end_positions (B,))`` pair instead of ``dists``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, n = queries.shape
    m = reference.shape[0]
    acc = accum_dtype(jnp.result_type(queries, reference))
    BIG = big(acc)

    if qlens is None:
        qlens = jnp.full((b,), n, jnp.int32)
    if carry is None:
        bcol = jnp.full((b, n), BIG, acc)
        best = jnp.full((b,), BIG, acc)
        pos = jnp.full((b,), -1, jnp.int32)
    else:
        if len(carry) == 2:                 # legacy (bcol, best) pair
            bcol, best = carry
            pos = jnp.full((b,), -1, jnp.int32)
        else:
            bcol, best, pos = carry
        bcol = bcol.astype(acc)
        best = best.astype(acc)
        pos = pos.astype(jnp.int32)
    bp = _ceil_to(b, block_q)
    mp = _ceil_to(max(m, block_m), block_m)

    q_pad = jnp.zeros((bp, n), queries.dtype).at[:b].set(queries)
    r_pad = jnp.zeros((1, mp), reference.dtype).at[0, :m].set(reference)
    qlen_pad = jnp.ones((bp, 1), jnp.int32).at[:b, 0].set(qlens)
    rlen = jnp.full((1, 1), m, jnp.int32)
    off = jnp.full((1, 1), ref_offset, jnp.int32)
    bcol_pad = jnp.full((bp, n), BIG, acc).at[:b].set(bcol)
    best_pad = jnp.full((bp, 1), BIG, acc).at[:b, 0].set(best)
    pos_pad = jnp.full((bp, 1), -1, jnp.int32).at[:b, 0].set(pos)

    grid = (bp // block_q, mp // block_m)
    kernel = functools.partial(_sdtw_kernel, metric, n, block_m)

    out, bound, pos_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, n), lambda qb, t: (qb, 0)),
            pl.BlockSpec((1, block_m), lambda qb, t: (0, t)),
            pl.BlockSpec((block_q, 1), lambda qb, t: (qb, 0)),
            pl.BlockSpec((1, 1), lambda qb, t: (0, 0)),
            pl.BlockSpec((1, 1), lambda qb, t: (0, 0)),
            pl.BlockSpec((block_q, n), lambda qb, t: (qb, 0)),
            pl.BlockSpec((block_q, 1), lambda qb, t: (qb, 0)),
            pl.BlockSpec((block_q, 1), lambda qb, t: (qb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, 1), lambda qb, t: (qb, 0)),
            pl.BlockSpec((block_q, n), lambda qb, t: (qb, 0)),
            pl.BlockSpec((block_q, 1), lambda qb, t: (qb, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, 1), acc),
            jax.ShapeDtypeStruct((bp, n), acc),
            jax.ShapeDtypeStruct((bp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(q_pad, r_pad, qlen_pad, rlen, off, bcol_pad, best_pad, pos_pad)
    dist = out[:b, 0]
    end_pos = pos_out[:b, 0]
    res = (dist, end_pos) if return_positions else dist
    if return_carry:
        return res, (bound[:b], dist, end_pos)
    return res
