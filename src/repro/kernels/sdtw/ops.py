"""Jitted wrapper around the sDTW Pallas kernel.

Handles padding/alignment, BlockSpec plumbing, dtype promotion, the
interpret-mode fallback (this container is CPU-only; TPU is the target —
``interpret=None`` auto-selects interpret mode off-TPU, per the validation
protocol), and the chunk-carry protocol: a call may start from a
(boundary-column, best) carry produced by a previous call over an earlier
reference slice and return the carry for the next slice, so an arbitrarily
long reference can be streamed through fixed-shape kernel launches — the
same O(N) boundary-column hand-off MATSA performs between subarrays
(§III-B), lifted to the call boundary. In span mode the carry includes the
DP start-pointer lane, so streamed slices report exact global match
spans; the plain variant keeps the untaxed value+position lanes."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.distances import INT_FAR, accum_dtype, big
from .sdtw import _sdtw_kernel

DEFAULT_BLOCK_Q = 8     # sublane-aligned query block
DEFAULT_BLOCK_M = 512   # lane-aligned reference tile (multiple of 128)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(
    jax.jit,
    static_argnames=("metric", "block_q", "block_m", "interpret",
                     "return_carry", "return_positions", "return_spans",
                     "track_start"))
def sdtw_pallas(queries, reference, qlens=None, metric: str = "abs_diff",
                block_q: int = DEFAULT_BLOCK_Q,
                block_m: int = DEFAULT_BLOCK_M,
                interpret: bool | None = None,
                carry=None,
                return_carry: bool = False,
                ref_offset=0,
                return_positions: bool = False,
                return_spans: bool = False,
                track_start: bool = False,
                ref_len=None):
    """Batched sDTW on TPU via Pallas. queries (B, N), reference (M,) → (B,).

    VMEM working set per grid cell ≈ block_q·(2·block_m + 3·N) accumulator
    words plain, ≈ block_q·(3·block_m + 5·N) in span mode (the start lanes
    are int32) — block shapes must be chosen so this fits (~16 MB VMEM on
    v5e); the defaults handle N ≤ 48K (plain) / N ≤ 24K (spans)
    comfortably.

    Chunk-carry protocol: ``carry`` is an optional
    ``(bcol (B, N), best (B,), pos (B,))`` triple — the DP boundary column
    S[:, -1] of the reference slice processed so far, the running
    per-query best, and the global end position of that best (a legacy
    ``(bcol, best)`` pair is accepted and seeds positions at -1). In span
    mode (``return_spans=True``, or ``track_start=True`` to track without
    changing the primary result, e.g. mid-stream) the carry is the
    5-tuple ``(bcol, bstart, best, pos, start)`` with the boundary
    column's start-pointer lane and the global start of the running best;
    passing a 5-tuple carry selects span mode by itself. Passing the
    carry returned by a previous call (``return_carry=True``) continues
    the recurrence as if the two reference slices had been one array.
    ``ref_offset`` is the global column index of ``reference[0]`` (traced;
    no recompile per slice) so reported positions are global. ``ref_len``
    (traced, default the full array) marks only the first ``ref_len``
    columns of ``reference`` as real: the kernel already masks columns
    ≥ rlen and exits its carry at column ``rlen - 1``, so a streaming
    caller can right-pad variable-size slices to one static shape and
    still chain the carry exactly — no recompile per fed chunk length.

    With ``return_positions=True`` the primary result is a
    ``(dists (B,), end_positions (B,))`` pair; with ``return_spans=True``
    it is a ``(dists, starts, ends)`` triple.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, n = queries.shape
    m = reference.shape[0]
    acc = accum_dtype(jnp.result_type(queries, reference))
    BIG = big(acc)

    carry = tuple(carry) if carry is not None else ()
    track = return_spans or track_start or len(carry) == 5
    bstart = pos = start = None
    if len(carry) == 5:
        bcol, bstart, best, pos, start = carry
    elif len(carry) == 3:               # (bcol, best, pos) triple
        bcol, best, pos = carry
    elif len(carry) == 2:               # legacy (bcol, best) pair
        bcol, best = carry
    elif len(carry) == 0:
        bcol = jnp.full((b, n), BIG, acc)
        best = jnp.full((b,), BIG, acc)
    else:
        raise ValueError(f"carry must have 2, 3 or 5 elements, got "
                         f"{len(carry)}")
    if pos is None:
        pos = jnp.full((b,), -1, jnp.int32)
    bcol = bcol.astype(acc)
    best = best.astype(acc)
    pos = pos.astype(jnp.int32)
    if track:
        if bstart is None:
            bstart = jnp.full((b, n), INT_FAR, jnp.int32)
        if start is None:
            start = jnp.full((b,), -1, jnp.int32)
        bstart = bstart.astype(jnp.int32)
        start = start.astype(jnp.int32)
    if qlens is None:
        qlens = jnp.full((b,), n, jnp.int32)
    bp = _ceil_to(b, block_q)
    mp = _ceil_to(max(m, block_m), block_m)

    q_pad = jnp.zeros((bp, n), queries.dtype).at[:b].set(queries)
    r_pad = jnp.zeros((1, mp), reference.dtype).at[0, :m].set(reference)
    qlen_pad = jnp.ones((bp, 1), jnp.int32).at[:b, 0].set(qlens)
    rlen = jnp.full((1, 1), m if ref_len is None else ref_len, jnp.int32)
    off = jnp.full((1, 1), ref_offset, jnp.int32)
    bcol_pad = jnp.full((bp, n), BIG, acc).at[:b].set(bcol)
    best_pad = jnp.full((bp, 1), BIG, acc).at[:b, 0].set(best)
    pos_pad = jnp.full((bp, 1), -1, jnp.int32).at[:b, 0].set(pos)

    grid = (bp // block_q, mp // block_m)
    kernel = functools.partial(_sdtw_kernel, metric, n, block_m, track)

    col_spec = pl.BlockSpec((block_q, n), lambda qb, t: (qb, 0))
    scalar_spec = pl.BlockSpec((block_q, 1), lambda qb, t: (qb, 0))
    tile_spec = pl.BlockSpec((1, block_m), lambda qb, t: (0, t))
    one_spec = pl.BlockSpec((1, 1), lambda qb, t: (0, 0))

    inputs = [q_pad, r_pad, qlen_pad, rlen, off, bcol_pad]
    in_specs = [col_spec, tile_spec, scalar_spec, one_spec, one_spec,
                col_spec]
    if track:
        bstart_pad = jnp.full((bp, n), INT_FAR,
                              jnp.int32).at[:b].set(bstart)
        inputs += [bstart_pad]
        in_specs += [col_spec]
    inputs += [best_pad, pos_pad]
    in_specs += [scalar_spec, scalar_spec]
    if track:
        start_pad = jnp.full((bp, 1), -1, jnp.int32).at[:b, 0].set(start)
        inputs += [start_pad]
        in_specs += [scalar_spec]

    out_specs = [scalar_spec, col_spec]
    out_shape = [jax.ShapeDtypeStruct((bp, 1), acc),
                 jax.ShapeDtypeStruct((bp, n), acc)]
    if track:
        out_specs += [col_spec]
        out_shape += [jax.ShapeDtypeStruct((bp, n), jnp.int32)]
    out_specs += [scalar_spec]
    out_shape += [jax.ShapeDtypeStruct((bp, 1), jnp.int32)]
    if track:
        out_specs += [scalar_spec]
        out_shape += [jax.ShapeDtypeStruct((bp, 1), jnp.int32)]

    outs = pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret,
    )(*inputs)
    if track:
        out, bound, bound_start, pos_out, start_out = outs
    else:
        out, bound, pos_out = outs
    dist = out[:b, 0]
    end_pos = pos_out[:b, 0]
    if return_spans:
        res = (dist, start_out[:b, 0], end_pos)
    elif return_positions:
        res = (dist, end_pos)
    else:
        res = dist
    if return_carry:
        if track:
            new_carry = (bound[:b], bound_start[:b], dist, end_pos,
                         start_out[:b, 0])
        else:
            new_carry = (bound[:b], dist, end_pos)
        return res, new_carry
    return res
