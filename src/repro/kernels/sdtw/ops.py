"""Jitted wrapper around the sDTW Pallas kernel.

Handles padding/alignment, BlockSpec plumbing, dtype promotion, the
interpret-mode fallback (this container is CPU-only; TPU is the target —
``interpret=None`` auto-selects interpret mode off-TPU, per the validation
protocol), and the chunk-carry protocol: a call may start from a
(boundary-column, best) carry produced by a previous call over an earlier
reference slice and return the carry for the next slice, so an arbitrarily
long reference can be streamed through fixed-shape kernel launches — the
same O(N) boundary-column hand-off MATSA performs between subarrays
(§III-B), lifted to the call boundary. In span mode the carry includes the
DP start-pointer lane, so streamed slices report exact global match
spans; the plain variant keeps the untaxed value+position lanes.

Auto-tuning (``block_q``/``block_m``/``scan_scheme``/``row_tile`` default
to ``None``): with ``tune='off'`` (the kernel-level default) the legacy
hand-tuned constants apply — on TPU the sublane-aligned (8, 512) block
with the Hillis-Steele ``"shift"`` scan and ``row_tile=8``; in interpret
mode (off-TPU) the block is fitted to the actual batch (no sublane
constraint to respect) with a tile large enough to cover the reference up
to a working-set budget, the work-efficient ``"assoc"`` scan, and no row
unrolling (XLA-CPU gains nothing from it). With ``tune='model'`` (what
``engine.sdtw`` passes by default) the unset knobs come from the
``repro.tune`` oracle instead: a tuning-table hit for this (backend,
metric, dtype, pow-2 shape bucket), else the analytical cost model's
ranked pick (``tune='measure'`` is downgraded to the table here — this
resolves at trace time, where measuring would time tracing; the engine
runs measured refinement *before* dispatch). Explicit knobs always win.
Every configuration produces bitwise-identical int32 results — schemes
and block shapes differ only in float32 summation order, so tuning can
change speed but never answers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.distances import INT_FAR, accum_dtype, big
from .sdtw import _sdtw_kernel

DEFAULT_BLOCK_Q = 8     # sublane-aligned query block (TPU)
DEFAULT_BLOCK_M = 512   # lane-aligned reference tile (multiple of 128, TPU)
DEFAULT_ROW_TILE = 8    # rows per boundary-column slice access (TPU)

#: Interpret-mode working-set budget: block_q * block_m is kept at or
#: under this many accumulator elements (~8 MB int32 per live row array).
INTERPRET_ELEM_BUDGET = 1 << 21
INTERPRET_MAX_BLOCK_Q = 32


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pow2_at_least(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


def resolve_blocks(b: int, m: int, block_q, block_m, scan_scheme, row_tile,
                   interpret: bool, *, n=None, metric: str = "abs_diff",
                   dtype: str = "int32", tune: str = "off",
                   span: bool = False):
    """Fill in the auto (None) kernel tuning knobs for this call shape.

    Returns ``(block_q, block_m, scan_scheme, row_tile)``. With
    ``tune != 'off'`` (and ``n`` known) the unset knobs come from the
    ``repro.tune`` oracle — table hit, else cost-model pick; explicit
    (non-None) knobs always win. Otherwise the legacy heuristics apply:
    interpret mode has no sublane/lane alignment to respect, so the query
    block fits the batch exactly (padding queries to a multiple of 8
    would be pure wasted compute) and the reference tile grows to cover
    the reference up to ``INTERPRET_ELEM_BUDGET`` (fewer boundary-column
    crossings, wider work-efficient scans).
    """
    if (tune != "off" and n is not None
            and (block_q is None or block_m is None
                 or scan_scheme is None or row_tile is None)):
        from repro.tune import tuned_blocks
        tq, tm, ts, tr = tuned_blocks(
            b, m, n=int(n), backend="tpu" if not interpret else "interpret",
            metric=metric, dtype=dtype, mode=tune, span=span)
        block_q = tq if block_q is None else block_q
        block_m = tm if block_m is None else block_m
        scan_scheme = ts if scan_scheme is None else scan_scheme
        row_tile = tr if row_tile is None else row_tile
    if block_q is None:
        block_q = (DEFAULT_BLOCK_Q if not interpret
                   else max(1, min(INTERPRET_MAX_BLOCK_Q, b)))
    if block_m is None:
        if not interpret:
            block_m = DEFAULT_BLOCK_M
        else:
            # Largest power of two keeping block_q * block_m at or under
            # the budget (rounding the quotient *up* would overshoot by
            # up to 1.5x for non-power-of-two batches). The floor is 16
            # (the block_m minimum), not 512: flooring the *quotient* at
            # 512 let an explicit block_q > 4096 push block_q * block_m
            # past INTERPRET_ELEM_BUDGET.
            budget = max(16, INTERPRET_ELEM_BUDGET // block_q)
            budget_pow2 = 1 << (budget.bit_length() - 1)
            block_m = min(max(16, _pow2_at_least(m)), budget_pow2)
    if scan_scheme is None:
        scan_scheme = "shift" if not interpret else "assoc"
    if row_tile is None:
        row_tile = DEFAULT_ROW_TILE if not interpret else 1
    return block_q, block_m, scan_scheme, row_tile


def pallas_carry_init(b: int, n: int, dtype, track_start: bool = False):
    """Fresh kernel chunk carry for a (b, N) query batch.

    ``(bcol (b, N), best (b,), pos (b,))`` — or the 5-tuple
    ``(bcol, bstart, best, pos, start)`` with ``track_start`` — exactly
    the structure ``sdtw_pallas(return_carry=True)`` emits, so a host loop
    can seed its first call with a real pytree (one compiled executable
    for every slice, first included) instead of ``carry=None``.
    """
    acc = accum_dtype(dtype)
    BIG = big(acc)
    bcol = jnp.full((b, n), BIG, acc)
    best = jnp.full((b,), BIG, acc)
    pos = jnp.full((b,), -1, jnp.int32)
    if not track_start:
        return bcol, best, pos
    bstart = jnp.full((b, n), INT_FAR, jnp.int32)
    start = jnp.full((b,), -1, jnp.int32)
    return bcol, bstart, best, pos, start


@functools.partial(
    jax.jit,
    static_argnames=("metric", "block_q", "block_m", "interpret",
                     "return_carry", "return_positions", "return_spans",
                     "track_start", "scan_scheme", "row_tile",
                     "return_lastrow", "tune"))
def sdtw_pallas(queries, reference, qlens=None, metric: str = "abs_diff",
                block_q: int | None = None,
                block_m: int | None = None,
                interpret: bool | None = None,
                carry=None,
                return_carry: bool = False,
                ref_offset=0,
                return_positions: bool = False,
                return_spans: bool = False,
                track_start: bool = False,
                ref_len=None,
                ref_lead=0,
                scan_scheme: str | None = None,
                row_tile: int | None = None,
                return_lastrow: bool = False,
                tune: str = "off"):
    """Batched sDTW on TPU via Pallas. queries (B, N), reference (M,) → (B,).

    VMEM working set per grid cell ≈
    ``block_q · (3·block_m + 3·N)`` accumulator words plain,
    ``block_q · (6·block_m + 5·N)`` in span mode (the start lanes are
    int32): the boundary column and (span mode) its start lane live in
    persistent VMEM *scratch* accessed one ``row_tile``-wide slice per
    loop iteration, and the row loop keeps ~3 (plain) / ~6 (span)
    block-wide row vectors live (prev / captured-last-row / scan
    temporaries, plus the start lanes). ``return_lastrow`` adds one
    ``block_q · block_m`` output block (+ its int32 start lane in span
    mode). Block shapes must be chosen so this fits (~16 MB VMEM on v5e);
    the TPU defaults handle N ≤ 48K (plain) / N ≤ 24K (spans) comfortably.
    ``repro.tune.KernelCostModel.vmem_words`` prices candidates with this
    same formula, so any config the autotuner proposes fits by construction.

    Chunk-carry protocol: ``carry`` is an optional
    ``(bcol (B, N), best (B,), pos (B,))`` triple — the DP boundary column
    S[:, -1] of the reference slice processed so far, the running
    per-query best, and the global end position of that best (a legacy
    ``(bcol, best)`` pair is accepted and seeds positions at -1;
    ``pallas_carry_init`` builds a fresh one explicitly). In span mode
    (``return_spans=True``, or ``track_start=True`` to track without
    changing the primary result, e.g. mid-stream) the carry is the
    5-tuple ``(bcol, bstart, best, pos, start)`` with the boundary
    column's start-pointer lane and the global start of the running best;
    passing a 5-tuple carry selects span mode by itself. Passing the
    carry returned by a previous call (``return_carry=True``) continues
    the recurrence as if the two reference slices had been one array.
    ``ref_offset`` is the global column index of ``reference[0]`` (traced;
    no recompile per slice) so reported positions are global. ``ref_len``
    (traced, default the full array) marks only the first ``ref_len``
    columns of ``reference`` as real: the kernel masks columns ≥ rlen and
    exits its carry at column ``rlen - 1``, so a streaming caller can
    right-pad variable-size slices to one static shape and still chain the
    carry exactly — no recompile per fed chunk length. ``ref_lead``
    (traced, default 0) additionally masks the first ``ref_lead`` columns
    — the left padding of a pruned-search halo group; it assumes a fresh
    carry (the pad columns behave like the implicit BIG columns before the
    reference starts).

    With ``return_positions=True`` the primary result is a
    ``(dists (B,), end_positions (B,))`` pair; with ``return_spans=True``
    it is a ``(dists, starts, ends)`` triple.

    ``return_lastrow=True`` appends the in-kernel last-row capture to the
    return: the (B, M) candidate row — the DP's row ``qlen - 1``, i.e. the
    cost of a match *ending* at each reference column (BIG at masked
    columns), plus its (B, M) start lane in span mode. This is the same
    row ``repro.core.sdtw.sdtw_chunk_batch_topk`` harvests, so top-K
    consumers fold it with the identical ``topk_fold_lastrow`` merge.
    Return order: ``res[, new_carry][, lastrow[, lastrow_starts]]``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, n = queries.shape
    m = reference.shape[0]
    acc = accum_dtype(jnp.result_type(queries, reference))
    BIG = big(acc)
    block_q, block_m, scan_scheme, row_tile = resolve_blocks(
        b, m, block_q, block_m, scan_scheme, row_tile, interpret,
        n=n, metric=metric,
        dtype=str(jnp.result_type(queries, reference)), tune=tune,
        span=return_spans or track_start)

    carry = tuple(carry) if carry is not None else ()
    track = return_spans or track_start or len(carry) == 5
    bstart = pos = start = None
    if len(carry) == 5:
        bcol, bstart, best, pos, start = carry
    elif len(carry) == 3:               # (bcol, best, pos) triple
        bcol, best, pos = carry
    elif len(carry) == 2:               # legacy (bcol, best) pair
        bcol, best = carry
    elif len(carry) == 0:
        bcol = jnp.full((b, n), BIG, acc)
        best = jnp.full((b,), BIG, acc)
    else:
        raise ValueError(f"carry must have 2, 3 or 5 elements, got "
                         f"{len(carry)}")
    if pos is None:
        pos = jnp.full((b,), -1, jnp.int32)
    bcol = bcol.astype(acc)
    best = best.astype(acc)
    pos = pos.astype(jnp.int32)
    if track:
        if bstart is None:
            bstart = jnp.full((b, n), INT_FAR, jnp.int32)
        if start is None:
            start = jnp.full((b,), -1, jnp.int32)
        bstart = bstart.astype(jnp.int32)
        start = start.astype(jnp.int32)
    if qlens is None:
        qlens = jnp.full((b,), n, jnp.int32)
    bp = _ceil_to(b, block_q)
    mp = _ceil_to(max(m, block_m), block_m)

    q_pad = jnp.zeros((bp, n), queries.dtype).at[:b].set(queries)
    r_pad = jnp.zeros((1, mp), reference.dtype).at[0, :m].set(reference)
    qlen_pad = jnp.ones((bp, 1), jnp.int32).at[:b, 0].set(qlens)
    rlen = jnp.full((1, 1), m if ref_len is None else ref_len, jnp.int32)
    lead = jnp.full((1, 1), ref_lead, jnp.int32)
    off = jnp.full((1, 1), ref_offset, jnp.int32)
    bcol_pad = jnp.full((bp, n), BIG, acc).at[:b].set(bcol)
    best_pad = jnp.full((bp, 1), BIG, acc).at[:b, 0].set(best)
    pos_pad = jnp.full((bp, 1), -1, jnp.int32).at[:b, 0].set(pos)

    grid = (bp // block_q, mp // block_m)
    kernel = functools.partial(_sdtw_kernel, metric, n, block_m, track,
                               return_lastrow, scan_scheme, row_tile)

    col_spec = pl.BlockSpec((block_q, n), lambda qb, t: (qb, 0))
    scalar_spec = pl.BlockSpec((block_q, 1), lambda qb, t: (qb, 0))
    tile_spec = pl.BlockSpec((1, block_m), lambda qb, t: (0, t))
    one_spec = pl.BlockSpec((1, 1), lambda qb, t: (0, 0))
    row_spec = pl.BlockSpec((block_q, block_m), lambda qb, t: (qb, t))

    inputs = [q_pad, r_pad, qlen_pad, rlen, lead, off, bcol_pad]
    in_specs = [col_spec, tile_spec, scalar_spec, one_spec, one_spec,
                one_spec, col_spec]
    if track:
        bstart_pad = jnp.full((bp, n), INT_FAR,
                              jnp.int32).at[:b].set(bstart)
        inputs += [bstart_pad]
        in_specs += [col_spec]
    inputs += [best_pad, pos_pad]
    in_specs += [scalar_spec, scalar_spec]
    if track:
        start_pad = jnp.full((bp, 1), -1, jnp.int32).at[:b, 0].set(start)
        inputs += [start_pad]
        in_specs += [scalar_spec]

    out_specs = [scalar_spec, col_spec]
    out_shape = [jax.ShapeDtypeStruct((bp, 1), acc),
                 jax.ShapeDtypeStruct((bp, n), acc)]
    if track:
        out_specs += [col_spec]
        out_shape += [jax.ShapeDtypeStruct((bp, n), jnp.int32)]
    out_specs += [scalar_spec]
    out_shape += [jax.ShapeDtypeStruct((bp, 1), jnp.int32)]
    if track:
        out_specs += [scalar_spec]
        out_shape += [jax.ShapeDtypeStruct((bp, 1), jnp.int32)]
    if return_lastrow:
        out_specs += [row_spec]
        out_shape += [jax.ShapeDtypeStruct((bp, mp), acc)]
        if track:
            out_specs += [row_spec]
            out_shape += [jax.ShapeDtypeStruct((bp, mp), jnp.int32)]

    scratch_shapes = [pltpu.VMEM((block_q, n), acc)]
    if track:
        scratch_shapes += [pltpu.VMEM((block_q, n), jnp.int32)]

    outs = pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(*inputs)
    outs = list(outs)
    out = outs.pop(0)
    bound = outs.pop(0)
    bound_start = outs.pop(0) if track else None
    pos_out = outs.pop(0)
    start_out = outs.pop(0) if track else None
    lastrow = outs.pop(0) if return_lastrow else None
    lastrow_start = outs.pop(0) if (return_lastrow and track) else None

    dist = out[:b, 0]
    end_pos = pos_out[:b, 0]
    if return_spans:
        res = (dist, start_out[:b, 0], end_pos)
    elif return_positions:
        res = (dist, end_pos)
    else:
        res = dist
    extras = []
    if return_carry:
        if track:
            extras.append((bound[:b], bound_start[:b], dist, end_pos,
                           start_out[:b, 0]))
        else:
            extras.append((bound[:b], dist, end_pos))
    if return_lastrow:
        extras.append(lastrow[:b, :m])
        if track:
            extras.append(lastrow_start[:b, :m])
    if extras:
        return (res, *extras)
    return res
