"""Pallas TPU kernel for batched sDTW — MATSA's compute subarray, TPU-native.

Mapping of MATSA's mechanisms onto the TPU (DESIGN.md §2):

  * MATSA column-parallelism  → VPU lanes: each kernel invocation processes a
    (block_q × block_m) strip with the reference dimension vectorized across
    lanes and queries across sublanes/grid.
  * O(4M) linear data mapping → only two row vectors (prev/cur) + a boundary
    column live in VMEM; the N×M matrix is never materialised and HBM traffic
    is O(N + M) per query instead of O(N·M).
  * wavefront dependency-breaking → the per-row recurrence
        s[j] = d[j] + min(min(prev[j-1], prev[j]), s[j-1])
    is solved in log2(block_m) lane-shift steps over the (min,+) semiring
    (Hillis-Steele doubling), instead of MATSA's bit-serial diagonal shifts.
  * query pipelining → the Pallas grid double-buffers the next reference tile
    from HBM while the current one computes.

Grid: (num_query_blocks, num_ref_tiles); the tile dimension is innermost and
sequential, carrying the DP boundary column in VMEM scratch — the exact
analogue of MATSA's inter-subarray pass gates (§III-B).

Match spans (``track=True``, selected statically by the wrapper when the
caller asks for spans): every DP lane becomes a lexicographic
``(value, start)`` pair — ``start`` is the row-0 reference column where the
cell's best path began, with value ties resolved toward the smaller start
(``repro.core.distances.lex_min``, the single shared rule). The start lane
rides the Hillis-Steele doubling, the boundary column, and the cross-call
chunk carry, so streamed slices report exact global ``(start, end)``
spans. The plain variant keeps PR 2's untaxed lanes (value + end position
only) — distance/position callers pay nothing for the span feature.

Accumulates in float32 or saturating int32 (see core.distances). Exclusion
zones are not supported here (ops.py falls back to the rowscan path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from repro.core.distances import INT_FAR, big, lex_min, sat_add

NEG_SHIFT_FILL_A = 0  # identity element of the tropical composition: f(x) = x


def _distance(q, r, metric):
    d = q - r
    if metric == "abs_diff":
        return jnp.abs(d)
    return d * d


def _tropical_row_scan(a, u, su, big_val):
    """Inclusive Hillis-Steele scan of f_j(x) = min(u_j, a_j + x) along
    lanes. With ``su`` (a start lane) the u-component carries it
    lexicographically; with ``su=None`` it is the plain value scan.

    Returns (a_pref, u_pref, su_pref|None) with u_pref[j] = s_j assuming
    x_init folded in by the caller via (lex)min(u_pref, a_pref + x_init).
    Identity = (a=0, u=BIG, su=INT_FAR).
    """
    bm = a.shape[-1]
    shift = 1
    while shift < bm:
        a_sh = jnp.pad(a, ((0, 0), (shift, 0)), constant_values=0)[:, :bm]
        u_sh = jnp.pad(u, ((0, 0), (shift, 0)),
                       constant_values=big_val)[:, :bm]
        if su is None:
            u = jnp.minimum(u, sat_add(a, u_sh))
        else:
            su_sh = jnp.pad(su, ((0, 0), (shift, 0)),
                            constant_values=INT_FAR)[:, :bm]
            u, su = lex_min(u, su, sat_add(a, u_sh), su_sh)
        a = sat_add(a, a_sh)
        shift *= 2
    return a, u, su


def _sdtw_kernel(metric, n, block_m, track, *refs):
    """One (query_block, ref_tile) cell of the grid.

    Refs, in order (``track=False`` omits every *start* ref — the lanes
    marked ⊕ exist only in the span variant):

    q_ref:       (block_q, N)   queries (VMEM)
    r_ref:       (1, block_m)   reference tile (VMEM)
    qlen_ref:    (block_q, 1)   true query lengths
    rlen_ref:    (1, 1)         true reference length
    off_ref:     (1, 1)         global column offset of this reference slice
                                (chunk-carry streaming) — reported match
                                positions are ``off + local column``
    bcol_in_ref: (block_q, N)   carry in: boundary column entering this call
                                (BIG for a fresh start)
    bstart_in_ref:(block_q, N) ⊕ carry in: start lane of that boundary
                                column (INT_FAR for a fresh start)
    best_in_ref: (block_q, 1)   carry in: running per-query best
    pos_in_ref:  (block_q, 1)   carry in: end position of that best (-1 for
                                a fresh start)
    start_in_ref:(block_q, 1) ⊕ carry in: start position of that best (-1)
    out_ref:     (block_q, 1)   running per-query best (min over last valid
                                row)
    bound_ref:   (block_q, N)   output: boundary column — seeded from the
                                previous *reference slice* (chunk-carry
                                protocol), threaded between tiles, and
                                returned as the carry for the next slice
    bound_start_ref:(block_q,N)⊕ output: start lane of the boundary column
    pos_ref:     (block_q, 1)   output: global end position of the best
                                match (leftmost column attaining it);
                                updated only on strict improvement so
                                earlier slices/tiles win ties, matching the
                                rowscan's leftmost ``argmin``
    start_ref:   (block_q, 1) ⊕ output: global start position of that match
                                (the smallest row-0 column among its
                                minimum-cost alignments)
    """
    if track:
        (q_ref, r_ref, qlen_ref, rlen_ref, off_ref, bcol_in_ref,
         bstart_in_ref, best_in_ref, pos_in_ref, start_in_ref, out_ref,
         bound_ref, bound_start_ref, pos_ref, start_ref) = refs
    else:
        (q_ref, r_ref, qlen_ref, rlen_ref, off_ref, bcol_in_ref,
         best_in_ref, pos_in_ref, out_ref, bound_ref, pos_ref) = refs
    t = pl.program_id(1)
    acc = out_ref.dtype
    BIG = big(acc)
    bq = q_ref.shape[0]
    INT_FAR_ = jnp.int32(INT_FAR)

    r = r_ref[...].astype(acc)                       # (1, bm)
    qlen = qlen_ref[...].astype(jnp.int32)           # (bq, 1)
    rlen = rlen_ref[0, 0]
    off = off_ref[0, 0]
    j_global = t * block_m + lax.broadcasted_iota(jnp.int32, (1, block_m), 1)
    col_ok = j_global < rlen                         # (1, bm)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = best_in_ref[...]
        bound_ref[...] = bcol_in_ref[...]
        pos_ref[...] = pos_in_ref[...]
        if track:
            bound_start_ref[...] = bstart_in_ref[...]
            start_ref[...] = start_in_ref[...]

    best0 = out_ref[...]                             # (bq, 1)
    pos0 = pos_ref[...]                              # (bq, 1)
    sstart0 = start_ref[...] if track else pos0      # (bq, 1)

    def row_body(i, carry):
        prev, pstart, b_im1, bs_im1, best, pos, sbest = carry
        qi = jax.lax.dynamic_slice_in_dim(q_ref[...], i, 1, axis=1).astype(acc)
        d = _distance(qi, r, metric)                 # (bq, bm) broadcast
        d = jnp.where(col_ok, d, BIG)

        # Boundary from the previous tile, row i (read BEFORE overwrite).
        b_row = jax.lax.dynamic_slice_in_dim(bound_ref[...], i, 1, axis=1)

        # prev shifted right by one lane; lane 0 takes the diagonal boundary.
        lane0 = lax.broadcasted_iota(jnp.int32, prev.shape, 1) == 0
        prev_sh = jnp.pad(prev, ((0, 0), (1, 0)),
                          constant_values=0)[:, :block_m]
        prev_sh = jnp.where(lane0, b_im1, prev_sh)
        if track:
            bs_row = jax.lax.dynamic_slice_in_dim(bound_start_ref[...], i,
                                                  1, axis=1)
            pstart_sh = jnp.pad(pstart, ((0, 0), (1, 0)),
                                constant_values=INT_FAR)[:, :block_m]
            pstart_sh = jnp.where(lane0, bs_im1, pstart_sh)
            # lexmin(S[i-1,j-1], S[i-1,j]) with its start lane
            m, ms = lex_min(prev_sh, pstart_sh, prev, pstart)
        else:
            bs_row = bs_im1
            m, ms = jnp.minimum(prev_sh, prev), None

        u = sat_add(d, m)
        a = d
        a_p, u_p, su_p = _tropical_row_scan(a, u, ms, BIG)
        if track:
            s_rec, ss_rec = lex_min(u_p, su_p, sat_add(a_p, b_row), bs_row)
            gstart = jnp.broadcast_to(off + j_global, (bq, block_m))
            sstart = jnp.where(i == 0, gstart, ss_rec)
        else:
            s_rec = jnp.minimum(u_p, sat_add(a_p, b_row))
            sstart = pstart                          # unused dummy
        s = jnp.where(i == 0, d, s_rec)              # free-start row
        s = jnp.where(col_ok, s, BIG)
        if track:
            sstart = jnp.where(col_ok, sstart, INT_FAR_)

        # Record min over the last valid row of each query, plus the
        # leftmost global column attaining it (strict < so earlier
        # tiles/slices keep ties) and — in span mode — that cell's start.
        row_min = jnp.min(s, axis=1, keepdims=True)
        at_last = i == qlen - 1
        is_min = s == row_min
        cand = jnp.min(jnp.where(is_min,
                                 jnp.broadcast_to(off + j_global, s.shape),
                                 INT_FAR_), axis=1, keepdims=True)
        improve = at_last & (row_min < best)
        pos = jnp.where(improve, cand.astype(jnp.int32), pos)
        if track:
            at_cand = is_min & (jnp.broadcast_to(off + j_global, s.shape)
                                == cand)
            cand_start = jnp.min(jnp.where(at_cand, sstart, INT_FAR_),
                                 axis=1, keepdims=True)
            sbest = jnp.where(improve, cand_start.astype(jnp.int32), sbest)
        best = jnp.where(at_last, jnp.minimum(best, row_min), best)

        # Persist this tile's last *valid* column as the next boundary (the
        # returned carry must be S[:, rlen-1], not a BIG padding lane, for
        # cross-call chaining to be exact); a tile past rlen keeps b_row.
        last_local = jnp.clip(rlen - 1 - t * block_m, 0, block_m - 1)
        sel = lax.broadcasted_iota(jnp.int32, s.shape, 1) == last_local
        in_tile = t * block_m < rlen
        new_b = jnp.min(jnp.where(sel, s, BIG), axis=1, keepdims=True)
        new_b = jnp.where(in_tile, new_b, b_row)
        bound_ref[...] = jax.lax.dynamic_update_slice_in_dim(
            bound_ref[...], new_b, i, axis=1)
        if track:
            new_bs = jnp.min(jnp.where(sel, sstart, INT_FAR_), axis=1,
                             keepdims=True)
            new_bs = jnp.where(in_tile, new_bs, bs_row)
            bound_start_ref[...] = jax.lax.dynamic_update_slice_in_dim(
                bound_start_ref[...], new_bs, i, axis=1)
        return s, sstart, b_row, bs_row, best, pos, sbest

    prev0 = jnp.full((bq, block_m), BIG, acc)
    pstart0 = jnp.full((bq, block_m), INT_FAR_, jnp.int32)
    b0 = jnp.full((bq, 1), BIG, acc)
    bs0 = jnp.full((bq, 1), INT_FAR_, jnp.int32)
    _, _, _, _, best, pos, sbest = lax.fori_loop(
        0, n, row_body, (prev0, pstart0, b0, bs0, best0, pos0, sstart0))
    out_ref[...] = best
    pos_ref[...] = pos
    if track:
        start_ref[...] = sbest
