"""Pallas TPU kernel for batched sDTW — MATSA's compute subarray, TPU-native.

Mapping of MATSA's mechanisms onto the TPU (DESIGN.md §2):

  * MATSA column-parallelism  → VPU lanes: each kernel invocation processes a
    (block_q × block_m) strip with the reference dimension vectorized across
    lanes and queries across sublanes/grid.
  * O(4M) linear data mapping → only two row vectors (prev/cur) + a boundary
    column live in VMEM; the N×M matrix is never materialised and HBM traffic
    is O(N + M) per query instead of O(N·M).
  * wavefront dependency-breaking → the per-row recurrence
        s[j] = d[j] + min(min(prev[j-1], prev[j]), s[j-1])
    is a first-order linear recurrence over the (min, +) semiring, solved
    by a parallel scan across the lane dimension (see *scan schemes*).
  * query pipelining → the Pallas grid double-buffers the next reference tile
    from HBM while the current one computes.

Grid: (num_query_blocks, num_ref_tiles); the tile dimension is innermost and
sequential. The DP boundary column lives in a persistent VMEM *scratch*
buffer (``scratch_shapes`` — allocated once for the whole grid, so it
carries across the sequential tile dimension exactly like MATSA's
inter-subarray pass gates, §III-B) and is read/written **one row slice at
a time** (``ref[:, pl.ds(i, …)]``): the old scheme re-read and re-wrote
the full (block_q, N) column per DP row, i.e. O(N²·block_q) VMEM traffic
per tile — the slice protocol makes it O(N·block_q). The final tile copies
the scratch into the ``bound`` output so the cross-call chunk-carry
protocol is unchanged.

Scan schemes (both exactly associative over the tropical semiring, so
int32 results are bitwise-identical between them; float32 differs only in
summation order):

  * ``"shift"`` — Hillis-Steele doubling in log2(block_m) lane-shift
    steps: the right scheme on TPU hardware, where lane shifts are cheap
    and the log factor is hidden by the VPU.
  * ``"assoc"`` — ``lax.associative_scan`` (work-efficient odd-even
    recursion, O(block_m) combines): the right scheme off-TPU / in
    interpret mode, where each shift step costs a full memory sweep and
    the work-efficient form is ~2× faster end to end.

Row tiling: ``row_tile`` consecutive DP rows are processed per loop
iteration — the boundary-column slice read/write is batched to one
(block_q, row_tile) access per iteration and the loop-control overhead of
the row loop (plus the per-row scan set-up) is amortized over the tile.
The per-row scans themselves stay sequential (row r+1 consumes row r's
output — the DP's true dependency).

In-kernel last-row capture (``want_lastrow``): the kernel additionally
emits row ``qlen - 1`` of the DP — the cost of a match *ending* at every
reference column, i.e. exactly the candidate row
``repro.core.sdtw.sdtw_chunk_batch_topk`` consumes — so top-K search
survivors and streaming monitor tiles can score on the kernel path
instead of falling back to the rowscan. The best/pos/start outputs are
harvested from this captured row once per tile (each query's ``qlen - 1``
row is unique), instead of the old per-row candidate bookkeeping.

Match spans (``track=True``): every DP lane becomes a lexicographic
``(value, start)`` pair — ``start`` is the row-0 reference column where the
cell's best path began, with value ties resolved toward the smaller start
(``repro.core.distances.lex_min``, the single shared rule). The start lane
rides the scan, the boundary column, and the cross-call chunk carry. The
plain variant keeps the untaxed value+position lanes.

Accumulates in float32 or saturating int32 (see core.distances).
Per-query exclusion zones are not supported here (ops.py falls back to the
rowscan path); the traced ``lead``/``rlen`` window masks a *leading* /
*trailing* band of columns, which is what the pruned search's halo groups
and right-padded streaming tails need.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from repro.core.distances import (INT_FAR, big, lex_min, sat_add,
                                  tropical_combine, tropical_combine_span)


def _distance(q, r, metric):
    d = q - r
    if metric == "abs_diff":
        return jnp.abs(d)
    return d * d


def _tropical_row_scan(a, u, su, big_val):
    """Inclusive Hillis-Steele scan of f_j(x) = min(u_j, a_j + x) along
    lanes. With ``su`` (a start lane) the u-component carries it
    lexicographically; with ``su=None`` it is the plain value scan.

    Returns (a_pref, u_pref, su_pref|None) with u_pref[j] = s_j assuming
    x_init folded in by the caller via (lex)min(u_pref, a_pref + x_init).
    Identity = (a=0, u=BIG, su=INT_FAR).
    """
    bm = a.shape[-1]
    shift = 1
    while shift < bm:
        a_sh = jnp.pad(a, ((0, 0), (shift, 0)), constant_values=0)[:, :bm]
        u_sh = jnp.pad(u, ((0, 0), (shift, 0)),
                       constant_values=big_val)[:, :bm]
        if su is None:
            u = jnp.minimum(u, sat_add(a, u_sh))
        else:
            su_sh = jnp.pad(su, ((0, 0), (shift, 0)),
                            constant_values=INT_FAR)[:, :bm]
            u, su = lex_min(u, su, sat_add(a, u_sh), su_sh)
        a = sat_add(a, a_sh)
        shift *= 2
    return a, u, su


def _tropical_row_scan_assoc(a, u, su, big_val):
    """Work-efficient variant of ``_tropical_row_scan`` via
    ``lax.associative_scan`` over the shared semiring combine. Same
    contract, same int32 bits (tropical min/+ is exactly associative);
    ~2× fewer memory sweeps than the shift scheme off-TPU."""
    if su is None:
        a_p, u_p = lax.associative_scan(tropical_combine, (a, u), axis=1)
        return a_p, u_p, None
    a_p, u_p, su_p = lax.associative_scan(tropical_combine_span, (a, u, su),
                                          axis=1)
    return a_p, u_p, su_p


_SCAN_SCHEMES = {"shift": _tropical_row_scan,
                 "assoc": _tropical_row_scan_assoc}


def _sdtw_kernel(metric, n, block_m, track, want_lastrow, scheme, row_tile,
                 *refs):
    """One (query_block, ref_tile) cell of the grid.

    Refs, in order (``track=False`` omits every *start* ref — the lanes
    marked ⊕ exist only in the span variant; the ``lastrow`` outputs only
    with ``want_lastrow``):

    q_ref:       (block_q, N)   queries (VMEM) — read once per tile
    r_ref:       (1, block_m)   reference tile (VMEM)
    qlen_ref:    (block_q, 1)   true query lengths
    rlen_ref:    (1, 1)         true reference length (columns >= rlen are
                                masked; the carry exits at column rlen-1)
    lead_ref:    (1, 1)         leading banned columns (columns < lead are
                                masked — the pruned search's left halo pad;
                                0 for ordinary calls)
    off_ref:     (1, 1)         global column offset of this reference slice
                                (chunk-carry streaming) — reported match
                                positions are ``off + local column``
    bcol_in_ref: (block_q, N)   carry in: boundary column entering this call
                                (BIG for a fresh start)
    bstart_in_ref:(block_q, N) ⊕ carry in: start lane of that boundary
                                column (INT_FAR for a fresh start)
    best_in_ref: (block_q, 1)   carry in: running per-query best
    pos_in_ref:  (block_q, 1)   carry in: end position of that best (-1 for
                                a fresh start)
    start_in_ref:(block_q, 1) ⊕ carry in: start position of that best (-1)
    out_ref:     (block_q, 1)   running per-query best (min over last valid
                                row)
    bound_ref:   (block_q, N)   output: boundary column for the next slice
                                (written from the VMEM scratch on the final
                                tile — the chunk-carry protocol)
    bound_start_ref:(block_q,N)⊕ output: start lane of the boundary column
    pos_ref:     (block_q, 1)   output: global end position of the best
                                match (leftmost column attaining it);
                                updated only on strict improvement so
                                earlier slices/tiles win ties, matching the
                                rowscan's leftmost ``argmin``
    start_ref:   (block_q, 1) ⊕ output: global start position of that match
    lastrow_ref: (block_q, block_m) output per tile: row ``qlen - 1`` of
                                the DP (BIG at masked columns) — the
                                candidate row for top-K folding
    lastrow_start_ref: ⊕        its start-pointer lane
    bscratch:    (block_q, N)   VMEM scratch: the live boundary column,
                                persistent across the sequential tile grid
    bsscratch:   (block_q, N) ⊕ VMEM scratch: its start lane
    """
    it = iter(refs)
    q_ref = next(it)
    r_ref = next(it)
    qlen_ref = next(it)
    rlen_ref = next(it)
    lead_ref = next(it)
    off_ref = next(it)
    bcol_in_ref = next(it)
    bstart_in_ref = next(it) if track else None
    best_in_ref = next(it)
    pos_in_ref = next(it)
    start_in_ref = next(it) if track else None
    out_ref = next(it)
    bound_ref = next(it)
    bound_start_ref = next(it) if track else None
    pos_ref = next(it)
    start_ref = next(it) if track else None
    lastrow_ref = next(it) if want_lastrow else None
    lastrow_start_ref = next(it) if (want_lastrow and track) else None
    bscratch = next(it)
    bsscratch = next(it) if track else None

    t = pl.program_id(1)
    nt = pl.num_programs(1)
    acc = out_ref.dtype
    BIG = big(acc)
    bq = q_ref.shape[0]
    INT_FAR_ = jnp.int32(INT_FAR)
    scan = _SCAN_SCHEMES[scheme]

    # Loop invariants, read/computed once per tile (the old kernel re-read
    # the full q_ref inside every DP row).
    q = q_ref[...].astype(acc)                       # (bq, N)
    r = r_ref[...].astype(acc)                       # (1, bm)
    qlen = qlen_ref[...].astype(jnp.int32)           # (bq, 1)
    rlen = rlen_ref[0, 0]
    lead = lead_ref[0, 0]
    off = off_ref[0, 0]
    j_local = t * block_m + lax.broadcasted_iota(jnp.int32, (1, block_m), 1)
    col_ok = (j_local >= lead) & (j_local < rlen)    # (1, bm)
    gcol = jnp.broadcast_to(off + j_local, (bq, block_m))
    lane0 = lax.broadcasted_iota(jnp.int32, (bq, block_m), 1) == 0
    last_local = jnp.clip(rlen - 1 - t * block_m, 0, block_m - 1)
    in_tile = t * block_m < rlen

    @pl.when(t == 0)
    def _init():
        out_ref[...] = best_in_ref[...]
        pos_ref[...] = pos_in_ref[...]
        bscratch[...] = bcol_in_ref[...]
        if track:
            start_ref[...] = start_in_ref[...]
            bsscratch[...] = bstart_in_ref[...]

    def one_row(i, prev, pstart, b_im1, bs_im1, b_row, bs_row, lrow, lstart):
        """One DP row. ``b_row``/``bs_row`` are the boundary column's row-i
        entries from the *previous* tile (read before overwrite);
        ``b_im1``/``bs_im1`` are row i-1's. Returns the new row state plus
        this row's boundary exit values."""
        qi = lax.dynamic_slice_in_dim(q, i, 1, axis=1)       # (bq, 1)
        d = _distance(qi, r, metric)                         # (bq, bm)
        d = jnp.where(col_ok, d, BIG)

        # prev shifted right by one lane; lane 0 takes the diagonal boundary.
        prev_sh = jnp.pad(prev, ((0, 0), (1, 0)),
                          constant_values=0)[:, :block_m]
        prev_sh = jnp.where(lane0, b_im1, prev_sh)
        if track:
            pstart_sh = jnp.pad(pstart, ((0, 0), (1, 0)),
                                constant_values=INT_FAR)[:, :block_m]
            pstart_sh = jnp.where(lane0, bs_im1, pstart_sh)
            mn, mns = lex_min(prev_sh, pstart_sh, prev, pstart)
        else:
            mn, mns = jnp.minimum(prev_sh, prev), None

        u = sat_add(d, mn)
        a_p, u_p, su_p = scan(d, u, mns, BIG)
        if track:
            s_rec, ss_rec = lex_min(u_p, su_p, sat_add(a_p, b_row), bs_row)
            sstart = jnp.where(i == 0, gcol, ss_rec)
        else:
            s_rec = jnp.minimum(u_p, sat_add(a_p, b_row))
            sstart = pstart                                  # unused dummy
        s = jnp.where(i == 0, d, s_rec)                      # free-start row
        s = jnp.where(col_ok, s, BIG)
        if track:
            sstart = jnp.where(col_ok, sstart, INT_FAR_)

        # Capture row qlen-1 (each query hits it exactly once per tile).
        at_last = i == qlen - 1
        lrow = jnp.where(at_last, s, lrow)
        if track:
            lstart = jnp.where(at_last, sstart, lstart)

        # This tile's last *valid* column is the next boundary (the
        # returned carry must be S[:, rlen-1], not a BIG padding lane, for
        # cross-call chaining to be exact); a tile past rlen keeps b_row.
        new_b = jnp.where(
            in_tile, lax.dynamic_slice_in_dim(s, last_local, 1, axis=1),
            b_row)
        new_bs = bs_row
        if track:
            new_bs = jnp.where(
                in_tile,
                lax.dynamic_slice_in_dim(sstart, last_local, 1, axis=1),
                bs_row)
        return s, sstart, lrow, lstart, new_b, new_bs

    def row_block(i0, prev, pstart, b_im1, bs_im1, lrow, lstart, width):
        """``width`` consecutive rows with one batched boundary-column
        slice read/write (``width`` is static — either ``row_tile`` or the
        tail remainder)."""
        bslab = bscratch[:, pl.ds(i0, width)]                # (bq, width)
        bsslab = bsscratch[:, pl.ds(i0, width)] if track else None
        new_cols, new_scols = [], []
        for rr in range(width):
            b_row = bslab[:, rr:rr + 1]
            bs_row = bsslab[:, rr:rr + 1] if track else None
            prev, pstart, lrow, lstart, nb, nbs = one_row(
                i0 + rr, prev, pstart, b_im1, bs_im1, b_row, bs_row,
                lrow, lstart)
            b_im1, bs_im1 = b_row, bs_row
            new_cols.append(nb)
            new_scols.append(nbs)
        bscratch[:, pl.ds(i0, width)] = jnp.concatenate(new_cols, axis=1)
        if track:
            bsscratch[:, pl.ds(i0, width)] = jnp.concatenate(new_scols,
                                                             axis=1)
        return prev, pstart, b_im1, bs_im1, lrow, lstart

    prev0 = jnp.full((bq, block_m), BIG, acc)
    pstart0 = jnp.full((bq, block_m), INT_FAR_, jnp.int32)
    b0 = jnp.full((bq, 1), BIG, acc)
    bs0 = jnp.full((bq, 1), INT_FAR_, jnp.int32)
    lrow0 = jnp.full((bq, block_m), BIG, acc)
    lstart0 = jnp.full((bq, block_m), INT_FAR_, jnp.int32)

    n_main, n_tail = divmod(n, row_tile)
    if track:
        def body(ib, carry):
            return row_block(ib * row_tile, *carry, row_tile)

        carry = (prev0, pstart0, b0, bs0, lrow0, lstart0)
        carry = lax.fori_loop(0, n_main, body, carry)
        if n_tail:
            carry = row_block(n_main * row_tile, *carry, n_tail)
        _, _, _, _, lrow, lstart = carry
    else:
        # Keep the loop carry lean in the plain variant (no start lanes).
        def body(ib, carry):
            prev, b_im1, lrow = carry
            prev, _, b_im1, _, lrow, _ = row_block(
                ib * row_tile, prev, pstart0, b_im1, bs0, lrow, lstart0,
                row_tile)
            return prev, b_im1, lrow

        prev, b_im1, lrow = lax.fori_loop(0, n_main, body,
                                          (prev0, b0, lrow0))
        if n_tail:
            _, _, _, _, lrow, _ = row_block(
                n_main * row_tile, prev, pstart0, b_im1, bs0, lrow, lstart0,
                n_tail)
        lstart = lstart0

    # Harvest best / end position / start from the captured last row, once
    # per tile (the old kernel paid this bookkeeping on every DP row).
    best0 = out_ref[...]
    pos0 = pos_ref[...]
    row_min = jnp.min(lrow, axis=1, keepdims=True)
    is_min = lrow == row_min
    cand = jnp.min(jnp.where(is_min, gcol, INT_FAR_), axis=1, keepdims=True)
    improve = row_min < best0      # strict: earlier tiles/slices keep ties
    out_ref[...] = jnp.minimum(best0, row_min)
    pos_ref[...] = jnp.where(improve, cand.astype(jnp.int32), pos0)
    if track:
        start0 = start_ref[...]
        at_cand = is_min & (gcol == cand)
        cand_start = jnp.min(jnp.where(at_cand, lstart, INT_FAR_), axis=1,
                             keepdims=True)
        start_ref[...] = jnp.where(improve, cand_start.astype(jnp.int32),
                                   start0)
    if want_lastrow:
        lastrow_ref[...] = lrow
        if track:
            lastrow_start_ref[...] = lstart

    @pl.when(t == nt - 1)
    def _emit_bound():
        bound_ref[...] = bscratch[...]
        if track:
            bound_start_ref[...] = bsscratch[...]
