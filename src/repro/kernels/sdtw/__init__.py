from .ops import sdtw_pallas
from .ref import sdtw_ref_jnp

__all__ = ["sdtw_pallas", "sdtw_ref_jnp"]
