from .ops import pallas_carry_init, resolve_blocks, sdtw_pallas

__all__ = ["pallas_carry_init", "resolve_blocks", "sdtw_pallas"]
