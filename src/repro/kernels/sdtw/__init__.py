from .ops import sdtw_pallas

__all__ = ["sdtw_pallas"]
