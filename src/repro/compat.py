"""Version shims for the pinned jax (0.4.37).

The codebase targets the modern jax surface (``jax.tree.flatten_with_path``,
``jax.shard_map``); the pinned 0.4.x release spells these differently.
Everything version-sensitive goes through this module so a future jax bump
is a one-file change.
"""
from __future__ import annotations

import jax

__all__ = ["tree_flatten_with_path", "shard_map"]


def tree_flatten_with_path(tree, is_leaf=None):
    """``jax.tree.flatten_with_path`` (jax >= 0.4.34ish) or the tree_util
    spelling available on every 0.4.x."""
    fn = getattr(jax.tree, "flatten_with_path", None)
    if fn is None:
        fn = jax.tree_util.tree_flatten_with_path
    return fn(tree, is_leaf=is_leaf)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` when present; otherwise the experimental spelling.

    The replication-checker kwarg was renamed ``check_rep`` → ``check_vma``
    when shard_map was promoted out of jax.experimental; we accept the new
    name and translate.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
