"""Fixed-size top-K match heaps with exclusion-zone suppression.

The search layer (``repro.search``) and the streaming sDTW paths report not
just the best alignment distance but the K best *match end positions* — the
paper's actual workload (anomaly/motif search over ECG-class streams, §I,
§V). A "heap" here is a pair of fixed-shape arrays

    (distances (k,), positions (k,))

sorted ascending by distance, padded with ``(BIG, -1)`` — fixed shapes so
the heap can ride a ``lax.scan`` carry (the chunk boundary-carry protocol)
and a ``lax.ppermute`` (the sharded systolic pipeline) unchanged.

Selection semantics — greedy best-first with an exclusion zone, the matrix-
profile convention: repeatedly take the lowest remaining distance, then
suppress every candidate whose end position is within ``excl_zone`` of it,
so the K reported matches are non-trivially distinct (no stack of matches
one sample apart). Ties break toward the lowest end position (``argmin`` is
leftmost, and streamed chunks merge in reference order). Saturated
candidates (distance ≥ BIG, e.g. the int32 ceiling) are never reported —
they come back as ``(BIG, -1)`` padding.

The streamed top-1 is exact: it is the global ``min`` with the leftmost end
index, bitwise-equal to ``engine.sdtw()``. For K > 1 the greedy suppression
is order-dependent in the usual way (a candidate suppressed by a better
neighbour cannot "come back" if that neighbour is later suppressed
itself); every reported match is still a genuine alignment distance.
"""
from __future__ import annotations

import jax.numpy as jnp

from .distances import big


def topk_init(nq: int, k: int, acc):
    """Empty batched heap: ((nq, k) BIG distances, (nq, k) -1 positions)."""
    return (jnp.full((nq, k), big(acc), acc),
            jnp.full((nq, k), -1, jnp.int32))


def topk_select(scores, positions, k: int, excl_zone: int):
    """K rounds of select-then-suppress over one candidate row.

    Args:
      scores:    (C,) candidate distances (BIG = absent/banned/saturated).
      positions: (C,) global end positions of the candidates.
      k:         static heap size.
      excl_zone: suppression radius — after a pick at position p, every
                 candidate with |position - p| <= excl_zone is removed.

    Returns (k,) distances ascending + (k,) positions, (BIG, -1)-padded.
    """
    acc = scores.dtype
    BIG = big(acc)
    out_d, out_p = [], []
    for _ in range(k):
        idx = jnp.argmin(scores)                    # leftmost on ties
        d = scores[idx]
        live = d < BIG
        p = jnp.where(live, positions[idx], -1)
        suppress = live & (jnp.abs(positions - p) <= excl_zone)
        scores = jnp.where(suppress, BIG, scores)
        out_d.append(jnp.where(live, d, BIG))
        out_p.append(p)
    return jnp.stack(out_d), jnp.stack(out_p)


def topk_merge(heap_d, heap_p, scores, positions, k: int, excl_zone: int):
    """Fold a fresh candidate row into a (k,) heap (one query).

    The heap's entries come first in the concatenation, so on exact ties
    the earlier (lower-position, earlier-chunk) match wins — this is what
    keeps the streamed top-1 bitwise-equal to the one-shot ``argmin``.
    """
    d = jnp.concatenate([heap_d, scores.astype(heap_d.dtype)])
    p = jnp.concatenate([heap_p, positions.astype(jnp.int32)])
    return topk_select(d, p, k, excl_zone)
