"""Fixed-size top-K match heaps with exclusion-zone suppression.

The search layer (``repro.search``) and the streaming sDTW paths report not
just the best alignment distance but the K best *match spans* — the
paper's actual workload (anomaly/motif search over ECG-class streams, §I,
§V) consumes the aligned event, not just a score. A "heap" here is a
triple of fixed-shape arrays

    (distances (k,), end_positions (k,), start_positions (k,))

sorted ascending by distance, padded with ``(BIG, -1, -1)`` — fixed shapes
so the heap can ride a ``lax.scan`` carry (the chunk boundary-carry
protocol) and a ``lax.ppermute`` (the sharded systolic pipeline) unchanged.
Start positions are produced by the DP's start-pointer lane (see
``repro.core.sdtw``): the row-0 reference column where the matched
alignment began.

Selection semantics — greedy best-first with an exclusion zone, the matrix-
profile convention: repeatedly take the lowest remaining distance, then
suppress every candidate "too close" to it, so the K reported matches are
non-trivially distinct (no stack of matches one sample apart). Two
suppression keys:

  * end-distance (default): candidates with ``|end - picked_end| <=
    excl_zone`` are removed — the classic matrix-profile rule.
  * span overlap (``excl_span=True``): candidates whose span
    ``[start, end]`` intersects the picked span widened by ``excl_zone``
    on both sides are removed — two reported events never share reference
    samples (``excl_zone=0`` is pure interval overlap).

Ties break toward the lowest end position (``argmin`` is leftmost, and
streamed chunks merge in reference order). Saturated candidates (distance
≥ BIG, e.g. the int32 ceiling) are never reported — they come back as
``(BIG, -1, -1)`` padding.

The streamed top-1 is exact: it is the global ``min`` with the leftmost end
index, bitwise-equal to ``engine.sdtw()``. For K > 1 the greedy suppression
is order-dependent in the usual way (a candidate suppressed by a better
neighbour cannot "come back" if that neighbour is later suppressed
itself); every reported match is still a genuine alignment distance.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .distances import big


def topk_init(nq: int, k: int, acc):
    """Empty batched heap: ((nq, k) BIG distances, (nq, k) -1 end
    positions, (nq, k) -1 start positions)."""
    return (jnp.full((nq, k), big(acc), acc),
            jnp.full((nq, k), -1, jnp.int32),
            jnp.full((nq, k), -1, jnp.int32))


def topk_select(scores, positions, starts, k: int, excl_zone,
                excl_span: bool = False):
    """K rounds of select-then-suppress over one candidate row.

    Args:
      scores:    (C,) candidate distances (BIG = absent/banned/saturated).
      positions: (C,) global end positions of the candidates.
      starts:    (C,) global start positions (the DP start-pointer lane).
      k:         static heap size.
      excl_zone: suppression radius — end-distance mode removes candidates
                 with |position - picked| <= excl_zone; span mode widens
                 the picked span by excl_zone on both sides first.
      excl_span: suppress on span overlap instead of end distance.

    Returns (k,) distances ascending + (k,) ends + (k,) starts,
    (BIG, -1, -1)-padded.
    """
    acc = scores.dtype
    BIG = big(acc)
    out_d, out_p, out_s = [], [], []
    for _ in range(k):
        idx = jnp.argmin(scores)                    # leftmost on ties
        d = scores[idx]
        live = d < BIG
        p = jnp.where(live, positions[idx], -1)
        s = jnp.where(live, starts[idx], -1)
        if excl_span:
            hit = (starts <= p + excl_zone) & (positions >= s - excl_zone)
        else:
            hit = jnp.abs(positions - p) <= excl_zone
        suppress = live & hit
        scores = jnp.where(suppress, BIG, scores)
        out_d.append(jnp.where(live, d, BIG))
        out_p.append(p)
        out_s.append(s)
    return jnp.stack(out_d), jnp.stack(out_p), jnp.stack(out_s)


def topk_merge(heap_d, heap_p, heap_s, scores, positions, starts, k: int,
               excl_zone, excl_span: bool = False):
    """Fold a fresh candidate row into a (k,) heap (one query).

    The heap's entries come first in the concatenation, so on exact ties
    the earlier (lower-position, earlier-chunk) match wins — this is what
    keeps the streamed top-1 bitwise-equal to the one-shot ``argmin``.
    """
    d = jnp.concatenate([heap_d, scores.astype(heap_d.dtype)])
    p = jnp.concatenate([heap_p, positions.astype(jnp.int32)])
    s = jnp.concatenate([heap_s, starts.astype(jnp.int32)])
    return topk_select(d, p, s, k, excl_zone, excl_span)


# ----------------------------------------------------------------------
# Matrix-profile reductions over a finished nearest-neighbor table.
#
# The per-window heaps above are device code riding carries; these two
# consume the *host-side* profile that ``repro.search.profile`` assembles
# from them — an O(nw) numpy pass, tiny next to the DP. Both are the same
# greedy select-then-suppress convention, with suppression measured in
# sample units over window start positions, so stride > 1 self-joins
# never collapse the band to window-index spacing. Invalid entries (no
# admissible neighbor: dist is BIG/inf/nan or the neighbor index is -1)
# are never selected — padding is (-1, -1, inf) for motifs and
# (-1, -inf) for discords.
# ----------------------------------------------------------------------


def mutual_nearest_pairs(nn_dist, nn_window, starts, k: int, excl_zone):
    """Greedy top-K motif pairs: mutually-nearest, exclusion-distinct.

    Args:
      nn_dist:   (nw,) each window's nearest-neighbor distance (float;
                 inf/nan = no admissible neighbor).
      nn_window: (nw,) index of each window's nearest neighbor (-1 = none).
      starts:    (nw,) window start positions in samples.
      k:         pairs to report.
      excl_zone: suppression radius in samples — once a pair is picked, any
                 candidate pair with a member window starting within
                 ``excl_zone`` samples of either picked member is dropped.

    A pair (i, j) is a candidate iff ``nn_window[i] == j`` and
    ``nn_window[j] == i`` (each is the other's nearest neighbor). sDTW
    self-join distances are direction-dependent — window i aligned over
    the series near j need not cost the same as window j aligned near i —
    so the pair is ranked by ``min(nn_dist[i], nn_dist[j])``, the cheaper
    direction. Ties break toward the smaller (i, j).

    Returns ``(a_idx, b_idx, dist)`` int64/int64/float64 arrays of shape
    (k,), ``a_idx < b_idx``, padded with ``(-1, -1, inf)``.
    """
    nn_dist = np.asarray(nn_dist, np.float64)
    nn_window = np.asarray(nn_window, np.int64)
    starts = np.asarray(starts, np.int64)
    nw = nn_dist.shape[0]
    ok = (nn_window >= 0) & np.isfinite(nn_dist)
    i_all = np.arange(nw)
    mutual = ok & (nn_window < nw) & (i_all < nn_window)
    mutual &= np.where(mutual, nn_window[np.clip(nn_window, 0, nw - 1)]
                       == i_all, False)
    a = i_all[mutual]
    b = nn_window[mutual]
    d = np.minimum(nn_dist[a], nn_dist[b])
    order = np.lexsort((b, a, d))        # distance, then smaller (i, j)
    a, b, d = a[order], b[order], d[order]

    out_a = np.full((k,), -1, np.int64)
    out_b = np.full((k,), -1, np.int64)
    out_d = np.full((k,), np.inf, np.float64)
    alive = np.ones(a.shape[0], bool)
    zone = int(excl_zone)
    for slot in range(k):
        idx = np.nonzero(alive)[0]
        if not idx.size:
            break
        pick = idx[0]
        out_a[slot], out_b[slot], out_d[slot] = a[pick], b[pick], d[pick]
        for member in (a[pick], b[pick]):
            near_a = np.abs(starts[a] - starts[member]) <= zone
            near_b = np.abs(starts[b] - starts[member]) <= zone
            alive &= ~(near_a | near_b)
    return out_a, out_b, out_d


def discord_select(nn_dist, starts, k: int, excl_zone):
    """Greedy top-K discords: the windows whose nearest admissible
    neighbor is *farthest* (the matrix-profile anomaly rule), suppressed
    within ``excl_zone`` samples of each pick so the K reported anomalies
    are distinct events. Invalid entries (inf/nan ``nn_dist`` — e.g. a
    fully-banned window, which would otherwise masquerade as the largest
    anomaly) are never reported.

    Returns ``(idx, dist)`` of shape (k,), best (largest) first, padded
    with ``(-1, -inf)``.
    """
    nn_dist = np.asarray(nn_dist, np.float64)
    starts = np.asarray(starts, np.int64)
    score = np.where(np.isfinite(nn_dist), nn_dist, -np.inf)
    out_i = np.full((k,), -1, np.int64)
    out_d = np.full((k,), -np.inf, np.float64)
    zone = int(excl_zone)
    if not score.size:
        return out_i, out_d
    for slot in range(k):
        pick = int(np.argmax(score))     # leftmost on ties
        if not np.isfinite(score[pick]):
            break
        out_i[slot], out_d[slot] = pick, score[pick]
        score[np.abs(starts - starts[pick]) <= zone] = -np.inf
    return out_i, out_d
