"""Fixed-size top-K match heaps with exclusion-zone suppression.

The search layer (``repro.search``) and the streaming sDTW paths report not
just the best alignment distance but the K best *match spans* — the
paper's actual workload (anomaly/motif search over ECG-class streams, §I,
§V) consumes the aligned event, not just a score. A "heap" here is a
triple of fixed-shape arrays

    (distances (k,), end_positions (k,), start_positions (k,))

sorted ascending by distance, padded with ``(BIG, -1, -1)`` — fixed shapes
so the heap can ride a ``lax.scan`` carry (the chunk boundary-carry
protocol) and a ``lax.ppermute`` (the sharded systolic pipeline) unchanged.
Start positions are produced by the DP's start-pointer lane (see
``repro.core.sdtw``): the row-0 reference column where the matched
alignment began.

Selection semantics — greedy best-first with an exclusion zone, the matrix-
profile convention: repeatedly take the lowest remaining distance, then
suppress every candidate "too close" to it, so the K reported matches are
non-trivially distinct (no stack of matches one sample apart). Two
suppression keys:

  * end-distance (default): candidates with ``|end - picked_end| <=
    excl_zone`` are removed — the classic matrix-profile rule.
  * span overlap (``excl_span=True``): candidates whose span
    ``[start, end]`` intersects the picked span widened by ``excl_zone``
    on both sides are removed — two reported events never share reference
    samples (``excl_zone=0`` is pure interval overlap).

Ties break toward the lowest end position (``argmin`` is leftmost, and
streamed chunks merge in reference order). Saturated candidates (distance
≥ BIG, e.g. the int32 ceiling) are never reported — they come back as
``(BIG, -1, -1)`` padding.

The streamed top-1 is exact: it is the global ``min`` with the leftmost end
index, bitwise-equal to ``engine.sdtw()``. For K > 1 the greedy suppression
is order-dependent in the usual way (a candidate suppressed by a better
neighbour cannot "come back" if that neighbour is later suppressed
itself); every reported match is still a genuine alignment distance.
"""
from __future__ import annotations

import jax.numpy as jnp

from .distances import big


def topk_init(nq: int, k: int, acc):
    """Empty batched heap: ((nq, k) BIG distances, (nq, k) -1 end
    positions, (nq, k) -1 start positions)."""
    return (jnp.full((nq, k), big(acc), acc),
            jnp.full((nq, k), -1, jnp.int32),
            jnp.full((nq, k), -1, jnp.int32))


def topk_select(scores, positions, starts, k: int, excl_zone,
                excl_span: bool = False):
    """K rounds of select-then-suppress over one candidate row.

    Args:
      scores:    (C,) candidate distances (BIG = absent/banned/saturated).
      positions: (C,) global end positions of the candidates.
      starts:    (C,) global start positions (the DP start-pointer lane).
      k:         static heap size.
      excl_zone: suppression radius — end-distance mode removes candidates
                 with |position - picked| <= excl_zone; span mode widens
                 the picked span by excl_zone on both sides first.
      excl_span: suppress on span overlap instead of end distance.

    Returns (k,) distances ascending + (k,) ends + (k,) starts,
    (BIG, -1, -1)-padded.
    """
    acc = scores.dtype
    BIG = big(acc)
    out_d, out_p, out_s = [], [], []
    for _ in range(k):
        idx = jnp.argmin(scores)                    # leftmost on ties
        d = scores[idx]
        live = d < BIG
        p = jnp.where(live, positions[idx], -1)
        s = jnp.where(live, starts[idx], -1)
        if excl_span:
            hit = (starts <= p + excl_zone) & (positions >= s - excl_zone)
        else:
            hit = jnp.abs(positions - p) <= excl_zone
        suppress = live & hit
        scores = jnp.where(suppress, BIG, scores)
        out_d.append(jnp.where(live, d, BIG))
        out_p.append(p)
        out_s.append(s)
    return jnp.stack(out_d), jnp.stack(out_p), jnp.stack(out_s)


def topk_merge(heap_d, heap_p, heap_s, scores, positions, starts, k: int,
               excl_zone, excl_span: bool = False):
    """Fold a fresh candidate row into a (k,) heap (one query).

    The heap's entries come first in the concatenation, so on exact ties
    the earlier (lower-position, earlier-chunk) match wins — this is what
    keeps the streamed top-1 bitwise-equal to the one-shot ``argmin``.
    """
    d = jnp.concatenate([heap_d, scores.astype(heap_d.dtype)])
    p = jnp.concatenate([heap_p, positions.astype(jnp.int32)])
    s = jnp.concatenate([heap_s, starts.astype(jnp.int32)])
    return topk_select(d, p, s, k, excl_zone, excl_span)
