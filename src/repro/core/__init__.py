"""MATSA core: sDTW algorithms, the accelerator API, and evaluation models."""
from .distances import METRICS, pointwise_distance
from .engine import align, choose_impl, sdtw, stream
from .traceback import AlignResult, check_path, path_cost, traceback_path
from .matsa_api import MatsaResult, load_real_workload_shapes, matsa, synthetic_timeseries
from .pum_model import (MATSA_EMBEDDED, MATSA_HPC, MATSA_PORTABLE, SWEEP,
                        VERSIONS, MramParams, OpCounts, SimResult, Workload,
                        endurance_writes_per_cell, simulate)
from .platforms import PAPER_TABLE6, PLATFORMS, PlatformModel
from .sdtw import (sdtw_batch, sdtw_chunked, sdtw_rowscan, sdtw_wavefront,
                   self_join_windows)
from .sdtw_ref import dtw_ref, sdtw_matrix, sdtw_ref
from .topk import topk_init, topk_merge, topk_select

__all__ = [
    "sdtw", "align", "choose_impl", "sdtw_chunked", "stream",
    "AlignResult", "traceback_path", "path_cost", "check_path",
    "METRICS", "pointwise_distance",
    "MatsaResult", "matsa", "load_real_workload_shapes", "synthetic_timeseries",
    "MramParams", "OpCounts", "Workload", "SimResult", "simulate",
    "endurance_writes_per_cell", "SWEEP", "VERSIONS",
    "MATSA_EMBEDDED", "MATSA_PORTABLE", "MATSA_HPC",
    "PLATFORMS", "PAPER_TABLE6", "PlatformModel",
    "sdtw_batch", "sdtw_rowscan", "sdtw_wavefront", "self_join_windows",
    "sdtw_ref", "sdtw_matrix", "dtw_ref",
    "topk_init", "topk_merge", "topk_select",
]
