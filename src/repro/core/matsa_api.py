"""MATSA host interface (paper Listing 1), as a JAX-native API.

    void matsa(DTYPE* ref, DTYPE* queries, uint64_t* ref_size,
               uint64_t* query_sizes, uint64_t n_queries, char* mode,
               char* dist_metric, DTYPE anomaly_thres,
               bool* anomalies, DTYPE* distances)

Mapped to Python: arrays in, ``MatsaResult(distances, anomalies)`` out.
Supported dtypes follow the paper (int8/int16/int32, float32; int64/float64
are accepted and computed at int32/float32 accumulator precision — the paper
notes int32 covers all evaluated workloads).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from . import engine
from .sdtw import self_join_exclusion, self_join_windows

MODES = ("query_filtering", "self_join")


@dataclasses.dataclass
class MatsaResult:
    distances: jnp.ndarray          # (n_queries,) sDTW distance per query
    anomalies: Optional[jnp.ndarray]  # (n_queries,) bool, if threshold given
    window_starts: Optional[jnp.ndarray] = None  # self_join only
    profile: Optional[object] = None  # self_join: the full ProfileResult


def matsa(reference,
          queries=None,
          query_sizes=None,
          *,
          mode: str = "query_filtering",
          dist_metric: str = "abs_diff",
          anomaly_threshold=None,
          window: int = None,
          stride: int = 1,
          exclusion: bool = True,
          impl: str = "auto",
          chunk: int = None,
          mesh=None) -> MatsaResult:
    """Run TSA over a reference, per the paper's host API.

    query_filtering: ``queries`` (n_queries, max_len) padded array compared
      against ``reference``; ``query_sizes`` gives true lengths.
    self_join: sliding windows of size ``window`` (stride ``stride``) of the
      reference are compared against the reference itself; ``exclusion`` bans
      the trivial self-match zone (window ± window/2).

    An ``anomaly_threshold`` marks queries whose best-alignment distance
    exceeds it (discords, per §II-A), mirroring the paper's anomaly output.

    All distance computation routes through ``repro.core.engine.sdtw`` —
    ``impl`` (default 'auto'), ``chunk`` (reference streaming tile), and
    ``mesh`` (multi-device reference sharding) pass straight through.

    Self-join with ``exclusion=True``, ``impl='auto'`` and no ``mesh``
    routes through ``repro.search.profile.matrix_profile`` (exact,
    ``prune=False``): windows are processed in bounded batches instead
    of one (nw, window) slab, distances are bitwise-identical (the
    streamed top-1 *is* the engine's answer), and the returned
    ``MatsaResult.profile`` carries the full matrix profile — spans,
    motif pairs, discords. Exclusion zones are always derived in
    **sample** units via ``self_join_exclusion`` (stride-invariant).
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    reference = jnp.asarray(reference)

    window_starts = None
    if mode == "self_join":
        if window is None:
            raise ValueError("self_join mode requires window=")
        if exclusion and impl == "auto" and mesh is None:
            from repro.search.profile import matrix_profile
            prof = matrix_profile(np.asarray(reference), window,
                                  stride=stride, metric=dist_metric,
                                  chunk=chunk, prune=False)
            distances = jnp.asarray(prof.nn_dist)
            anomalies = None
            if anomaly_threshold is not None:
                anomalies = distances > jnp.asarray(anomaly_threshold,
                                                    distances.dtype)
            return MatsaResult(distances=distances, anomalies=anomalies,
                               window_starts=jnp.asarray(prof.starts,
                                                         jnp.int32),
                               profile=prof)
        queries, window_starts = self_join_windows(reference, window, stride)
        nq = queries.shape[0]
        qlens = jnp.full((nq,), window, jnp.int32)
        if exclusion:
            excl_lo, excl_hi = self_join_exclusion(window_starts, window)
        else:
            excl_lo = jnp.full((nq,), -1, jnp.int32)
            excl_hi = jnp.full((nq,), -1, jnp.int32)
    else:
        if queries is None:
            raise ValueError("query_filtering mode requires queries=")
        queries = jnp.asarray(queries)
        if queries.ndim == 1:
            queries = queries[None, :]
        nq = queries.shape[0]
        qlens = (jnp.full((nq,), queries.shape[1], jnp.int32)
                 if query_sizes is None else jnp.asarray(query_sizes, jnp.int32))
        excl_lo = excl_hi = None

    distances = engine.sdtw(queries, reference, qlens, metric=dist_metric,
                            impl=impl, chunk=chunk, mesh=mesh,
                            excl_lo=excl_lo, excl_hi=excl_hi)
    anomalies = None
    if anomaly_threshold is not None:
        anomalies = distances > jnp.asarray(anomaly_threshold, distances.dtype)
    return MatsaResult(distances=distances, anomalies=anomalies,
                       window_starts=window_starts)


def load_real_workload_shapes():
    """Table V of the paper: the six real-world workload shapes."""
    return {
        "Human":      dict(ref_size=7_997,     query_size=120,  num_queries=131_072),
        "Song":       dict(ref_size=20_234,    query_size=200,  num_queries=65_536),
        "Penguin":    dict(ref_size=109_842,   query_size=800,  num_queries=32_768),
        "Seismology": dict(ref_size=1_727_990, query_size=64,   num_queries=16_384),
        "Power":      dict(ref_size=1_754_985, query_size=1536, num_queries=16_384),
        "ECG":        dict(ref_size=1_800_000, query_size=512,  num_queries=16_384),
    }


def synthetic_timeseries(rng: np.random.Generator, size: int,
                         anomaly_rate: float = 0.01, dtype=np.int32):
    """Synthetic sensor stream: smooth base signal + sparse anomalies.

    Used by the examples and the characterization benchmarks (the paper uses
    64 synthetic datasets for its design-space exploration)."""
    t = np.arange(size)
    base = (1000 * np.sin(2 * np.pi * t / 97.0)
            + 400 * np.sin(2 * np.pi * t / 31.0)
            + rng.normal(0, 20, size))
    n_anom = max(1, int(size * anomaly_rate / 64))
    starts = rng.integers(0, max(1, size - 64), n_anom)
    for s in starts:
        base[s:s + 64] += rng.normal(0, 800, min(64, size - s))
    return base.astype(dtype)
