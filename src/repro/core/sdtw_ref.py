"""Naive reference sDTW (Algorithm 1 of the paper) — the correctness oracle.

Materialises the full O(N*M) scoring matrix in numpy with explicit loops.
Slow but unambiguous; every production implementation (wavefront,
associative-scan, Pallas kernel) is validated against this module.

Semantics
---------
``subsequence`` DTW aligns the *whole* query against *any* contiguous part of
the reference:

  * row 0 (first query point) starts a fresh alignment at any reference
    position: S[0, j] = d(Q[0], R[j])                       (free start)
  * column 0 accumulates (the query cannot skip its own points):
    S[i, 0] = S[i-1, 0] + d(Q[i], R[0])
  * interior: S[i, j] = d(Q[i], R[j]) + min(S[i-1,j-1], S[i,j-1], S[i-1,j])
  * answer: min(S[N-1, :])                                  (free end)

Note: the paper's Algorithm 1 listing initialises only S[0,0] and leaves the
rest of row 0 at zero. Taken literally this makes the first query point free
*everywhere except* j=0, which contradicts the standard sDTW definition the
paper cites ([71], Berndt & Clifford) and its own description ("allows the
query to be aligned with part of the reference"). We treat that as a listing
typo and implement the standard free-start initialisation; the literal
variant is available via ``literal_init=True`` for comparison.
"""
from __future__ import annotations

import numpy as np


def _dist(q, r, metric: str):
    d = np.asarray(q, dtype=np.float64) - np.asarray(r, dtype=np.float64)
    if metric == "abs_diff":
        return np.abs(d)
    if metric == "square_diff":
        return d * d
    raise ValueError(f"unknown metric {metric!r}")


def sdtw_matrix(query, reference, metric: str = "abs_diff",
                literal_init: bool = False) -> np.ndarray:
    """Full N×M scoring matrix in float64 (exact for int inputs)."""
    q = np.asarray(query, dtype=np.float64)
    r = np.asarray(reference, dtype=np.float64)
    n, m = len(q), len(r)
    if n == 0 or m == 0:
        raise ValueError("query and reference must be non-empty")
    S = np.zeros((n, m), dtype=np.float64)
    # Row 0.
    if literal_init:
        S[0, 0] = _dist(q[0], r[0], metric)  # paper's literal listing
    else:
        S[0, :] = _dist(q[0], r, metric)     # standard free start
    # Column 0 accumulates.
    for i in range(1, n):
        S[i, 0] = S[i - 1, 0] + _dist(q[i], r[0], metric)
    # Interior.
    for i in range(1, n):
        di = _dist(q[i], r, metric)
        for j in range(1, m):
            S[i, j] = di[j] + min(S[i - 1, j - 1], S[i, j - 1], S[i - 1, j])
    return S


def sdtw_ref(query, reference, metric: str = "abs_diff",
             literal_init: bool = False) -> float:
    """min over the last row — the sDTW distance of Algorithm 1."""
    return float(sdtw_matrix(query, reference, metric, literal_init)[-1, :].min())


def dtw_ref(query, reference, metric: str = "abs_diff") -> float:
    """Classic (non-subsequence) DTW: both boundaries pinned.

    Used by property tests: sDTW(Q, R) == min over windows W of DTW(Q, W)
    is NOT an identity (windows overlap), but sDTW <= DTW(Q, R) always holds.
    """
    q = np.asarray(query, dtype=np.float64)
    r = np.asarray(reference, dtype=np.float64)
    n, m = len(q), len(r)
    S = np.full((n, m), np.inf)
    S[0, 0] = _dist(q[0], r[0], metric)
    for j in range(1, m):
        S[0, j] = S[0, j - 1] + _dist(q[0], r[j], metric)
    for i in range(1, n):
        S[i, 0] = S[i - 1, 0] + _dist(q[i], r[0], metric)
    for i in range(1, n):
        for j in range(1, m):
            S[i, j] = _dist(q[i], r[j], metric) + min(
                S[i - 1, j - 1], S[i, j - 1], S[i - 1, j])
    return float(S[-1, -1])
