"""Baseline platform models (CPU / GPU / FPGA / PNM) for Table VI.

The paper measures real hardware (cpui7/cpuxeon via RAPL, GPU via nvidia-smi,
UPMEM/FPGA/ARM via vendor tools or ZSim+McPAT). That hardware is unavailable
here, so each baseline is an analytic (throughput, power) model anchored to:

  1. the paper's own §II-D characterization (measured sustained GINTOPS,
     arithmetic intensity, utilization), and
  2. public hardware specs (TDP, bandwidth, core counts).

Derivation trail (full napkin math in EXPERIMENTS.md §Paper-validation):

  * sDTW inner loop ≈ 8 integer ops/cell (sub, abs, 2 cmp, 2 sel, add, +addr).
  * gpu:   §II-D measures ~1% of 15.7 TINTOPS peak → ~157 GINTOPS sustained
           → 19.7 GCells/s; V100 TDP 300W (+HBM) → ~17 nJ/cell.
  * upmem: compute-bound at DPU throughput (paper: 146 GINTOPS peak) →
           ~19.4 GCells/s; power set so UPMEM energy = 0.63× GPU — the
           paper's measured "37% reduction" (§II-D) — → ~10.8 nJ/cell.
  * cpuxeon: memory-bound; 2×Xeon 6154 (~230 GB/s, AI 0.55 INTOP/B measured
           on the Phi → ~127 GINTOPS ceiling, 41% util class) → ~16.7 GCells/s;
           2-socket server wall power ~700W.
  * cpui7 / cpuarm / fpga: scaled the same way from §IV-C's reported ratios
           against MATSA-Portable/Embedded and public TDPs.

These constants make the baselines *independent* of the MATSA model (they are
cells/s + watts), so Table VI ratios computed by ``benchmarks/table6`` are a
genuine cross-check of the MATSA PUM model, not an identity.
"""
from __future__ import annotations

import dataclasses

from .pum_model import Workload


@dataclasses.dataclass(frozen=True)
class PlatformModel:
    name: str
    cells_per_s: float        # sustained sDTW DP-cell throughput
    watts: float              # average package power during the kernel
    peak_gintops: float       # platform peak (for roofline reporting)
    ai_intop_per_byte: float  # measured arithmetic intensity (paper §II-D)
    note: str = ""

    def exec_time_s(self, w: Workload) -> float:
        return w.num_queries * w.query_size * w.ref_size / self.cells_per_s

    def energy_j(self, w: Workload) -> float:
        return self.exec_time_s(w) * self.watts

    def energy_per_cell_j(self) -> float:
        return self.watts / self.cells_per_s

    def utilization(self, ops_per_cell: float = 8.0) -> float:
        return self.cells_per_s * ops_per_cell / (self.peak_gintops * 1e9)


@dataclasses.dataclass(frozen=True)
class BackendModel:
    """Calibrated per-term execution-cost constants for one *execution
    backend of this repo* (as opposed to ``PlatformModel``, which models
    the paper's baseline hardware as whole-kernel cells/s).

    ``repro.tune.cost.KernelCostModel`` prices every engine regime
    (rowscan / wavefront / chunked / pallas) per configuration from these
    constants; the units are microseconds per the named event. The
    ``interpret`` constants were fitted to in-container XLA-CPU
    measurements of the committed bench shapes (see
    ``repro/tune/tables/interpret.json`` provenance); the ``tpu``
    constants are anchored to the v5e roofline (``launch/roofline.V5E``)
    and the kernel's documented VMEM working set — on real TPU hardware
    the measured stage (``tune='measure'``) refines them into the table.
    """
    name: str                    # 'interpret' (XLA CPU) | 'tpu'
    call_fixed_us: float         # per-dispatch overhead of one jitted call
    row_step_fixed_us: float     # per sequential DP row step (rowscan)
    scan_elem_us: float          # per accumulator element per row scan
    wf_step_fixed_us: float      # per anti-diagonal step (wavefront)
    wf_elem_us: float            # per (query-row) element per wavefront step
    chunk_fixed_us: float        # per reference tile (chunked streaming)
    cache_elems: int             # live-row working-set knee (elements);
                                 # beyond it scan_elem_us inflates
    tile_fixed_us: float         # per pallas grid cell (launch/fill)
    pallas_row_fixed_us: float   # per DP row per pallas grid cell
    pallas_elem_us: float        # per DP cell, scheme-independent base
    pallas_pass_us: float        # per DP cell per scan *pass* (depth term)
    scheme_mult: tuple           # (('shift', x), ('assoc', y)) pass-cost
                                 # multipliers — which scan scheme is cheap
                                 # is exactly what differs per backend
    hbm_bw_bytes_per_s: float    # streaming bandwidth for the HBM term
    vmem_budget_words: int       # pallas per-config working-set cap

    def scheme_cost_mult(self, scheme: str) -> float:
        return dict(self.scheme_mult)[scheme]


#: XLA-CPU (pallas interpret mode) — fitted to this container's measured
#: bench shapes: rowscan ~0.027us/elem/row + ~60us/row-step; wavefront
#: ~0.004us/elem/step + ~0.4us/step (why the wavefront wins every CPU
#: in-core shape, 2.5-6.7x measured); interpret-mode pallas pays a
#: per-scan-pass cost that grows with log2(block_m * block_q), so small
#: tiles win despite more grid cells.
INTERPRET_BACKEND = BackendModel(
    name="interpret", call_fixed_us=500.0, row_step_fixed_us=60.0,
    scan_elem_us=0.027, wf_step_fixed_us=0.4, wf_elem_us=0.004,
    chunk_fixed_us=200.0, cache_elems=1 << 17, tile_fixed_us=150.0,
    pallas_row_fixed_us=30.0, pallas_elem_us=0.01, pallas_pass_us=0.013,
    scheme_mult=(("assoc", 1.0), ("shift", 1.6)),
    hbm_bw_bytes_per_s=20e9, vmem_budget_words=1 << 21)

#: TPU v5e — roofline-anchored (819 GB/s HBM, ~16 MB VMEM/core): the
#: vector unit makes the Hillis-Steele 'shift' scan the cheap scheme, the
#: per-cell cost is far below CPU, and the binding constraint is the VMEM
#: working set ``block_q * (3*block_m + 3*N)`` words (span mode
#: ``block_q * (6*block_m + 5*N)``).
TPU_BACKEND = BackendModel(
    name="tpu", call_fixed_us=30.0, row_step_fixed_us=2.0,
    scan_elem_us=0.0004, wf_step_fixed_us=1.0, wf_elem_us=0.001,
    chunk_fixed_us=40.0, cache_elems=1 << 21, tile_fixed_us=3.5,
    pallas_row_fixed_us=0.05, pallas_elem_us=0.00005,
    pallas_pass_us=0.00002,
    scheme_mult=(("shift", 1.0), ("assoc", 1.4)),
    hbm_bw_bytes_per_s=819e9, vmem_budget_words=1 << 21)

BACKENDS = {b.name: b for b in (INTERPRET_BACKEND, TPU_BACKEND)}


def backend_model(name: str) -> BackendModel:
    """The cost-constant set for an execution backend; every non-TPU
    backend string ('cpu', 'gpu', ...) maps to the interpret model until
    it gets its own calibration."""
    return BACKENDS.get(name, INTERPRET_BACKEND)


CPU_ARM = PlatformModel(
    "cpuarm", cells_per_s=0.133e9, watts=24.8, peak_gintops=40.0,
    ai_intop_per_byte=0.55,
    note="4-core ARM @2.5GHz, LPDDR4; ZSim+Ramulator+McPAT in the paper")
CPU_I7 = PlatformModel(
    "cpui7", cells_per_s=3.09e9, watts=134.0, peak_gintops=614.0,
    ai_intop_per_byte=0.55,
    note="6C/12T i7 @3.2GHz AVX2, DDR4; RAPL-measured in the paper")
CPU_XEON = PlatformModel(
    "cpuxeon", cells_per_s=16.7e9, watts=769.0, peak_gintops=6900.0,
    ai_intop_per_byte=0.55,
    note="2×18C Xeon Gold 6154 AVX-512, 768GB DDR4; memory-bound (§II-D)")
GPU = PlatformModel(
    "gpu", cells_per_s=19.9e9, watts=342.0, peak_gintops=15700.0,
    ai_intop_per_byte=0.55,
    note="V100 32GB HBM; §II-D measures ~1% of peak INT throughput")
FPGA = PlatformModel(
    "fpga", cells_per_s=0.49e9, watts=49.0, peak_gintops=600.0,
    ai_intop_per_byte=0.55,
    note="Alveo U50, 8 HLS compute units, <7% of peak (§II-D)")
UPMEM = PlatformModel(
    "upmem", cells_per_s=19.4e9, watts=210.0, peak_gintops=146.0,
    ai_intop_per_byte=3.0,
    note="2560 DPUs @425MHz; compute-bound (§II-D); energy = 0.63× GPU")

PLATFORMS = {p.name: p for p in
             (CPU_ARM, CPU_I7, CPU_XEON, GPU, FPGA, UPMEM)}

# Paper Table VI — the claims we validate against.
PAPER_TABLE6 = {
    ("matsa-embedded", "cpuarm"): (30.20, 45.67),
    ("matsa-portable", "cpui7"): (10.41, 10.65),
    ("matsa-portable", "fpga"): (65.01, 24.58),
    ("matsa-hpc", "cpuxeon"): (7.35, 11.29),
    ("matsa-hpc", "upmem"): (6.31, 2.65),
    ("matsa-hpc", "gpu"): (6.15, 4.21),
}
