"""Production sDTW implementations in JAX.

Two execution schemes, both using the paper's O(4M)-style linear memory
mapping (no N×M matrix is ever materialised):

``sdtw_wavefront``
    Paper-faithful anti-diagonal wavefront (MATSA §III-E): a scan over the
    N+M-1 anti-diagonals, vectorized along the diagonal. This mirrors MATSA's
    PE array, where each crossbar column is one wavefront element and data is
    shifted diagonally between steps. Sequential depth: N+M-1.

``sdtw_rowscan``
    Beyond-paper TPU-native scheme. The row recurrence

        s[j] = d[j] + min(m[j], s[j-1]),   m[j] = min(prev[j-1], prev[j])

    is a first-order *linear* recurrence over the (min, +) tropical semiring:
    with u[j] = d[j] + m[j] it is  s[j] = min(u[j], d[j] + s[j-1]), i.e. the
    tropical analogue of s_j = a_j * s_{j-1} + b_j. It therefore admits an
    associative-scan solution with O(log M) depth per row. Sequential depth:
    N (vs N+M-1) — a massive win when the reference is much longer than the
    query, which is the common case in the paper's workloads (e.g. ECG:
    M=1.8M, N=512). MATSA's bit-serial PEs cannot express this; TPU VPUs can.

Both return ``min(S[N-1, :])`` per Algorithm 1 and are validated against
``sdtw_ref.sdtw_ref`` over shape/dtype/metric sweeps in the test suite.

Exclusion zones (for self-join / matrix-profile-style use) are supported by
banning a column range [excl_lo, excl_hi): any path through those reference
positions is given +inf distance, which removes trivial self-matches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .distances import accum_dtype, big, pointwise_distance, sat_add


def _tropical_combine(left, right):
    """Compose f_r ∘ f_l where f(x) = min(u, a + x) over the (min,+) semiring."""
    a_l, u_l = left
    a_r, u_r = right
    return sat_add(a_l, a_r), jnp.minimum(u_r, sat_add(a_r, u_l))


def _masked_distance(qi, ref, metric, excl_lo, excl_hi, BIG):
    d = pointwise_distance(qi, ref, metric)
    j = jnp.arange(ref.shape[0])
    banned = (j >= excl_lo) & (j < excl_hi)
    return jnp.where(banned, BIG, d)


# ---------------------------------------------------------------------------
# Row-scan (associative scan over the tropical semiring) — beyond-paper.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("metric",))
def sdtw_rowscan(query, reference, qlen=None, metric: str = "abs_diff",
                 excl_lo=None, excl_hi=None):
    """sDTW distance via per-row tropical associative scan.

    Args:
      query:     (N,) possibly padded query.
      reference: (M,) reference sequence.
      qlen:      actual query length (<= N); defaults to N. Padded rows are
                 ignored — the answer is min over row ``qlen - 1``.
      metric:    'abs_diff' | 'square_diff'.
      excl_lo/excl_hi: optional banned reference column range (self-join).

    Returns: scalar sDTW distance in the accumulator dtype.
    """
    acc = accum_dtype(jnp.result_type(query, reference))
    BIG = big(acc)
    n = query.shape[0]
    qlen = jnp.asarray(n if qlen is None else qlen, jnp.int32)
    excl_lo = jnp.asarray(-1 if excl_lo is None else excl_lo, jnp.int32)
    excl_hi = jnp.asarray(-1 if excl_hi is None else excl_hi, jnp.int32)

    d0 = _masked_distance(query[0], reference, metric, excl_lo, excl_hi, BIG)
    prev = d0                                           # row 0: free start
    best0 = jnp.where(qlen == 1, jnp.min(d0), BIG)

    def row_step(carry, qi):
        prev, best, i = carry
        d = _masked_distance(qi, reference, metric, excl_lo, excl_hi, BIG)
        prev_shift = jnp.concatenate([jnp.full((1,), BIG, acc), prev[:-1]])
        m = jnp.minimum(prev_shift, prev)               # min(S[i-1,j-1], S[i-1,j])
        s0 = sat_add(prev[0], d[0])                     # column-0 accumulation
        u = sat_add(d, m).at[0].set(s0)
        a = d.at[0].set(BIG)
        _, s = lax.associative_scan(_tropical_combine, (a, u))
        best = jnp.where(i == qlen - 1, jnp.minimum(best, jnp.min(s)), best)
        # Freeze rows past the true query end so `prev` stays meaningless-safe.
        return (s, best, i + 1), None

    (_, best, _), _ = lax.scan(row_step, (prev, best0, jnp.int32(1)), query[1:])
    return best


# ---------------------------------------------------------------------------
# Anti-diagonal wavefront — paper-faithful (MATSA §III-E execution flow).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("metric",))
def sdtw_wavefront(query, reference, qlen=None, metric: str = "abs_diff",
                   excl_lo=None, excl_hi=None):
    """sDTW distance via anti-diagonal wavefront scan (MATSA's schedule).

    Diagonal k holds cells (i, j) with i + j = k, indexed by i. The carry is
    the last two diagonals (the paper's temporal S_vectors); each step
    consumes one new reference "column" — the direct analogue of MATSA's
    diagonal row copies between crossbar columns.
    """
    acc = accum_dtype(jnp.result_type(query, reference))
    BIG = big(acc)
    n = query.shape[0]
    m = reference.shape[0]
    qlen = jnp.asarray(n if qlen is None else qlen, jnp.int32)
    excl_lo = jnp.asarray(-1 if excl_lo is None else excl_lo, jnp.int32)
    excl_hi = jnp.asarray(-1 if excl_hi is None else excl_hi, jnp.int32)

    q = query.astype(accum_dtype(query.dtype))
    # R[k - i] for i in [0, n): pad front with n-1 dummies, slice, reverse.
    r_pad = jnp.concatenate([jnp.zeros((n - 1,), reference.dtype), reference,
                             jnp.zeros((n,), reference.dtype)])
    i_idx = jnp.arange(n)

    def step(carry, k):
        dm1, dm2, best = carry
        j_idx = k - i_idx                               # ref position per cell
        valid = (j_idx >= 0) & (j_idx < m) & (i_idx < qlen)
        r_rev = lax.dynamic_slice(r_pad, (k,), (n,))[::-1]
        d = pointwise_distance(q, r_rev.astype(acc), metric)
        banned = (j_idx >= excl_lo) & (j_idx < excl_hi)
        d = jnp.where(banned, BIG, d)
        shift1 = jnp.concatenate([jnp.full((1,), BIG, acc), dm1[:-1]])  # S[i-1,j]
        shift2 = jnp.concatenate([jnp.full((1,), BIG, acc), dm2[:-1]])  # S[i-1,j-1]
        mins = jnp.minimum(jnp.minimum(shift2, shift1), dm1)            # +S[i,j-1]
        cur = jnp.where(i_idx == 0, d, sat_add(d, mins))
        cur = jnp.where(valid, cur, BIG)
        last = jnp.where((i_idx == qlen - 1) & valid, cur, BIG)
        best = jnp.minimum(best, jnp.min(last))
        return (cur, dm1, best), None

    init = (jnp.full((n,), BIG, acc), jnp.full((n,), BIG, acc), BIG)
    (_, _, best), _ = lax.scan(step, init, jnp.arange(n + m - 1))
    return best


# ---------------------------------------------------------------------------
# Batched front-ends.
# ---------------------------------------------------------------------------

_IMPLS = {"rowscan": sdtw_rowscan, "wavefront": sdtw_wavefront}


def sdtw_batch(queries, reference, qlens=None, metric: str = "abs_diff",
               impl: str = "rowscan", excl_lo=None, excl_hi=None):
    """Batched sDTW: (nq, N) queries against a shared (M,) reference.

    Queries are embarrassingly parallel (paper §II-D) — this is MATSA's
    reference-replication / query-pipelining axis, mapped to vmap.
    """
    fn = _IMPLS[impl]
    nq, n = queries.shape
    if qlens is None:
        qlens = jnp.full((nq,), n, jnp.int32)
    if excl_lo is None:
        excl_lo = jnp.full((nq,), -1, jnp.int32)
        excl_hi = jnp.full((nq,), -1, jnp.int32)
    return jax.vmap(
        lambda qu, ql, lo, hi: fn(qu, reference, ql, metric, lo, hi)
    )(queries, qlens, excl_lo, excl_hi)


def self_join_windows(reference, window: int, stride: int = 1):
    """Extract sliding windows (the paper's self_join mode: slices of the
    reference compared against the reference itself)."""
    m = reference.shape[0]
    starts = jnp.arange(0, m - window + 1, stride)
    idx = starts[:, None] + jnp.arange(window)[None, :]
    return reference[idx], starts
