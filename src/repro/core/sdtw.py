"""Production sDTW implementations in JAX.

Two execution schemes, both using the paper's O(4M)-style linear memory
mapping (no N×M matrix is ever materialised):

``sdtw_wavefront``
    Paper-faithful anti-diagonal wavefront (MATSA §III-E): a scan over the
    N+M-1 anti-diagonals, vectorized along the diagonal. This mirrors MATSA's
    PE array, where each crossbar column is one wavefront element and data is
    shifted diagonally between steps. Sequential depth: N+M-1.

``sdtw_rowscan``
    Beyond-paper TPU-native scheme. The row recurrence

        s[j] = d[j] + min(m[j], s[j-1]),   m[j] = min(prev[j-1], prev[j])

    is a first-order *linear* recurrence over the (min, +) tropical semiring:
    with u[j] = d[j] + m[j] it is  s[j] = min(u[j], d[j] + s[j-1]), i.e. the
    tropical analogue of s_j = a_j * s_{j-1} + b_j. It therefore admits an
    associative-scan solution with O(log M) depth per row. Sequential depth:
    N (vs N+M-1) — a massive win when the reference is much longer than the
    query, which is the common case in the paper's workloads (e.g. ECG:
    M=1.8M, N=512). MATSA's bit-serial PEs cannot express this; TPU VPUs can.

Both return ``min(S[N-1, :])`` per Algorithm 1 and are validated against
``sdtw_ref.sdtw_ref`` over shape/dtype/metric sweeps in the test suite.

Exclusion zones (for self-join / matrix-profile-style use) are supported by
banning a column range [excl_lo, excl_hi): any path through those reference
positions is given +inf distance, which removes trivial self-matches.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from .distances import accum_dtype, big, pointwise_distance, sat_add
from .topk import topk_init, topk_merge


def _tropical_combine(left, right):
    """Compose f_r ∘ f_l where f(x) = min(u, a + x) over the (min,+) semiring."""
    a_l, u_l = left
    a_r, u_r = right
    return sat_add(a_l, a_r), jnp.minimum(u_r, sat_add(a_r, u_l))


def _masked_distance(qi, ref, metric, excl_lo, excl_hi, BIG):
    d = pointwise_distance(qi, ref, metric)
    j = jnp.arange(ref.shape[0])
    banned = (j >= excl_lo) & (j < excl_hi)
    return jnp.where(banned, BIG, d)


# ---------------------------------------------------------------------------
# Row-scan (associative scan over the tropical semiring) — beyond-paper.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("metric", "return_position"))
def sdtw_rowscan(query, reference, qlen=None, metric: str = "abs_diff",
                 excl_lo=None, excl_hi=None, return_position: bool = False):
    """sDTW distance via per-row tropical associative scan.

    Args:
      query:     (N,) possibly padded query.
      reference: (M,) reference sequence.
      qlen:      actual query length (<= N); defaults to N. Padded rows are
                 ignored — the answer is min over row ``qlen - 1``.
      metric:    'abs_diff' | 'square_diff'.
      excl_lo/excl_hi: optional banned reference column range (self-join).
      return_position: also return the match end position — the leftmost
                 reference index attaining the minimum of row ``qlen - 1``.

    Returns: scalar sDTW distance in the accumulator dtype (or a
    ``(distance, end_position)`` pair with ``return_position=True``).
    """
    acc = accum_dtype(jnp.result_type(query, reference))
    BIG = big(acc)
    n = query.shape[0]
    qlen = jnp.asarray(n if qlen is None else qlen, jnp.int32)
    excl_lo = jnp.asarray(-1 if excl_lo is None else excl_lo, jnp.int32)
    excl_hi = jnp.asarray(-1 if excl_hi is None else excl_hi, jnp.int32)

    d0 = _masked_distance(query[0], reference, metric, excl_lo, excl_hi, BIG)
    prev = d0                                           # row 0: free start
    best0 = jnp.where(qlen == 1, jnp.min(d0), BIG)
    pos0 = jnp.where(qlen == 1, jnp.argmin(d0).astype(jnp.int32),
                     jnp.int32(-1))

    def row_step(carry, qi):
        prev, best, pos, i = carry
        d = _masked_distance(qi, reference, metric, excl_lo, excl_hi, BIG)
        prev_shift = jnp.concatenate([jnp.full((1,), BIG, acc), prev[:-1]])
        m = jnp.minimum(prev_shift, prev)               # min(S[i-1,j-1], S[i-1,j])
        s0 = sat_add(prev[0], d[0])                     # column-0 accumulation
        u = sat_add(d, m).at[0].set(s0)
        a = d.at[0].set(BIG)
        _, s = lax.associative_scan(_tropical_combine, (a, u))
        hit = i == qlen - 1
        best = jnp.where(hit, jnp.minimum(best, jnp.min(s)), best)
        pos = jnp.where(hit, jnp.argmin(s).astype(jnp.int32), pos)
        # Freeze rows past the true query end so `prev` stays meaningless-safe.
        return (s, best, pos, i + 1), None

    (_, best, pos, _), _ = lax.scan(
        row_step, (prev, best0, pos0, jnp.int32(1)), query[1:])
    if return_position:
        return best, pos
    return best


# ---------------------------------------------------------------------------
# Anti-diagonal wavefront — paper-faithful (MATSA §III-E execution flow).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("metric", "return_position"))
def sdtw_wavefront(query, reference, qlen=None, metric: str = "abs_diff",
                   excl_lo=None, excl_hi=None, return_position: bool = False):
    """sDTW distance via anti-diagonal wavefront scan (MATSA's schedule).

    Diagonal k holds cells (i, j) with i + j = k, indexed by i. The carry is
    the last two diagonals (the paper's temporal S_vectors); each step
    consumes one new reference "column" — the direct analogue of MATSA's
    diagonal row copies between crossbar columns. With
    ``return_position=True`` the leftmost end index of the best match is
    tracked alongside (diagonal k touches row qlen-1 at exactly one column,
    ``k - qlen + 1``, and k ascends — a strict improvement test keeps the
    earliest column, matching ``sdtw_rowscan``'s leftmost ``argmin``).
    """
    acc = accum_dtype(jnp.result_type(query, reference))
    BIG = big(acc)
    n = query.shape[0]
    m = reference.shape[0]
    qlen = jnp.asarray(n if qlen is None else qlen, jnp.int32)
    excl_lo = jnp.asarray(-1 if excl_lo is None else excl_lo, jnp.int32)
    excl_hi = jnp.asarray(-1 if excl_hi is None else excl_hi, jnp.int32)

    q = query.astype(accum_dtype(query.dtype))
    # R[k - i] for i in [0, n): pad front with n-1 dummies, slice, reverse.
    r_pad = jnp.concatenate([jnp.zeros((n - 1,), reference.dtype), reference,
                             jnp.zeros((n,), reference.dtype)])
    i_idx = jnp.arange(n)

    def step(carry, k):
        dm1, dm2, best, pos = carry
        j_idx = k - i_idx                               # ref position per cell
        valid = (j_idx >= 0) & (j_idx < m) & (i_idx < qlen)
        r_rev = lax.dynamic_slice(r_pad, (k,), (n,))[::-1]
        d = pointwise_distance(q, r_rev.astype(acc), metric)
        banned = (j_idx >= excl_lo) & (j_idx < excl_hi)
        d = jnp.where(banned, BIG, d)
        shift1 = jnp.concatenate([jnp.full((1,), BIG, acc), dm1[:-1]])  # S[i-1,j]
        shift2 = jnp.concatenate([jnp.full((1,), BIG, acc), dm2[:-1]])  # S[i-1,j-1]
        mins = jnp.minimum(jnp.minimum(shift2, shift1), dm1)            # +S[i,j-1]
        cur = jnp.where(i_idx == 0, d, sat_add(d, mins))
        cur = jnp.where(valid, cur, BIG)
        last = jnp.where((i_idx == qlen - 1) & valid, cur, BIG)
        lmin = jnp.min(last)
        pos = jnp.where(lmin < best, (k - qlen + 1).astype(jnp.int32), pos)
        best = jnp.minimum(best, lmin)
        return (cur, dm1, best, pos), None

    init = (jnp.full((n,), BIG, acc), jnp.full((n,), BIG, acc), BIG,
            jnp.int32(-1))
    (_, _, best, pos), _ = lax.scan(step, init, jnp.arange(n + m - 1))
    if return_position:
        return best, pos
    return best


# ---------------------------------------------------------------------------
# Chunked reference streaming (boundary-column carry).
#
# The reference axis is processed in fixed-size tiles; between tiles only the
# O(N) boundary column S[:, tile_end] is carried — the direct analogue of
# MATSA's inter-subarray pass gates (§III-B). The same carry doubles as the
# inter-device protocol of ``repro.distributed.sdtw_sharded`` (ppermute the
# column to the device holding the next reference segment).
# ---------------------------------------------------------------------------

def sdtw_carry_init(nq: int, n: int, acc):
    """Fresh chunk carry: (boundary column (nq, N), running best (nq,)).

    BIG everywhere = "no reference columns seen yet": a BIG left/diagonal
    neighbour reproduces the global column-0 recurrence exactly (the only
    finite predecessor of cell (i, 0) is S[i-1, 0])."""
    BIG = big(acc)
    return jnp.full((nq, n), BIG, acc), jnp.full((nq,), BIG, acc)


def _chunk_masked_distance(qi, ref_chunk, metric, j0, m_total, excl_lo,
                           excl_hi, BIG):
    """Distance row for one chunk, masking by *global* reference position.

    Columns outside [0, m_total) are banned — a negative ``j0`` lets the
    pruned-search halo pad a chunk group past the left edge of the
    reference without perturbing the DP (the pad columns behave exactly
    like the implicit BIG columns before the reference starts)."""
    d = pointwise_distance(qi, ref_chunk, metric)
    j = j0 + jnp.arange(ref_chunk.shape[0])
    banned = ((j >= excl_lo) & (j < excl_hi)) | (j >= m_total) | (j < 0)
    return jnp.where(banned, BIG, d)


def sdtw_rowscan_chunk(query, ref_chunk, bcol, best, qlen=None, j0=0,
                       m_total=None, metric: str = "abs_diff",
                       excl_lo=None, excl_hi=None,
                       return_lastrow: bool = False):
    """One reference chunk of the row-scan, entered/exited via the carry.

    Args:
      query:     (N,) possibly padded query.
      ref_chunk: (C,) reference tile covering global columns [j0, j0 + C).
      bcol:      (N,) boundary column S[:, j0 - 1] (BIG for the first chunk).
      best:      scalar running best (min over row qlen-1 of prior chunks).
      qlen:      true query length; j0: global column offset of the chunk;
      m_total:   true reference length (columns >= m_total are masked).
      return_lastrow: also return row ``qlen - 1`` of the chunk — the match
                 score of every alignment *ending* at each of the chunk's
                 columns, which is what top-K extraction consumes.

    Returns (new_bcol, new_best) with new_bcol = S[:, j0 + C - 1], plus the
    (C,) last row when ``return_lastrow``.
    """
    acc = accum_dtype(jnp.result_type(query, ref_chunk))
    BIG = big(acc)
    n = query.shape[0]
    qlen = jnp.asarray(n if qlen is None else qlen, jnp.int32)
    m_total = (j0 + ref_chunk.shape[0] if m_total is None else m_total)
    excl_lo = jnp.asarray(-1 if excl_lo is None else excl_lo, jnp.int32)
    excl_hi = jnp.asarray(-1 if excl_hi is None else excl_hi, jnp.int32)
    bcol = bcol.astype(acc)
    best = jnp.asarray(best, acc)

    dist = functools.partial(_chunk_masked_distance, metric=metric, j0=j0,
                             m_total=m_total, excl_lo=excl_lo,
                             excl_hi=excl_hi, BIG=BIG)
    s0 = dist(query[0], ref_chunk)                  # row 0: free start
    best = jnp.where(qlen == 1, jnp.minimum(best, jnp.min(s0)), best)

    # The (C,) last-row buffer rides the carry only when asked for —
    # the plain streaming hot path stays untaxed.
    def row_step(carry, xs):
        if return_lastrow:
            prev, best, lrow, i = carry
        else:
            prev, best, i = carry
        qi, b_left, b_diag = xs          # S[i, j0-1], S[i-1, j0-1]
        d = dist(qi, ref_chunk)
        prev_sh = jnp.concatenate([b_diag[None], prev[:-1]])
        mn = jnp.minimum(prev_sh, prev)  # min(S[i-1,j-1], S[i-1,j])
        a, u = d, sat_add(d, mn)
        a_p, u_p = lax.associative_scan(_tropical_combine, (a, u))
        s = jnp.minimum(u_p, sat_add(a_p, b_left))  # fold in S[i, j0-1]
        hit = i == qlen - 1
        best = jnp.where(hit, jnp.minimum(best, jnp.min(s)), best)
        if return_lastrow:
            lrow = jnp.where(hit, s, lrow)
            return (s, best, lrow, i + 1), s[-1]
        return (s, best, i + 1), s[-1]

    xs = (query[1:], bcol[1:], bcol[:-1])
    if return_lastrow:
        lrow0 = jnp.where(qlen == 1, s0, jnp.full_like(s0, BIG))
        (_, best, lrow, _), tail = lax.scan(
            row_step, (s0, best, lrow0, jnp.int32(1)), xs)
    else:
        (_, best, _), tail = lax.scan(row_step, (s0, best, jnp.int32(1)), xs)
    new_bcol = jnp.concatenate([s0[-1:], tail])
    if return_lastrow:
        return new_bcol, best, lrow
    return new_bcol, best


def sdtw_chunk_batch(queries, ref_chunk, qlens, carry, j0, m_total,
                     metric: str, excl_lo, excl_hi):
    """Advance the batched carry (bcol (nq, N), best (nq,)) by one chunk."""
    bcol, best = carry
    return jax.vmap(
        lambda q, ql, bc, be, lo, hi: sdtw_rowscan_chunk(
            q, ref_chunk, bc, be, ql, j0, m_total, metric, lo, hi)
    )(queries, qlens, bcol, best, excl_lo, excl_hi)


def sdtw_chunk_batch_topk(queries, ref_chunk, qlens, carry, j0, m_total,
                          metric: str, excl_lo, excl_hi, k: int,
                          excl_zone):
    """Advance the *top-K* carry (bcol, best, top_d, top_p) by one chunk.

    On top of the boundary-column hand-off, the carry holds a per-query
    match heap (top_d (nq, k), top_p (nq, k)): the chunk's last DP row —
    the score of every alignment ending at each chunk column — is folded
    into the heap with exclusion-zone suppression (``repro.core.topk``;
    ``excl_zone`` is a per-query (nq,) radius, so a ragged bucket keeps
    each query's own zone). End positions are global (``j0`` offsets the
    chunk), so the same code serves the in-process streamer and the
    sharded systolic pipeline.
    """
    bcol, best, top_d, top_p = carry
    pos = j0 + jnp.arange(ref_chunk.shape[0], dtype=jnp.int32)

    def one(q, ql, bc, be, lo, hi, hd, hp, ez):
        nbc, nbe, lrow = sdtw_rowscan_chunk(
            q, ref_chunk, bc, be, ql, j0, m_total, metric, lo, hi,
            return_lastrow=True)
        nd, np_ = topk_merge(hd, hp, lrow, pos, k, ez)
        return nbc, nbe, nd, np_

    return jax.vmap(one)(queries, qlens, bcol, best, excl_lo, excl_hi,
                         top_d, top_p, excl_zone)


def default_excl_zone(qlens):
    """The documented default suppression radius: half the *true* query
    length, per query (not the padded bucket width — ragged dispatch must
    match the equivalent per-query call)."""
    return jnp.maximum(1, jnp.asarray(qlens, jnp.int32) // 2)


def sdtw_segment_topk(queries, segment, qlens, carry, j0, m_total,
                      metric: str, chunk: int, excl_lo, excl_hi, k: int,
                      excl_zone):
    """``sdtw_segment`` with the top-K heap riding the chunk carry."""
    n_tiles = segment.shape[0] // chunk
    tiles = segment.reshape(n_tiles, chunk)

    def step(c, xs):
        tile, t = xs
        return sdtw_chunk_batch_topk(queries, tile, qlens, c,
                                     j0 + t * chunk, m_total, metric,
                                     excl_lo, excl_hi, k, excl_zone), None

    carry, _ = lax.scan(step, carry, (tiles, jnp.arange(n_tiles)))
    return carry


def sdtw_segment(queries, segment, qlens, carry, j0, m_total, metric: str,
                 chunk: int, excl_lo, excl_hi):
    """Stream a reference segment through the carry in ``chunk``-sized tiles.

    ``segment`` length must be a static multiple of ``chunk``; ``j0`` (the
    segment's global column offset) and ``m_total`` may be traced — this is
    what lets the sharded driver reuse the code with a per-device offset.
    Memory is O(nq·N + chunk) regardless of segment length (lax.scan).
    """
    n_tiles = segment.shape[0] // chunk
    tiles = segment.reshape(n_tiles, chunk)

    def step(c, xs):
        tile, k = xs
        return sdtw_chunk_batch(queries, tile, qlens, c, j0 + k * chunk,
                                m_total, metric, excl_lo, excl_hi), None

    carry, _ = lax.scan(step, carry, (tiles, jnp.arange(n_tiles)))
    return carry


@functools.partial(jax.jit, static_argnames=("metric", "chunk", "top_k",
                                             "return_positions"))
def sdtw_chunked(queries, reference, qlens=None, metric: str = "abs_diff",
                 chunk: int = 4096, excl_lo=None, excl_hi=None,
                 top_k: Optional[int] = None, excl_zone=None,
                 return_positions: bool = False):
    """Batched sDTW over an arbitrarily long reference in bounded memory.

    The reference is padded to a multiple of ``chunk`` and scanned tile by
    tile under a single jitted shape; only the (nq, N) boundary column is
    carried between tiles. M = millions runs in O(nq·N + chunk) live memory.

    Top-K mode: with ``top_k=k`` the carry additionally holds a per-query
    (distances, end-positions) heap (see ``repro.core.topk``); the call
    returns ``(dists (nq, k), positions (nq, k))``, best first, matches at
    least ``excl_zone + 1`` apart (``excl_zone``: scalar or (nq,); default
    half of each query's *true* length). With only
    ``return_positions=True`` the top-1 pair is returned unstacked:
    ``(dists (nq,), positions (nq,))``. The top-1 distance is bitwise-equal
    to the plain streaming result; its position is the leftmost end index
    attaining it.
    """
    nq, n = queries.shape
    m = reference.shape[0]
    acc = accum_dtype(jnp.result_type(queries, reference))
    if qlens is None:
        qlens = jnp.full((nq,), n, jnp.int32)
    if excl_lo is None:
        excl_lo = jnp.full((nq,), -1, jnp.int32)
        excl_hi = jnp.full((nq,), -1, jnp.int32)
    n_tiles = -(-m // chunk)
    r_pad = jnp.pad(reference, (0, n_tiles * chunk - m))
    carry = sdtw_carry_init(nq, n, acc)
    if top_k is None and not return_positions:
        _, best = sdtw_segment(queries, r_pad, qlens, carry, 0, m, metric,
                               chunk, excl_lo, excl_hi)
        return best
    k = 1 if top_k is None else top_k
    zone = (default_excl_zone(qlens) if excl_zone is None
            else jnp.broadcast_to(jnp.asarray(excl_zone, jnp.int32), (nq,)))
    carry = carry + topk_init(nq, k, acc)
    _, _, top_d, top_p = sdtw_segment_topk(
        queries, r_pad, qlens, carry, 0, m, metric, chunk, excl_lo,
        excl_hi, k, zone)
    if top_k is None:                       # return_positions only: top-1
        return top_d[:, 0], top_p[:, 0]
    return top_d, top_p


# ---------------------------------------------------------------------------
# Batched front-ends.
# ---------------------------------------------------------------------------

_IMPLS = {"rowscan": sdtw_rowscan, "wavefront": sdtw_wavefront}


def sdtw_batch(queries, reference, qlens=None, metric: str = "abs_diff",
               impl: str = "rowscan", excl_lo=None, excl_hi=None,
               return_positions: bool = False):
    """Batched sDTW: (nq, N) queries against a shared (M,) reference.

    Queries are embarrassingly parallel (paper §II-D) — this is MATSA's
    reference-replication / query-pipelining axis, mapped to vmap. With
    ``return_positions=True`` returns ``(dists (nq,), end_positions (nq,))``.
    """
    fn = _IMPLS[impl]
    nq, n = queries.shape
    if qlens is None:
        qlens = jnp.full((nq,), n, jnp.int32)
    if excl_lo is None:
        excl_lo = jnp.full((nq,), -1, jnp.int32)
        excl_hi = jnp.full((nq,), -1, jnp.int32)
    return jax.vmap(
        lambda qu, ql, lo, hi: fn(qu, reference, ql, metric, lo, hi,
                                  return_positions)
    )(queries, qlens, excl_lo, excl_hi)


def self_join_windows(reference, window: int, stride: int = 1):
    """Extract sliding windows (the paper's self_join mode: slices of the
    reference compared against the reference itself)."""
    m = reference.shape[0]
    starts = jnp.arange(0, m - window + 1, stride)
    idx = starts[:, None] + jnp.arange(window)[None, :]
    return reference[idx], starts
