"""Production sDTW implementations in JAX.

Two execution schemes, both using the paper's O(4M)-style linear memory
mapping (no N×M matrix is ever materialised):

``sdtw_wavefront``
    Paper-faithful anti-diagonal wavefront (MATSA §III-E): a scan over the
    N+M-1 anti-diagonals, vectorized along the diagonal. This mirrors MATSA's
    PE array, where each crossbar column is one wavefront element and data is
    shifted diagonally between steps. Sequential depth: N+M-1.

``sdtw_rowscan``
    Beyond-paper TPU-native scheme. The row recurrence

        s[j] = d[j] + min(m[j], s[j-1]),   m[j] = min(prev[j-1], prev[j])

    is a first-order *linear* recurrence over the (min, +) tropical semiring:
    with u[j] = d[j] + m[j] it is  s[j] = min(u[j], d[j] + s[j-1]), i.e. the
    tropical analogue of s_j = a_j * s_{j-1} + b_j. It therefore admits an
    associative-scan solution with O(log M) depth per row. Sequential depth:
    N (vs N+M-1) — a massive win when the reference is much longer than the
    query, which is the common case in the paper's workloads (e.g. ECG:
    M=1.8M, N=512). MATSA's bit-serial PEs cannot express this; TPU VPUs can.

Both return ``min(S[N-1, :])`` per Algorithm 1 and are validated against
the test oracle over shape/dtype/metric sweeps in the test suite.

Match spans (the start-pointer lane)
------------------------------------
Every scheme can additionally report *where* the best alignment begins:
each DP cell carries, alongside its value, the row-0 reference column
where its best path started. The combined lane is a lexicographic
``(value, start)`` pair — lower value wins, value ties take the smaller
start — which keeps the lane associative, so it rides the tropical
associative scan, the anti-diagonal shift, the chunk boundary-column
carry, and the sharded ``ppermute`` hand-off unchanged. Reported spans
are therefore deterministic and identical across every execution regime:
``start`` is the smallest row-0 column among all minimum-cost alignments
ending at the reported (leftmost-argmin) end column. Start values are
meaningless (and unspecified) when the distance saturates at BIG.

Exclusion zones (for self-join / matrix-profile-style use) are supported by
banning a column range [excl_lo, excl_hi): any path through those reference
positions is given +inf distance, which removes trivial self-matches.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .distances import (INT_FAR as _INT_FAR_INT, accum_dtype, big, lex_min,
                        pointwise_distance, sat_add, tropical_combine,
                        tropical_combine_span)
from .topk import topk_init, topk_merge

#: See ``repro.core.distances.INT_FAR`` — re-bound as an int32 scalar for
#: the jnp lanes here.
INT_FAR = np.int32(_INT_FAR_INT)

_lex_min = lex_min

# The (min,+) semiring combines live in ``repro.core.distances`` (shared
# with the Pallas kernel's work-efficient scan scheme); the old private
# names stay bound for existing importers.
_tropical_combine = tropical_combine
_tropical_combine_span = tropical_combine_span


def _masked_distance(qi, ref, metric, excl_lo, excl_hi, BIG):
    d = pointwise_distance(qi, ref, metric)
    j = jnp.arange(ref.shape[0])
    banned = (j >= excl_lo) & (j < excl_hi)
    return jnp.where(banned, BIG, d)


# ---------------------------------------------------------------------------
# Row-scan (associative scan over the tropical semiring) — beyond-paper.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("metric", "return_position",
                                             "return_spans"))
def sdtw_rowscan(query, reference, qlen=None, metric: str = "abs_diff",
                 excl_lo=None, excl_hi=None, return_position: bool = False,
                 return_spans: bool = False):
    """sDTW distance via per-row tropical associative scan.

    Args:
      query:     (N,) possibly padded query.
      reference: (M,) reference sequence.
      qlen:      actual query length (<= N); defaults to N. Padded rows are
                 ignored — the answer is min over row ``qlen - 1``.
      metric:    'abs_diff' | 'square_diff'.
      excl_lo/excl_hi: optional banned reference column range (self-join).
      return_position: also return the match end position — the leftmost
                 reference index attaining the minimum of row ``qlen - 1``.
      return_spans: return ``(distance, start, end)`` — the start-pointer
                 lane rides the associative scan as a lexicographic
                 (value, start) pair.

    Returns: scalar sDTW distance in the accumulator dtype (or a
    ``(distance, end_position)`` pair with ``return_position=True``, or a
    ``(distance, start, end)`` triple with ``return_spans=True``).
    """
    acc = accum_dtype(jnp.result_type(query, reference))
    BIG = big(acc)
    n = query.shape[0]
    m = reference.shape[0]
    qlen = jnp.asarray(n if qlen is None else qlen, jnp.int32)
    excl_lo = jnp.asarray(-1 if excl_lo is None else excl_lo, jnp.int32)
    excl_hi = jnp.asarray(-1 if excl_hi is None else excl_hi, jnp.int32)

    d0 = _masked_distance(query[0], reference, metric, excl_lo, excl_hi, BIG)
    prev = d0                                           # row 0: free start
    best0 = jnp.where(qlen == 1, jnp.min(d0), BIG)
    pos0 = jnp.where(qlen == 1, jnp.argmin(d0).astype(jnp.int32),
                     jnp.int32(-1))

    if not return_spans:
        def row_step(carry, qi):
            prev, best, pos, i = carry
            d = _masked_distance(qi, reference, metric, excl_lo, excl_hi,
                                 BIG)
            prev_shift = jnp.concatenate([jnp.full((1,), BIG, acc),
                                          prev[:-1]])
            mn = jnp.minimum(prev_shift, prev)  # min(S[i-1,j-1], S[i-1,j])
            s0 = sat_add(prev[0], d[0])         # column-0 accumulation
            u = sat_add(d, mn).at[0].set(s0)
            a = d.at[0].set(BIG)
            _, s = lax.associative_scan(_tropical_combine, (a, u))
            hit = i == qlen - 1
            best = jnp.where(hit, jnp.minimum(best, jnp.min(s)), best)
            pos = jnp.where(hit, jnp.argmin(s).astype(jnp.int32), pos)
            # Freeze rows past the true query end so `prev` stays
            # meaningless-safe.
            return (s, best, pos, i + 1), None

        (_, best, pos, _), _ = lax.scan(
            row_step, (prev, best0, pos0, jnp.int32(1)), query[1:])
        if return_position:
            return best, pos
        return best

    # Span mode: the start lane rides every cell as a lex (value, start)
    # pair. Row 0 starts fresh at its own column.
    pstart0 = jnp.arange(m, dtype=jnp.int32)
    start0 = jnp.where(qlen == 1, pos0, jnp.int32(-1))

    def row_step_span(carry, qi):
        prev, pstart, best, pos, start, i = carry
        d = _masked_distance(qi, reference, metric, excl_lo, excl_hi, BIG)
        prev_shift = jnp.concatenate([jnp.full((1,), BIG, acc), prev[:-1]])
        pstart_shift = jnp.concatenate([jnp.full((1,), INT_FAR, jnp.int32),
                                        pstart[:-1]])
        mn, mns = _lex_min(prev_shift, pstart_shift, prev, pstart)
        s0 = sat_add(prev[0], d[0])             # column-0 accumulation
        u = sat_add(d, mn).at[0].set(s0)
        su = mns.at[0].set(pstart[0])
        a = d.at[0].set(BIG)
        _, s, sstart = lax.associative_scan(_tropical_combine_span,
                                            (a, u, su))
        hit = i == qlen - 1
        j = jnp.argmin(s).astype(jnp.int32)
        best = jnp.where(hit, jnp.minimum(best, jnp.min(s)), best)
        pos = jnp.where(hit, j, pos)
        start = jnp.where(hit, sstart[j], start)
        return (s, sstart, best, pos, start, i + 1), None

    (_, _, best, pos, start, _), _ = lax.scan(
        row_step_span, (prev, pstart0, best0, pos0, start0, jnp.int32(1)),
        query[1:])
    return best, start, pos


# ---------------------------------------------------------------------------
# Anti-diagonal wavefront — paper-faithful (MATSA §III-E execution flow).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("metric", "return_position",
                                             "return_spans"))
def sdtw_wavefront(query, reference, qlen=None, metric: str = "abs_diff",
                   excl_lo=None, excl_hi=None, return_position: bool = False,
                   return_spans: bool = False):
    """sDTW distance via anti-diagonal wavefront scan (MATSA's schedule).

    Diagonal k holds cells (i, j) with i + j = k, indexed by i. The carry is
    the last two diagonals (the paper's temporal S_vectors); each step
    consumes one new reference "column" — the direct analogue of MATSA's
    diagonal row copies between crossbar columns. With
    ``return_position=True`` the leftmost end index of the best match is
    tracked alongside (diagonal k touches row qlen-1 at exactly one column,
    ``k - qlen + 1``, and k ascends — a strict improvement test keeps the
    earliest column, matching ``sdtw_rowscan``'s leftmost ``argmin``).
    ``return_spans=True`` additionally shifts the start-pointer lane with
    the diagonals and returns ``(distance, start, end)``.
    """
    acc = accum_dtype(jnp.result_type(query, reference))
    BIG = big(acc)
    n = query.shape[0]
    m = reference.shape[0]
    qlen = jnp.asarray(n if qlen is None else qlen, jnp.int32)
    excl_lo = jnp.asarray(-1 if excl_lo is None else excl_lo, jnp.int32)
    excl_hi = jnp.asarray(-1 if excl_hi is None else excl_hi, jnp.int32)

    q = query.astype(accum_dtype(query.dtype))
    # R[k - i] for i in [0, n): pad front with n-1 dummies, slice, reverse.
    r_pad = jnp.concatenate([jnp.zeros((n - 1,), reference.dtype), reference,
                             jnp.zeros((n,), reference.dtype)])
    i_idx = jnp.arange(n)

    def cell_inputs(k):
        j_idx = k - i_idx                               # ref position per cell
        valid = (j_idx >= 0) & (j_idx < m) & (i_idx < qlen)
        r_rev = lax.dynamic_slice(r_pad, (k,), (n,))[::-1]
        d = pointwise_distance(q, r_rev.astype(acc), metric)
        banned = (j_idx >= excl_lo) & (j_idx < excl_hi)
        return j_idx, valid, jnp.where(banned, BIG, d)

    if not return_spans:
        def step(carry, k):
            dm1, dm2, best, pos = carry
            j_idx, valid, d = cell_inputs(k)
            shift1 = jnp.concatenate(
                [jnp.full((1,), BIG, acc), dm1[:-1]])   # S[i-1,j]
            shift2 = jnp.concatenate(
                [jnp.full((1,), BIG, acc), dm2[:-1]])   # S[i-1,j-1]
            mins = jnp.minimum(jnp.minimum(shift2, shift1), dm1)  # +S[i,j-1]
            cur = jnp.where(i_idx == 0, d, sat_add(d, mins))
            cur = jnp.where(valid, cur, BIG)
            last = jnp.where((i_idx == qlen - 1) & valid, cur, BIG)
            lmin = jnp.min(last)
            pos = jnp.where(lmin < best, (k - qlen + 1).astype(jnp.int32),
                            pos)
            best = jnp.minimum(best, lmin)
            return (cur, dm1, best, pos), None

        init = (jnp.full((n,), BIG, acc), jnp.full((n,), BIG, acc), BIG,
                jnp.int32(-1))
        (_, _, best, pos), _ = lax.scan(step, init, jnp.arange(n + m - 1))
        if return_position:
            return best, pos
        return best

    def step_span(carry, k):
        dm1, sm1, dm2, sm2, best, pos, start = carry
        j_idx, valid, d = cell_inputs(k)
        shift1v = jnp.concatenate([jnp.full((1,), BIG, acc), dm1[:-1]])
        shift1s = jnp.concatenate([jnp.full((1,), INT_FAR, jnp.int32),
                                   sm1[:-1]])
        shift2v = jnp.concatenate([jnp.full((1,), BIG, acc), dm2[:-1]])
        shift2s = jnp.concatenate([jnp.full((1,), INT_FAR, jnp.int32),
                                   sm2[:-1]])
        mv, ms = _lex_min(shift2v, shift2s, shift1v, shift1s)
        mv, ms = _lex_min(mv, ms, dm1, sm1)
        cur = jnp.where(i_idx == 0, d, sat_add(d, mv))
        curs = jnp.where(i_idx == 0, j_idx.astype(jnp.int32), ms)
        cur = jnp.where(valid, cur, BIG)
        curs = jnp.where(valid, curs, INT_FAR)
        at_last = (i_idx == qlen - 1) & valid
        last = jnp.where(at_last, cur, BIG)
        lmin = jnp.min(last)
        lstart = jnp.min(jnp.where(at_last, curs, INT_FAR))
        improve = lmin < best
        pos = jnp.where(improve, (k - qlen + 1).astype(jnp.int32), pos)
        start = jnp.where(improve, lstart, start)
        best = jnp.minimum(best, lmin)
        return (cur, curs, dm1, sm1, best, pos, start), None

    init = (jnp.full((n,), BIG, acc), jnp.full((n,), INT_FAR, jnp.int32),
            jnp.full((n,), BIG, acc), jnp.full((n,), INT_FAR, jnp.int32),
            BIG, jnp.int32(-1), jnp.int32(-1))
    (_, _, _, _, best, pos, start), _ = lax.scan(step_span, init,
                                                 jnp.arange(n + m - 1))
    return best, start, pos


# ---------------------------------------------------------------------------
# Chunked reference streaming (boundary-column carry).
#
# The reference axis is processed in fixed-size tiles; between tiles only the
# O(N) boundary column S[:, tile_end] is carried — the direct analogue of
# MATSA's inter-subarray pass gates (§III-B). The same carry doubles as the
# inter-device protocol of ``repro.distributed.sdtw_sharded`` (ppermute the
# column to the device holding the next reference segment). In span /
# top-K mode the carry gains the start-pointer lane: the boundary column
# becomes a (value, start) pair of lanes, and the heap holds
# (dist, end, start) triples.
# ---------------------------------------------------------------------------

def sdtw_carry_init(nq: int, n: int, acc, track_start: bool = False):
    """Fresh chunk carry: ``(boundary column (nq, N), running best (nq,))``,
    or ``(bcol, bstart, best)`` with ``track_start=True``.

    BIG everywhere = "no reference columns seen yet": a BIG left/diagonal
    neighbour reproduces the global column-0 recurrence exactly (the only
    finite predecessor of cell (i, 0) is S[i-1, 0]). The start lane is
    seeded with INT_FAR so the empty carry never wins a lexicographic
    tie."""
    BIG = big(acc)
    if track_start:
        return (jnp.full((nq, n), BIG, acc),
                jnp.full((nq, n), INT_FAR, jnp.int32),
                jnp.full((nq,), BIG, acc))
    return jnp.full((nq, n), BIG, acc), jnp.full((nq,), BIG, acc)


def _chunk_masked_distance(qi, ref_chunk, metric, j0, m_total, excl_lo,
                           excl_hi, BIG):
    """Distance row for one chunk, masking by *global* reference position.

    Columns outside [0, m_total) are banned — a negative ``j0`` lets the
    pruned-search halo pad a chunk group past the left edge of the
    reference without perturbing the DP (the pad columns behave exactly
    like the implicit BIG columns before the reference starts)."""
    d = pointwise_distance(qi, ref_chunk, metric)
    j = j0 + jnp.arange(ref_chunk.shape[0])
    banned = ((j >= excl_lo) & (j < excl_hi)) | (j >= m_total) | (j < 0)
    return jnp.where(banned, BIG, d)


def sdtw_rowscan_chunk(query, ref_chunk, bcol, best, qlen=None, j0=0,
                       m_total=None, metric: str = "abs_diff",
                       excl_lo=None, excl_hi=None,
                       return_lastrow: bool = False, bstart=None,
                       clen=None):
    """One reference chunk of the row-scan, entered/exited via the carry.

    Args:
      query:     (N,) possibly padded query.
      ref_chunk: (C,) reference tile covering global columns [j0, j0 + C).
      bcol:      (N,) boundary column S[:, j0 - 1] (BIG for the first chunk).
      best:      scalar running best (min over row qlen-1 of prior chunks).
      qlen:      true query length; j0: global column offset of the chunk;
      m_total:   true reference length (columns >= m_total are masked).
      return_lastrow: also return row ``qlen - 1`` of the chunk — the match
                 score of every alignment *ending* at each of the chunk's
                 columns, which is what top-K extraction consumes.
      bstart:    (N,) start lane of the boundary column (INT_FAR for the
                 first chunk). Passing it switches on start tracking: every
                 output gains the matching start lane.
      clen:      true number of reference columns in this chunk (traced;
                 defaults to C). With ``clen`` the returned boundary column
                 is S[:, j0 + clen - 1] instead of the final chunk column,
                 so a tile right-padded past the true stream end (the pad
                 columns must be banned via ``m_total``) still exits with a
                 carry the next chunk can continue from — the streaming
                 session's one-compiled-shape-per-tile trick.

    Returns ``(new_bcol, new_best)`` with new_bcol = S[:, j0 + C - 1] (or
    at ``clen - 1``), plus the (C,) last row when ``return_lastrow``. With
    ``bstart`` the returns become
    ``(new_bcol, new_bstart, new_best[, lastrow, lastrow_starts])``.
    """
    track = bstart is not None
    if clen is None:
        pick = lambda v: v[-1]
    else:
        _cl = jnp.asarray(clen, jnp.int32) - 1
        pick = lambda v: lax.dynamic_index_in_dim(v, _cl, keepdims=False)
    acc = accum_dtype(jnp.result_type(query, ref_chunk))
    BIG = big(acc)
    n = query.shape[0]
    qlen = jnp.asarray(n if qlen is None else qlen, jnp.int32)
    m_total = (j0 + ref_chunk.shape[0] if m_total is None else m_total)
    excl_lo = jnp.asarray(-1 if excl_lo is None else excl_lo, jnp.int32)
    excl_hi = jnp.asarray(-1 if excl_hi is None else excl_hi, jnp.int32)
    bcol = bcol.astype(acc)
    best = jnp.asarray(best, acc)

    dist = functools.partial(_chunk_masked_distance, metric=metric, j0=j0,
                             m_total=m_total, excl_lo=excl_lo,
                             excl_hi=excl_hi, BIG=BIG)
    s0 = dist(query[0], ref_chunk)                  # row 0: free start
    st0 = (j0 + jnp.arange(ref_chunk.shape[0])).astype(jnp.int32)
    best = jnp.where(qlen == 1, jnp.minimum(best, jnp.min(s0)), best)

    # The (C,) last-row buffer (and the start lane) ride the carry only
    # when asked for — the plain streaming hot path stays untaxed.
    def row_step(carry, xs):
        if track:
            if return_lastrow:
                prev, pstart, best, lrow, lstart, i = carry
            else:
                prev, pstart, best, i = carry
            qi, b_left, b_diag, bs_left, bs_diag = xs
        else:
            if return_lastrow:
                prev, best, lrow, i = carry
            else:
                prev, best, i = carry
            qi, b_left, b_diag = xs      # S[i, j0-1], S[i-1, j0-1]
        d = dist(qi, ref_chunk)
        prev_sh = jnp.concatenate([b_diag[None], prev[:-1]])
        if track:
            pstart_sh = jnp.concatenate([bs_diag[None], pstart[:-1]])
            mn, mns = _lex_min(prev_sh, pstart_sh, prev, pstart)
            a, u, su = d, sat_add(d, mn), mns
            a_p, u_p, su_p = lax.associative_scan(_tropical_combine_span,
                                                  (a, u, su))
            # Fold in S[i, j0-1] with its start lane.
            s, sstart = _lex_min(u_p, su_p, sat_add(a_p, b_left), bs_left)
        else:
            mn = jnp.minimum(prev_sh, prev)  # min(S[i-1,j-1], S[i-1,j])
            a, u = d, sat_add(d, mn)
            a_p, u_p = lax.associative_scan(_tropical_combine, (a, u))
            s = jnp.minimum(u_p, sat_add(a_p, b_left))  # fold in S[i, j0-1]
        hit = i == qlen - 1
        best = jnp.where(hit, jnp.minimum(best, jnp.min(s)), best)
        if track:
            if return_lastrow:
                lrow = jnp.where(hit, s, lrow)
                lstart = jnp.where(hit, sstart, lstart)
                return ((s, sstart, best, lrow, lstart, i + 1),
                        (pick(s), pick(sstart)))
            return (s, sstart, best, i + 1), (pick(s), pick(sstart))
        if return_lastrow:
            lrow = jnp.where(hit, s, lrow)
            return (s, best, lrow, i + 1), pick(s)
        return (s, best, i + 1), pick(s)

    if track:
        bstart = bstart.astype(jnp.int32)
        xs = (query[1:], bcol[1:], bcol[:-1], bstart[1:], bstart[:-1])
    else:
        xs = (query[1:], bcol[1:], bcol[:-1])
    if return_lastrow:
        lrow0 = jnp.where(qlen == 1, s0, jnp.full_like(s0, BIG))
        if track:
            (_, _, best, lrow, lstart, _), tail = lax.scan(
                row_step, (s0, st0, best, lrow0, st0, jnp.int32(1)), xs)
        else:
            (_, best, lrow, _), tail = lax.scan(
                row_step, (s0, best, lrow0, jnp.int32(1)), xs)
    else:
        if track:
            (_, _, best, _), tail = lax.scan(
                row_step, (s0, st0, best, jnp.int32(1)), xs)
        else:
            (_, best, _), tail = lax.scan(row_step, (s0, best, jnp.int32(1)),
                                          xs)
    if track:
        tail_v, tail_s = tail
        new_bcol = jnp.concatenate([pick(s0)[None], tail_v])
        new_bstart = jnp.concatenate([pick(st0)[None], tail_s])
        if return_lastrow:
            return new_bcol, new_bstart, best, lrow, lstart
        return new_bcol, new_bstart, best
    new_bcol = jnp.concatenate([pick(s0)[None], tail])
    if return_lastrow:
        return new_bcol, best, lrow
    return new_bcol, best


def sdtw_chunk_batch(queries, ref_chunk, qlens, carry, j0, m_total,
                     metric: str, excl_lo, excl_hi, clen=None):
    """Advance the batched carry by one chunk.

    ``carry`` is ``(bcol (nq, N), best (nq,))`` or, with the start lane,
    ``(bcol, bstart, best)`` — the lane is tracked iff it is present.
    ``clen`` (traced) is the chunk's true column count — see
    ``sdtw_rowscan_chunk``."""
    if len(carry) == 3:
        bcol, bstart, best = carry
        return jax.vmap(
            lambda q, ql, bc, bs, be, lo, hi: sdtw_rowscan_chunk(
                q, ref_chunk, bc, be, ql, j0, m_total, metric, lo, hi,
                bstart=bs, clen=clen)
        )(queries, qlens, bcol, bstart, best, excl_lo, excl_hi)
    bcol, best = carry
    return jax.vmap(
        lambda q, ql, bc, be, lo, hi: sdtw_rowscan_chunk(
            q, ref_chunk, bc, be, ql, j0, m_total, metric, lo, hi,
            clen=clen)
    )(queries, qlens, bcol, best, excl_lo, excl_hi)


def sdtw_chunk_batch_topk(queries, ref_chunk, qlens, carry, j0, m_total,
                          metric: str, excl_lo, excl_hi, k: int,
                          excl_zone, excl_span: bool = False,
                          track_start: bool = False, clen=None,
                          return_lastrow: bool = False):
    """Advance the *top-K* carry by one chunk.

    The carry is ``(bcol, best, top_d, top_p, top_s)`` or — with
    ``track_start`` — ``(bcol, bstart, best, top_d, top_p, top_s)``.
    On top of the boundary-column hand-off, the carry holds a per-query
    match heap (top_d (nq, k), top_p (nq, k), top_s (nq, k)): the chunk's
    last DP row — the score of every alignment ending at each chunk
    column, with (when tracked) the start-pointer lane giving its span —
    is folded into the heap with exclusion-zone suppression
    (``repro.core.topk``; ``excl_zone`` is a per-query (nq,) radius, so a
    ragged bucket keeps each query's own zone; ``excl_span`` switches
    suppression to span overlap and requires ``track_start``). Without
    tracking, the heap's start lane stays -1 and the boundary carry keeps
    the untaxed value-only lane. End positions are global (``j0`` offsets
    the chunk), so the same code serves the in-process streamer and the
    sharded systolic pipeline. ``return_lastrow`` appends the (nq, C)
    candidate row (and, when tracked, its start lane) to the output —
    the streaming monitor's threshold-alert feed.
    """
    pos = j0 + jnp.arange(ref_chunk.shape[0], dtype=jnp.int32)
    if track_start:
        bcol, bstart, best, top_d, top_p, top_s = carry

        def one(q, ql, bc, bs, be, lo, hi, hd, hp, hs, ez):
            nbc, nbs, nbe, lrow, lstart = sdtw_rowscan_chunk(
                q, ref_chunk, bc, be, ql, j0, m_total, metric, lo, hi,
                return_lastrow=True, bstart=bs, clen=clen)
            nd, np_, ns = topk_merge(hd, hp, hs, lrow, pos, lstart, k, ez,
                                     excl_span)
            if return_lastrow:
                return nbc, nbs, nbe, nd, np_, ns, lrow, lstart
            return nbc, nbs, nbe, nd, np_, ns

        return jax.vmap(one)(queries, qlens, bcol, bstart, best, excl_lo,
                             excl_hi, top_d, top_p, top_s, excl_zone)
    assert not excl_span, "span-overlap suppression needs the start lane"
    bcol, best, top_d, top_p, top_s = carry
    no_start = jnp.full_like(pos, -1)

    def one(q, ql, bc, be, lo, hi, hd, hp, hs, ez):
        nbc, nbe, lrow = sdtw_rowscan_chunk(
            q, ref_chunk, bc, be, ql, j0, m_total, metric, lo, hi,
            return_lastrow=True, clen=clen)
        nd, np_, ns = topk_merge(hd, hp, hs, lrow, pos, no_start, k, ez)
        if return_lastrow:
            return nbc, nbe, nd, np_, ns, lrow
        return nbc, nbe, nd, np_, ns

    return jax.vmap(one)(queries, qlens, bcol, best, excl_lo, excl_hi,
                         top_d, top_p, top_s, excl_zone)


def topk_fold_lastrow(heap, lastrow, lstarts, j0, k: int, excl_zone,
                      excl_span: bool = False):
    """Fold a batched (nq, C) candidate row into the top-K heap.

    ``lastrow`` is the DP's row ``qlen - 1`` over C reference columns
    (global columns ``[j0, j0 + C)``) — exactly what the rowscan chunk
    path harvests with ``return_lastrow=True`` and what the Pallas
    kernel's in-kernel last-row capture emits — and the merge performed
    here is the *same* ``topk_merge`` call the rowscan streaming path runs
    per chunk, so a Pallas-scored tile updates the heap bitwise-
    identically to the rowscan-scored one. ``lstarts`` is the candidate
    row's start-pointer lane (``None`` when the caller does not track
    spans — the heap's start lane then stays -1); ``excl_zone`` is the
    per-query (nq,) suppression radius.
    """
    hd, hp, hs = heap
    c = lastrow.shape[1]
    pos = j0 + jnp.arange(c, dtype=jnp.int32)
    if lstarts is None:
        lstarts = jnp.full_like(lastrow, -1, dtype=jnp.int32)

    def one(hd_, hp_, hs_, lr, ls, ez):
        return topk_merge(hd_, hp_, hs_, lr, pos, ls, k, ez, excl_span)

    return jax.vmap(one)(hd.astype(lastrow.dtype), hp, hs, lastrow, lstarts,
                         jnp.asarray(excl_zone, jnp.int32))


def default_excl_zone(qlens):
    """The documented default suppression radius: half the *true* query
    length, per query (not the padded bucket width — ragged dispatch must
    match the equivalent per-query call)."""
    return jnp.maximum(1, jnp.asarray(qlens, jnp.int32) // 2)


def sdtw_segment_topk(queries, segment, qlens, carry, j0, m_total,
                      metric: str, chunk: int, excl_lo, excl_hi, k: int,
                      excl_zone, excl_span: bool = False,
                      track_start: bool = False):
    """``sdtw_segment`` with the top-K heap riding the chunk carry."""
    n_tiles = segment.shape[0] // chunk
    tiles = segment.reshape(n_tiles, chunk)

    def step(c, xs):
        tile, t = xs
        return sdtw_chunk_batch_topk(queries, tile, qlens, c,
                                     j0 + t * chunk, m_total, metric,
                                     excl_lo, excl_hi, k, excl_zone,
                                     excl_span, track_start), None

    carry, _ = lax.scan(step, carry, (tiles, jnp.arange(n_tiles)))
    return carry


def sdtw_segment(queries, segment, qlens, carry, j0, m_total, metric: str,
                 chunk: int, excl_lo, excl_hi):
    """Stream a reference segment through the carry in ``chunk``-sized tiles.

    ``segment`` length must be a static multiple of ``chunk``; ``j0`` (the
    segment's global column offset) and ``m_total`` may be traced — this is
    what lets the sharded driver reuse the code with a per-device offset.
    Memory is O(nq·N + chunk) regardless of segment length (lax.scan).
    The start lane is tracked iff the carry includes it (3-tuple).
    """
    n_tiles = segment.shape[0] // chunk
    tiles = segment.reshape(n_tiles, chunk)

    def step(c, xs):
        tile, k = xs
        return sdtw_chunk_batch(queries, tile, qlens, c, j0 + k * chunk,
                                m_total, metric, excl_lo, excl_hi), None

    carry, _ = lax.scan(step, carry, (tiles, jnp.arange(n_tiles)))
    return carry


@functools.partial(jax.jit, static_argnames=("metric", "chunk", "top_k",
                                             "return_positions",
                                             "return_spans", "excl_mode"))
def sdtw_chunked(queries, reference, qlens=None, metric: str = "abs_diff",
                 chunk: int = 4096, excl_lo=None, excl_hi=None,
                 top_k: Optional[int] = None, excl_zone=None,
                 return_positions: bool = False,
                 return_spans: bool = False, excl_mode: str = "end"):
    """Batched sDTW over an arbitrarily long reference in bounded memory.

    The reference is padded to a multiple of ``chunk`` and scanned tile by
    tile under a single jitted shape; only the (nq, N) boundary column is
    carried between tiles. M = millions runs in O(nq·N + chunk) live memory.

    Top-K mode: with ``top_k=k`` the carry additionally holds a per-query
    (distances, ends, starts) heap (see ``repro.core.topk``); the call
    returns ``(dists (nq, k), positions (nq, k))``, best first, matches at
    least ``excl_zone + 1`` apart (``excl_zone``: scalar or (nq,); default
    half of each query's *true* length — or 0 with ``excl_mode='span'``,
    which keys suppression on span overlap instead of end distance). With
    only ``return_positions=True`` the top-1 pair is returned unstacked:
    ``(dists (nq,), positions (nq,))``. ``return_spans=True`` inserts the
    start-pointer lane into the result: ``(dists, starts, ends)`` (stacked
    (nq, k) with ``top_k``). The top-1 distance is bitwise-equal to the
    plain streaming result; its position is the leftmost end index
    attaining it, and its start the smallest row-0 column among the
    minimum-cost alignments ending there.
    """
    nq, n = queries.shape
    m = reference.shape[0]
    acc = accum_dtype(jnp.result_type(queries, reference))
    if qlens is None:
        qlens = jnp.full((nq,), n, jnp.int32)
    if excl_lo is None:
        excl_lo = jnp.full((nq,), -1, jnp.int32)
        excl_hi = jnp.full((nq,), -1, jnp.int32)
    n_tiles = -(-m // chunk)
    r_pad = jnp.pad(reference, (0, n_tiles * chunk - m))
    if top_k is None and not (return_positions or return_spans):
        carry = sdtw_carry_init(nq, n, acc)
        _, best = sdtw_segment(queries, r_pad, qlens, carry, 0, m, metric,
                               chunk, excl_lo, excl_hi)
        return best
    k = 1 if top_k is None else top_k
    if excl_zone is None:
        zone = (default_excl_zone(qlens) if excl_mode == "end"
                else jnp.zeros((nq,), jnp.int32))
    else:
        zone = jnp.broadcast_to(jnp.asarray(excl_zone, jnp.int32), (nq,))
    # The start lane is only paid for when starts are consumed — spans
    # requested, or span-overlap suppression (which selects on them).
    track = return_spans or excl_mode == "span"
    carry = (sdtw_carry_init(nq, n, acc, track_start=track)
             + topk_init(nq, k, acc))
    out = sdtw_segment_topk(
        queries, r_pad, qlens, carry, 0, m, metric, chunk, excl_lo,
        excl_hi, k, zone, excl_span=(excl_mode == "span"),
        track_start=track)
    top_d, top_p, top_s = out[-3:]
    if top_k is None:                       # top-1, unstacked
        if return_spans:
            return top_d[:, 0], top_s[:, 0], top_p[:, 0]
        return top_d[:, 0], top_p[:, 0]
    if return_spans:
        return top_d, top_s, top_p
    return top_d, top_p


# ---------------------------------------------------------------------------
# Batched front-ends.
# ---------------------------------------------------------------------------

_IMPLS = {"rowscan": sdtw_rowscan, "wavefront": sdtw_wavefront}


def sdtw_batch(queries, reference, qlens=None, metric: str = "abs_diff",
               impl: str = "rowscan", excl_lo=None, excl_hi=None,
               return_positions: bool = False, return_spans: bool = False):
    """Batched sDTW: (nq, N) queries against a shared (M,) reference.

    Queries are embarrassingly parallel (paper §II-D) — this is MATSA's
    reference-replication / query-pipelining axis, mapped to vmap. With
    ``return_positions=True`` returns ``(dists (nq,), end_positions (nq,))``;
    with ``return_spans=True`` returns ``(dists, starts, ends)``.
    """
    fn = _IMPLS[impl]
    nq, n = queries.shape
    if qlens is None:
        qlens = jnp.full((nq,), n, jnp.int32)
    if excl_lo is None:
        excl_lo = jnp.full((nq,), -1, jnp.int32)
        excl_hi = jnp.full((nq,), -1, jnp.int32)
    return jax.vmap(
        lambda qu, ql, lo, hi: fn(qu, reference, ql, metric, lo, hi,
                                  return_positions, return_spans)
    )(queries, qlens, excl_lo, excl_hi)


def self_join_windows(reference, window: int, stride: int = 1):
    """Extract sliding windows (the paper's self_join mode: slices of the
    reference compared against the reference itself).

    ``starts`` are window start positions in **sample** units — for
    ``stride > 1`` they are *not* consecutive window indices. Every
    consumer deriving exclusion zones from them (``self_join_exclusion``,
    ``matsa``, ``repro.search.profile``) must stay in sample space."""
    m = reference.shape[0]
    starts = jnp.arange(0, m - window + 1, stride)
    idx = starts[:, None] + jnp.arange(window)[None, :]
    return reference[idx], starts


def self_join_exclusion(starts, window: int, zone: int = None):
    """Trivial-match exclusion band per self-join window, in sample units.

    A window occupying samples ``[s, s + window)`` must not be matched
    against itself or a near-identical shifted copy; the matrix-profile
    convention bans reference columns within ``zone`` samples (default
    ``window // 2``) of the window's own extent.

    ``starts`` must be sample positions (what ``self_join_windows``
    returns), NOT window indices — the band is then stride-invariant:
    with ``stride > 1`` each window still bans exactly
    ``[s - zone, s + window + zone)`` *samples*, never a range scaled by
    the window-index spacing. Returns ``(excl_lo, excl_hi)`` int32
    arrays for ``engine.sdtw``'s half-open banned-column range.
    """
    starts = jnp.asarray(starts, jnp.int32)
    z = jnp.int32(window // 2 if zone is None else int(zone))
    lo = jnp.maximum(starts - z, 0)
    hi = starts + jnp.int32(window) + z
    return lo, hi
