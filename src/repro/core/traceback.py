"""Alignment-path traceback in bounded memory — the anomaly-localization
subsystem.

The engine's span mode (``engine.sdtw(..., return_spans=True)``) reports
*where* the best alignment of a query lies in the reference: a
``(distance, start, end)`` triple. This module recovers the full monotone
warping path between those endpoints — which reference sample each query
sample aligned to — the output NATSA-style TSA pipelines and the paper's
anomaly workloads (§I, §V) actually consume.

The algorithm is a checkpoint-and-replay (Hirschberg-style divide) over
the ``[start, end]`` reference window only — the DP is *re-run*, never
stored globally:

  1. Forward sweep over the window, column by column, keeping one O(N)
     column alive and checkpointing the boundary column at every
     ``chunk``-th column — exactly the boundary-column carry the streaming
     engine hands between tiles.
  2. Backward sweep, last block first: each (N × chunk) block is rebuilt
     from its entry checkpoint and the path is traced through it to the
     block's left edge, then the block is dropped.

Peak memory is O(N·chunk) for the live block plus O(N·S/chunk) for the
checkpoints (S = window width ≤ span) — never O(N·M) and never O(N·S)
materialised at once.

Semantics match the engine bitwise:

  * The window DP pins the free-start row to the reported ``start`` column
    (row 0 is finite only at ``start``), so the path replayed is a
    minimum-cost alignment from ``(0, start)`` to ``(qlen-1, end)`` whose
    accumulated cost reproduces the reported distance — bitwise for int32
    (saturating adds are exact) and for integer-valued float32; for
    general float32 the engine's lanes accumulate in tree order
    (associative scan / Hillis-Steele) while the replay is sequential, so
    the two agree only to float32 ULPs — compare with a tolerance there.
  * Predecessor ties during traceback break diagonal-first, then left,
    then up — the deterministic convention the test oracle shares.

Saturated results (distance ≥ BIG) carry no meaningful span and are
rejected.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .distances import INT_BIG

#: Default traceback block width (reference columns rebuilt at once).
DEFAULT_TRACE_CHUNK = 64


def _accum(dtype):
    """numpy accumulator matching ``repro.core.distances.accum_dtype``."""
    if np.issubdtype(dtype, np.floating):
        return np.float32
    return np.int64          # int64 carries int32-sat values exactly


def _dist_col(q, rj, metric, acc):
    d = q.astype(acc) - acc(rj)
    if metric == "abs_diff":
        return np.abs(d)
    return d * d


def _sat(x, acc):
    if acc is np.float32:
        return x
    return np.minimum(x, np.int64(INT_BIG))


@dataclasses.dataclass
class AlignResult:
    """One query's best alignment: span endpoints plus the warping path.

    ``path`` is an (L, 2) int64 array of (query_row, reference_column)
    pairs, monotone in both coordinates, from ``(0, start)`` to
    ``(qlen - 1, end)``. ``distance`` is in the engine's accumulator
    dtype; replaying the pointwise distances along ``path`` in order
    reproduces it — bitwise for int32 / integer-valued float32, to
    float32 ULPs otherwise (see the module docstring).
    """
    distance: object
    start: int
    end: int
    path: np.ndarray

    @property
    def span(self):
        return (self.start, self.end)


def _forward_checkpoints(q, window, metric, acc, chunk):
    """Column sweep of the start-pinned window DP.

    Returns the list of boundary columns S[:, c*chunk - 1] entering each
    block c >= 1 (block 0 starts from the pinned column 0). Only one (N,)
    column is live at a time.
    """
    n = q.shape[0]
    BIG = acc(np.inf) if acc is np.float32 else np.int64(INT_BIG)
    col = np.empty((n,), acc)
    d0 = _dist_col(q, window[0], metric, acc)
    col[0] = d0[0]
    for i in range(1, n):                   # pinned start: column 0 accumulates
        col[i] = _sat(col[i - 1] + d0[i], acc)
    checkpoints = []
    for j in range(1, window.shape[0]):
        if j % chunk == 0:
            checkpoints.append(col.copy())
        dj = _dist_col(q, window[j], metric, acc)
        new = np.empty_like(col)
        new[0] = BIG                        # row 0 finite only at column 0
        for i in range(1, n):
            best = min(col[i - 1], col[i], new[i - 1])
            new[i] = _sat(dj[i] + best, acc) if best < BIG else BIG
        col = new
    return checkpoints, col


def _block_matrix(q, window, metric, acc, j_lo, j_hi, entry_col):
    """Materialise window columns [j_lo, j_hi) of the pinned DP from the
    entry boundary column S[:, j_lo - 1] (None for the first block)."""
    n = q.shape[0]
    BIG = acc(np.inf) if acc is np.float32 else np.int64(INT_BIG)
    S = np.full((n, j_hi - j_lo), BIG, acc)
    for c, j in enumerate(range(j_lo, j_hi)):
        dj = _dist_col(q, window[j], metric, acc)
        if j == 0:
            S[0, c] = dj[0]
            for i in range(1, n):
                S[i, c] = _sat(S[i - 1, c] + dj[i], acc)
            continue
        left = entry_col if c == 0 else S[:, c - 1]
        for i in range(1, n):
            best = min(left[i - 1], left[i], S[i - 1, c])
            S[i, c] = _sat(dj[i] + best, acc) if best < BIG else BIG
    return S


def traceback_path(query, reference, start: int, end: int, qlen=None,
                   metric: str = "abs_diff",
                   chunk: int = DEFAULT_TRACE_CHUNK) -> np.ndarray:
    """Recover the full warping path of the span ``[start, end]``.

    Re-runs the DP inside the window only, in ``chunk``-column blocks
    (peak memory O(qlen·chunk + qlen·span/chunk)), and returns the (L, 2)
    monotone path of (query_row, global_reference_column) pairs.
    Endpoint convention matches ``engine.sdtw(return_spans=True)``:
    the path starts at ``(0, start)`` and ends at ``(qlen - 1, end)``.
    """
    q = np.asarray(query)
    r = np.asarray(reference)
    if qlen is not None:
        q = q[:int(qlen)]
    n = q.shape[0]
    start, end = int(start), int(end)
    if not (0 <= start <= end < r.shape[0]):
        raise ValueError(f"invalid span [{start}, {end}] for reference of "
                         f"length {r.shape[0]} (saturated/absent matches "
                         "carry no span)")
    chunk = max(1, int(chunk))
    acc = _accum(np.result_type(q, r))
    window = r[start:end + 1]
    width = window.shape[0]

    checkpoints, _ = _forward_checkpoints(q, window, metric, acc, chunk)

    path = []
    i, j = n - 1, width - 1                 # local window coordinates
    blk = j // chunk
    while True:
        j_lo = blk * chunk
        j_hi = min(width, j_lo + chunk)
        entry = checkpoints[blk - 1] if blk > 0 else None
        S = _block_matrix(q, window, metric, acc, j_lo, j_hi, entry)
        while j >= j_lo:
            path.append((i, j))
            if i == 0:
                assert j == 0, "pinned-start traceback must end at column 0"
                break
            c = j - j_lo
            here = S[i, c]
            dij = _dist_col(q[i:i + 1], window[j], metric, acc)[0]
            left = entry if c == 0 else S[:, c - 1]
            # Diagonal-first, then left, then up — the shared convention.
            if j > 0 and _sat(left[i - 1] + dij, acc) == here:
                i, j = i - 1, j - 1
            elif j > 0 and _sat(left[i] + dij, acc) == here:
                j = j - 1
            elif _sat(S[i - 1, c] + dij, acc) == here:
                i = i - 1
            else:                           # row 0 free start: d == here
                assert j == 0 and i == 0
                break
        # Done only once (0, 0) itself is on the path — a move may *land*
        # on (0, 0) across the block boundary (chunk=1 diagonal), in which
        # case block 0 still has to replay and append it.
        if path[-1] == (0, 0):
            break
        blk -= 1
    path.reverse()
    out = np.asarray(path, np.int64)
    out[:, 1] += start                      # back to global columns
    return out


def path_cost(query, reference, path, metric: str = "abs_diff"):
    """Accumulate the pointwise distances along ``path`` in path order,
    in the engine's accumulator semantics (saturating int32 / float32).
    For the engine's own span this equals the reported distance —
    bitwise for int32 and integer-valued float32 (exact arithmetic);
    general float32 agrees to ULPs only (the engine sums in tree order,
    this replay is sequential), so compare with a tolerance there."""
    q = np.asarray(query)
    r = np.asarray(reference)
    acc = _accum(np.result_type(q, r))
    total = acc(0)
    for i, j in np.asarray(path):
        d = _dist_col(q[int(i):int(i) + 1], r[int(j)], metric, acc)[0]
        total = _sat(total + d, acc)
    if acc is np.int64:
        return np.int32(total)
    return np.float32(total)


def check_path(path, start: int, end: int, qlen: int) -> bool:
    """Structural validity: endpoints, monotone steps from
    {(1,1), (0,1), (1,0)}, contiguity."""
    p = np.asarray(path)
    if p.ndim != 2 or p.shape[1] != 2 or p.shape[0] == 0:
        return False
    if tuple(p[0]) != (0, start) or tuple(p[-1]) != (qlen - 1, end):
        return False
    steps = np.diff(p, axis=0)
    ok = ((steps[:, 0] >= 0) & (steps[:, 0] <= 1)
          & (steps[:, 1] >= 0) & (steps[:, 1] <= 1)
          & ((steps[:, 0] | steps[:, 1]) == 1))
    return bool(np.all(ok))
