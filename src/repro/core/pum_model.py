"""MATSA analytic performance/energy model (the paper's in-house simulator).

The paper evaluates MATSA with an in-house simulator that takes (workload
characteristics, MRAM device characteristics) and returns execution time and
energy (§IV-A, Fig. 8). This module reproduces that model from the
architecture description in §III.

Cost derivation (per DP cell, W-bit operands, abs_diff metric)
--------------------------------------------------------------
MATSA computes each cell with the §III-E step sequence, built from the §III-C
PUM operations. Bit-serial add/sub takes "two memory cycles per bit, divided
into four half cycles": [read+sum, write sum, read+carry, write carry] →
2 reads + 2 writes per bit. Column-lock-step control means every column takes
the *worst-case* path of data-dependent ops (e.g. abs always pays the
invert+increment).

  step                      reads            writes
  1a. subtract (dist)       2W               2W
  1b. absolute value        1 + W + 2W       W + 2W      (sign, invert, +1)
  2.  min3 = 2×(sub+select) 2(2W + W)        2(2W + W)
  3.  add (d + min)         2W               2W
  4-5. 2× diagonal copy     2W               2W          (RSA reg transfer/bit)
  6.  vertical copy         W                W           (paired half cycles)
  7.  query diagonal copy   W                W

  total (W=32):             reads = 545      writes = 544

``square_diff`` replaces 1a-1b by a bit-serial multiply (W shifted adds):
reads += 2W² - (3W+1+ ...), modelled as mult = 2W² reads + 2W² writes.

Schedule model (§III-D/E)
-------------------------
With C compute columns and reference length M: replication factor
R = max(1, C // M) (reference replicated to process R queries concurrently);
if M > C the reference is processed in ceil(M/C) sequential column-batches.
The wavefront computes one cell per column per macro-step; with query
pipelining (Fig. 7b) a replica group retires one query every N macro-steps
after a single M-step fill:

  macro_steps = ceil(n_q * N * M / C) + min(M, C) - 1     (work-conserving)
  t_cell      = reads * t_rd + writes * t_wr
  exec_time   = macro_steps * t_cell
  energy      = n_q * N * M * e_cell

The schedule is *work-conserving*: queries are re-packed into idle columns
both across replicas (C // M granularity) and across reference column-batches
(M > C). The paper's Fig. 13 shows "almost-ideal scaling" with column count
(Key Observation 6), which is only achievable work-conservingly; a
ceil-granular variant is kept for comparison (``work_conserving=False``) and
costs ~10% at the paper's dataset shapes — see EXPERIMENTS.md §Paper-validation.

Energy interpretation: Table III read/write energies are charged per
word-line activation (a bit-step activates rows shared across all columns;
2 activations per bit-step, W bit-steps per word op → 2·bits/W word-level
activations per cell ≈ 34r + 34w). This interpretation reproduces the
paper's Table VI energy ratios to within 1% and its Fig. 10 read/write split
(42/58 model vs 45/55 paper); charging per-bit instead would make MATSA
*lose* to the GPU on energy, contradicting every energy claim in the paper —
the full hypothesis trail is in EXPERIMENTS.md.

Latency/energy parameters default to the paper's bold operating point
(Table III: rd 5ns / wr 10ns, rd 50pJ / wr 70pJ).

Calibration note (recorded in EXPERIMENTS.md): the paper's Fig. 9 endpoint
ratios (4.7× / 6.5× for 10× read / write latency) imply an effective
read:write *count* ratio of ≈0.7:1, while Fig. 10's 45/55 energy split
implies ≈1.15:1 at the 50/70pJ point. A single linear model cannot satisfy
both; our first-principles counts (545:544 ≈ 1:1) sit between them, and we
report both presets.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class MramParams:
    """MRAM device operating point (Table III)."""
    read_ns: float = 5.0
    write_ns: float = 10.0
    read_pj: float = 50.0
    write_pj: float = 70.0


# Table III sweep values.
SWEEP = dict(
    read_ns=(1, 3, 5, 10, 20),
    write_ns=(1, 3, 5, 10, 20),
    read_pj=(20, 50, 100),
    write_pj=(30, 70, 400),
    num_crossbars=(128, 256, 512, 1024, 2048, 4096),
)

CROSSBAR_DIM = 256  # 256x256 cells (Table III)


@dataclasses.dataclass(frozen=True)
class OpCounts:
    reads: int
    writes: int

    @staticmethod
    def derive(width: int = 32, metric: str = "abs_diff",
               preset: str = "first_principles") -> "OpCounts":
        w = width
        if metric == "abs_diff":
            dist_r, dist_w = 2 * w + (1 + w + 2 * w), 2 * w + (w + 2 * w)
        elif metric == "square_diff":
            dist_r, dist_w = 2 * w * w, 2 * w * w  # bit-serial multiply
        else:
            raise ValueError(metric)
        min3_r = min3_w = 2 * (2 * w + w)
        add_r = add_w = 2 * w
        copy_r = copy_w = 2 * w + w + w  # 2 diag + 1 vertical + query diag
        r = dist_r + min3_r + add_r + copy_r
        wr = dist_w + min3_w + add_w + copy_w
        if preset == "first_principles":
            return OpCounts(r, wr)
        if preset == "fig9_calibrated":
            # Fig. 9 endpoint ratios imply reads:writes ≈ 0.7:1.
            return OpCounts(int(round(0.7 * wr)), wr)
        raise ValueError(preset)


@dataclasses.dataclass(frozen=True)
class MatsaVersion:
    """One of the paper's three system versions (§III-F / §IV-A)."""
    name: str
    compute_crossbars: int
    memory_crossbars: int

    @property
    def compute_columns(self) -> int:
        return self.compute_crossbars * CROSSBAR_DIM


MATSA_EMBEDDED = MatsaVersion("matsa-embedded", 128, 896)      # 32K columns
MATSA_PORTABLE = MatsaVersion("matsa-portable", 1024, 7168)    # 256K columns
MATSA_HPC = MatsaVersion("matsa-hpc", 4096, 28672)             # 1M columns
VERSIONS = {v.name: v for v in (MATSA_EMBEDDED, MATSA_PORTABLE, MATSA_HPC)}


@dataclasses.dataclass(frozen=True)
class Workload:
    ref_size: int
    query_size: int
    num_queries: int
    metric: str = "abs_diff"
    width: int = 32


@dataclasses.dataclass(frozen=True)
class SimResult:
    exec_time_s: float
    energy_j: float
    macro_steps: int
    cells: int
    read_time_frac: float
    read_energy_frac: float
    throughput_cells_per_s: float


def simulate(workload: Workload,
             columns: int,
             params: MramParams = MramParams(),
             counts: OpCounts | None = None,
             work_conserving: bool = True) -> SimResult:
    """Analytic MATSA simulation: (workload, device) → (time, energy)."""
    if counts is None:
        counts = OpCounts.derive(workload.width, workload.metric)
    n, m, nq = workload.query_size, workload.ref_size, workload.num_queries
    c = columns
    w = workload.width

    t_cell = (counts.reads * params.read_ns + counts.writes * params.write_ns) * 1e-9
    # Per-word-line-activation energy: 2 activations/bit-step, W steps/word.
    e_cell = (2.0 * counts.reads / w * params.read_pj
              + 2.0 * counts.writes / w * params.write_pj) * 1e-12

    cells = nq * n * m
    if work_conserving:
        macro_steps = math.ceil(cells / c) + min(m, c) - 1
    else:
        replication = max(1, c // m)
        col_batches = math.ceil(m / c)
        macro_steps = (math.ceil(nq / replication) * n * col_batches
                       + min(m, c) - 1)

    exec_time = macro_steps * t_cell
    energy = cells * e_cell

    rd_t = counts.reads * params.read_ns
    wr_t = counts.writes * params.write_ns
    rd_e = counts.reads * params.read_pj
    wr_e = counts.writes * params.write_pj
    return SimResult(
        exec_time_s=exec_time,
        energy_j=energy,
        macro_steps=macro_steps,
        cells=cells,
        read_time_frac=rd_t / (rd_t + wr_t),
        read_energy_frac=rd_e / (rd_e + wr_e),
        throughput_cells_per_s=cells / exec_time if exec_time else float("inf"),
    )


def endurance_writes_per_cell(params: MramParams = MramParams(),
                              years: float = 10.0,
                              counts: OpCounts | None = None) -> float:
    """§IV-B endurance estimate: writes per cell over `years` of 24/7 use.

    A cell in the working set is written once per per-bit write phase of the
    ops that touch its column slice; the paper estimates ≈4e9 writes over ten
    years for 5/10ns cells. We model: each macro-step writes `writes` bits
    spread over the ~160-cell working slice of a column (4 vectors × 32b +
    aux), i.e. writes/macro-step/cell ≈ counts.writes / 160.
    """
    if counts is None:
        counts = OpCounts.derive()
    t_cell = (counts.reads * params.read_ns + counts.writes * params.write_ns) * 1e-9
    steps = years * 365.25 * 24 * 3600 / t_cell
    return steps * counts.writes / 160.0
