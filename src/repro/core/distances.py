"""Pointwise distance metrics for (s)DTW.

The paper supports two metrics (Section II-C / Listing 1):
  * ``abs_diff``:    d(q, r) = |q - r|
  * ``square_diff``: d(q, r) = (q - r)^2

Distances are computed in an accumulator dtype wide enough for the DP sums:
float inputs accumulate in float32, integer inputs accumulate in int32 with
saturating adds against ``INT_BIG`` (the DP recurrence is monotone, so
saturation preserves argmin ordering as long as true DP values stay below
``INT_BIG``; the paper evaluates int32 sensor data whose ranges are small).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Large sentinel for integer DP lattices. Chosen so that sat_add(INT_BIG,
# INT_BIG) does not overflow int32 (2**29 + 2**29 = 2**30 < 2**31 - 1).
# Kept as a python int / numpy literal (NOT a jax array) so Pallas kernels
# can close over it without capturing a traced constant.
INT_BIG = 2**29

# Start-pointer-lane filler for cells with no (finite) path yet. Larger
# than any real reference column so a BIG-valued lane never wins a
# lexicographic tie against a genuine start. Plain python int for the same
# Pallas-closure reason as INT_BIG.
INT_FAR = 2**31 - 1

METRICS = ("abs_diff", "square_diff")


def lex_min(v1, s1, v2, s2):
    """Lexicographic min over (value, start) lane pairs: lower value wins,
    value ties take the smaller start.

    This single definition is the tie-break rule behind the cross-regime
    "spans are bitwise-identical" guarantee — every execution scheme
    (rowscan scan, wavefront shift, Pallas doubling, chunk carry) must use
    it, never a local copy."""
    take2 = (v2 < v1) | ((v2 == v1) & (s2 < s1))
    return jnp.where(take2, v2, v1), jnp.where(take2, s2, s1)


def tropical_combine(left, right):
    """Compose f_r ∘ f_l where f(x) = min(u, a + x) over the (min,+)
    semiring — the associative operator behind every sDTW row scan
    (``lax.associative_scan`` in the rowscan schedule, the Hillis-Steele
    doubling and the work-efficient scheme in the Pallas kernel). Defined
    once here so no execution regime can drift."""
    a_l, u_l = left
    a_r, u_r = right
    return sat_add(a_l, a_r), jnp.minimum(u_r, sat_add(a_r, u_l))


def tropical_combine_span(left, right):
    """``tropical_combine`` with the start lane riding the u-component:
    f(x, sx) = lexmin((u, su), (a + x, sx))."""
    a_l, u_l, s_l = left
    a_r, u_r, s_r = right
    u, s = lex_min(u_r, s_r, sat_add(a_r, u_l), s_l)
    return sat_add(a_l, a_r), u, s


def accum_dtype(dtype) -> jnp.dtype:
    """Accumulator dtype for a given input dtype."""
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.float32
    return jnp.int32


def big(dtype):
    """+infinity equivalent in the accumulator dtype (numpy scalar)."""
    if jnp.issubdtype(dtype, jnp.floating):
        return np.asarray(np.inf, dtype)
    return np.asarray(INT_BIG, dtype)


def sat_add(a, b):
    """Saturating add: exact for floats (inf-safe), clamped for ints."""
    rt = jnp.result_type(a, b)
    if jnp.issubdtype(rt, jnp.floating):
        return a + b
    return jnp.minimum(a + b, np.asarray(INT_BIG, rt))


def pointwise_distance(q, r, metric: str):
    """d(q, r) in the accumulator dtype. q/r broadcast."""
    if metric not in METRICS:
        raise ValueError(f"unknown metric {metric!r}; expected one of {METRICS}")
    acc = accum_dtype(jnp.result_type(q, r))
    qa = q.astype(acc)
    ra = r.astype(acc)
    diff = qa - ra
    if metric == "abs_diff":
        return jnp.abs(diff)
    return diff * diff
