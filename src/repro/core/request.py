"""Unified request objects — ONE argument surface for every sDTW front door.

Before this module existed, the ~15 overlapping keyword arguments of
``engine.sdtw``, ``engine.stream`` and ``repro.search.search_topk`` were
triple-duplicated, each front door re-implementing its own validation
with slowly drifting defaults, docstrings and error messages. Now each
front door is a *thin shim* that builds a frozen request dataclass and
funnels it through one shared validator/dispatcher:

  * ``SdtwRequest``   — an offline call: ``op='sdtw'`` (the engine) or
    ``op='search_topk'`` (the pruned search layer). ``request.run()``
    validates, normalizes and dispatches; it is byte-for-byte the same
    code path as the keyword front doors, so kwargs callers and serve-
    tier tenants (``repro.serve``) hit identical argument semantics.
  * ``StreamRequest`` — an online session: ``request.open()`` returns
    the ``StreamSession`` / ``ShardedStreamSession`` that
    ``engine.stream`` would have built.

The request object is also the serve tier's *queue element*: an
admission-controlled router (``repro.serve``) enqueues validated
requests and coalesces the ones that share a ``coalesce_key()`` into one
batched engine call per ragged power-of-two bucket — the same
bucketing/compile-cache key derivation the engine itself uses, defined
here exactly once.

Argument semantics documented once (the front-door docstrings point
here):

  * ``excl_zone`` — top-K suppression radius between reported matches.
    ``None`` derives the default *per query*: half of each query's true
    length with ``excl_mode='end'`` (the matrix-profile convention), 0
    with ``excl_mode='span'`` (span-overlap suppression already keeps
    events sample-disjoint). A scalar applies to every query. A
    per-query ``(nq,)`` array is honoured by the single-device chunked
    path only — the sharded driver and the search layer take scalars
    (the search layer historically *silently truncated* arrays via
    ``int()``; the shared validator now rejects them loudly there).
  * ``excl_lo``/``excl_hi`` — banned reference column range (self-join
    exclusion); must be given together on every front door (a one-sided
    zone would silently ban nothing).
  * ``top_k``/``k`` — matches per query; positive int. The search front
    door spells it ``k``; both land in ``SdtwRequest.top_k``.

Validation error messages are preserved byte-for-byte from the pre-
request front doors (tests pin them); where two front doors historically
used *different* words for the same rejection, the shared validator
keeps each op's message under one roof instead of quietly changing a
public contract — the drift is now visible in one file instead of three.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

#: Engine execution regimes (``SdtwRequest.impl``).
IMPLS = ("auto", "rowscan", "wavefront", "pallas", "chunked", "sharded")
#: Streaming session regimes (``StreamRequest.impl``).
STREAM_IMPLS = ("auto", "rowscan", "pallas", "sharded")
#: Top-K suppression modes.
EXCL_MODES = ("end", "span")
#: Search-layer DP backends (``SdtwRequest.engine_impl``).
SEARCH_ENGINE_IMPLS = ("auto", "rowscan", "pallas")
#: Request operations.
OPS = ("sdtw", "search_topk")
#: Autotuning modes (``SdtwRequest.tune``) — see ``repro.tune``.
TUNE_MODES = ("model", "measure", "off")


def resolve_mesh(mesh, mesh_shape):
    """``mesh_shape=`` builds the (dp, mp) mesh via the distributed layer."""
    if mesh_shape is None:
        return mesh
    if mesh is not None:
        raise ValueError("pass either mesh= (a prebuilt jax Mesh) or "
                         "mesh_shape= (built for you), not both")
    from repro.distributed.sharding import get_mesh
    return get_mesh(mesh_shape)


def _check_forced_impl(impl: str, *, mesh, chunk, top_k):
    """Explicit precedence for forced impls: reject contradictory args
    instead of silently ignoring them."""
    if impl in ("rowscan", "wavefront"):
        if mesh is not None:
            raise ValueError(
                f"impl={impl!r} is an in-core path but mesh= requests the "
                "sharded driver; drop mesh= or use impl='sharded'/'auto'")
        if chunk is not None:
            raise ValueError(
                f"impl={impl!r} runs in-core and would ignore chunk=; drop "
                "chunk= or use impl='chunked'/'pallas' for streaming")
        if top_k is not None:
            raise ValueError(
                f"impl={impl!r} does not carry a top-K heap; top_k= runs on "
                "the chunked/sharded streaming paths (impl='auto' routes it)")
    elif impl == "pallas":
        if mesh is not None:
            raise ValueError(
                "impl='pallas' is single-device; drop mesh= or use "
                "impl='sharded'/'auto'")
        if top_k is not None:
            raise ValueError(
                "impl='pallas' reports the single best match "
                "(return_positions/return_spans); offline top_k= runs on "
                "the chunked/sharded streaming paths — the kernel's "
                "last-row capture serves top-K via repro.search "
                "(engine_impl='pallas') and streaming sessions")
    elif impl == "chunked" and mesh is not None:
        raise ValueError(
            "impl='chunked' is single-device; drop mesh= or use "
            "impl='sharded'/'auto'")


def _check_sharded_args(*, mesh, impl, n_micro, excl_zone, top_k,
                        return_positions):
    """Loud rejection of options the sharded path cannot honour — instead
    of silently mishandling them deep in the driver."""
    sharded = mesh is not None or impl == "sharded"
    if n_micro is not None and not sharded:
        raise ValueError("n_micro= schedules the sharded systolic "
                         "pipeline; pass mesh=/mesh_shape= (or "
                         "impl='sharded') or drop n_micro=")
    if not sharded:
        return
    if excl_zone is not None and np.ndim(excl_zone) != 0:
        raise ValueError("the sharded driver takes a scalar excl_zone (or "
                         "None for the per-query default); per-query zone "
                         "arrays run on the single-device chunked path "
                         "(drop mesh=)")
    if return_positions and top_k is not None:
        raise ValueError("top_k= already returns (dists, positions) on "
                         "the sharded driver; return_positions=True adds "
                         "nothing there — drop it (or use return_spans=)")


def _check_common(req, *, op_word: str = "top_k"):
    """Checks every offline op shares (messages pinned by the test
    matrix). ``op_word`` keeps the historically different spelling of the
    top-K argument per front door ('top_k' for the engine, 'k' for the
    search layer)."""
    if (req.excl_lo is None) != (req.excl_hi is None):
        raise ValueError("excl_lo and excl_hi must be given together "
                         "(a one-sided zone would silently ban nothing)")
    if req.top_k is not None and (not isinstance(req.top_k, int)
                                  or req.top_k < 1):
        raise ValueError(f"{op_word} must be a positive int, got "
                         f"{req.top_k!r}")
    if isinstance(req.queries, (list, tuple)) and req.qlens is not None:
        raise ValueError("qlens is implied by ragged (list) queries")


def _mesh_fingerprint(mesh):
    """Hashable identity of a mesh for compile-cache / coalesce keys —
    axis names + device ids, as the sharded pipeline cache keys it."""
    if mesh is None:
        return None
    try:
        return (tuple(mesh.axis_names),
                tuple(int(d.id) for d in np.ravel(mesh.devices)))
    except AttributeError:                     # test doubles / stubs
        return ("mesh", id(mesh))


def _scalar_or_id(val):
    """Coalesce-key component for a possibly-array argument: scalar
    values coalesce by value, arrays never coalesce across requests."""
    if val is None:
        return None
    if np.ndim(val) == 0:
        return ("s", float(np.asarray(val)))
    return ("a", id(val))


def _reject_unknown(cls, kwargs):
    fields = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(kwargs) - fields)
    if unknown:
        raise ValueError(
            f"unknown {cls.__name__} argument(s) {unknown}; valid "
            f"arguments are {sorted(fields)}")


@dataclasses.dataclass(frozen=True)
class SdtwRequest:
    """One offline sDTW call, as data.

    ``op='sdtw'`` runs the engine (``repro.core.engine``);
    ``op='search_topk'`` runs the pruned search layer (``repro.search``).
    The fields are exactly the union of the two front doors' keyword
    arguments — see their docstrings (and the module docstring above for
    the semantics shared verbatim between them). Search-only fields
    (``prune``, ``span_cap``, ``normalize``, ``cache``, ``ref_key``,
    ``engine_impl``) are ignored by ``op='sdtw'``.

    Frozen: a request is immutable after construction; derive variants
    with ``dataclasses.replace``. ``run()`` validates, normalizes and
    dispatches — the same path every keyword front door takes.
    """
    queries: Any = None
    reference: Any = None
    qlens: Any = None
    metric: str = "abs_diff"
    impl: str = "auto"
    chunk: Optional[int] = None
    excl_lo: Any = None
    excl_hi: Any = None
    mesh: Any = None
    mesh_shape: Any = None
    ref_axis: str = "ref"
    n_micro: Optional[int] = None
    top_k: Optional[int] = None
    return_positions: bool = False
    return_spans: bool = False
    excl_zone: Any = None
    excl_mode: str = "end"
    block_q: Optional[int] = None
    block_m: Optional[int] = None
    #: Autotuning mode: 'model' (cost model + tuning table fill unset
    #: knobs, the default), 'measure' (refine the bucket on-device once
    #: per process before dispatch), 'off' (legacy hand-tuned constants).
    #: Bitwise-safe: int32 results are invariant to it.
    tune: str = "model"
    #: Return ``(result, repro.tune.DispatchDecision)`` instead of the
    #: bare result. Rejected by the serve tier (a coalesced batch has no
    #: single per-request decision) and for ragged lists.
    explain: bool = False
    op: str = "sdtw"
    # --- serve-tier-only -------------------------------------------------
    # Scheduling metadata for the admission queue (``repro.serve``):
    # higher ``priority`` drains sooner (aging keeps lower classes
    # starvation-free), ``tenant`` scopes per-tenant quotas. Both are
    # ignored by ``run()`` and deliberately excluded from
    # ``coalesce_key()`` — requests from different tenants/priorities
    # still share one merged engine call once drained into a window.
    priority: int = 0
    tenant: Any = None
    # --- search_topk-only ------------------------------------------------
    prune: bool = True
    span_cap: Optional[int] = None
    normalize: bool = False
    cache: Any = None
    ref_key: Any = None
    engine_impl: str = "auto"

    @classmethod
    def from_kwargs(cls, **kwargs) -> "SdtwRequest":
        """Build a request from a kwargs dict, rejecting unknown keys
        loudly (the dict-driven serve tier's entry point — a typo'd
        argument must not be silently dropped)."""
        _reject_unknown(cls, kwargs)
        return cls(**kwargs)

    # ------------------------------------------------------------------
    # the shared validator
    # ------------------------------------------------------------------

    def validate(self) -> "SdtwRequest":
        """Run every front-door check (shape-independent ones; the
        dispatcher still owns shape-dependent rejections such as
        pallas × exclusion zones after ``impl='auto'`` resolution).
        Returns ``self`` so calls chain."""
        if self.op not in OPS:
            raise ValueError(f"op must be one of {OPS}, got {self.op!r}")
        if not isinstance(self.priority, int) \
                or isinstance(self.priority, bool):
            raise ValueError(f"priority must be an int (higher drains "
                             f"sooner), got {self.priority!r}")
        try:
            hash(self.tenant)
        except TypeError:
            raise ValueError(f"tenant must be hashable (it keys per-tenant "
                             f"quotas), got {type(self.tenant).__name__}") \
                from None
        if self.tune not in TUNE_MODES:
            raise ValueError(f"tune must be one of {TUNE_MODES}, got "
                             f"{self.tune!r}")
        if self.op == "search_topk":
            return self._validate_search()
        if self.impl not in IMPLS:
            raise ValueError(f"impl must be one of {IMPLS}, got "
                             f"{self.impl!r}")
        if self.excl_mode not in EXCL_MODES:
            raise ValueError(f"excl_mode must be one of {EXCL_MODES}, got "
                             f"{self.excl_mode!r}")
        _check_common(self, op_word="top_k")
        if self.excl_mode == "span" and self.top_k is None:
            raise ValueError("excl_mode='span' only affects top-K "
                             "suppression; pass top_k= (k=1 selection "
                             "never suppresses)")
        mesh = resolve_mesh(self.mesh, self.mesh_shape)
        _check_forced_impl(self.impl, mesh=mesh, chunk=self.chunk,
                           top_k=self.top_k)
        _check_sharded_args(mesh=mesh, impl=self.impl, n_micro=self.n_micro,
                            excl_zone=self.excl_zone, top_k=self.top_k,
                            return_positions=self.return_positions)
        return self

    def _validate_search(self) -> "SdtwRequest":
        # The search front door spells top_k as ``k`` and keeps its own
        # historical message wording — pinned by the existing test matrix.
        if self.top_k is None or not isinstance(self.top_k, int) \
                or self.top_k < 1:
            raise ValueError(f"k must be a positive int, got {self.top_k!r}")
        if self.excl_mode not in EXCL_MODES:
            raise ValueError(f"excl_mode must be 'end' or 'span', got "
                             f"{self.excl_mode!r}")
        if (self.excl_lo is None) != (self.excl_hi is None):
            raise ValueError("excl_lo and excl_hi must be given together "
                             "(a one-sided zone would silently ban nothing)")
        if self.excl_zone is not None and np.ndim(self.excl_zone) != 0:
            raise ValueError("search_topk takes a scalar excl_zone (or "
                             "None for the per-query default); per-query "
                             "zone arrays run on engine.sdtw's chunked "
                             "path")
        mesh = resolve_mesh(self.mesh, self.mesh_shape)
        if mesh is not None and self.prune:
            raise ValueError("mesh= runs the sharded engine over every "
                             "chunk; pass prune=False explicitly (the LB "
                             "cascade is single-process)")
        if self.engine_impl not in SEARCH_ENGINE_IMPLS:
            raise ValueError(f"engine_impl must be 'auto', 'rowscan' or "
                             f"'pallas', got {self.engine_impl!r}")
        has_excl = self.excl_lo is not None or self.excl_hi is not None
        if self.engine_impl == "pallas" and has_excl:
            raise ValueError("the pallas kernel does not support per-query "
                             "exclusion zones; use engine_impl='rowscan'")
        if isinstance(self.queries, (list, tuple)) and self.qlens is not None:
            raise ValueError("qlens is implied by ragged (list) queries")
        return self

    def normalized(self) -> "SdtwRequest":
        """Validate and return the canonical form: ``mesh_shape`` resolved
        to a concrete mesh (so equal-meaning requests compare equal where
        it matters — dispatch and coalescing see one field, not two)."""
        self.validate()
        if self.mesh_shape is None:
            return self
        return dataclasses.replace(
            self, mesh=resolve_mesh(self.mesh, self.mesh_shape),
            mesh_shape=None)

    # ------------------------------------------------------------------
    # dispatch
    # ------------------------------------------------------------------

    def run(self):
        """Validate, normalize and execute — byte-identical to calling the
        keyword front door (``engine.sdtw`` / ``search_topk``), because
        the front doors are shims over this very method."""
        req = self.normalized()
        if req.op == "search_topk":
            from repro.search import search as search_mod
            return search_mod._execute_search(req)
        from repro.core import engine
        return engine._execute_sdtw(req)

    # ------------------------------------------------------------------
    # serve-tier key derivation (bucketing / compile cache / coalescing)
    # ------------------------------------------------------------------

    def coalesce_key(self, ref_id=None):
        """Hashable key under which requests may share one batched engine
        call: everything that selects a compiled executable or changes
        per-query semantics, *except* the queries themselves. Two
        requests with equal keys (and the same reference, folded in via
        ``ref_id``) can be concatenated into one ragged batch — the
        engine's power-of-two bucketing then guarantees one dispatch per
        bucket per microbatch window, and per-query independence of the
        DP guarantees bitwise-identical answers to per-client calls.

        Per-query exclusion arrays (``excl_lo/hi/zone`` as arrays) key by
        object identity, i.e. such requests never coalesce with others.
        """
        return (self.op, self.metric, self.impl, self.chunk,
                self.top_k, self.return_positions, self.return_spans,
                self.excl_mode, self.block_q, self.block_m, self.tune,
                self.ref_axis, self.n_micro,
                _mesh_fingerprint(resolve_mesh(self.mesh, self.mesh_shape)),
                _scalar_or_id(self.excl_zone),
                _scalar_or_id(self.excl_lo), _scalar_or_id(self.excl_hi),
                bool(self.prune) if self.op == "search_topk" else None,
                self.span_cap if self.op == "search_topk" else None,
                bool(self.normalize) if self.op == "search_topk" else None,
                self.engine_impl if self.op == "search_topk" else None,
                ref_id)


@dataclasses.dataclass(frozen=True)
class StreamRequest:
    """One streaming session, as data — ``engine.stream``'s argument
    surface. ``open()`` validates and returns the live session
    (``StreamSession`` or ``ShardedStreamSession``), exactly as the
    keyword front door would. See ``SdtwRequest`` (and the module
    docstring) for the shared field semantics."""
    queries: Any = None
    qlens: Any = None
    metric: str = "abs_diff"
    impl: str = "auto"
    chunk: Optional[int] = None
    mesh: Any = None
    mesh_shape: Any = None
    ref_axis: str = "ref"
    n_micro: Optional[int] = None
    top_k: Optional[int] = None
    excl_zone: Any = None
    excl_mode: str = "end"
    return_spans: bool = False
    return_positions: bool = False
    excl_lo: Any = None
    excl_hi: Any = None
    prune: bool = False
    span_cap: Optional[int] = None
    alert_threshold: Any = None
    on_alert: Any = None
    cache: Any = None
    ref_key: Any = None
    block_q: Optional[int] = None
    block_m: Optional[int] = None

    @classmethod
    def from_kwargs(cls, **kwargs) -> "StreamRequest":
        """Build a request from a kwargs dict, rejecting unknown keys
        loudly."""
        _reject_unknown(cls, kwargs)
        return cls(**kwargs)

    # ------------------------------------------------------------------
    # the shared validator
    # ------------------------------------------------------------------

    def validate(self) -> "StreamRequest":
        """Front-door checks for ``engine.stream`` — the sharded-session
        rejections (pruning/alerts/cache/span_cap are single-process) and
        the session-argument checks, in the pre-request order so error
        messages land unchanged."""
        if self.impl not in STREAM_IMPLS:
            raise ValueError(
                f"impl must be 'auto', 'rowscan', 'pallas' or 'sharded' "
                f"for streaming, got {self.impl!r}")
        mesh = resolve_mesh(self.mesh, self.mesh_shape)
        if self.n_micro is not None and mesh is None \
                and self.impl != "sharded":
            raise ValueError("n_micro= schedules the sharded systolic "
                             "pipeline; pass mesh=/mesh_shape= (or "
                             "impl='sharded') or drop n_micro=")
        if mesh is not None or self.impl == "sharded":
            if self.prune:
                raise ValueError("mesh= streams every chunk; the LB cascade "
                                 "is single-process (drop prune=True)")
            if self.alert_threshold is not None or self.on_alert is not None:
                raise ValueError("alerts are single-process; drop mesh=")
            if self.cache is not None or self.ref_key is not None:
                raise ValueError("the envelope cache is built by the "
                                 "single-process pruning path; "
                                 "cache=/ref_key= have no effect on a "
                                 "sharded session (drop them or drop "
                                 "mesh=)")
            if self.span_cap is not None:
                raise ValueError("span_cap= only bounds the pruned path; a "
                                 "sharded session streams every chunk "
                                 "exactly")
            return self
        return self.validate_session()

    def validate_session(self) -> "StreamRequest":
        """The single-process session checks — ``StreamSession.__init__``
        delegates here, so a directly-constructed session and the
        ``engine.stream`` front door cannot drift."""
        if self.excl_mode not in EXCL_MODES:
            raise ValueError(f"excl_mode must be one of {EXCL_MODES}, got "
                             f"{self.excl_mode!r}")
        if self.top_k is not None and (not isinstance(self.top_k, int)
                                       or self.top_k < 1):
            raise ValueError(f"top_k must be a positive int, got "
                             f"{self.top_k!r}")
        if self.excl_mode == "span" and self.top_k is None \
                and not self.return_spans:
            raise ValueError("excl_mode='span' only affects top-K "
                             "suppression; pass top_k=")
        if (self.excl_lo is None) != (self.excl_hi is None):
            raise ValueError("excl_lo and excl_hi must be given together")
        if self.prune and self.top_k is None:
            raise ValueError("prune=True reports the top-K heap only; "
                             "pass top_k=")
        if self.prune and self.alert_threshold is not None:
            raise ValueError("alerts need every tile's candidate row, "
                             "which pruning skips; use prune=False for a "
                             "threshold monitor")
        if self.impl == "pallas" and self.excl_lo is not None:
            raise ValueError("the pallas kernel does not support "
                             "exclusion zones; use impl='rowscan'")
        if self.chunk is not None and int(self.chunk) < 1:
            raise ValueError(f"chunk must be >= 1, got {int(self.chunk)}")
        return self

    # ------------------------------------------------------------------
    # dispatch
    # ------------------------------------------------------------------

    def open(self):
        """Validate and open the session — byte-identical to
        ``engine.stream(**kwargs)``, which is a shim over this method."""
        import jax

        from repro.stream import ShardedStreamSession, StreamSession
        self.validate()
        mesh = resolve_mesh(self.mesh, self.mesh_shape)
        if mesh is not None or self.impl == "sharded":
            return ShardedStreamSession(
                self.queries, qlens=self.qlens, metric=self.metric,
                mesh=mesh, axis=self.ref_axis, chunk=self.chunk,
                n_micro=self.n_micro, top_k=self.top_k,
                excl_zone=self.excl_zone, excl_mode=self.excl_mode,
                return_spans=self.return_spans,
                return_positions=self.return_positions,
                excl_lo=self.excl_lo, excl_hi=self.excl_hi)
        impl = self.impl
        if impl == "auto":
            # Only per-query exclusion zones force the rowscan tile loop —
            # top-K heaps, threshold alerts and online pruning all score
            # on the kernel's in-kernel last-row capture.
            impl = ("pallas" if jax.default_backend() == "tpu"
                    and self.excl_lo is None else "rowscan")
        return StreamSession(
            self.queries, qlens=self.qlens, metric=self.metric,
            chunk=self.chunk, impl=impl, top_k=self.top_k,
            excl_zone=self.excl_zone, excl_mode=self.excl_mode,
            return_spans=self.return_spans,
            return_positions=self.return_positions,
            excl_lo=self.excl_lo, excl_hi=self.excl_hi, prune=self.prune,
            span_cap=self.span_cap, alert_threshold=self.alert_threshold,
            on_alert=self.on_alert, cache=self.cache, ref_key=self.ref_key,
            block_q=self.block_q, block_m=self.block_m)
