"""Unified sDTW engine — the single front door every caller routes through.

``sdtw()`` hides four execution regimes behind one call:

  * ``rowscan`` / ``wavefront`` — the in-core JAX schedules of
    ``repro.core.sdtw`` (tropical associative scan vs the paper-faithful
    anti-diagonal wavefront).
  * ``pallas``  — the TPU kernel of ``repro.kernels.sdtw`` (interpret mode
    off-TPU).
  * ``chunked`` — reference streaming: the reference is processed in
    fixed-size tiles carrying only the O(N) boundary column between tiles
    (MATSA's inter-subarray pass gates, §III-B), so the paper's M≈1.8M ECG
    references run in bounded memory under one jitted shape.
  * ``sharded`` — the reference axis is sharded across devices
    (``repro.distributed.sdtw_sharded``); the chunk carry is exchanged
    between neighbouring devices with ``lax.ppermute``.

Dispatch rules (``impl="auto"``):

  1. ``mesh`` given (or ``impl="sharded"``)        → sharded driver.
  2. ``chunk`` given explicitly                    → chunked streaming.
  3. TPU backend and no exclusion zone             → Pallas kernel (its
     tile grid already streams arbitrary M).
  4. M ≥ ``CHUNK_THRESHOLD``                       → chunked streaming.
  5. M < 2·N (reference not much longer than query)→ wavefront (diagonal
     depth N+M-1 ≈ cheap; avoids the associative-scan constant).
  6. otherwise                                     → rowscan.

Rules 1–4 are *structural* (hard constraints); rules 5–6 are the legacy
``tune='off'`` heuristics.  Under the engine default ``tune='model'`` the
in-core choice comes from the ``repro.tune`` cost-model ranking (or a
tuning-table hit) instead — on measured CPU shapes that picks the
wavefront well beyond the ``M < 2N`` line — and the chunked / sharded /
pallas paths take their ``chunk`` / ``n_micro`` / block shapes from the
same oracle.  ``sdtw(..., explain=True)`` returns the
``repro.tune.DispatchDecision`` explaining what won and why.

``impl=`` is an escape hatch that forces any of the five paths. Forcing a
path makes argument precedence *explicit*: arguments that belong to a
different path are rejected instead of silently ignored —
``impl='rowscan'`` (or ``'wavefront'``) with ``mesh=`` or ``chunk=`` is a
``ValueError``, as is ``mesh=`` with any non-sharded forced impl. The one
deliberate combination is ``impl='pallas'`` with ``chunk=``: the reference
is streamed through the kernel's chunk-carry protocol *on the device*.
For references up to ``PALLAS_FUSED_MAX`` samples this is the single-
launch grid path (the kernel's own sequential tile dimension already
streams HBM→VMEM tile by tile, so one ``pallas_call`` covers any
device-resident reference and ``chunk`` is advisory); beyond it, the
reference is scanned in ``chunk``-sized statically-shaped slices inside
one jitted ``lax.scan`` (``_pallas_scan_streamed``) — the carry never
leaves the device and there is exactly one compiled executable regardless
of reference length or tail size (the tail slice is right-padded and
masked via the kernel's traced ``ref_len``). ``_pallas_host_loop`` keeps
the legacy one-launch-per-slice loop — not dispatched automatically, but
kept callable as the semantic reference the device-side paths are
differential-tested against, and for callers that must slice a
host-resident reference themselves; it pads the ragged tail to the
static ``chunk`` shape, so it too emits exactly one compiled executable.

Match spans: ``return_spans=True`` returns ``(dists, starts, ends)`` on
every path — the DP carries a start-pointer lane (each cell remembers the
row-0 reference column its best alignment began at, lexicographic
tie-break toward the smaller start; see ``repro.core.sdtw``), so the span
is exact and identical across all five regimes. ``engine.align()`` goes
one step further and recovers the full warping path by re-running the DP
inside the span window only (``repro.core.traceback``).

Top-K search mode: ``top_k=k`` returns the k best *match end positions*
per query, ``(dists (nq, k), positions (nq, k))`` — or
``(dists, starts, ends)`` with ``return_spans=True`` — best first, with
an exclusion zone (``excl_zone``, default: half of each query's true
length) keeping the matches non-trivially distinct;
``excl_mode='span'`` keys the suppression on span overlap instead of end
distance (default zone 0: reported events share no reference samples).
The heap rides the chunk boundary carry (streaming/sharded paths).
``return_positions=True`` alone returns the top-1 pair
``(dists (nq,), positions (nq,))`` and is supported on every path (the
Pallas kernel tracks the best end position in its carry).

The layers above compose this machinery rather than re-deriving it:
``repro.search.search_topk`` puts the LB cascade in front of the chunked
top-K path, and ``repro.search.profile.matrix_profile`` (with its
streaming twin ``repro.stream.StreamProfile``) runs the self-join matrix
profile — every sliding window of a series as a query batch against the
series itself, trivial matches banned via per-query ``excl_lo/excl_hi``
in sample units — returning motif pairs and top-K discords.

Ragged batches: a *list* of 1-D queries with mixed lengths is bucketed —
each query is padded up to the next power-of-two length (min
``MIN_BUCKET``) and queries sharing a bucket run as one batched call. The
compiled-shape count is therefore O(log max_len) across the process
lifetime instead of one shape per distinct query length.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .distances import accum_dtype, big
from .request import SdtwRequest, StreamRequest, resolve_mesh
from .sdtw import sdtw_batch, sdtw_chunked
from .traceback import AlignResult, DEFAULT_TRACE_CHUNK, traceback_path

CHUNK_THRESHOLD = 1 << 17   # auto-switch to streaming above this M
DEFAULT_CHUNK = 8192        # tile size for chunked/sharded streaming
MIN_BUCKET = 16             # smallest ragged-batch padded length

#: Largest reference (samples) the pallas+chunk path runs as one
#: single-launch kernel grid; longer references stream through the
#: device-side ``lax.scan`` of chunk-sized slices. 4M samples = 16 MB of
#: int32 — far below HBM, but the single-launch grid is unrolled per tile
#: at trace time, so the cap also bounds compile time.
PALLAS_FUSED_MAX = 1 << 22


def choose_impl_explained(nq: int, n: int, m: int, *,
                          backend: Optional[str] = None, mesh=None,
                          chunk: Optional[int] = None,
                          has_exclusion: bool = False,
                          top_k: Optional[int] = None, tune: str = "off",
                          metric: str = "abs_diff",
                          dtype: str = "int32") -> tuple:
    """``choose_impl`` with its reasoning: ``(impl, source, reason,
    candidates)`` where ``source``/``candidates`` follow
    ``repro.tune.DispatchDecision``.  The structural rules (mesh / top-K /
    explicit chunk / TPU / memory bound) are hard constraints and fire
    before any scoring; with ``tune != 'off'`` the remaining in-core
    choice (wavefront vs rowscan) comes from the cost-model ranking (or a
    tuning-table hit) instead of the legacy ``M < 2N`` rule."""
    if mesh is not None:
        return ("sharded", "structural",
                "mesh shards the reference axis", ())
    if top_k is not None:
        # The top-K heap rides the chunk boundary carry — streaming path.
        return ("chunked", "structural",
                "top-K heap rides the chunk boundary carry", ())
    if chunk is not None:
        return ("chunked", "structural",
                "explicit chunk forces streaming", ())
    backend = jax.default_backend() if backend is None else backend
    if backend == "tpu" and not has_exclusion:
        # The Pallas kernel streams arbitrary M through its own tile grid —
        # long references stay on the kernel path on the target hardware.
        return ("pallas", "structural",
                "TPU backend (kernel grid streams any M)", ())
    if m >= CHUNK_THRESHOLD:
        return ("chunked", "structural",
                f"M >= CHUNK_THRESHOLD (1<<{CHUNK_THRESHOLD.bit_length() - 1})",
                ())
    if tune != "off":
        from repro.tune import rank_incore
        res = rank_incore(nq, n, m, backend=backend, metric=metric,
                          dtype=dtype, mode=tune)
        impl = res.config.impl
        if impl in ("rowscan", "wavefront"):
            return (impl, res.source,
                    f"in-core ranking ({res.source})", res.candidates)
    if m < 2 * n:
        return ("wavefront", "legacy",
                "M < 2N: diagonal depth is cheap", ())
    return ("rowscan", "legacy", "default in-core schedule", ())


def choose_impl(nq: int, n: int, m: int, *, backend: Optional[str] = None,
                mesh=None, chunk: Optional[int] = None,
                has_exclusion: bool = False,
                top_k: Optional[int] = None, tune: str = "off",
                metric: str = "abs_diff", dtype: str = "int32") -> str:
    """The ``impl="auto"`` dispatch rule (documented in the module docstring,
    exercised directly by the tests).  ``tune`` defaults to ``'off'``
    (the legacy heuristics) here; ``SdtwRequest`` defaults to
    ``'model'``."""
    return choose_impl_explained(
        nq, n, m, backend=backend, mesh=mesh, chunk=chunk,
        has_exclusion=has_exclusion, top_k=top_k, tune=tune,
        metric=metric, dtype=dtype)[0]


def _bucket_len(length: int) -> int:
    return max(MIN_BUCKET, 1 << max(0, int(length) - 1).bit_length())


def _is_ragged(queries) -> bool:
    if isinstance(queries, (list, tuple)):
        return True
    return False


def _normalize_excl(val, nq: int):
    if val is None:
        return jnp.full((nq,), -1, jnp.int32)
    arr = jnp.asarray(val, jnp.int32)
    if arr.ndim == 0:
        arr = jnp.full((nq,), arr, jnp.int32)
    return arr


#: Kept as module aliases — the canonical definitions live with the
#: shared validator in ``repro.core.request``.
_resolve_mesh = resolve_mesh


def sdtw(queries, reference, qlens=None, *, metric: str = "abs_diff",
         impl: str = "auto", chunk: Optional[int] = None,
         excl_lo=None, excl_hi=None, mesh=None, mesh_shape=None,
         ref_axis: str = "ref", n_micro: Optional[int] = None,
         top_k: Optional[int] = None, return_positions: bool = False,
         return_spans: bool = False, excl_zone: Optional[int] = None,
         excl_mode: str = "end", block_q: Optional[int] = None,
         block_m: Optional[int] = None, tune: str = "model",
         explain: bool = False):
    """Subsequence-DTW distances of ``queries`` against ``reference``.

    Args:
      queries:   (nq, N) padded array, a single (N,) query, or a list of
                 1-D queries with mixed lengths (ragged — bucketed dispatch).
      reference: (M,) reference sequence.
      qlens:     (nq,) true query lengths for padded 2-D input.
      metric:    'abs_diff' | 'square_diff'.
      impl:      one of ``IMPLS``; 'auto' applies the dispatch rules above.
                 A forced impl rejects arguments belonging to another path.
      chunk:     reference tile size for the chunked/sharded paths (forces
                 streaming under 'auto'); with ``impl='pallas'`` the
                 reference is streamed through the kernel in chunk-sized
                 slices via the kernel carry.
      excl_lo/excl_hi: banned reference column range per query (self-join
                 exclusion zones); scalar or (nq,).
      mesh:      a jax Mesh whose ``ref_axis`` shards the reference axis;
                 forces the sharded driver under 'auto'. A 2-D (dp, mp)
                 mesh (see ``repro.distributed.get_mesh``) additionally
                 shards query microbatches over the dp rows.
      mesh_shape: build the mesh for you — an int, ``(mp,)`` or
                 ``(dp, mp)`` tuple (``-1`` wildcards allowed) passed to
                 ``repro.distributed.get_mesh``; mutually exclusive with
                 ``mesh``.
      n_micro:   microbatch count per dp row for the sharded systolic
                 schedule (default fills the pipeline); results are
                 bitwise-invariant to it for int32 inputs.
      top_k:     return the k best match end positions per query as
                 ``(dists (nq, k), positions (nq, k))``, best first,
                 suppressed so positions are > ``excl_zone`` apart.
      return_positions: return ``(dists, end_positions)`` (top-1); without
                 ``top_k`` this works on every impl.
      return_spans: return ``(dists, starts, ends)`` — the start-pointer
                 lane; works on every impl, stacks to (nq, k) with top_k.
      excl_zone: top-K suppression radius — semantics documented ONCE on
                 ``repro.core.request`` (shared with ``search_topk``):
                 ``None`` derives per query (half the true length, or 0
                 with ``excl_mode='span'``); scalar applies to all;
                 per-query (nq,) arrays run on the single-device chunked
                 path only.
      excl_mode: 'end' suppresses matches whose *end* is within
                 ``excl_zone``; 'span' suppresses matches whose spans
                 overlap (widened by ``excl_zone``). Only meaningful with
                 ``top_k``.
      block_q/block_m: Pallas kernel block shape (``None`` = auto-tuned
                 per backend; see ``repro.kernels.sdtw.resolve_blocks``).
      tune:      ``'model'`` (default) fills unset performance knobs —
                 in-core impl choice, kernel blocks, chunk size, sharded
                 microbatch count — from the ``repro.tune`` oracle (table
                 hit, else analytical cost model); ``'measure'``
                 additionally refines this bucket with a short on-device
                 measured search *before* dispatch (once per process per
                 bucket); ``'off'`` keeps the legacy hand-tuned
                 constants.  Explicit kwargs always win, and every tuned
                 knob is bitwise-safe: int32 results are invariant to it.
      explain:   return ``(result, decision)`` where ``decision`` is the
                 ``repro.tune.DispatchDecision`` describing which impl
                 and knobs ran and why (not supported for ragged lists —
                 buckets may dispatch differently).

    Returns: (nq,) distances in the accumulator dtype — scalar for a single
    1-D query; a (dists, positions) pair or (dists, starts, ends) triple
    in the positions/spans modes.
    """
    return SdtwRequest(
        queries=queries, reference=reference, qlens=qlens, metric=metric,
        impl=impl, chunk=chunk, excl_lo=excl_lo, excl_hi=excl_hi,
        mesh=mesh, mesh_shape=mesh_shape, ref_axis=ref_axis,
        n_micro=n_micro, top_k=top_k, return_positions=return_positions,
        return_spans=return_spans, excl_zone=excl_zone,
        excl_mode=excl_mode, block_q=block_q, block_m=block_m,
        tune=tune, explain=explain, op="sdtw").run()


def _execute_sdtw(req: SdtwRequest):
    """The engine dispatcher behind ``SdtwRequest.run()`` — the request is
    already validated/normalized (mesh resolved); this owns shape
    resolution, ``impl='auto'`` dispatch, and the execution paths."""
    (queries, reference, qlens, metric, impl, chunk, excl_lo, excl_hi,
     mesh, ref_axis, n_micro, top_k, return_positions, return_spans,
     excl_zone, excl_mode, block_q, block_m, tune, explain) = (
        req.queries, req.reference, req.qlens, req.metric, req.impl,
        req.chunk, req.excl_lo, req.excl_hi, req.mesh, req.ref_axis,
        req.n_micro, req.top_k, req.return_positions, req.return_spans,
        req.excl_zone, req.excl_mode, req.block_q, req.block_m,
        req.tune, req.explain)

    if _is_ragged(queries):
        if explain:
            raise ValueError(
                "explain=True is not supported for ragged query lists — "
                "each bucket may dispatch differently; call per bucket")
        return _sdtw_ragged(queries, reference, metric=metric, impl=impl,
                            chunk=chunk, excl_lo=excl_lo, excl_hi=excl_hi,
                            mesh=mesh, ref_axis=ref_axis, n_micro=n_micro,
                            top_k=top_k,
                            return_positions=return_positions,
                            return_spans=return_spans, excl_zone=excl_zone,
                            excl_mode=excl_mode,
                            block_q=block_q, block_m=block_m, tune=tune)

    queries = jnp.asarray(queries)
    reference = jnp.asarray(reference)
    single = queries.ndim == 1
    if single:
        queries = queries[None, :]
    nq, n = queries.shape
    m = reference.shape[0]
    if qlens is not None:
        qlens = jnp.asarray(qlens, jnp.int32)
    dtype = str(jnp.result_type(queries, reference))

    if tune == "measure":
        # Measured refinement must never run inside a trace — resolve the
        # bucket eagerly here (once per process per bucket; the LRU and
        # the process table absorb repeats), then every downstream
        # consultation is a table hit.
        from repro.tune import resolve as _tune_resolve
        _tune_resolve(nq, n, m, metric=metric, dtype=dtype,
                      mode="measure", span=return_spans)

    has_excl = excl_lo is not None or excl_hi is not None
    if impl == "auto":
        impl, source, reason, candidates = choose_impl_explained(
            nq, n, m, mesh=mesh, chunk=chunk, has_exclusion=has_excl,
            top_k=top_k, tune=tune, metric=metric, dtype=dtype)
    else:
        source, reason, candidates = (
            "explicit", "impl forced by the caller", ())
    if impl == "pallas" and has_excl:
        raise ValueError("the pallas kernel does not support exclusion "
                         "zones; use impl='rowscan' or 'chunked'")

    config: dict = {}
    if impl in ("rowscan", "wavefront"):
        lo = _normalize_excl(excl_lo, nq) if has_excl else None
        hi = _normalize_excl(excl_hi, nq) if has_excl else None
        out = sdtw_batch(queries, reference, qlens, metric, impl, lo, hi,
                         return_positions=return_positions,
                         return_spans=return_spans)
    elif impl == "pallas":
        from repro.kernels.sdtw import resolve_blocks, sdtw_pallas
        if explain:
            interp = jax.default_backend() != "tpu"
            rbq, rbm, rscheme, rrt = resolve_blocks(
                nq, m, block_q, block_m, None, None, interp, n=n,
                metric=metric, dtype=dtype, tune=tune, span=return_spans)
            config = {"block_q": rbq, "block_m": rbm,
                      "scan_scheme": rscheme, "row_tile": rrt}
        if chunk is None:
            out = sdtw_pallas(queries, reference, qlens, metric,
                              block_q=block_q, block_m=block_m,
                              return_positions=return_positions,
                              return_spans=return_spans, tune=tune)
        else:
            out = _pallas_streamed(queries, reference, qlens, metric, chunk,
                                   block_q, block_m, return_positions,
                                   return_spans, tune=tune)
    elif impl == "chunked":
        if chunk is None and tune != "off":
            from repro.tune import tuned_chunk
            chunk = tuned_chunk(nq, n, m, metric=metric, dtype=dtype,
                                mode=tune)
        config = {"chunk": chunk or DEFAULT_CHUNK}
        out = sdtw_chunked(queries, reference, qlens, metric,
                           chunk or DEFAULT_CHUNK,
                           _normalize_excl(excl_lo, nq),
                           _normalize_excl(excl_hi, nq),
                           top_k=top_k, excl_zone=excl_zone,
                           return_positions=return_positions,
                           return_spans=return_spans, excl_mode=excl_mode)
    else:  # sharded
        from repro.distributed.sdtw_sharded import sdtw_sharded
        if n_micro is None and tune != "off" and mesh is not None:
            from repro.tune import resolve_n_micro
            sizes = dict(mesh.shape)
            n_mp = int(sizes.pop(ref_axis, 1))
            n_dp = int(np.prod(list(sizes.values()))) if sizes else 1
            n_micro = resolve_n_micro(nq, n_dp, n_mp, n=n, m=m,
                                      metric=metric, dtype=dtype,
                                      mode=tune)
        config = {"chunk": chunk or DEFAULT_CHUNK, "n_micro": n_micro}
        out = sdtw_sharded(queries, reference, qlens, metric=metric,
                           mesh=mesh, axis=ref_axis, n_micro=n_micro,
                           chunk=chunk or DEFAULT_CHUNK,
                           excl_lo=_normalize_excl(excl_lo, nq),
                           excl_hi=_normalize_excl(excl_hi, nq),
                           top_k=top_k, excl_zone=excl_zone,
                           return_positions=return_positions,
                           return_spans=return_spans, excl_mode=excl_mode)
    if single:
        out = (tuple(o[0] for o in out) if isinstance(out, tuple)
               else out[0])
    if explain:
        from repro.tune import DispatchDecision
        score = candidates[0][1] if candidates else None
        return out, DispatchDecision(impl=impl, source=source,
                                     reason=reason, config=config,
                                     score_us=score, candidates=candidates)
    return out


def stream(queries, *, qlens=None, metric: str = "abs_diff",
           impl: str = "auto", chunk: Optional[int] = None,
           mesh=None, mesh_shape=None,
           ref_axis: str = "ref", n_micro: Optional[int] = None,
           top_k: Optional[int] = None, excl_zone=None,
           excl_mode: str = "end", return_spans: bool = False,
           return_positions: bool = False, excl_lo=None, excl_hi=None,
           prune: bool = False, span_cap: Optional[int] = None,
           alert_threshold=None, on_alert=None, cache=None, ref_key=None,
           block_q: Optional[int] = None, block_m: Optional[int] = None):
    """Open an online monitoring session: the streaming front door.

    Where ``sdtw()`` answers one offline query batch against a
    materialized reference, ``stream()`` returns a session whose
    ``feed(chunk)`` consumes the reference as an unbounded chunk sequence
    — the chunk-carry protocol run forever. ``session.results()`` at any
    point equals the offline ``sdtw()`` / ``search_topk()`` answer over
    the samples fed so far (bitwise for int32, any feed partition);
    ``session.snapshot()`` / ``StreamSession.restore()`` give
    fault-tolerant serving. See ``repro.stream`` for the session API
    (top-K heaps, online LB pruning, threshold alerts).

    Dispatch: ``mesh=`` (or ``impl='sharded'``) returns the
    ``ShardedStreamSession`` (per-device chunk streams through the
    ppermute carry); ``impl='pallas'`` streams fed chunks through the
    kernel's carry entry/exit — including top-K heaps, threshold alerts
    and online pruning, which score on the kernel's in-kernel last-row
    capture; ``'auto'`` picks the Pallas path on a TPU backend (rowscan
    only for per-query exclusion zones, which the kernel does not
    support) and the rowscan tile loop everywhere else. ``chunk`` is the
    internal DP tile size (compile granularity) — feed granularity is
    independent of it.
    """
    return StreamRequest(
        queries=queries, qlens=qlens, metric=metric, impl=impl,
        chunk=chunk, mesh=mesh, mesh_shape=mesh_shape, ref_axis=ref_axis,
        n_micro=n_micro, top_k=top_k, excl_zone=excl_zone,
        excl_mode=excl_mode, return_spans=return_spans,
        return_positions=return_positions, excl_lo=excl_lo,
        excl_hi=excl_hi, prune=prune, span_cap=span_cap,
        alert_threshold=alert_threshold, on_alert=on_alert, cache=cache,
        ref_key=ref_key, block_q=block_q, block_m=block_m).open()


def align(queries, reference, qlens=None, *, metric: str = "abs_diff",
          impl: str = "auto", chunk: Optional[int] = None, mesh=None,
          ref_axis: str = "ref",
          trace_chunk: int = DEFAULT_TRACE_CHUNK):
    """Best alignment of each query, localized: span plus full warping path.

    Composes two bounded-memory passes: (1) the engine's span mode finds
    ``(distance, start, end)`` on whatever execution path ``impl``/"auto"
    selects; (2) ``repro.core.traceback`` re-runs the DP inside the
    ``[start, end]`` window only, in ``trace_chunk``-column blocks, to
    recover the monotone warping path (peak memory
    O(N·trace_chunk + N·span/trace_chunk), never O(N·M)).

    Returns an ``AlignResult`` for a single 1-D query, else a list of
    ``AlignResult`` (one per query, in caller order; ragged lists
    accepted). Saturated matches (distance ≥ BIG — no finite alignment,
    e.g. fully banned reference) come back with ``start = end = -1`` and
    ``path = None``.
    """
    ragged = _is_ragged(queries)
    single = not ragged and jnp.asarray(queries).ndim == 1
    d, s, e = sdtw(queries, reference, qlens, metric=metric, impl=impl,
                   chunk=chunk, mesh=mesh, ref_axis=ref_axis,
                   return_spans=True)
    if single:
        d, s, e = d[None], s[None], e[None]
    d = np.asarray(d)
    s = np.asarray(s, np.int64)
    e = np.asarray(e, np.int64)
    if ragged:
        qs = [np.asarray(q) for q in queries]
        lens = [len(q) for q in qs]
    else:
        q2 = np.asarray(queries)
        q2 = q2[None, :] if q2.ndim == 1 else q2
        lens = (np.full((q2.shape[0],), q2.shape[1], np.int64)
                if qlens is None else np.asarray(qlens, np.int64))
        qs = [q2[i, :int(lens[i])] for i in range(q2.shape[0])]
    ref_np = np.asarray(reference)
    BIG = big(d.dtype)
    results = []
    for i, q in enumerate(qs):
        if d[i] >= BIG or s[i] < 0:
            results.append(AlignResult(distance=d[i], start=-1, end=-1,
                                       path=None))
            continue
        path = traceback_path(q, ref_np, int(s[i]), int(e[i]),
                              metric=metric, chunk=trace_chunk)
        results.append(AlignResult(distance=d[i], start=int(s[i]),
                                   end=int(e[i]), path=path))
    return results[0] if single else results


def _pallas_streamed(queries, reference, qlens, metric, chunk, block_q,
                     block_m, return_positions, return_spans=False,
                     tune: str = "off"):
    """The ``impl='pallas'`` + ``chunk=`` dispatcher.

    Device-resident references (M ≤ ``PALLAS_FUSED_MAX``) take the
    single-launch grid path — the kernel's own sequential tile dimension
    already streams the reference HBM→VMEM with the boundary column in
    VMEM scratch, so one compiled program covers the whole reference and
    ``chunk`` is advisory. Longer references run the device-side
    ``lax.scan`` over chunk-sized slices. Either way the carry never
    leaves the device and exactly one executable is compiled."""
    m = reference.shape[0]
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    if m <= PALLAS_FUSED_MAX:
        from repro.kernels.sdtw import sdtw_pallas
        return sdtw_pallas(queries, reference, qlens, metric,
                           block_q=block_q, block_m=block_m,
                           return_positions=return_positions,
                           return_spans=return_spans, tune=tune)
    return _pallas_scan_streamed(queries, reference, qlens, metric,
                                 chunk=chunk, block_q=block_q,
                                 block_m=block_m,
                                 return_positions=return_positions,
                                 return_spans=return_spans, tune=tune)


def _unpack_pallas_carry(carry, return_positions, return_spans):
    if return_spans:
        _, _, best, pos, start = carry
        return best, start, pos
    _, best, pos = carry
    return (best, pos) if return_positions else best


@functools.partial(jax.jit, static_argnames=(
    "metric", "chunk", "block_q", "block_m", "return_positions",
    "return_spans", "tune"))
def _pallas_scan_streamed(queries, reference, qlens, metric, *, chunk,
                          block_q, block_m, return_positions,
                          return_spans, tune: str = "off"):
    """Device-side chunk pipeline: one jitted ``lax.scan`` over statically-
    shaped reference slices, chaining the kernel carry in device memory —
    no host hop between slices, one compile for any reference length (the
    ragged tail is right-padded to ``chunk`` and masked via the kernel's
    traced ``ref_len``). The start-pointer lane joins the carry only when
    spans are requested (the plain stream keeps the untaxed
    (bcol, best, pos) triple)."""
    from repro.kernels.sdtw import pallas_carry_init, sdtw_pallas
    b, n = queries.shape
    m = reference.shape[0]
    n_slices = -(-m // chunk)
    r_pad = jnp.pad(reference, (0, n_slices * chunk - m))
    slices = r_pad.reshape(n_slices, chunk)
    offs = jnp.arange(n_slices, dtype=jnp.int32) * chunk
    clens = jnp.minimum(chunk, m - offs)
    acc = accum_dtype(jnp.result_type(queries, reference))
    carry = pallas_carry_init(b, n, acc, track_start=return_spans)

    def step(c, xs):
        sl, off, cl = xs
        _, c2 = sdtw_pallas(queries, sl, qlens, metric, block_q=block_q,
                            block_m=block_m, carry=c, ref_offset=off,
                            ref_len=cl, return_carry=True,
                            track_start=return_spans, tune=tune)
        return c2, None

    carry, _ = jax.lax.scan(step, carry, (slices, offs, clens))
    return _unpack_pallas_carry(carry, return_positions, return_spans)


def _pallas_host_loop(queries, reference, qlens, metric, chunk, block_q=None,
                      block_m=None, return_positions=False,
                      return_spans=False):
    """Legacy host-side chunk loop: one kernel launch per slice, the carry
    round-tripping through dispatch. Not dispatched automatically (both
    device-side paths subsume it); kept as the semantic reference the
    device-side paths are differential-tested against, and for callers
    that need to slice a host-resident reference themselves.

    The ragged tail slice is right-padded to the static ``chunk`` shape
    and masked via the kernel's traced ``ref_len``, and the first slice
    starts from an explicit ``pallas_carry_init`` pytree, so the loop
    emits exactly one compiled executable for any reference length (the
    old version sliced ``reference[off:off + chunk]`` raw, recompiling for
    every distinct tail length)."""
    from repro.kernels.sdtw import pallas_carry_init, sdtw_pallas
    b, n = queries.shape
    m = reference.shape[0]
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    acc = accum_dtype(jnp.result_type(queries, reference))
    carry = pallas_carry_init(b, n, acc, track_start=return_spans)
    for off in range(0, m, chunk):
        sl = reference[off:off + chunk]
        cl = sl.shape[0]
        if cl < chunk:
            sl = jnp.pad(sl, (0, chunk - cl))
        _, carry = sdtw_pallas(queries, sl, qlens, metric, block_q=block_q,
                               block_m=block_m, carry=carry, ref_offset=off,
                               ref_len=cl, return_carry=True,
                               track_start=return_spans)
    return _unpack_pallas_carry(carry, return_positions, return_spans)


def bucketize(lengths: Sequence[int]):
    """Group query indices by padded power-of-two bucket length.

    Returns {bucket_len: [query indices]} with deterministic ordering.
    """
    buckets: dict[int, list[int]] = {}
    for i, L in enumerate(lengths):
        if L < 1:
            raise ValueError(f"query {i} is empty")
        buckets.setdefault(_bucket_len(L), []).append(i)
    return dict(sorted(buckets.items()))


def pad_ragged_bucket(qs, idxs, blen: int):
    """Materialise one ragged bucket: zero-pad the selected queries to
    (len(idxs), blen) in their promoted dtype.

    Shared by the engine's ragged dispatch and ``repro.search`` so the
    pad/bucket conventions cannot drift. Returns numpy
    ``(padded, qlens)``.
    """
    dtype = np.result_type(*[qs[i].dtype for i in idxs])
    padded = np.zeros((len(idxs), blen), dtype)
    qlens = np.empty((len(idxs),), np.int32)
    for k, i in enumerate(idxs):
        padded[k, :len(qs[i])] = qs[i]
        qlens[k] = len(qs[i])
    return padded, qlens


def _sdtw_ragged(queries, reference, *, metric, impl, chunk, excl_lo,
                 excl_hi, mesh, ref_axis, n_micro=None, top_k,
                 return_positions, return_spans, excl_zone, excl_mode,
                 block_q, block_m, tune: str = "model"):
    """Bucketed dispatch for mixed-length query sets."""
    qs = [np.asarray(q) for q in queries]
    nq = len(qs)
    n_out = (3 if return_spans
             else 2 if (top_k is not None or return_positions) else 1)
    if nq == 0:
        kk = 1 if top_k is None else top_k
        shape = (0,) if top_k is None else (0, kk)
        empty = tuple(jnp.zeros(shape, jnp.int32) for _ in range(n_out))
        return empty if n_out > 1 else empty[0]
    lo = np.asarray(_normalize_excl(excl_lo, nq))
    hi = np.asarray(_normalize_excl(excl_hi, nq))
    buckets = bucketize([len(q) for q in qs])

    outs = [[None] * nq for _ in range(n_out)]
    for blen, idxs in buckets.items():
        padded, qlens = pad_ragged_bucket(qs, idxs, blen)
        res = sdtw(jnp.asarray(padded), reference, jnp.asarray(qlens),
                   metric=metric, impl=impl, chunk=chunk,
                   excl_lo=jnp.asarray(lo[idxs]),
                   excl_hi=jnp.asarray(hi[idxs]),
                   mesh=mesh, ref_axis=ref_axis, n_micro=n_micro,
                   top_k=top_k,
                   return_positions=return_positions,
                   return_spans=return_spans, excl_zone=excl_zone,
                   excl_mode=excl_mode, block_q=block_q, block_m=block_m,
                   tune=tune)
        res = res if isinstance(res, tuple) else (res,)
        for t in range(n_out):
            for k, i in enumerate(idxs):
                outs[t][i] = res[t][k]
    stacked = tuple(jnp.stack(o) for o in outs)
    return stacked if n_out > 1 else stacked[0]
