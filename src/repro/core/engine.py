"""Unified sDTW engine — the single front door every caller routes through.

``sdtw()`` hides four execution regimes behind one call:

  * ``rowscan`` / ``wavefront`` — the in-core JAX schedules of
    ``repro.core.sdtw`` (tropical associative scan vs the paper-faithful
    anti-diagonal wavefront).
  * ``pallas``  — the TPU kernel of ``repro.kernels.sdtw`` (interpret mode
    off-TPU).
  * ``chunked`` — reference streaming: the reference is processed in
    fixed-size tiles carrying only the O(N) boundary column between tiles
    (MATSA's inter-subarray pass gates, §III-B), so the paper's M≈1.8M ECG
    references run in bounded memory under one jitted shape.
  * ``sharded`` — the reference axis is sharded across devices
    (``repro.distributed.sdtw_sharded``); the chunk carry is exchanged
    between neighbouring devices with ``lax.ppermute``.

Dispatch rules (``impl="auto"``):

  1. ``mesh`` given (or ``impl="sharded"``)        → sharded driver.
  2. ``chunk`` given explicitly                    → chunked streaming.
  3. TPU backend and no exclusion zone             → Pallas kernel (its
     tile grid already streams arbitrary M).
  4. M ≥ ``CHUNK_THRESHOLD``                       → chunked streaming.
  5. M < 2·N (reference not much longer than query)→ wavefront (diagonal
     depth N+M-1 ≈ cheap; avoids the associative-scan constant).
  6. otherwise                                     → rowscan.

``impl=`` is an escape hatch that forces any of the five paths. Forcing a
path makes argument precedence *explicit*: arguments that belong to a
different path are rejected instead of silently ignored —
``impl='rowscan'`` (or ``'wavefront'``) with ``mesh=`` or ``chunk=`` is a
``ValueError``, as is ``mesh=`` with any non-sharded forced impl. The one
deliberate combination is ``impl='pallas'`` with ``chunk=``: the reference
is streamed through the kernel in ``chunk``-sized slices via the kernel's
chunk-carry protocol (one kernel launch per slice), which is how a
TPU-resident caller bounds the per-launch reference footprint.

Match spans: ``return_spans=True`` returns ``(dists, starts, ends)`` on
every path — the DP carries a start-pointer lane (each cell remembers the
row-0 reference column its best alignment began at, lexicographic
tie-break toward the smaller start; see ``repro.core.sdtw``), so the span
is exact and identical across all five regimes. ``engine.align()`` goes
one step further and recovers the full warping path by re-running the DP
inside the span window only (``repro.core.traceback``).

Top-K search mode: ``top_k=k`` returns the k best *match end positions*
per query, ``(dists (nq, k), positions (nq, k))`` — or
``(dists, starts, ends)`` with ``return_spans=True`` — best first, with
an exclusion zone (``excl_zone``, default: half of each query's true
length) keeping the matches non-trivially distinct;
``excl_mode='span'`` keys the suppression on span overlap instead of end
distance (default zone 0: reported events share no reference samples).
The heap rides the chunk boundary carry (streaming/sharded paths).
``return_positions=True`` alone returns the top-1 pair
``(dists (nq,), positions (nq,))`` and is supported on every path (the
Pallas kernel tracks the best end position in its carry).

Ragged batches: a *list* of 1-D queries with mixed lengths is bucketed —
each query is padded up to the next power-of-two length (min
``MIN_BUCKET``) and queries sharing a bucket run as one batched call. The
compiled-shape count is therefore O(log max_len) across the process
lifetime instead of one shape per distinct query length.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .distances import big
from .sdtw import sdtw_batch, sdtw_chunked
from .traceback import AlignResult, DEFAULT_TRACE_CHUNK, traceback_path

IMPLS = ("auto", "rowscan", "wavefront", "pallas", "chunked", "sharded")
EXCL_MODES = ("end", "span")

CHUNK_THRESHOLD = 1 << 17   # auto-switch to streaming above this M
DEFAULT_CHUNK = 8192        # tile size for chunked/sharded streaming
MIN_BUCKET = 16             # smallest ragged-batch padded length


def choose_impl(nq: int, n: int, m: int, *, backend: Optional[str] = None,
                mesh=None, chunk: Optional[int] = None,
                has_exclusion: bool = False,
                top_k: Optional[int] = None) -> str:
    """The ``impl="auto"`` dispatch rule (documented in the module docstring,
    exercised directly by the tests)."""
    if mesh is not None:
        return "sharded"
    if top_k is not None:
        # The top-K heap rides the chunk boundary carry — streaming path.
        return "chunked"
    if chunk is not None:
        return "chunked"
    backend = jax.default_backend() if backend is None else backend
    if backend == "tpu" and not has_exclusion:
        # The Pallas kernel streams arbitrary M through its own tile grid —
        # long references stay on the kernel path on the target hardware.
        return "pallas"
    if m >= CHUNK_THRESHOLD:
        return "chunked"
    if m < 2 * n:
        return "wavefront"
    return "rowscan"


def _bucket_len(length: int) -> int:
    return max(MIN_BUCKET, 1 << max(0, int(length) - 1).bit_length())


def _is_ragged(queries) -> bool:
    if isinstance(queries, (list, tuple)):
        return True
    return False


def _normalize_excl(val, nq: int):
    if val is None:
        return jnp.full((nq,), -1, jnp.int32)
    arr = jnp.asarray(val, jnp.int32)
    if arr.ndim == 0:
        arr = jnp.full((nq,), arr, jnp.int32)
    return arr


def _check_forced_impl(impl: str, *, mesh, chunk, top_k):
    """Explicit precedence for forced impls: reject contradictory args
    instead of silently ignoring them."""
    if impl in ("rowscan", "wavefront"):
        if mesh is not None:
            raise ValueError(
                f"impl={impl!r} is an in-core path but mesh= requests the "
                "sharded driver; drop mesh= or use impl='sharded'/'auto'")
        if chunk is not None:
            raise ValueError(
                f"impl={impl!r} runs in-core and would ignore chunk=; drop "
                "chunk= or use impl='chunked'/'pallas' for streaming")
        if top_k is not None:
            raise ValueError(
                f"impl={impl!r} does not carry a top-K heap; top_k= runs on "
                "the chunked/sharded streaming paths (impl='auto' routes it)")
    elif impl == "pallas":
        if mesh is not None:
            raise ValueError(
                "impl='pallas' is single-device; drop mesh= or use "
                "impl='sharded'/'auto'")
        if top_k is not None:
            raise ValueError(
                "the pallas kernel tracks only the best end position "
                "(return_positions=True); top_k= runs on the chunked/"
                "sharded streaming paths")
    elif impl == "chunked" and mesh is not None:
        raise ValueError(
            "impl='chunked' is single-device; drop mesh= or use "
            "impl='sharded'/'auto'")


def sdtw(queries, reference, qlens=None, *, metric: str = "abs_diff",
         impl: str = "auto", chunk: Optional[int] = None,
         excl_lo=None, excl_hi=None, mesh=None, ref_axis: str = "ref",
         top_k: Optional[int] = None, return_positions: bool = False,
         return_spans: bool = False, excl_zone: Optional[int] = None,
         excl_mode: str = "end", block_q: int = 8, block_m: int = 512):
    """Subsequence-DTW distances of ``queries`` against ``reference``.

    Args:
      queries:   (nq, N) padded array, a single (N,) query, or a list of
                 1-D queries with mixed lengths (ragged — bucketed dispatch).
      reference: (M,) reference sequence.
      qlens:     (nq,) true query lengths for padded 2-D input.
      metric:    'abs_diff' | 'square_diff'.
      impl:      one of ``IMPLS``; 'auto' applies the dispatch rules above.
                 A forced impl rejects arguments belonging to another path.
      chunk:     reference tile size for the chunked/sharded paths (forces
                 streaming under 'auto'); with ``impl='pallas'`` the
                 reference is streamed through the kernel in chunk-sized
                 slices via the kernel carry.
      excl_lo/excl_hi: banned reference column range per query (self-join
                 exclusion zones); scalar or (nq,).
      mesh:      a jax Mesh whose ``ref_axis`` shards the reference axis;
                 forces the sharded driver under 'auto'.
      top_k:     return the k best match end positions per query as
                 ``(dists (nq, k), positions (nq, k))``, best first,
                 suppressed so positions are > ``excl_zone`` apart.
      return_positions: return ``(dists, end_positions)`` (top-1); without
                 ``top_k`` this works on every impl.
      return_spans: return ``(dists, starts, ends)`` — the start-pointer
                 lane; works on every impl, stacks to (nq, k) with top_k.
      excl_zone: top-K suppression radius; scalar, or default half of
                 each query's true length (0 with ``excl_mode='span'``).
      excl_mode: 'end' suppresses matches whose *end* is within
                 ``excl_zone``; 'span' suppresses matches whose spans
                 overlap (widened by ``excl_zone``). Only meaningful with
                 ``top_k``.
      block_q/block_m: Pallas kernel block shape.

    Returns: (nq,) distances in the accumulator dtype — scalar for a single
    1-D query; a (dists, positions) pair or (dists, starts, ends) triple
    in the positions/spans modes.
    """
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    if excl_mode not in EXCL_MODES:
        raise ValueError(f"excl_mode must be one of {EXCL_MODES}, got "
                         f"{excl_mode!r}")
    if (excl_lo is None) != (excl_hi is None):
        raise ValueError("excl_lo and excl_hi must be given together "
                         "(a one-sided zone would silently ban nothing)")
    if top_k is not None and (not isinstance(top_k, int) or top_k < 1):
        raise ValueError(f"top_k must be a positive int, got {top_k!r}")
    if excl_mode == "span" and top_k is None:
        raise ValueError("excl_mode='span' only affects top-K suppression; "
                         "pass top_k= (k=1 selection never suppresses)")
    _check_forced_impl(impl, mesh=mesh, chunk=chunk, top_k=top_k)

    if _is_ragged(queries):
        if qlens is not None:
            raise ValueError("qlens is implied by ragged (list) queries")
        return _sdtw_ragged(queries, reference, metric=metric, impl=impl,
                            chunk=chunk, excl_lo=excl_lo, excl_hi=excl_hi,
                            mesh=mesh, ref_axis=ref_axis, top_k=top_k,
                            return_positions=return_positions,
                            return_spans=return_spans, excl_zone=excl_zone,
                            excl_mode=excl_mode,
                            block_q=block_q, block_m=block_m)

    queries = jnp.asarray(queries)
    reference = jnp.asarray(reference)
    single = queries.ndim == 1
    if single:
        queries = queries[None, :]
    nq, n = queries.shape
    m = reference.shape[0]
    if qlens is not None:
        qlens = jnp.asarray(qlens, jnp.int32)

    has_excl = excl_lo is not None or excl_hi is not None
    if impl == "auto":
        impl = choose_impl(nq, n, m, mesh=mesh, chunk=chunk,
                           has_exclusion=has_excl, top_k=top_k)
    if impl == "pallas" and has_excl:
        raise ValueError("the pallas kernel does not support exclusion "
                         "zones; use impl='rowscan' or 'chunked'")

    if impl in ("rowscan", "wavefront"):
        lo = _normalize_excl(excl_lo, nq) if has_excl else None
        hi = _normalize_excl(excl_hi, nq) if has_excl else None
        out = sdtw_batch(queries, reference, qlens, metric, impl, lo, hi,
                         return_positions=return_positions,
                         return_spans=return_spans)
    elif impl == "pallas":
        from repro.kernels.sdtw import sdtw_pallas
        if chunk is None:
            out = sdtw_pallas(queries, reference, qlens, metric,
                              block_q=block_q, block_m=block_m,
                              return_positions=return_positions,
                              return_spans=return_spans)
        else:
            out = _pallas_streamed(queries, reference, qlens, metric, chunk,
                                   block_q, block_m, return_positions,
                                   return_spans)
    elif impl == "chunked":
        out = sdtw_chunked(queries, reference, qlens, metric,
                           chunk or DEFAULT_CHUNK,
                           _normalize_excl(excl_lo, nq),
                           _normalize_excl(excl_hi, nq),
                           top_k=top_k, excl_zone=excl_zone,
                           return_positions=return_positions,
                           return_spans=return_spans, excl_mode=excl_mode)
    else:  # sharded
        from repro.distributed.sdtw_sharded import sdtw_sharded
        out = sdtw_sharded(queries, reference, qlens, metric=metric,
                           mesh=mesh, axis=ref_axis,
                           chunk=chunk or DEFAULT_CHUNK,
                           excl_lo=_normalize_excl(excl_lo, nq),
                           excl_hi=_normalize_excl(excl_hi, nq),
                           top_k=top_k, excl_zone=excl_zone,
                           return_positions=return_positions,
                           return_spans=return_spans, excl_mode=excl_mode)
    if single:
        return (tuple(o[0] for o in out) if isinstance(out, tuple)
                else out[0])
    return out


def stream(queries, *, qlens=None, metric: str = "abs_diff",
           impl: str = "auto", chunk: Optional[int] = None,
           mesh=None, ref_axis: str = "ref", n_micro: Optional[int] = None,
           top_k: Optional[int] = None, excl_zone=None,
           excl_mode: str = "end", return_spans: bool = False,
           return_positions: bool = False, excl_lo=None, excl_hi=None,
           prune: bool = False, span_cap: Optional[int] = None,
           alert_threshold=None, on_alert=None, cache=None, ref_key=None,
           block_q: int = 8, block_m: int = 512):
    """Open an online monitoring session: the streaming front door.

    Where ``sdtw()`` answers one offline query batch against a
    materialized reference, ``stream()`` returns a session whose
    ``feed(chunk)`` consumes the reference as an unbounded chunk sequence
    — the chunk-carry protocol run forever. ``session.results()`` at any
    point equals the offline ``sdtw()`` / ``search_topk()`` answer over
    the samples fed so far (bitwise for int32, any feed partition);
    ``session.snapshot()`` / ``StreamSession.restore()`` give
    fault-tolerant serving. See ``repro.stream`` for the session API
    (top-K heaps, online LB pruning, threshold alerts).

    Dispatch: ``mesh=`` (or ``impl='sharded'``) returns the
    ``ShardedStreamSession`` (per-device chunk streams through the
    ppermute carry); ``impl='pallas'`` streams fed chunks through the
    kernel's carry entry/exit; ``'auto'`` picks the Pallas path on a TPU
    backend for plain distance/span monitoring and the rowscan tile loop
    everywhere else. ``chunk`` is the internal DP tile size (compile
    granularity) — feed granularity is independent of it.
    """
    from repro.stream import ShardedStreamSession, StreamSession
    if impl not in ("auto", "rowscan", "pallas", "sharded"):
        raise ValueError(
            f"impl must be 'auto', 'rowscan', 'pallas' or 'sharded' for "
            f"streaming, got {impl!r}")
    if mesh is not None or impl == "sharded":
        if prune:
            raise ValueError("mesh= streams every chunk; the LB cascade "
                             "is single-process (drop prune=True)")
        if alert_threshold is not None or on_alert is not None:
            raise ValueError("alerts are single-process; drop mesh=")
        if cache is not None or ref_key is not None:
            raise ValueError("the envelope cache is built by the "
                             "single-process pruning path; cache=/ref_key= "
                             "have no effect on a sharded session (drop "
                             "them or drop mesh=)")
        if span_cap is not None:
            raise ValueError("span_cap= only bounds the pruned path; a "
                             "sharded session streams every chunk exactly")
        return ShardedStreamSession(
            queries, qlens=qlens, metric=metric, mesh=mesh, axis=ref_axis,
            chunk=chunk, n_micro=n_micro, top_k=top_k, excl_zone=excl_zone,
            excl_mode=excl_mode, return_spans=return_spans,
            return_positions=return_positions, excl_lo=excl_lo,
            excl_hi=excl_hi)
    if impl == "auto":
        wants_rowscan = (top_k is not None or prune
                         or alert_threshold is not None
                         or excl_lo is not None)
        impl = ("pallas" if jax.default_backend() == "tpu"
                and not wants_rowscan else "rowscan")
    return StreamSession(
        queries, qlens=qlens, metric=metric, chunk=chunk, impl=impl,
        top_k=top_k, excl_zone=excl_zone, excl_mode=excl_mode,
        return_spans=return_spans, return_positions=return_positions,
        excl_lo=excl_lo, excl_hi=excl_hi, prune=prune, span_cap=span_cap,
        alert_threshold=alert_threshold, on_alert=on_alert, cache=cache,
        ref_key=ref_key, block_q=block_q, block_m=block_m)


def align(queries, reference, qlens=None, *, metric: str = "abs_diff",
          impl: str = "auto", chunk: Optional[int] = None, mesh=None,
          ref_axis: str = "ref",
          trace_chunk: int = DEFAULT_TRACE_CHUNK):
    """Best alignment of each query, localized: span plus full warping path.

    Composes two bounded-memory passes: (1) the engine's span mode finds
    ``(distance, start, end)`` on whatever execution path ``impl``/"auto"
    selects; (2) ``repro.core.traceback`` re-runs the DP inside the
    ``[start, end]`` window only, in ``trace_chunk``-column blocks, to
    recover the monotone warping path (peak memory
    O(N·trace_chunk + N·span/trace_chunk), never O(N·M)).

    Returns an ``AlignResult`` for a single 1-D query, else a list of
    ``AlignResult`` (one per query, in caller order; ragged lists
    accepted). Saturated matches (distance ≥ BIG — no finite alignment,
    e.g. fully banned reference) come back with ``start = end = -1`` and
    ``path = None``.
    """
    ragged = _is_ragged(queries)
    single = not ragged and jnp.asarray(queries).ndim == 1
    d, s, e = sdtw(queries, reference, qlens, metric=metric, impl=impl,
                   chunk=chunk, mesh=mesh, ref_axis=ref_axis,
                   return_spans=True)
    if single:
        d, s, e = d[None], s[None], e[None]
    d = np.asarray(d)
    s = np.asarray(s, np.int64)
    e = np.asarray(e, np.int64)
    if ragged:
        qs = [np.asarray(q) for q in queries]
        lens = [len(q) for q in qs]
    else:
        q2 = np.asarray(queries)
        q2 = q2[None, :] if q2.ndim == 1 else q2
        lens = (np.full((q2.shape[0],), q2.shape[1], np.int64)
                if qlens is None else np.asarray(qlens, np.int64))
        qs = [q2[i, :int(lens[i])] for i in range(q2.shape[0])]
    ref_np = np.asarray(reference)
    BIG = big(d.dtype)
    results = []
    for i, q in enumerate(qs):
        if d[i] >= BIG or s[i] < 0:
            results.append(AlignResult(distance=d[i], start=-1, end=-1,
                                       path=None))
            continue
        path = traceback_path(q, ref_np, int(s[i]), int(e[i]),
                              metric=metric, chunk=trace_chunk)
        results.append(AlignResult(distance=d[i], start=int(s[i]),
                                   end=int(e[i]), path=path))
    return results[0] if single else results


def _pallas_streamed(queries, reference, qlens, metric, chunk, block_q,
                     block_m, return_positions, return_spans=False):
    """Stream the reference through the Pallas kernel in chunk-sized slices,
    chaining the kernel carry between launches — the explicit meaning of
    ``impl='pallas'`` + ``chunk=``. The start-pointer lane joins the carry
    only when spans are requested (the plain stream keeps the untaxed
    (bcol, best, pos) triple)."""
    from repro.kernels.sdtw import sdtw_pallas
    m = reference.shape[0]
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    carry = None
    for off in range(0, m, chunk):
        _, carry = sdtw_pallas(queries, reference[off:off + chunk], qlens,
                               metric, block_q=block_q, block_m=block_m,
                               carry=carry, ref_offset=off,
                               return_carry=True,
                               track_start=return_spans)
    if return_spans:
        _, _, best, pos, start = carry
        return best, start, pos
    _, best, pos = carry
    return (best, pos) if return_positions else best


def bucketize(lengths: Sequence[int]):
    """Group query indices by padded power-of-two bucket length.

    Returns {bucket_len: [query indices]} with deterministic ordering.
    """
    buckets: dict[int, list[int]] = {}
    for i, L in enumerate(lengths):
        if L < 1:
            raise ValueError(f"query {i} is empty")
        buckets.setdefault(_bucket_len(L), []).append(i)
    return dict(sorted(buckets.items()))


def pad_ragged_bucket(qs, idxs, blen: int):
    """Materialise one ragged bucket: zero-pad the selected queries to
    (len(idxs), blen) in their promoted dtype.

    Shared by the engine's ragged dispatch and ``repro.search`` so the
    pad/bucket conventions cannot drift. Returns numpy
    ``(padded, qlens)``.
    """
    dtype = np.result_type(*[qs[i].dtype for i in idxs])
    padded = np.zeros((len(idxs), blen), dtype)
    qlens = np.empty((len(idxs),), np.int32)
    for k, i in enumerate(idxs):
        padded[k, :len(qs[i])] = qs[i]
        qlens[k] = len(qs[i])
    return padded, qlens


def _sdtw_ragged(queries, reference, *, metric, impl, chunk, excl_lo,
                 excl_hi, mesh, ref_axis, top_k, return_positions,
                 return_spans, excl_zone, excl_mode, block_q, block_m):
    """Bucketed dispatch for mixed-length query sets."""
    qs = [np.asarray(q) for q in queries]
    nq = len(qs)
    n_out = (3 if return_spans
             else 2 if (top_k is not None or return_positions) else 1)
    if nq == 0:
        kk = 1 if top_k is None else top_k
        shape = (0,) if top_k is None else (0, kk)
        empty = tuple(jnp.zeros(shape, jnp.int32) for _ in range(n_out))
        return empty if n_out > 1 else empty[0]
    lo = np.asarray(_normalize_excl(excl_lo, nq))
    hi = np.asarray(_normalize_excl(excl_hi, nq))
    buckets = bucketize([len(q) for q in qs])

    outs = [[None] * nq for _ in range(n_out)]
    for blen, idxs in buckets.items():
        padded, qlens = pad_ragged_bucket(qs, idxs, blen)
        res = sdtw(jnp.asarray(padded), reference, jnp.asarray(qlens),
                   metric=metric, impl=impl, chunk=chunk,
                   excl_lo=jnp.asarray(lo[idxs]),
                   excl_hi=jnp.asarray(hi[idxs]),
                   mesh=mesh, ref_axis=ref_axis, top_k=top_k,
                   return_positions=return_positions,
                   return_spans=return_spans, excl_zone=excl_zone,
                   excl_mode=excl_mode, block_q=block_q, block_m=block_m)
        res = res if isinstance(res, tuple) else (res,)
        for t in range(n_out):
            for k, i in enumerate(idxs):
                outs[t][i] = res[t][k]
    stacked = tuple(jnp.stack(o) for o in outs)
    return stacked if n_out > 1 else stacked[0]
