"""Unified sDTW engine — the single front door every caller routes through.

``sdtw()`` hides four execution regimes behind one call:

  * ``rowscan`` / ``wavefront`` — the in-core JAX schedules of
    ``repro.core.sdtw`` (tropical associative scan vs the paper-faithful
    anti-diagonal wavefront).
  * ``pallas``  — the TPU kernel of ``repro.kernels.sdtw`` (interpret mode
    off-TPU).
  * ``chunked`` — reference streaming: the reference is processed in
    fixed-size tiles carrying only the O(N) boundary column between tiles
    (MATSA's inter-subarray pass gates, §III-B), so the paper's M≈1.8M ECG
    references run in bounded memory under one jitted shape.
  * ``sharded`` — the reference axis is sharded across devices
    (``repro.distributed.sdtw_sharded``); the chunk carry is exchanged
    between neighbouring devices with ``lax.ppermute``.

Dispatch rules (``impl="auto"``):

  1. ``mesh`` given (or ``impl="sharded"``)        → sharded driver.
  2. ``chunk`` given explicitly                    → chunked streaming.
  3. TPU backend and no exclusion zone             → Pallas kernel (its
     tile grid already streams arbitrary M).
  4. M ≥ ``CHUNK_THRESHOLD``                       → chunked streaming.
  5. M < 2·N (reference not much longer than query)→ wavefront (diagonal
     depth N+M-1 ≈ cheap; avoids the associative-scan constant).
  6. otherwise                                     → rowscan.

``impl=`` is an escape hatch that forces any of the five paths.

Ragged batches: a *list* of 1-D queries with mixed lengths is bucketed —
each query is padded up to the next power-of-two length (min
``MIN_BUCKET``) and queries sharing a bucket run as one batched call. The
compiled-shape count is therefore O(log max_len) across the process
lifetime instead of one shape per distinct query length.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .sdtw import sdtw_batch, sdtw_chunked

IMPLS = ("auto", "rowscan", "wavefront", "pallas", "chunked", "sharded")

CHUNK_THRESHOLD = 1 << 17   # auto-switch to streaming above this M
DEFAULT_CHUNK = 8192        # tile size for chunked/sharded streaming
MIN_BUCKET = 16             # smallest ragged-batch padded length


def choose_impl(nq: int, n: int, m: int, *, backend: Optional[str] = None,
                mesh=None, chunk: Optional[int] = None,
                has_exclusion: bool = False) -> str:
    """The ``impl="auto"`` dispatch rule (documented in the module docstring,
    exercised directly by the tests)."""
    if mesh is not None:
        return "sharded"
    if chunk is not None:
        return "chunked"
    backend = jax.default_backend() if backend is None else backend
    if backend == "tpu" and not has_exclusion:
        # The Pallas kernel streams arbitrary M through its own tile grid —
        # long references stay on the kernel path on the target hardware.
        return "pallas"
    if m >= CHUNK_THRESHOLD:
        return "chunked"
    if m < 2 * n:
        return "wavefront"
    return "rowscan"


def _bucket_len(length: int) -> int:
    return max(MIN_BUCKET, 1 << max(0, int(length) - 1).bit_length())


def _is_ragged(queries) -> bool:
    if isinstance(queries, (list, tuple)):
        return True
    return False


def _normalize_excl(val, nq: int):
    if val is None:
        return jnp.full((nq,), -1, jnp.int32)
    arr = jnp.asarray(val, jnp.int32)
    if arr.ndim == 0:
        arr = jnp.full((nq,), arr, jnp.int32)
    return arr


def sdtw(queries, reference, qlens=None, *, metric: str = "abs_diff",
         impl: str = "auto", chunk: Optional[int] = None,
         excl_lo=None, excl_hi=None, mesh=None, ref_axis: str = "ref",
         block_q: int = 8, block_m: int = 512):
    """Subsequence-DTW distances of ``queries`` against ``reference``.

    Args:
      queries:   (nq, N) padded array, a single (N,) query, or a list of
                 1-D queries with mixed lengths (ragged — bucketed dispatch).
      reference: (M,) reference sequence.
      qlens:     (nq,) true query lengths for padded 2-D input.
      metric:    'abs_diff' | 'square_diff'.
      impl:      one of ``IMPLS``; 'auto' applies the dispatch rules above.
      chunk:     reference tile size for the chunked/sharded paths; setting
                 it forces streaming under 'auto'.
      excl_lo/excl_hi: banned reference column range per query (self-join
                 exclusion zones); scalar or (nq,).
      mesh:      a jax Mesh whose ``ref_axis`` shards the reference axis;
                 forces the sharded driver under 'auto'.
      block_q/block_m: Pallas kernel block shape.

    Returns: (nq,) distances in the accumulator dtype — scalar for a single
    1-D query.
    """
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    if (excl_lo is None) != (excl_hi is None):
        raise ValueError("excl_lo and excl_hi must be given together "
                         "(a one-sided zone would silently ban nothing)")

    if _is_ragged(queries):
        if qlens is not None:
            raise ValueError("qlens is implied by ragged (list) queries")
        return _sdtw_ragged(queries, reference, metric=metric, impl=impl,
                            chunk=chunk, excl_lo=excl_lo, excl_hi=excl_hi,
                            mesh=mesh, ref_axis=ref_axis,
                            block_q=block_q, block_m=block_m)

    queries = jnp.asarray(queries)
    reference = jnp.asarray(reference)
    single = queries.ndim == 1
    if single:
        queries = queries[None, :]
    nq, n = queries.shape
    m = reference.shape[0]
    if qlens is not None:
        qlens = jnp.asarray(qlens, jnp.int32)

    has_excl = excl_lo is not None or excl_hi is not None
    if impl == "auto":
        impl = choose_impl(nq, n, m, mesh=mesh, chunk=chunk,
                           has_exclusion=has_excl)
    if impl == "pallas" and has_excl:
        raise ValueError("the pallas kernel does not support exclusion "
                         "zones; use impl='rowscan' or 'chunked'")

    if impl in ("rowscan", "wavefront"):
        lo = _normalize_excl(excl_lo, nq) if has_excl else None
        hi = _normalize_excl(excl_hi, nq) if has_excl else None
        out = sdtw_batch(queries, reference, qlens, metric, impl, lo, hi)
    elif impl == "pallas":
        from repro.kernels.sdtw import sdtw_pallas
        out = sdtw_pallas(queries, reference, qlens, metric,
                          block_q=block_q, block_m=block_m)
    elif impl == "chunked":
        out = sdtw_chunked(queries, reference, qlens, metric,
                           chunk or DEFAULT_CHUNK,
                           _normalize_excl(excl_lo, nq),
                           _normalize_excl(excl_hi, nq))
    else:  # sharded
        from repro.distributed.sdtw_sharded import sdtw_sharded
        out = sdtw_sharded(queries, reference, qlens, metric=metric,
                           mesh=mesh, axis=ref_axis,
                           chunk=chunk or DEFAULT_CHUNK,
                           excl_lo=_normalize_excl(excl_lo, nq),
                           excl_hi=_normalize_excl(excl_hi, nq))
    return out[0] if single else out


def bucketize(lengths: Sequence[int]):
    """Group query indices by padded power-of-two bucket length.

    Returns {bucket_len: [query indices]} with deterministic ordering.
    """
    buckets: dict[int, list[int]] = {}
    for i, L in enumerate(lengths):
        if L < 1:
            raise ValueError(f"query {i} is empty")
        buckets.setdefault(_bucket_len(L), []).append(i)
    return dict(sorted(buckets.items()))


def _sdtw_ragged(queries, reference, *, metric, impl, chunk, excl_lo,
                 excl_hi, mesh, ref_axis, block_q, block_m):
    """Bucketed dispatch for mixed-length query sets."""
    qs = [np.asarray(q) for q in queries]
    nq = len(qs)
    if nq == 0:
        return jnp.zeros((0,), jnp.int32)
    lo = np.asarray(_normalize_excl(excl_lo, nq))
    hi = np.asarray(_normalize_excl(excl_hi, nq))
    buckets = bucketize([len(q) for q in qs])

    out = [None] * nq
    for blen, idxs in buckets.items():
        dtype = np.result_type(*[qs[i].dtype for i in idxs])
        padded = np.zeros((len(idxs), blen), dtype)
        qlens = np.empty((len(idxs),), np.int32)
        for k, i in enumerate(idxs):
            padded[k, :len(qs[i])] = qs[i]
            qlens[k] = len(qs[i])
        dists = sdtw(jnp.asarray(padded), reference, jnp.asarray(qlens),
                     metric=metric, impl=impl, chunk=chunk,
                     excl_lo=jnp.asarray(lo[idxs]),
                     excl_hi=jnp.asarray(hi[idxs]),
                     mesh=mesh, ref_axis=ref_axis,
                     block_q=block_q, block_m=block_m)
        for k, i in enumerate(idxs):
            out[i] = dists[k]
    return jnp.stack(out)
