"""Logical sharding rules: DP / TP / EP / SP over the production mesh.

Axis conventions (DESIGN.md §6):
  * batch            → data-parallel axes ("pod", "data") — "pod" is the
                       cross-pod pure-DP axis of the multi-pod mesh
  * heads / d_ff / experts / d_inner → tensor-parallel axis ("model")
  * vocab            → "model" (embedding + logits sharding)
  * long-context KV sequence → "data" (sequence parallelism for decode)

All annotations go through ``Axes`` so a model runs unmodified on any mesh
(including none at all — every helper degrades to a no-op when mesh is None,
which is what the CPU smoke tests use).

Non-divisible shardings (e.g. phi3's 40 heads or granite-moe's 49155 vocab
on a 16-way model axis) rely on GSPMD's padded uneven sharding — they
compile correctly; the roofline accounting charges the padding.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Axes:
    """Mesh axis handles threaded through every model function."""
    mesh: Optional[Mesh] = None
    dp: tuple = ("data",)        # ("pod", "data") on the multi-pod mesh
    tp: Optional[str] = "model"
    sp: Optional[str] = "data"   # sequence-parallel axis for long KV

    @staticmethod
    def from_mesh(mesh: Optional[Mesh]) -> "Axes":
        if mesh is None:
            return Axes(mesh=None, dp=(), tp=None, sp=None)
        names = mesh.axis_names
        dp = tuple(n for n in names if n in ("pod", "data"))
        tp = "model" if "model" in names else None
        sp = "data" if "data" in names else None
        return Axes(mesh=mesh, dp=dp, tp=tp, sp=sp)

    @property
    def tp_size(self) -> int:
        if self.mesh is None or self.tp is None:
            return 1
        return self.mesh.shape[self.tp]

    def tp_if_divisible(self, n: int):
        """TP axis name iff it evenly divides n.

        Forcing a padded uneven sharding (e.g. phi3's 40 heads on a 16-way
        axis) makes the SPMD partitioner fall back to full re-replication
        ("involuntary full rematerialization"); leaving the dim unconstrained
        lets GSPMD pick a compatible factored sharding instead."""
        return self.tp if (self.tp and n and n % self.tp_size == 0) else None

    def spec(self, *dims) -> P:
        """Build a PartitionSpec, dropping axes absent from the mesh.

        dims entries: None | "dp" | "tp" | "sp" | explicit axis name/tuple.
        """
        out = []
        for d in dims:
            if d == "dp":
                out.append(self.dp if self.dp else None)
            elif d == "tp":
                out.append(self.tp)
            elif d == "sp":
                out.append(self.sp)
            else:
                out.append(d)
        return P(*out)

    def constrain(self, x, *dims):
        """with_sharding_constraint if a mesh is active, else identity."""
        if self.mesh is None:
            return x
        return lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*dims)))

    def sharding(self, *dims) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*dims))


def tree_shardings(axes: Axes, spec_tree):
    """Map a pytree of spec-dim tuples to NamedShardings (None mesh → None)."""
    if axes.mesh is None:
        return None
    return jax.tree.map(
        lambda dims: NamedSharding(axes.mesh, axes.spec(*dims)),
        spec_tree, is_leaf=lambda v: isinstance(v, tuple))
