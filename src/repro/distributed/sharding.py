"""Logical sharding rules: DP / TP / EP / SP over the production mesh.

Axis conventions (DESIGN.md §6):
  * batch            → data-parallel axes ("pod", "data") — "pod" is the
                       cross-pod pure-DP axis of the multi-pod mesh
  * heads / d_ff / experts / d_inner → tensor-parallel axis ("model")
  * vocab            → "model" (embedding + logits sharding)
  * long-context KV sequence → "data" (sequence parallelism for decode)

All annotations go through ``Axes`` so a model runs unmodified on any mesh
(including none at all — every helper degrades to a no-op when mesh is None,
which is what the CPU smoke tests use).

Non-divisible shardings (e.g. phi3's 40 heads or granite-moe's 49155 vocab
on a 16-way model axis) rely on GSPMD's padded uneven sharding — they
compile correctly; the roofline accounting charges the padding.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Axes:
    """Mesh axis handles threaded through every model function."""
    mesh: Optional[Mesh] = None
    dp: tuple = ("data",)        # ("pod", "data") on the multi-pod mesh
    tp: Optional[str] = "model"
    sp: Optional[str] = "data"   # sequence-parallel axis for long KV

    @staticmethod
    def from_mesh(mesh: Optional[Mesh]) -> "Axes":
        if mesh is None:
            return Axes(mesh=None, dp=(), tp=None, sp=None)
        names = mesh.axis_names
        dp = tuple(n for n in names if n in ("pod", "data"))
        tp = "model" if "model" in names else None
        sp = "data" if "data" in names else None
        return Axes(mesh=mesh, dp=dp, tp=tp, sp=sp)

    @property
    def tp_size(self) -> int:
        if self.mesh is None or self.tp is None:
            return 1
        return self.mesh.shape[self.tp]

    def tp_if_divisible(self, n: int):
        """TP axis name iff it evenly divides n.

        Forcing a padded uneven sharding (e.g. phi3's 40 heads on a 16-way
        axis) makes the SPMD partitioner fall back to full re-replication
        ("involuntary full rematerialization"); leaving the dim unconstrained
        lets GSPMD pick a compatible factored sharding instead."""
        return self.tp if (self.tp and n and n % self.tp_size == 0) else None

    def spec(self, *dims) -> P:
        """Build a PartitionSpec, dropping axes absent from the mesh.

        dims entries: None | "dp" | "tp" | "sp" | explicit axis name/tuple.
        """
        out = []
        for d in dims:
            if d == "dp":
                out.append(self.dp if self.dp else None)
            elif d == "tp":
                out.append(self.tp)
            elif d == "sp":
                out.append(self.sp)
            else:
                out.append(d)
        return P(*out)

    def constrain(self, x, *dims):
        """with_sharding_constraint if a mesh is active, else identity."""
        if self.mesh is None:
            return x
        return lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*dims)))

    def sharding(self, *dims) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*dims))


def tree_shardings(axes: Axes, spec_tree):
    """Map a pytree of spec-dim tuples to NamedShardings (None mesh → None)."""
    if axes.mesh is None:
        return None
    return jax.tree.map(
        lambda dims: NamedSharding(axes.mesh, axes.spec(*dims)),
        spec_tree, is_leaf=lambda v: isinstance(v, tuple))


# ---------------------------------------------------------------------------
# sDTW scaling meshes: (dp, mp) construction + axis resolution
# ---------------------------------------------------------------------------

def get_mesh(shape=None, axis_names: Optional[Sequence[str]] = None, *,
             devices: Optional[Sequence] = None) -> Mesh:
    """Build a mesh for the sharded sDTW engine, redco-style.

    ``shape`` may be:
      * None        — all devices on one systolic axis ``("mp",)``
      * an int k    — ``(-1, k)``: k-way reference sharding, data-parallel
                      over the rest
      * a tuple     — explicit ``(mp,)`` or ``(dp, mp)``; at most one entry
                      may be ``-1`` (inferred from the device count)

    ``axis_names`` defaults to ``("mp",)`` / ``("dp", "mp")`` to match the
    tuple length. ``devices`` restricts the mesh to a device subset
    (defaults to ``jax.devices()``).
    """
    import numpy as np

    devs = list(jax.devices()) if devices is None else list(devices)
    ndev = len(devs)
    if shape is None:
        shape = (ndev,)
    elif isinstance(shape, int):
        shape = (-1, shape)
    else:
        shape = tuple(int(s) for s in shape)
    if len(shape) not in (1, 2):
        raise ValueError(f"mesh shape must be (mp,) or (dp, mp), got "
                         f"{shape!r}")
    if sum(1 for s in shape if s == -1) > 1:
        raise ValueError(f"at most one -1 wildcard allowed in mesh shape, "
                         f"got {shape!r}")
    if any(s == 0 or s < -1 for s in shape):
        raise ValueError(f"mesh shape entries must be positive or -1, got "
                         f"{shape!r}")
    if -1 in shape:
        known = 1
        for s in shape:
            if s != -1:
                known *= s
        if known == 0 or ndev % known != 0:
            raise ValueError(f"cannot infer -1 in mesh shape {shape!r}: "
                             f"{ndev} devices not divisible by {known}")
        shape = tuple(ndev // known if s == -1 else s for s in shape)
    total = 1
    for s in shape:
        total *= s
    if total != ndev:
        raise ValueError(f"mesh shape {shape!r} needs {total} devices, "
                         f"have {ndev}")
    if axis_names is None:
        axis_names = ("mp",) if len(shape) == 1 else ("dp", "mp")
    axis_names = tuple(axis_names)
    if len(axis_names) != len(shape):
        raise ValueError(f"axis_names {axis_names!r} does not match mesh "
                         f"shape {shape!r}")
    return Mesh(np.array(devs).reshape(shape), axis_names)


def pipeline_axes(mesh: Mesh, ref_axis: str = "ref",
                  dp_axis: Optional[str] = None):
    """Resolve (dp_axis, mp_axis) for the sharded sDTW pipeline.

    The systolic (reference-sharded) axis is ``ref_axis`` if the mesh has
    it, else ``"mp"``, else the sole axis of a 1-D mesh. The data-parallel
    axis is ``dp_axis`` if given, else the single remaining axis (None for
    a 1-D mesh). Ambiguous or missing axes raise.
    """
    names = tuple(mesh.axis_names)
    if ref_axis in names:
        mp = ref_axis
    elif "mp" in names:
        mp = "mp"
    elif len(names) == 1:
        mp = names[0]
    else:
        raise ValueError(f"cannot pick a systolic axis from mesh axes "
                         f"{names!r}: pass ref_axis= naming one of them")
    rest = tuple(n for n in names if n != mp)
    if dp_axis is not None:
        if dp_axis not in rest:
            raise ValueError(f"dp_axis {dp_axis!r} not in mesh axes "
                             f"{names!r} (systolic axis is {mp!r})")
        return dp_axis, mp
    if len(rest) == 0:
        return None, mp
    if len(rest) == 1:
        return rest[0], mp
    raise ValueError(f"mesh has several non-systolic axes {rest!r}; pass "
                     f"dp_axis= naming the data-parallel one")


def init_multi_host(coordinator_address: str, num_processes: int,
                    process_id: int, **kwargs):
    """Join a multi-host mesh via ``jax.distributed``.

    Call once per process before any other jax API, then build the global
    mesh with ``get_mesh`` — ``jax.devices()`` spans all hosts afterwards.
    Returns (process_index, process_count).
    """
    jax.distributed.initialize(
        coordinator_address=coordinator_address,
        num_processes=num_processes,
        process_id=process_id, **kwargs)
    return jax.process_index(), jax.process_count()
