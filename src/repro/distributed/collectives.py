"""Distributed-optimization collectives: compressed gradient reduction.

``int8 all-reduce with error feedback`` — the classic bandwidth trick for
cross-pod gradient sync (the "pod" axis of the multi-pod mesh has the lowest
bandwidth):

  1. residual-corrected gradient  g' = g + e      (error feedback buffer e)
  2. per-tensor symmetric int8 quantisation       (scale = max|g'| / 127)
  3. all-reduce in int32 (no overflow up to 2^23 summands)
  4. dequantise with the psum'd scales; update    e ← g' - dequant(quant(g'))

Quantisation+feedback is exact-in-expectation and keeps SGD convergence
(Karimireddy et al. 2019). ``quantize/dequantize`` are also used standalone
by the train step's local simulation mode (mesh-free tests), so the wire
format is unit-testable without devices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def quantize_int8(g):
    """Symmetric per-tensor int8. Returns (q int8, scale f32)."""
    g = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, feedback):
    """Quantise a grad pytree with error feedback.

    Returns (dequantised grads — what the wire would deliver on a 1-device
    reduction —, new feedback buffers, bytes_saved_fraction)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return deq, corrected - deq

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(feedback)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    deq = td.unflatten([o[0] for o in outs])
    new_fb = td.unflatten([o[1] for o in outs])
    return deq, new_fb


def compressed_psum(g, axis_name):
    """int8-quantised psum along ``axis_name`` (inside shard_map/pmap).

    Two-phase: (1) agree on a global scale (pmax of local max-abs — a
    4-byte collective), (2) quantise against the SHARED scale and psum in
    int32 (no overflow below 2^23 participants). Summing int8 values
    quantised with heterogeneous per-shard scales would be wrong — the
    per-shard scale is lost in the integer accumulation.
    Wire cost: 1 byte/grad element + 4 bytes/tensor.
    """
    g = g.astype(jnp.float32)
    local_max = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    scale = lax.pmax(local_max, axis_name) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    acc = lax.psum(q.astype(jnp.int32), axis_name)
    n = lax.psum(jnp.ones((), jnp.float32), axis_name)
    return acc.astype(jnp.float32) * scale / n


def init_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
