"""Distributed-optimization collectives: compressed gradient reduction.

``int8 all-reduce with error feedback`` — the classic bandwidth trick for
cross-pod gradient sync (the "pod" axis of the multi-pod mesh has the lowest
bandwidth):

  1. residual-corrected gradient  g' = g + e      (error feedback buffer e)
  2. per-tensor symmetric int8 quantisation       (scale = max|g'| / 127)
  3. all-reduce in int32 (no overflow up to 2^23 summands)
  4. dequantise with the psum'd scales; update    e ← g' - dequant(quant(g'))

Quantisation+feedback is exact-in-expectation and keeps SGD convergence
(Karimireddy et al. 2019). ``quantize/dequantize`` are also used standalone
by the train step's local simulation mode (mesh-free tests), so the wire
format is unit-testable without devices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def quantize_int8(g):
    """Symmetric per-tensor int8. Returns (q int8, scale f32)."""
    g = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, feedback):
    """Quantise a grad pytree with error feedback.

    Returns (dequantised grads — what the wire would deliver on a 1-device
    reduction —, new feedback buffers, bytes_saved_fraction)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return deq, corrected - deq

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(feedback)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    deq = td.unflatten([o[0] for o in outs])
    new_fb = td.unflatten([o[1] for o in outs])
    return deq, new_fb


def compressed_psum(g, axis_name):
    """int8-quantised psum along ``axis_name`` (inside shard_map/pmap).

    Two-phase: (1) agree on a global scale (pmax of local max-abs — a
    4-byte collective), (2) quantise against the SHARED scale and psum in
    int32 (no overflow below 2^23 participants). Summing int8 values
    quantised with heterogeneous per-shard scales would be wrong — the
    per-shard scale is lost in the integer accumulation.
    Wire cost: 1 byte/grad element + 4 bytes/tensor.
    """
    g = g.astype(jnp.float32)
    local_max = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    scale = lax.pmax(local_max, axis_name) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    acc = lax.psum(q.astype(jnp.int32), axis_name)
    n = lax.psum(jnp.ones((), jnp.float32), axis_name)
    return acc.astype(jnp.float32) * scale / n


def init_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def neighbor_perm(n: int):
    """ppermute permutation for a left-to-right systolic hand-off.

    Device i sends to i+1; device n-1's output is dropped (it has left the
    pipeline) and device 0 receives zeros.
    """
    return [(i, i + 1) for i in range(n - 1)]


def psum_harvest(outs, axis_name: str, n_stages: int, n_keep: int):
    """Collect the last pipeline stage's scan outputs onto every device.

    In a GPipe-style schedule the last stage emits microbatch t at tick
    t + n_stages - 1, so the per-tick scan output pytree ``outs`` (leading
    dim = ticks) holds the finished results in the window
    [n_stages-1, n_stages-1+n_keep) — but only on the last stage; every
    other device's slots hold in-flight intermediates. Slice that window,
    zero it everywhere but the last stage, and psum so all devices end up
    with the replicated result (leading dim ``n_keep``).
    """
    sid = lax.axis_index(axis_name)

    def one(o):
        kept = lax.dynamic_slice_in_dim(o, n_stages - 1, n_keep, 0)
        kept = jnp.where(sid == n_stages - 1, kept,
                         jnp.zeros_like(kept))
        return lax.psum(kept, axis_name)

    return jax.tree.map(one, outs)
