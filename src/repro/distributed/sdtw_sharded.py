"""Multi-device sDTW: the reference axis sharded over a mesh axis.

Each device owns one contiguous reference segment (padded to a multiple of
the streaming chunk). The sDTW recurrence is sequential along the reference,
so a single query batch must visit the devices in order — but batches are
independent, which makes the schedule a classic systolic pipeline: the query
set is split into microbatches, device d processes microbatch t − d at tick
t, and the (boundary-column, best) chunk carry of ``repro.core.sdtw`` is
handed to the right-hand neighbour with one ``lax.ppermute`` per tick. The
inter-device protocol is *identical* to the intra-device chunk carry — a
device is just a very large chunk — mirroring MATSA's inter-subarray pass
gates scaled up to inter-accelerator links.

Steady-state all devices are busy; pipeline fill/drain costs S − 1 of
n_micro + S − 1 ticks. Devices compute garbage during fill (clipped
microbatch indices, zero-filled ppermute carries); only the last device's
in-window ticks are harvested, so the garbage never reaches the output.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.distances import accum_dtype
from repro.core.sdtw import (default_excl_zone, sdtw_carry_init,
                             sdtw_segment, sdtw_segment_topk)
from repro.core.topk import topk_init


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def default_mesh(axis: str = "ref") -> Mesh:
    """1-D mesh over every local device, reference axis sharded."""
    return Mesh(np.asarray(jax.devices()), (axis,))


@functools.lru_cache(maxsize=None)
def _build(mesh, axis: str, metric: str, chunk: int, ndev: int,
           n_micro: int, top_k, excl_zone, excl_span: bool,
           track_start: bool):
    """Jitted shard-mapped pipeline for one (mesh, schedule) configuration.

    With ``top_k`` set, the per-microbatch match heap (top-K distances,
    global end positions, and start positions, see ``repro.core.topk``)
    rides the systolic carry exactly like the boundary column — which
    itself gains the start-pointer lane so spans survive the inter-device
    hand-off: each device folds the candidates of its own reference
    segment into the heap it received from the left neighbour, so the heap
    exiting the last device is already the merged cross-shard top-K — the
    harvest is the one collective at the end, no extra per-shard gather
    round.
    """
    perm = [(i, i + 1) for i in range(ndev - 1)]
    ticks = n_micro + ndev - 1

    def body(r_shard, q_micro, qlen_micro, lo_micro, hi_micro, m_total):
        # r_shard: (1, seg) this device's reference segment; everything else
        # replicated. q_micro: (n_micro, mb, N).
        d = lax.axis_index(axis)
        seg = r_shard.shape[1]
        j0 = d * seg
        mb, n = q_micro.shape[1], q_micro.shape[2]
        acc = accum_dtype(jnp.result_type(q_micro, r_shard))
        fresh = sdtw_carry_init(mb, n, acc,
                                track_start=top_k is not None and
                                track_start)
        if top_k is not None:
            fresh = fresh + topk_init(mb, top_k, acc)

        def tick(carry, t):
            mb_idx = jnp.clip(t - d, 0, n_micro - 1)
            q = lax.dynamic_index_in_dim(q_micro, mb_idx, keepdims=False)
            ql = lax.dynamic_index_in_dim(qlen_micro, mb_idx, keepdims=False)
            lo = lax.dynamic_index_in_dim(lo_micro, mb_idx, keepdims=False)
            hi = lax.dynamic_index_in_dim(hi_micro, mb_idx, keepdims=False)
            # Device 0 always starts a microbatch from the fresh carry; the
            # others continue from whatever the left neighbour handed over.
            cin = jax.tree.map(
                lambda f, c: jnp.where(d == 0, f, c.astype(f.dtype)),
                fresh, carry)
            if top_k is not None:
                ez = (default_excl_zone(ql) if excl_zone is None
                      else jnp.full(ql.shape, excl_zone, jnp.int32))
                cout = sdtw_segment_topk(q, r_shard[0], ql, cin, j0,
                                         m_total, metric, chunk, lo, hi,
                                         top_k, ez, excl_span, track_start)
                emit = cout[-3:]                    # heap: d, ends, starts
            else:
                cout = sdtw_segment(q, r_shard[0], ql, cin, j0, m_total,
                                    metric, chunk, lo, hi)
                emit = cout[1]                      # running best
            nxt = jax.tree.map(lambda x: lax.ppermute(x, axis, perm), cout)
            return nxt, emit

        _, outs = lax.scan(tick, fresh, jnp.arange(ticks))  # (ticks, mb, ...)
        # The last device finishes microbatch μ at tick μ + ndev - 1; only
        # its in-window ticks carry fully merged results — zero everywhere
        # else and harvest with one psum.
        def harvest(o):
            o = lax.dynamic_slice_in_dim(o, ndev - 1, n_micro, 0)
            o = jnp.where(d == ndev - 1, o, jnp.zeros_like(o))
            return lax.psum(o, axis)
        return jax.tree.map(harvest, outs)

    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, axis), P(), P(), P(), P(), P()),
        out_specs=P(),
        check_vma=False)
    return jax.jit(mapped)


@functools.lru_cache(maxsize=None)
def _build_feed(mesh, axis: str, metric: str, chunk: int, ndev: int,
                n_micro: int, top_k, excl_zone, excl_span: bool,
                track_start: bool):
    """Jitted shard-mapped *streaming feed*: advance an explicit carry by
    one sharded macro-chunk and hand the carry back.

    Where ``_build`` starts every microbatch from a fresh carry and
    harvests only the final result, the feed variant takes the previous
    feed's per-microbatch carries as an input (device 0 enters each
    microbatch from them instead of from scratch) and harvests the *full*
    carry tuple exiting the last device — boundary column, start lane,
    running best, and heap — so the caller can keep feeding macro-chunks
    of an unbounded reference through the same ppermute systolic pipeline.
    """
    perm = [(i, i + 1) for i in range(ndev - 1)]
    ticks = n_micro + ndev - 1

    def body(r_shard, q_micro, qlen_micro, lo_micro, hi_micro, m_total,
             j0_base, carry_in):
        # carry_in leaves are (n_micro, mb, ...) — the stacked carries the
        # previous feed harvested (or the session's fresh init).
        d = lax.axis_index(axis)
        seg = r_shard.shape[1]
        j0 = j0_base + d * seg

        def tick(carry, t):
            mb_idx = jnp.clip(t - d, 0, n_micro - 1)
            q = lax.dynamic_index_in_dim(q_micro, mb_idx, keepdims=False)
            ql = lax.dynamic_index_in_dim(qlen_micro, mb_idx, keepdims=False)
            lo = lax.dynamic_index_in_dim(lo_micro, mb_idx, keepdims=False)
            hi = lax.dynamic_index_in_dim(hi_micro, mb_idx, keepdims=False)
            own = jax.tree.map(
                lambda x: lax.dynamic_index_in_dim(x, mb_idx,
                                                   keepdims=False),
                carry_in)
            # Device 0 enters from the session carry; the others continue
            # from whatever the left neighbour handed over.
            cin = jax.tree.map(
                lambda f, c: jnp.where(d == 0, f, c.astype(f.dtype)),
                own, carry)
            if top_k is not None:
                ez = (default_excl_zone(ql) if excl_zone is None
                      else jnp.full(ql.shape, excl_zone, jnp.int32))
                cout = sdtw_segment_topk(q, r_shard[0], ql, cin, j0,
                                         m_total, metric, chunk, lo, hi,
                                         top_k, ez, excl_span, track_start)
            else:
                cout = sdtw_segment(q, r_shard[0], ql, cin, j0, m_total,
                                    metric, chunk, lo, hi)
            nxt = jax.tree.map(lambda x: lax.ppermute(x, axis, perm), cout)
            return nxt, cout

        init = jax.tree.map(lambda x: jnp.zeros_like(x[0]), carry_in)
        _, outs = lax.scan(tick, init, jnp.arange(ticks))

        def harvest(o):
            o = lax.dynamic_slice_in_dim(o, ndev - 1, n_micro, 0)
            o = jnp.where(d == ndev - 1, o, jnp.zeros_like(o))
            return lax.psum(o, axis)
        return jax.tree.map(harvest, outs)

    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, axis), P(), P(), P(), P(), P(), P(), P()),
        out_specs=P(),
        check_vma=False)
    return jax.jit(mapped)


def sdtw_sharded_feed(r_macro, q_micro, qlen_micro, lo_micro, hi_micro,
                      carry, j0: int, m_total: int, *, mesh: Mesh,
                      axis: str = "ref", chunk: int, metric: str,
                      top_k=None, excl_zone=None, excl_span: bool = False,
                      track_start: bool = False):
    """Advance stacked per-microbatch carries by one sharded macro-chunk.

    ``r_macro`` is (ndev * seg,) with seg a multiple of ``chunk``; device d
    processes global columns ``[j0 + d*seg, j0 + (d+1)*seg)``. ``carry``
    leaves are (n_micro, mb, ...), as produced by a previous feed (or the
    caller's stacked fresh init); the return value is the updated carry in
    the same layout, replicated. ``m_total`` masks columns past the true
    stream end, so a right-padded final macro-chunk still folds correct
    distances/heaps (its exiting boundary column is garbage — a padded
    feed must be the last, which is why the sharded session treats a tail
    flush as terminal)."""
    ndev = mesh.shape[axis]
    n_micro = q_micro.shape[0]
    seg = r_macro.shape[0] // ndev
    if seg * ndev != r_macro.shape[0] or seg % chunk:
        raise ValueError(
            f"macro-chunk of {r_macro.shape[0]} does not split into "
            f"{ndev} devices x multiple of chunk={chunk}")
    run = _build_feed(mesh, axis, metric, chunk, ndev, n_micro,
                      top_k, excl_zone, excl_span, track_start)
    return run(r_macro.reshape(1, ndev * seg), q_micro, qlen_micro,
               lo_micro, hi_micro, jnp.int32(m_total), jnp.int32(j0),
               carry)


def sdtw_sharded(queries, reference, qlens=None, *, metric: str = "abs_diff",
                 mesh: Optional[Mesh] = None, axis: str = "ref",
                 chunk: int = 8192, n_micro: Optional[int] = None,
                 excl_lo=None, excl_hi=None,
                 top_k: Optional[int] = None,
                 excl_zone: Optional[int] = None,
                 return_positions: bool = False,
                 return_spans: bool = False, excl_mode: str = "end"):
    """Batched sDTW with the reference sharded across ``mesh[axis]``.

    queries (nq, N), reference (M,) → (nq,) distances, matching the
    single-device engine bit-for-bit for int32 inputs.

    ``top_k=k`` returns ``(dists (nq, k), positions (nq, k))`` — the match
    heap travels with the microbatch through the device pipeline (the same
    ppermute that hands over the boundary column), so the cross-shard merge
    costs no extra collective; positions are global reference indices.
    ``return_positions=True`` alone returns the top-1 pair;
    ``return_spans=True`` returns ``(dists, starts, ends)`` — the
    start-pointer lane crosses devices inside the same ppermute'd carry.
    ``excl_mode='span'`` keys heap suppression on span overlap.
    """
    if mesh is None:
        mesh = default_mesh(axis)
    ndev = mesh.shape[axis]
    queries = jnp.asarray(queries)
    reference = jnp.asarray(reference)
    nq, n = queries.shape
    m = reference.shape[0]
    if qlens is None:
        qlens = jnp.full((nq,), n, jnp.int32)
    if excl_lo is None:
        excl_lo = jnp.full((nq,), -1, jnp.int32)
    if excl_hi is None:
        excl_hi = jnp.full((nq,), -1, jnp.int32)

    # Segment = per-device reference slice, padded to a chunk multiple.
    seg = max(1, -(-m // ndev))
    chunk = min(chunk, seg)
    seg = _ceil_to(seg, chunk)
    r_pad = jnp.pad(reference, (0, seg * ndev - m)).reshape(1, seg * ndev)

    # Microbatch the query set for the systolic schedule.
    n_micro = ndev if n_micro is None else max(1, n_micro)
    n_micro = min(n_micro, max(1, nq))
    mb = -(-nq // n_micro)
    pad_q = n_micro * mb - nq
    q_pad = jnp.pad(queries, ((0, pad_q), (0, 0)))
    ql_pad = jnp.pad(qlens, (0, pad_q), constant_values=1)
    lo_pad = jnp.pad(excl_lo, (0, pad_q), constant_values=-1)
    hi_pad = jnp.pad(excl_hi, (0, pad_q), constant_values=-1)

    wants_pair = top_k is not None or return_positions or return_spans
    kk = (1 if top_k is None else top_k) if wants_pair else None
    if excl_zone is not None and np.ndim(excl_zone) != 0:
        # The zone is baked into the cached pipeline build; per-query
        # arrays (which sdtw_chunked accepts) would need to ride the
        # traced inputs — reject loudly rather than crash in int().
        raise ValueError("sdtw_sharded takes a scalar excl_zone (or None "
                         "for the per-query default); per-query zone "
                         "arrays are only supported on the single-device "
                         "chunked path")
    # zone is unused by the plain pipeline — pin it so non-top-K calls
    # share one _build cache entry. None = derive per query in the body
    # (half the true query length — or 0 in span mode — matching the
    # single-device default).
    if kk is None:
        zone = 0
    elif excl_zone is not None:
        zone = int(excl_zone)
    else:
        zone = None if excl_mode == "end" else 0
    # The start lane crosses the ppermute carry only when starts are
    # consumed (spans requested or span-overlap suppression).
    track = return_spans or excl_mode == "span"
    run = _build(mesh, axis, metric, chunk, ndev, n_micro, kk, zone,
                 excl_mode == "span", track)
    outs = run(r_pad, q_pad.reshape(n_micro, mb, n),
               ql_pad.reshape(n_micro, mb),
               lo_pad.reshape(n_micro, mb), hi_pad.reshape(n_micro, mb),
               jnp.int32(m))
    if not wants_pair:
        return outs.reshape(n_micro * mb)[:nq]
    dists, poss, starts = outs
    dists = dists.reshape(n_micro * mb, kk)[:nq]
    poss = poss.reshape(n_micro * mb, kk)[:nq]
    starts = starts.reshape(n_micro * mb, kk)[:nq]
    if top_k is None:                       # top-1, unstacked
        if return_spans:
            return dists[:, 0], starts[:, 0], poss[:, 0]
        return dists[:, 0], poss[:, 0]
    if return_spans:
        return dists, starts, poss
    return dists, poss
