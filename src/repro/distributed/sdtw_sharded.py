"""Multi-device sDTW: one systolic pipeline builder on a (dp, mp) mesh.

Each device along the systolic (``mp``) axis owns one contiguous reference
segment (padded to a multiple of the streaming chunk). The sDTW recurrence
is sequential along the reference, so a single query batch must visit the
``mp`` devices in order — but batches are independent, which makes the
schedule a classic systolic pipeline: the query set is split into
microbatches, device d processes microbatch t − d at tick t, and the
(boundary-column, best) chunk carry of ``repro.core.sdtw`` is handed to the
right-hand neighbour with one ``lax.ppermute`` per tick. The inter-device
protocol is *identical* to the intra-device chunk carry — a device is just
a very large chunk — mirroring MATSA's inter-subarray pass gates scaled up
to inter-accelerator links.

The optional data-parallel (``dp``) axis crosses that pipeline with query
replication: microbatch slots are sharded over ``dp`` rows (the reference
is replicated within a row), each row runs its own systolic schedule over
its slice of the queries, and the out-spec concatenation over ``dp`` is the
final harvest — queries never communicate across rows because each query's
DP is independent.

Steady-state all ``mp`` devices are busy; pipeline fill/drain costs S − 1
of n_micro + S − 1 ticks. Devices compute garbage during fill (clipped
microbatch indices, zero-filled ppermute carries); only the last device's
in-window ticks are harvested, so the garbage never reaches the output.

Every sharded entry point — ``sdtw_sharded`` (batch), ``sdtw_sharded_feed``
(streaming), top-K, spans — instantiates the ONE builder below
(``build_pipeline``) with an entry policy (``fresh`` carries per microbatch
vs ``carry`` handed in by the caller) and a harvest policy (final
``result`` vs the full ``carry`` tuple). Compiled pipelines live in a
bounded cache keyed on the mesh *fingerprint* (axis names + device ids),
not live Mesh objects — see ``clear_pipeline_cache``/``_cache_size``.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.distances import accum_dtype
from repro.core.sdtw import (default_excl_zone, sdtw_carry_init,
                             sdtw_segment, sdtw_segment_topk)
from repro.core.topk import topk_init
from repro.distributed.collectives import neighbor_perm, psum_harvest
from repro.distributed.sharding import pipeline_axes


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def default_mesh(axis: str = "ref") -> Mesh:
    """1-D mesh over every local device, reference axis sharded."""
    return Mesh(np.asarray(jax.devices()), (axis,))


# ---------------------------------------------------------------------------
# Schedule: microbatch layout + padding/reshape/unpad glue
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PipelineSchedule:
    """Microbatch layout for one pipeline launch.

    ``slots = n_dp * n_micro`` microbatch slots of ``mb`` queries each;
    slot s holds queries [s*mb, (s+1)*mb), dp row r owns slots
    [r*n_micro, (r+1)*n_micro). ``pack``/``unpack`` are inverses around
    the sharded call, so results come back in query order regardless of
    the (dp, mp, n_micro) factorization — which is what makes the sharded
    path bitwise schedule-invariant for int32.
    """
    dp_axis: Optional[str]
    mp_axis: str
    n_dp: int
    n_mp: int
    n_micro: int
    mb: int
    nq: int

    @property
    def slots(self) -> int:
        return self.n_dp * self.n_micro

    def pack(self, arr, fill=0):
        """Pad a (nq, ...) array to slots*mb rows, reshape (slots, mb, ...)."""
        arr = jnp.asarray(arr)
        pad = self.slots * self.mb - arr.shape[0]
        widths = ((0, pad),) + ((0, 0),) * (arr.ndim - 1)
        padded = jnp.pad(arr, widths, constant_values=fill)
        return padded.reshape((self.slots, self.mb) + arr.shape[1:])

    def unpack(self, out):
        """Inverse of ``pack`` over a pytree of (slots, mb, ...) leaves."""
        flat = self.slots * self.mb
        return jax.tree.map(
            lambda o: o.reshape((flat,) + o.shape[2:])[:self.nq], out)


def make_schedule(mesh: Mesh, nq: int, *, ref_axis: str = "ref",
                  dp_axis: Optional[str] = None,
                  n_micro: Optional[int] = None) -> PipelineSchedule:
    """Resolve mesh axes and pick the microbatch layout for ``nq`` queries.

    Default ``n_micro`` fills the systolic pipeline (up to ``n_mp``
    microbatches per dp row) without exceeding the query count. An
    explicit ``n_micro`` is validated: every dp row must get at least one
    real query per microbatch slot, otherwise the schedule would be pure
    padding — reject loudly instead of silently clamping.
    """
    dpax, mpax = pipeline_axes(mesh, ref_axis=ref_axis, dp_axis=dp_axis)
    n_dp = mesh.shape[dpax] if dpax is not None else 1
    n_mp = mesh.shape[mpax]
    if n_micro is None:
        n_micro = max(1, min(n_mp, -(-max(1, nq) // n_dp)))
    else:
        n_micro = int(n_micro)
        if n_micro < 1:
            raise ValueError(f"n_micro must be >= 1, got {n_micro}")
        if n_dp * n_micro > max(1, nq):
            raise ValueError(
                f"n_micro={n_micro} exceeds the padded batch: {n_dp} dp "
                f"row(s) x {n_micro} microbatches > {nq} queries, so at "
                f"least one microbatch slot would be pure padding; lower "
                f"n_micro or leave it None")
    mb = max(1, -(-nq // (n_dp * n_micro)))
    return PipelineSchedule(dpax, mpax, n_dp, n_mp, n_micro, mb, nq)


def _segment_layout(m: int, n_mp: int, chunk: int):
    """Per-device reference segment length (a chunk multiple) + the chunk."""
    seg = max(1, -(-m // n_mp))
    chunk = min(chunk, seg)
    seg = _ceil_to(seg, chunk)
    return seg, chunk


# ---------------------------------------------------------------------------
# Bounded pipeline cache (keyed on mesh fingerprints, not live Mesh objects)
# ---------------------------------------------------------------------------

_PIPELINE_CACHE: "OrderedDict[tuple, Callable]" = OrderedDict()
PIPELINE_CACHE_MAX = 64


def _mesh_key(mesh: Mesh) -> tuple:
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


def clear_pipeline_cache() -> None:
    """Drop every cached compiled pipeline (tests; device topology change)."""
    _PIPELINE_CACHE.clear()


def _cache_size() -> int:
    """Number of live compiled pipelines (the ``_cache_size()`` pattern)."""
    return len(_PIPELINE_CACHE)


# ---------------------------------------------------------------------------
# THE pipeline builder — the only systolic tick body in the sharded layer
# ---------------------------------------------------------------------------

def build_pipeline(mesh: Mesh, *, dp_axis: Optional[str], mp_axis: str,
                   metric: str, chunk: int, n_micro: int,
                   top_k: Optional[int] = None, excl_zone=0,
                   excl_span: bool = False, track_start: bool = False,
                   entry: str = "fresh", harvest: str = "result"):
    """Build (or fetch) the jitted shard-mapped systolic pipeline.

    One parameterized body serves every sharded path:

      * ``entry='fresh'``  — each microbatch starts from the fresh sDTW
        carry init (the batch paths);
        ``entry='carry'``  — device 0 enters each microbatch from stacked
        caller-provided carries (the streaming feed).
      * ``harvest='result'`` — emit only the final result per tick (the
        running best, or the top-K heap triple);
        ``harvest='carry'``  — emit the full carry tuple exiting the last
        device (boundary column, start lane, best, heap) so the caller can
        keep feeding macro-chunks.

    With ``top_k`` set, the per-microbatch match heap (top-K distances,
    global end positions, and start positions, see ``repro.core.topk``)
    rides the systolic carry exactly like the boundary column — which
    itself gains the start-pointer lane so spans survive the inter-device
    hand-off: each device folds the candidates of its own reference
    segment into the heap it received from the left neighbour, so the heap
    exiting the last device is already the merged cross-shard top-K.

    With a dp axis, microbatch slots (and carries) arrive sharded over it;
    each dp row runs the schedule on its local (n_micro, mb, ...) slice
    and the dp-sharded out-spec stitches rows back — the dp harvest is
    free.
    """
    if entry not in ("fresh", "carry"):
        raise ValueError(f"entry must be 'fresh' or 'carry', got {entry!r}")
    if harvest not in ("result", "carry"):
        raise ValueError(f"harvest must be 'result' or 'carry', got "
                         f"{harvest!r}")
    key = (_mesh_key(mesh), dp_axis, mp_axis, metric, chunk, n_micro,
           top_k, excl_zone, excl_span, track_start, entry, harvest)
    hit = _PIPELINE_CACHE.get(key)
    if hit is not None:
        _PIPELINE_CACHE.move_to_end(key)
        return hit

    n_mp = mesh.shape[mp_axis]
    perm = neighbor_perm(n_mp)
    ticks = n_micro + n_mp - 1
    with_carry = entry == "carry"

    def body(r_shard, q_micro, qlen_micro, lo_micro, hi_micro, m_total,
             j0_base, *carry_args):
        # r_shard: (1, seg) this device's reference segment, replicated
        # over dp. q_micro (and carry leaves): dp-local (n_micro, mb, ...).
        carry_in = carry_args[0] if with_carry else None
        d = lax.axis_index(mp_axis)
        seg = r_shard.shape[1]
        j0 = j0_base + d * seg
        mb, n = q_micro.shape[1], q_micro.shape[2]
        acc = accum_dtype(jnp.result_type(q_micro, r_shard))
        fresh = sdtw_carry_init(mb, n, acc,
                                track_start=top_k is not None and
                                track_start)
        if top_k is not None:
            fresh = fresh + topk_init(mb, top_k, acc)

        def tick(carry, t):
            mb_idx = jnp.clip(t - d, 0, n_micro - 1)
            pick = lambda x: lax.dynamic_index_in_dim(x, mb_idx,
                                                      keepdims=False)
            q, ql = pick(q_micro), pick(qlen_micro)
            lo, hi = pick(lo_micro), pick(hi_micro)
            # Device 0 always *enters* a microbatch: from the fresh init
            # (batch) or from the caller's stacked carry (feed). The
            # others continue from whatever the left neighbour handed
            # over.
            own = jax.tree.map(pick, carry_in) if with_carry else fresh
            cin = jax.tree.map(
                lambda f, c: jnp.where(d == 0, f, c.astype(f.dtype)),
                own, carry)
            if top_k is not None:
                ez = (default_excl_zone(ql) if excl_zone is None
                      else jnp.full(ql.shape, excl_zone, jnp.int32))
                cout = sdtw_segment_topk(q, r_shard[0], ql, cin, j0,
                                         m_total, metric, chunk, lo, hi,
                                         top_k, ez, excl_span, track_start)
            else:
                cout = sdtw_segment(q, r_shard[0], ql, cin, j0, m_total,
                                    metric, chunk, lo, hi)
            if harvest == "carry":
                emit = cout                        # full carry hand-off
            elif top_k is not None:
                emit = cout[-3:]                   # heap: d, ends, starts
            else:
                emit = cout[1]                     # running best
            nxt = jax.tree.map(lambda x: lax.ppermute(x, mp_axis, perm),
                               cout)
            return nxt, emit

        # The scan init never reaches a harvested value: device 0 always
        # swaps in its entry carry, and downstream devices only consume
        # ppermute'd outputs — ``fresh`` is just a correctly-shaped seed.
        _, outs = lax.scan(tick, fresh, jnp.arange(ticks))  # (ticks, mb,…)
        # The last device finishes microbatch μ at tick μ + n_mp - 1; only
        # its in-window ticks carry fully merged results.
        return psum_harvest(outs, mp_axis, n_mp, n_micro)

    mspec = P(dp_axis) if dp_axis is not None else P()
    in_specs = (P(None, mp_axis), mspec, mspec, mspec, mspec, P(), P())
    if with_carry:
        in_specs = in_specs + (mspec,)             # pytree prefix
    mapped = shard_map(
        body, mesh=mesh,
        in_specs=in_specs,
        out_specs=mspec,                           # pytree prefix
        check_vma=False)
    fn = jax.jit(mapped)
    _PIPELINE_CACHE[key] = fn
    while len(_PIPELINE_CACHE) > PIPELINE_CACHE_MAX:
        _PIPELINE_CACHE.popitem(last=False)
    return fn


# ---------------------------------------------------------------------------
# Entry points — thin instantiations of the one builder
# ---------------------------------------------------------------------------

def sdtw_sharded_feed(r_macro, q_micro, qlen_micro, lo_micro, hi_micro,
                      carry, j0: int, m_total: int, *, mesh: Mesh,
                      axis: str = "ref", dp_axis: Optional[str] = None,
                      chunk: int, metric: str,
                      top_k=None, excl_zone=None, excl_span: bool = False,
                      track_start: bool = False):
    """Advance stacked per-microbatch carries by one sharded macro-chunk.

    ``r_macro`` is (n_mp * seg,) with seg a multiple of ``chunk``; systolic
    device d processes global columns ``[j0 + d*seg, j0 + (d+1)*seg)``.
    ``carry`` leaves are (slots, mb, ...) with slots = n_dp * n_micro, as
    produced by a previous feed (or the caller's stacked fresh init); the
    return value is the updated carry in the same layout. ``m_total``
    masks columns past the true stream end, so a right-padded final
    macro-chunk still folds correct distances/heaps (its exiting boundary
    column is garbage — a padded feed must be the last, which is why the
    sharded session treats a tail flush as terminal)."""
    dpax, mpax = pipeline_axes(mesh, ref_axis=axis, dp_axis=dp_axis)
    n_dp = mesh.shape[dpax] if dpax is not None else 1
    n_mp = mesh.shape[mpax]
    slots = q_micro.shape[0]
    if slots % n_dp:
        raise ValueError(f"{slots} microbatch slots do not split over "
                         f"{n_dp} dp rows")
    n_micro = slots // n_dp
    seg = r_macro.shape[0] // n_mp
    if seg * n_mp != r_macro.shape[0] or seg % chunk:
        raise ValueError(
            f"macro-chunk of {r_macro.shape[0]} does not split into "
            f"{n_mp} devices x multiple of chunk={chunk}")
    run = build_pipeline(mesh, dp_axis=dpax, mp_axis=mpax, metric=metric,
                         chunk=chunk, n_micro=n_micro, top_k=top_k,
                         excl_zone=excl_zone, excl_span=excl_span,
                         track_start=track_start,
                         entry="carry", harvest="carry")
    return run(r_macro.reshape(1, n_mp * seg), q_micro, qlen_micro,
               lo_micro, hi_micro, jnp.int32(m_total), jnp.int32(j0),
               carry)


def sdtw_sharded(queries, reference, qlens=None, *, metric: str = "abs_diff",
                 mesh: Optional[Mesh] = None, axis: str = "ref",
                 dp_axis: Optional[str] = None,
                 chunk: int = 8192, n_micro: Optional[int] = None,
                 excl_lo=None, excl_hi=None,
                 top_k: Optional[int] = None,
                 excl_zone: Optional[int] = None,
                 return_positions: bool = False,
                 return_spans: bool = False, excl_mode: str = "end"):
    """Batched sDTW with the reference sharded across the mesh.

    queries (nq, N), reference (M,) → (nq,) distances, matching the
    single-device engine bit-for-bit for int32 inputs — across every
    (dp, mp) factorization and every valid ``n_micro``.

    On a 1-D mesh the whole device set forms the systolic pipeline; on a
    2-D (dp, mp) mesh each dp row runs the pipeline over its shard of the
    query microbatches with the reference replicated within the row (build
    one with ``repro.distributed.get_mesh``).

    ``top_k=k`` returns ``(dists (nq, k), positions (nq, k))`` — the match
    heap travels with the microbatch through the device pipeline (the same
    ppermute that hands over the boundary column), so the cross-shard merge
    costs no extra collective; positions are global reference indices.
    ``return_positions=True`` alone returns the top-1 pair;
    ``return_spans=True`` returns ``(dists, starts, ends)`` — the
    start-pointer lane crosses devices inside the same ppermute'd carry.
    ``excl_mode='span'`` keys heap suppression on span overlap.
    """
    if mesh is None:
        mesh = default_mesh(axis)
    queries = jnp.asarray(queries)
    reference = jnp.asarray(reference)
    nq, n = queries.shape
    m = reference.shape[0]
    if qlens is None:
        qlens = jnp.full((nq,), n, jnp.int32)
    if excl_lo is None:
        excl_lo = jnp.full((nq,), -1, jnp.int32)
    if excl_hi is None:
        excl_hi = jnp.full((nq,), -1, jnp.int32)

    sched = make_schedule(mesh, nq, ref_axis=axis, dp_axis=dp_axis,
                          n_micro=n_micro)
    seg, chunk = _segment_layout(m, sched.n_mp, chunk)
    r_pad = jnp.pad(reference, (0, seg * sched.n_mp - m)).reshape(
        1, seg * sched.n_mp)

    wants_pair = top_k is not None or return_positions or return_spans
    kk = (1 if top_k is None else top_k) if wants_pair else None
    if excl_zone is not None and np.ndim(excl_zone) != 0:
        # The zone is baked into the cached pipeline build; per-query
        # arrays (which sdtw_chunked accepts) would need to ride the
        # traced inputs — reject loudly rather than crash in int().
        raise ValueError("sdtw_sharded takes a scalar excl_zone (or None "
                         "for the per-query default); per-query zone "
                         "arrays are only supported on the single-device "
                         "chunked path")
    # zone is unused by the plain pipeline — pin it so non-top-K calls
    # share one pipeline cache entry. None = derive per query in the body
    # (half the true query length — or 0 in span mode — matching the
    # single-device default).
    if kk is None:
        zone = 0
    elif excl_zone is not None:
        zone = int(excl_zone)
    else:
        zone = None if excl_mode == "end" else 0
    # The start lane crosses the ppermute carry only when starts are
    # consumed (spans requested or span-overlap suppression).
    track = return_spans or excl_mode == "span"
    run = build_pipeline(mesh, dp_axis=sched.dp_axis, mp_axis=sched.mp_axis,
                         metric=metric, chunk=chunk, n_micro=sched.n_micro,
                         top_k=kk, excl_zone=zone,
                         excl_span=excl_mode == "span", track_start=track,
                         entry="fresh", harvest="result")
    outs = run(r_pad, sched.pack(queries),
               sched.pack(qlens, fill=1),
               sched.pack(excl_lo, fill=-1), sched.pack(excl_hi, fill=-1),
               jnp.int32(m), jnp.int32(0))
    if not wants_pair:
        return sched.unpack(outs)
    dists, poss, starts = sched.unpack(outs)
    if top_k is None:                       # top-1, unstacked
        if return_spans:
            return dists[:, 0], starts[:, 0], poss[:, 0]
        return dists[:, 0], poss[:, 0]
    if return_spans:
        return dists, starts, poss
    return dists, poss
