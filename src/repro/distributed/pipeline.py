"""GPipe-style pipeline parallelism over a mesh axis (shard_map +
collective_permute).

The layer stack is split into S contiguous stages, one per device along the
``stage`` axis; microbatches stream through with the classic GPipe schedule
(T = n_micro + S − 1 ticks; stage s processes microbatch t − s at tick t).
Activations move between stages with a single ppermute per tick — the
communication pattern maps 1:1 onto TPU ICI neighbours.

This is the optional PP axis of DESIGN.md §6 (the production dry-runs use
DP+TP, which fits every assigned arch); it exists, is tested against the
sequential execution in tests/_distributed_check.py, and composes with the
data-parallel axes (shard_map over ("stage",) while batch dims stay sharded
over dp).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.distributed.collectives import neighbor_perm, psum_harvest


def split_stages(stacked_params, n_stages: int):
    """Reshape [L, ...] stacked layer params into [S, L/S, ...]."""
    def r(p):
        l = p.shape[0]
        assert l % n_stages == 0, f"{l} layers not divisible by {n_stages}"
        return p.reshape(n_stages, l // n_stages, *p.shape[1:])
    return jax.tree.map(r, stacked_params)


def pipeline_apply(block_fn, stage_params, x_micro, mesh, axis: str = "stage"):
    """Run microbatches through pipeline stages.

    Args:
      block_fn: (layer_params, activation) → activation — one LAYER; each
        stage scans its local layers.
      stage_params: pytree with leading [S, L/S, ...] dims (split_stages).
      x_micro: (n_micro, mb, ...) microbatched input activations.
      mesh: mesh containing ``axis`` of size S.
    Returns: (n_micro, mb, ...) outputs (replicated over the stage axis).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1
    perm = neighbor_perm(n_stages)

    def stage_body(params_local, xs):
        # params_local: [1, L/S, ...] (shard_map keeps the stage dim), xs
        # replicated (n_micro, mb, ...).
        params_local = jax.tree.map(lambda p: p[0], params_local)
        sid = lax.axis_index(axis)
        zero = jnp.zeros_like(xs[0])

        def tick(carry, t):
            held = carry                       # activation entering my stage
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            fresh = lax.dynamic_index_in_dim(xs, mb_idx, keepdims=False)
            inp = jnp.where(sid == 0, fresh, held)

            def layer(x, lp):
                return block_fn(lp, x), None
            out, _ = lax.scan(layer, inp, params_local)
            nxt = lax.ppermute(out, axis, perm)
            return nxt, out

        _, outs = lax.scan(tick, zero, jnp.arange(ticks))   # (ticks, mb,…)
        # Last stage emits microbatch m at tick m + S - 1; harvest its
        # window and replicate to all stages.
        return psum_harvest(outs, axis, n_stages, n_micro)

    in_specs = jax.tree.map(lambda p: P(axis), stage_params)
    return shard_map(
        stage_body, mesh=mesh,
        in_specs=(in_specs, P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x_micro)
