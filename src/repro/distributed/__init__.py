from .sharding import Axes, tree_shardings

__all__ = ["Axes", "tree_shardings"]
