from .sharding import Axes, tree_shardings

__all__ = ["Axes", "tree_shardings", "sdtw_sharded"]


def __getattr__(name):
    # Lazy: sdtw_sharded pulls in repro.core; keep the base import light and
    # cycle-free (repro.core.engine lazily imports this module too).
    if name == "sdtw_sharded":
        from .sdtw_sharded import sdtw_sharded
        return sdtw_sharded
    raise AttributeError(name)
