from .sharding import (Axes, get_mesh, init_multi_host, pipeline_axes,
                       tree_shardings)

__all__ = ["Axes", "get_mesh", "init_multi_host", "pipeline_axes",
           "tree_shardings", "sdtw_sharded", "sdtw_sharded_feed",
           "build_pipeline", "make_schedule", "PipelineSchedule",
           "clear_pipeline_cache"]

_SDTW_NAMES = ("sdtw_sharded", "sdtw_sharded_feed", "build_pipeline",
               "make_schedule", "PipelineSchedule", "clear_pipeline_cache")


def __getattr__(name):
    # Lazy: the sharded driver pulls in repro.core; keep the base import
    # light and cycle-free (repro.core.engine lazily imports this module
    # too). Pin resolved names into globals() so the function named like
    # its defining submodule (sdtw_sharded) stays the function on repeat
    # access.
    if name in _SDTW_NAMES:
        import importlib
        mod = importlib.import_module(".sdtw_sharded", __name__)
        val = getattr(mod, name)
        globals()[name] = val
        return val
    raise AttributeError(name)
