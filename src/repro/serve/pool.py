"""Device pool: coalesced groups multiplexed over an explicit device set.

PR 7's router executed every merged engine call inline on the drain
thread against the process-global default device. The pool gives the
serve tier an explicit device topology instead: one worker thread per
device, each pinned via ``jax.default_device`` (thread-local in jax), so

  * groups drained from one window run **concurrently across devices**
    (one DP dispatch per device at a time), and
  * the host-side work of a group — merging trimmed queries before the
    call, slicing the batched result back per client and resolving
    futures after it — runs on the worker threads, overlapping the next
    group's device DP instead of serializing behind it on the drain
    thread.

Device selection (``devices=``):

  * ``None``  — one worker on the process-default device (PR 7
    behavior, still the default);
  * ``'all'`` — one worker pinned to each ``jax.local_devices()`` entry;
  * ``int n`` — the first n local devices;
  * an explicit sequence of jax devices (duplicates allowed: two
    workers sharing one device still overlap host slicing with DP).

Routing is **executable-affine** (``pick_device``): jit executables are
compiled per device assignment, so a group's first landing on a device
pays an XLA compile for its bucket shape (``batcher.group_shape``).
Naive least-loaded routing recompiles that shape on every device a
transient backlog happens to spill onto — a recurring multi-second tail
at serving time. Instead a process-global warm map (mirroring the jit
cache, which is process-global too — a new pool inherits placements
already compiled) remembers which devices have run each shape, and the
pool prefers the least-loaded *warm* one; it grows the warm set
onto a cold idle device only when every warm device is busy (sustained
same-shape pressure makes the one-off compile an investment, after
which the shape is warm there too) and only one cold landing at a time
per shape — an unthrottled grow rule avalanches, because the compile
itself keeps the cold device busy and pushes the next group onto yet
another cold device. A never-seen shape goes to the globally
least-loaded device.

Correctness: a group runs start-to-finish on one worker, the engine's
executables are compiled per device assignment, and the DP is integer
(int32) — so pooled answers are bitwise identical to a single-device
drain (pinned by ``tests/test_serve.py`` and the ``serve_bench``
``served_vs_offline`` gate). Each worker owns a private work queue;
the pool is unbounded because admission is already bounded upstream by
the ``AdmissionQueue``.
"""
from __future__ import annotations

import collections
import queue as _stdqueue
import threading

from . import batcher

__all__ = ["DevicePool", "clear_affinity_cache", "pick_device"]

# The jit cache is process-global (keyed on device assignment), so the
# warm map must be too: a fresh pool over the same devices inherits
# every placement already compiled instead of re-discovering them —
# bounded LRU like the distributed pipeline cache.
AFFINITY_CACHE_MAX = 1024
_affinity_lock = threading.Lock()
_warm_devices: "collections.OrderedDict" = collections.OrderedDict()
_growing: set = set()          # shapes with a cold landing in flight


def clear_affinity_cache():
    """Drop the process-global shape→devices warm map (tests)."""
    with _affinity_lock:
        _warm_devices.clear()
        _growing.clear()


def _mark_warm(shape, device):
    with _affinity_lock:
        _warm_devices.setdefault(shape, set()).add(device)
        _warm_devices.move_to_end(shape)
        while len(_warm_devices) > AFFINITY_CACHE_MAX:
            _warm_devices.popitem(last=False)


def resolve_devices(devices):
    """Normalize the ``devices=`` config into a list of worker bindings
    (``None`` = process-default device, i.e. no pinning)."""
    if devices is None:
        return [None]
    import jax
    if devices == "all":
        return list(jax.local_devices())
    if isinstance(devices, int):
        local = jax.local_devices()
        if not 1 <= devices <= len(local):
            raise ValueError(
                f"devices={devices} but only {len(local)} local "
                f"device(s) are visible; pass 1..{len(local)}, 'all', "
                "or an explicit device sequence")
        return local[:devices]
    out = list(devices)
    if not out:
        raise ValueError("devices= must name at least one device "
                         "(or None for the process default)")
    return out


# A warm device must have this many groups in flight/queued before the
# pool pays a cold compile to spread the shape: load 1 is every burst's
# steady state (one group per window), load >= 2 is a real backlog.
GROW_LOAD = 2


def pick_device(loads, warm, growing=False):
    """Executable-affinity routing policy (pure; caller holds the lock).

    ``loads`` is the per-device in-flight group count; ``warm`` the set
    of device indices that have already compiled this group's shape;
    ``growing`` is True while a previous cold landing of this shape is
    still in flight (i.e. the shape is mid-compile somewhere).

      * never-seen shape            → globally least-loaded device;
      * least-loaded warm device is
        below ``GROW_LOAD``         → that device (free cache reuse);
      * warm backlogged, cold idle,
        and not already growing     → lowest cold idle index (grow the
                                      warm set under pressure — pay one
                                      compile to add parallelism);
      * otherwise                   → least-loaded warm device (queueing
                                      milliseconds beats compiling
                                      seconds).

    The ``growing`` gate caps cold landings at one in flight per shape,
    and ``GROW_LOAD`` demands a real backlog first. Without them a
    compile *avalanches*: the first cold landing keeps its device busy
    for seconds, so every subsequent same-shape group "grows" onto yet
    another cold device and recompiles there — the pool floods itself
    with concurrent compiles of one executable.

    Ties break on the lowest index for determinism."""
    if warm:
        w = min(warm, key=lambda i: (loads[i], i))
        if loads[w] < GROW_LOAD or growing:
            return w
        for i, load in enumerate(loads):
            if load == 0 and i not in warm:
                return i
        return w
    return min(range(len(loads)), key=lambda i: (loads[i], i))


class DevicePool:
    """Per-device worker threads executing coalesced request groups."""

    def __init__(self, devices=None, *, name: str = "repro-serve-dev"):
        self._devices = resolve_devices(devices)
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._inflight = 0           # groups submitted, not yet finished
        self._loads = [0] * len(self._devices)
        self._queues = [_stdqueue.SimpleQueue() for _ in self._devices]
        self._closed = False
        self._threads = []
        for i, dev in enumerate(self._devices):
            t = threading.Thread(target=self._worker, args=(i, dev),
                                 name=f"{name}{i}", daemon=True)
            t.start()
            self._threads.append(t)

    @property
    def devices(self) -> list:
        return list(self._devices)

    @property
    def size(self) -> int:
        return len(self._devices)

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------

    def submit(self, group, telemetry=None):
        """Route one coalesced group to a worker (executable-affine, see
        ``pick_device``). Every member future is guaranteed an answer
        (``execute_group``'s contract); returns immediately."""
        with self._lock:
            if self._closed:
                raise RuntimeError("device pool is closed")
            shape = batcher.group_shape(group)
            with _affinity_lock:
                warm_devs = _warm_devices.setdefault(shape, set())
                _warm_devices.move_to_end(shape)
                while len(_warm_devices) > AFFINITY_CACHE_MAX:
                    _warm_devices.popitem(last=False)
                warm = {i for i, d in enumerate(self._devices)
                        if d in warm_devs}
                i = pick_device(self._loads, warm,
                                growing=shape in _growing)
                cold = i not in warm
                if cold:
                    _growing.add(shape)
                warm_devs.add(self._devices[i])
            self._loads[i] += 1
            self._inflight += 1
        self._queues[i].put((group, telemetry, shape if cold else None))

    def warmup(self, request) -> int:
        """Compile ``request``'s executables on every pool device and
        prime the affinity map, so no client ever pays the shape's XLA
        compile or waits out the warm set's backlog-gated growth.

        Runs sequentially (concurrent cold compiles contend with each
        other) and blocks until done — call before accepting traffic,
        with requests shaped like the coalesced buckets production
        windows will form (e.g. ``window_full_queries`` queries at
        serving length). Returns the number of devices warmed."""
        with self._lock:
            if self._closed:
                raise RuntimeError("device pool is closed")
        p = batcher.Pending(request=request, future=None, trace=None)
        shape = batcher.group_shape([p])
        for dev in self._devices:
            if dev is None:
                request.run()
            else:
                import jax
                with jax.default_device(dev):
                    request.run()
            _mark_warm(shape, dev)
        return len(self._devices)

    def join(self):
        """Block until every submitted group has finished executing."""
        with self._idle:
            self._idle.wait_for(lambda: self._inflight == 0)

    def close(self, *, wait: bool = True):
        """Stop the workers (after finishing queued work when ``wait``)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        for q in self._queues:
            q.put(None)
        if wait:
            for t in self._threads:
                t.join(timeout=10.0)

    # ------------------------------------------------------------------
    # workers
    # ------------------------------------------------------------------

    def _worker(self, i: int, dev):
        while True:
            task = self._queues[i].get()
            if task is None:
                return
            group, telemetry, cold_shape = task
            try:
                if dev is None:
                    batcher.execute_group(group, telemetry=telemetry)
                else:
                    import jax
                    with jax.default_device(dev):
                        batcher.execute_group(group, telemetry=telemetry)
            except Exception as exc:                     # noqa: BLE001
                # execute_group never raises by contract; this is a
                # last-ditch guard so a pool bug can never orphan
                # admitted futures.
                batcher.fail_group(group, exc, telemetry=telemetry)
            finally:
                if cold_shape is not None:
                    with _affinity_lock:
                        _growing.discard(cold_shape)
                with self._idle:
                    self._loads[i] -= 1
                    self._inflight -= 1
                    if self._inflight == 0:
                        self._idle.notify_all()

    def __enter__(self) -> "DevicePool":
        return self

    def __exit__(self, *exc):
        self.close()
