"""Microbatch coalescing: many client requests → few engine dispatches.

A drained window of admitted requests is grouped by
``SdtwRequest.coalesce_key()`` (everything that selects a compiled
executable or changes per-query semantics) plus the reference identity
and the query dtype. Each group becomes ONE merged ragged engine call:
every client's queries are trimmed to true length and concatenated into
one ragged list, so the engine's existing power-of-two bucketing yields
one DP dispatch per bucket per window — serving reuses the exact
amortization machinery of the offline path instead of duplicating it.

Within a group, **identical** requests deduplicate: the dedup key is
``(ref_fingerprint, query fingerprint, coalesce_key)`` — the group key
already pins the first and last components, and the query fingerprint
hashes each trimmed query's shape/dtype/bytes — so N concurrent clients
asking the same question cost one engine call and share one result
object (the same sliced arrays, bitwise-trivially; pinned by tests).

Correctness contract (pinned by ``tests/test_serve.py``):

  * ``op='sdtw'`` — the DP is per-query independent and the padded
    columns are masked by ``qlens``, so the merged call is **bitwise**
    identical (int32) to each client calling ``engine.sdtw`` alone.
  * ``op='search_topk'`` — the LB-cascade thresholds are batch-shared
    (a chunk is pruned only when *no* query in the batch can improve),
    so the merged call is bitwise identical to one offline *batched*
    ``search_topk`` over the same queries; top-1 distances additionally
    match the per-client calls exactly (the cascade never prunes a true
    winner).

A group of one request dispatches the request unchanged — zero
repacking, trivially identical to the offline call.

Delivery is cancellation-safe: a client that cancelled its future
before delivery is skipped via ``set_running_or_notify_cancel()`` (and
counted in telemetry) without disturbing the other members — a
cancelled future can no longer poison its group.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core.request import SdtwRequest

from .telemetry import RequestTrace


@dataclasses.dataclass
class Pending:
    """One admitted request waiting for dispatch."""
    request: SdtwRequest
    future: object               # concurrent.futures.Future
    trace: RequestTrace
    single: bool = False         # client passed one 1-D query
    entries: list = None         # true-length 1-D query arrays
    dupes: list = None           # identical requests sharing this
                                 # member's engine call and result


def ref_fingerprint(req: SdtwRequest):
    """Reference identity for grouping: the user's stable ``ref_key``
    when given (callers assert equal keys mean equal content — same
    contract as the envelope cache), else object identity; shape/dtype
    folded in so a stale key can never merge mismatched references."""
    ref = np.asarray(req.reference)
    base = req.ref_key if req.ref_key is not None else ("id",
                                                        id(req.reference))
    return (base, ref.shape, str(ref.dtype))


def query_entries(req: SdtwRequest):
    """Flatten a request's queries into true-length 1-D arrays.

    Returns ``(entries, single)`` — padded 2-D input is trimmed per
    ``qlens`` (the engine masks padded columns by qlens, so repacking
    is bitwise-invariant; the repo's ragged differential tests pin
    this)."""
    q = req.queries
    if isinstance(q, (list, tuple)):
        return [np.asarray(x) for x in q], False
    arr = np.asarray(q)
    if arr.ndim == 1:
        return [arr], True
    if req.qlens is not None:
        lens = np.asarray(req.qlens).astype(int)
        return [arr[i, :lens[i]] for i in range(arr.shape[0])], False
    return [arr[i] for i in range(arr.shape[0])], False


def query_fingerprint(p: Pending):
    """Content hash of a request's trimmed queries — the in-window dedup
    key component. Two requests with equal group keys and equal query
    fingerprints would run the byte-identical engine call, so one runs
    and both share its result. ``single`` is folded in because a 1-D
    client's slice unwraps to a scalar shape."""
    h = hashlib.blake2b(digest_size=16)
    h.update(b"1" if p.single else b"0")
    for e in p.entries:
        arr = np.ascontiguousarray(e)
        h.update(str(arr.shape).encode())
        h.update(str(arr.dtype).encode())
        h.update(arr.tobytes())
    return h.digest()


def group_key(req: SdtwRequest):
    """Full coalescing key: semantic key × reference × query dtype (the
    accumulator dtype depends on both operand dtypes, so mixing query
    dtypes in one batch would change every client's result type).
    Per-query exclusion *arrays* are sized to one request's batch and
    cannot be concatenated semantically — such requests never coalesce
    at all (unique key), even when two clients share the array object."""
    entries, _ = query_entries(req)
    qdtype = str(np.result_type(*entries)) if entries else "none"
    per_query = tuple(np.ndim(v) != 0 for v in
                      (req.excl_zone, req.excl_lo, req.excl_hi)
                      if v is not None)
    solo = (id(req),) if any(per_query) else ()
    return req.coalesce_key(ref_id=ref_fingerprint(req)) + (qdtype,) + solo


def group_window(pending: list, *, dedup: bool = True) -> list:
    """Partition a drained window into coalescable groups (stable
    order). With ``dedup`` (the default), identical requests within a
    group collapse onto the first-submitted member's ``dupes`` list —
    only the surviving members contribute query entries to the merged
    call."""
    groups: dict = {}
    for p in pending:
        p.entries, p.single = query_entries(p.request)
        p.dupes = []
        groups.setdefault(group_key(p.request), []).append(p)
    if not dedup:
        return list(groups.values())
    out = []
    for members in groups.values():
        primaries: dict = {}
        kept = []
        for p in members:
            fp = query_fingerprint(p)
            prim = primaries.get(fp)
            if prim is None:
                primaries[fp] = p
                kept.append(p)
            else:
                prim.dupes.append(p)
        out.append(kept)
    return out


def _pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def group_shape(group: list):
    """Proxy for the compiled executable a merged group will exercise:
    the pow-2 bucket its ragged batch lands in (query count and length
    are both bucketed by the engine) plus op and reference shape/dtype.
    The ``DevicePool`` keys executable affinity on this — two groups
    with equal shapes hit the same jit cache entry on a device that has
    run either, so routing them together avoids a recompile. The key is
    a heuristic: an imprecise match only costs one extra compile, never
    correctness (results are device-invariant, pinned by tests)."""
    p0 = group[0]
    for p in group:
        if p.entries is None:
            p.entries, p.single = query_entries(p.request)
    total = sum(len(p.entries) for p in group)
    qmax = max((e.shape[-1] for p in group for e in p.entries), default=0)
    ref = np.asarray(p0.request.reference)
    return (p0.request.op, _pow2(total), _pow2(qmax), ref.shape,
            str(ref.dtype))


def group_members(group: list):
    """Every client request answered by this group's engine call —
    the surviving members plus their deduplicated twins."""
    for p in group:
        yield p
        yield from (p.dupes or ())


def _slice_result(res, i0: int, i1: int, single: bool):
    """Cut one client's rows out of a merged result (array, tuple of
    arrays, or SearchResult — every payload's leading axis is nq)."""
    if isinstance(res, tuple):
        return tuple(_slice_result(r, i0, i1, single) for r in res)
    if hasattr(res, "distances"):        # SearchResult: slice the payload,
        return dataclasses.replace(      # share the batch-level telemetry
            res,
            distances=_slice_result(res.distances, i0, i1, single),
            positions=_slice_result(res.positions, i0, i1, single),
            starts=_slice_result(res.starts, i0, i1, single))
    out = res[i0:i1]
    return out[0] if single else out


def _deliver_one(p: Pending, result, exc, telemetry):
    """Resolve one member future, tolerating client cancellation and
    already-resolved futures (a cancelled/raced member must not disturb
    its groupmates)."""
    fut = p.future
    if fut.cancelled():
        if telemetry is not None:
            telemetry.record_cancelled(p.trace)
        return
    if fut.done():
        return                          # answered elsewhere (close race)
    if not fut.set_running_or_notify_cancel():
        if telemetry is not None:       # cancelled between the checks
            telemetry.record_cancelled(p.trace)
        return
    p.trace.mark_complete(error=exc is not None)
    if telemetry is not None:
        telemetry.record_complete(p.trace)
    if exc is not None:
        fut.set_exception(exc)
    else:
        fut.set_result(result)


def fail_group(group: list, exc, telemetry=None):
    """Answer every not-yet-resolved member future with ``exc``."""
    for p in group_members(group):
        _deliver_one(p, None, exc, telemetry)


def execute_group(group: list, telemetry=None):
    """Run one coalesced group and deliver every client future.

    Never raises: an execution error is propagated into every member
    future (the admission contract — admitted requests are always
    answered). Deduplicated twins receive the *same* result object as
    their surviving member. Each trace is completed and recorded
    *before* its future resolves, so a client that has its result is
    guaranteed to already be counted in the stats snapshot."""
    n_queries = sum(len(p.entries) for p in group)
    n_members = sum(1 for _ in group_members(group))
    for p in group_members(group):
        p.trace.mark_dispatch(batch_requests=n_members,
                              batch_queries=n_queries)

    def deliver(p, result=None, exc=None):
        for member in (p, *(p.dupes or ())):
            _deliver_one(member, result, exc, telemetry)

    try:
        if len(group) == 1:
            deliver(group[0], group[0].request.run())
            return
        merged = [e for p in group for e in p.entries]
        base = group[0].request
        res = dataclasses.replace(base, queries=merged, qlens=None).run()
        i0 = 0
        for p in group:
            i1 = i0 + len(p.entries)
            deliver(p, _slice_result(res, i0, i1, p.single))
            i0 = i1
    except Exception as exc:                           # noqa: BLE001
        fail_group(group, exc, telemetry=telemetry)
