"""A pool of streaming sessions multiplexed over shared reference feeds.

Online monitoring under serving: many tenants watch the *same* arriving
reference stream (one sensor feed, N monitoring queries). The pool keys
sessions by feed, so one ``feed()`` call advances every tenant attached
to that feed — each tenant keeps its own ``StreamSession`` (its own
queries, top-K heaps, alerts), but the arriving chunk is shared and the
per-chunk work amortizes across the pool exactly like the offline
batcher amortizes queries.

Tenant churn semantics (pinned by tests):

  * attach mid-feed → the new session starts at the *current* stream
    position; it only scores data fed after attachment (a monitoring
    query cannot retroactively see history it was not subscribed for —
    replay from a ``snapshot()`` if catch-up is needed).
  * detach → finalizes that tenant's session and returns its results;
    the feed keeps flowing for the others.
  * ``snapshot()``/``restore()`` round-trip the whole feed (every
    tenant) through flat npz-ready dicts — sessions continue
    bit-for-bit, the same fault-tolerance contract as a single
    ``StreamSession``.
"""
from __future__ import annotations

import threading
from typing import Optional

from repro.core.request import StreamRequest


class StreamSessionPool:
    """``feed_key → {tenant → StreamSession}`` with shared feeding."""

    def __init__(self):
        self._lock = threading.Lock()
        self._feeds: dict = {}

    # ------------------------------------------------------------------
    # tenant lifecycle
    # ------------------------------------------------------------------

    def attach(self, feed_key, tenant, request: Optional[StreamRequest]
               = None, **stream_kwargs):
        """Open a session for ``tenant`` on ``feed_key`` and return it.

        Pass a prebuilt ``StreamRequest`` or the ``engine.stream``
        keyword surface (validated by the shared validator — unknown
        kwargs are rejected loudly)."""
        if request is None:
            request = StreamRequest.from_kwargs(**stream_kwargs)
        elif stream_kwargs:
            raise ValueError("pass a StreamRequest or stream kwargs, "
                             "not both")
        session = request.open()
        with self._lock:
            tenants = self._feeds.setdefault(feed_key, {})
            if tenant in tenants:
                raise ValueError(f"tenant {tenant!r} is already attached "
                                 f"to feed {feed_key!r}; detach it first")
            tenants[tenant] = session
        return session

    def detach(self, feed_key, tenant, *, finalize: bool = True):
        """Remove ``tenant`` from the feed; returns its finalized
        ``StreamResult`` (or the raw session with ``finalize=False``)."""
        with self._lock:
            session = self._feeds[feed_key].pop(tenant)
            if not self._feeds[feed_key]:
                del self._feeds[feed_key]
        if not finalize:
            return session
        return session.results()

    def session(self, feed_key, tenant):
        with self._lock:
            return self._feeds[feed_key][tenant]

    def tenants(self, feed_key) -> list:
        with self._lock:
            return sorted(self._feeds.get(feed_key, {}))

    def feeds(self) -> list:
        with self._lock:
            return sorted(self._feeds, key=repr)

    # ------------------------------------------------------------------
    # the shared feed
    # ------------------------------------------------------------------

    def feed(self, feed_key, data) -> int:
        """Advance every tenant on ``feed_key`` by one arriving slice;
        returns the number of sessions fed."""
        with self._lock:
            sessions = list(self._feeds.get(feed_key, {}).values())
        for s in sessions:
            s.feed(data)
        return len(sessions)

    def finalize(self, feed_key) -> dict:
        """Collect every tenant's results (``StreamSession.results()``
        applies the buffered tail non-destructively) and drop the feed;
        returns ``{tenant: StreamResult}``."""
        with self._lock:
            tenants = self._feeds.pop(feed_key, {})
        return {t: s.results() for t, s in tenants.items()}

    # ------------------------------------------------------------------
    # snapshot / restore (whole-feed fault tolerance)
    # ------------------------------------------------------------------

    def snapshot(self, feed_key) -> dict:
        """``{tenant: flat-npz-dict}`` for every tenant on the feed."""
        with self._lock:
            tenants = dict(self._feeds.get(feed_key, {}))
        return {t: s.snapshot() for t, s in tenants.items()}

    def restore(self, feed_key, snaps: dict, *, session_cls=None,
                **restore_kwargs) -> list:
        """Rebuild a feed from ``snapshot()`` output; returns the
        restored tenant names. ``session_cls`` overrides the session
        type (default ``StreamSession``)."""
        if session_cls is None:
            from repro.stream import StreamSession
            session_cls = StreamSession
        restored = {t: session_cls.restore(snap, **restore_kwargs)
                    for t, snap in snaps.items()}
        with self._lock:
            tenants = self._feeds.setdefault(feed_key, {})
            dup = sorted(set(tenants) & set(restored))
            if dup:
                raise ValueError(f"tenant(s) {dup} already attached to "
                                 f"feed {feed_key!r}")
            tenants.update(restored)
        return sorted(restored)
