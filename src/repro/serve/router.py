"""The admission-controlled request router — the serve tier's front door.

Life of a request::

    client ──► submit() ──► AdmissionQueue ──► microbatch window ──►
    group by coalesce_key ──► ONE ragged engine call per group
    (pow-2 buckets inside) ──► slice per client ──► Future.result()

``submit()`` validates through the shared ``SdtwRequest`` validator
(invalid arguments are refused at the door, synchronously — never
queued), applies backpressure per the admission policy (``QueueFull``),
and returns a ``concurrent.futures.Future``. A background dispatcher
drains the queue every ``window_ms`` and hands each window to the
batcher; ``auto_dispatch=False`` gives deterministic manual control
(tests and the closed-loop benchmark call ``drain()`` themselves).

Shared across every tenant: one ``EnvelopeCache`` (injected into search
requests that did not bring their own), one process-wide jit
executable cache (coalesced groups reuse one compiled bucket shape per
window — the whole point), one ``StreamSessionPool``, one ``Telemetry``.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import threading
from typing import Optional

import numpy as np

from repro.core.request import SdtwRequest, StreamRequest
from repro.search.cache import EnvelopeCache

from . import batcher
from .queue import AdmissionQueue, QueueFull
from .sessions import StreamSessionPool
from .telemetry import RequestTrace, StatsSnapshot, Telemetry

__all__ = ["Router", "RouterConfig", "QueueFull"]


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Serving knobs (defaults favour low latency over occupancy)."""
    max_queue: int = 256          # admission bound (backpressure depth)
    window_ms: float = 2.0        # microbatch coalescing window
    admission: str = "block"      # 'block' | 'reject' on a full queue
    block_timeout_s: Optional[float] = None   # None = wait forever
    auto_dispatch: bool = True    # background dispatcher thread


def _request_nq(req: SdtwRequest) -> int:
    q = req.queries
    if isinstance(q, (list, tuple)):
        return len(q)
    arr = np.asarray(q)
    return 1 if arr.ndim == 1 else arr.shape[0]


class Router:
    """Admission queue + microbatcher + shared caches over the engine."""

    def __init__(self, config: Optional[RouterConfig] = None, *,
                 cache: Optional[EnvelopeCache] = None, **overrides):
        if config is None:
            config = RouterConfig(**overrides)
        elif overrides:
            raise ValueError("pass a RouterConfig or keyword overrides, "
                             "not both")
        self.config = config
        self.cache = EnvelopeCache() if cache is None else cache
        self.telemetry = Telemetry()
        self.sessions = StreamSessionPool()
        self._queue = AdmissionQueue(config.max_queue,
                                     admission=config.admission,
                                     timeout=config.block_timeout_s)
        self._dispatch_lock = threading.Lock()
        self._closed = False
        self._thread = None
        if config.auto_dispatch:
            self._thread = threading.Thread(target=self._dispatch_loop,
                                            name="repro-serve-dispatch",
                                            daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def submit(self, request=None, **kwargs) -> concurrent.futures.Future:
        """Admit one request; returns its Future.

        Accepts a prebuilt ``SdtwRequest`` or the kwargs surface
        (``op='sdtw'`` default; unknown keys rejected loudly). Invalid
        arguments raise here — at the door — with exactly the front-door
        error messages; a full queue raises ``QueueFull``."""
        if self._closed:
            raise RuntimeError("router is closed")
        if request is None:
            request = SdtwRequest.from_kwargs(**kwargs)
        elif kwargs:
            raise ValueError("pass an SdtwRequest or kwargs, not both")
        request.validate()
        if request.op == "search_topk" and request.cache is None:
            request = dataclasses.replace(request, cache=self.cache)
        trace = RequestTrace(op=request.op, nq=_request_nq(request))
        fut = concurrent.futures.Future()
        pending = batcher.Pending(request=request, future=fut, trace=trace)
        try:
            depth = self._queue.put(pending)
        except QueueFull:
            self.telemetry.record_reject()
            raise
        trace.queue_depth = depth
        self.telemetry.observe_depth(depth)
        return fut

    # Blocking conveniences — the offline call signatures, served.
    def sdtw(self, queries, reference, qlens=None, **kw):
        return self.submit(queries=queries, reference=reference,
                           qlens=qlens, op="sdtw", **kw).result()

    def search_topk(self, queries, reference, k: int = 1, **kw):
        return self.submit(queries=queries, reference=reference,
                           top_k=k, op="search_topk", **kw).result()

    # ------------------------------------------------------------------
    # streaming tenants
    # ------------------------------------------------------------------

    def open_stream(self, feed_key, tenant, request:
                    Optional[StreamRequest] = None, **stream_kwargs):
        """Attach a streaming tenant to a reference feed (see
        ``StreamSessionPool``); search-style pruned sessions share the
        router's envelope cache unless they bring their own."""
        if request is None:
            if stream_kwargs.get("prune") and "cache" not in stream_kwargs:
                stream_kwargs["cache"] = self.cache
            request = StreamRequest.from_kwargs(**stream_kwargs)
        elif stream_kwargs:
            raise ValueError("pass a StreamRequest or stream kwargs, "
                             "not both")
        return self.sessions.attach(feed_key, tenant, request)

    def feed(self, feed_key, data) -> int:
        return self.sessions.feed(feed_key, data)

    # ------------------------------------------------------------------
    # dispatch
    # ------------------------------------------------------------------

    def drain(self) -> int:
        """Process every pending request now (one microbatch window);
        returns the number of requests dispatched. Thread-safe; the
        manual-mode workhorse."""
        with self._dispatch_lock:
            window = self._queue.drain()
            if not window:
                return 0
            for grp in batcher.group_window(window):
                self.telemetry.record_dispatch(
                    n_requests=len(grp),
                    n_queries=sum(len(p.entries) for p in grp))
                batcher.execute_group(grp, telemetry=self.telemetry)
            return len(window)

    def _dispatch_loop(self):
        wait = threading.Event()
        while not self._closed:
            if not self._queue.wait_nonempty(timeout=0.1):
                continue
            # Let the microbatch accrue for one window, then drain it.
            wait.wait(self.config.window_ms / 1000.0)
            self.drain()

    # ------------------------------------------------------------------
    # lifecycle / observability
    # ------------------------------------------------------------------

    def stats(self) -> StatsSnapshot:
        return self.telemetry.snapshot()

    def close(self, *, drain: bool = True):
        """Stop admitting; optionally answer everything still queued."""
        if self._closed:
            return
        self._closed = True
        self._queue.close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        if drain:
            self.drain()

    def __enter__(self) -> "Router":
        return self

    def __exit__(self, *exc):
        self.close()
