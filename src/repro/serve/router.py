"""The admission-controlled request router — the serve tier's front door.

Life of a request::

    client ──► submit() ──► AdmissionQueue (priority + quota) ──►
    adaptive microbatch window ──► group by coalesce_key ──► dedup ──►
    DevicePool worker ──► ONE ragged engine call per group
    (pow-2 buckets inside) ──► slice per client ──► Future.result()

``submit()`` validates through the shared ``SdtwRequest`` validator
(invalid arguments are refused at the door, synchronously — never
queued), applies backpressure per the admission policy (``QueueFull``;
under ``'reject'`` a higher-priority arrival may instead shed the
lowest-priority pending request, whose future then fails with
``QueueFull``), and returns a ``concurrent.futures.Future``. A
background dispatcher drains adaptive coalescing windows and hands each
group to the device pool; ``auto_dispatch=False`` gives deterministic
manual control (tests and the closed-loop benchmark call ``drain()``
themselves).

The adaptive window (replacing PR 7's fixed ``window_ms`` sleep):

  * **closes early** the moment the pending query count reaches
    ``window_full_queries`` (a power-of-two engine bucket has filled —
    waiting longer only spills into the next bucket while every parked
    client pays the wait), snapping the window back to ``window_ms``;
  * **stretches** (doubling, up to ``window_max_ms``) when a window
    expires nearly empty — under light load a longer window buys
    coalescing without hurting an idle queue.

Lifecycle contract: once admitted, a request is ALWAYS answered —
result, execution error, shed-``QueueFull``, or (``close(drain=False)``)
a ``RuntimeError("router closed before dispatch")``; futures never hang.

Shared across every tenant: one ``EnvelopeCache`` (injected into search
requests that did not bring their own), one process-wide jit
executable cache per pool device (coalesced groups reuse one compiled
bucket shape per window — the whole point), one ``StreamSessionPool``,
one ``Telemetry``.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import threading
import time
from typing import Any, Optional

import numpy as np

from repro.core.request import SdtwRequest, StreamRequest
from repro.search.cache import EnvelopeCache

from . import batcher
from .pool import DevicePool
from .queue import AdmissionQueue, QueueFull
from .sessions import StreamSessionPool
from .telemetry import RequestTrace, StatsSnapshot, Telemetry

__all__ = ["Router", "RouterConfig", "QueueFull"]


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Serving knobs (defaults favour low latency over occupancy)."""
    max_queue: int = 256          # admission bound (backpressure depth)
    window_ms: float = 2.0        # base microbatch coalescing window
    admission: str = "block"      # 'block' | 'reject' on a full queue
    block_timeout_s: Optional[float] = None   # None = wait forever
    auto_dispatch: bool = True    # background dispatcher thread
    # --- scheduling --------------------------------------------------
    tenant_quota: Optional[int] = None  # max pending per tenant
    aging_s: Optional[float] = 0.5      # priority aging interval
                                        # (None = strict priority)
    max_window_requests: Optional[int] = None  # per-drain cap (highest
                                               # effective priority first)
    # --- adaptive window ---------------------------------------------
    window_full_queries: int = 64  # close early at this many pending
                                   # queries (a pow-2 bucket target)
    window_max_ms: Optional[float] = None  # stretch bound under light
                                           # load (None = 8 x window_ms)
    # --- dispatch ----------------------------------------------------
    devices: Any = None           # None | 'all' | int | device sequence
    dedup: bool = True            # in-window identical-request dedup
    telemetry_window: int = 8192  # percentile ring-buffer bound


def _request_nq(req: SdtwRequest) -> int:
    q = req.queries
    if isinstance(q, (list, tuple)):
        return len(q)
    arr = np.asarray(q)
    return 1 if arr.ndim == 1 else arr.shape[0]


class Router:
    """Admission queue + microbatcher + device pool + shared caches
    over the engine."""

    def __init__(self, config: Optional[RouterConfig] = None, *,
                 cache: Optional[EnvelopeCache] = None, **overrides):
        if config is None:
            config = RouterConfig(**overrides)
        elif overrides:
            raise ValueError("pass a RouterConfig or keyword overrides, "
                             "not both")
        self.config = config
        self.cache = EnvelopeCache() if cache is None else cache
        self.telemetry = Telemetry(window=config.telemetry_window)
        self.sessions = StreamSessionPool()
        self._queue = AdmissionQueue(config.max_queue,
                                     admission=config.admission,
                                     timeout=config.block_timeout_s,
                                     tenant_quota=config.tenant_quota,
                                     aging_s=config.aging_s)
        self._pool = DevicePool(config.devices)
        self._dispatch_lock = threading.Lock()
        self._closed = False
        self._thread = None
        if config.auto_dispatch:
            self._thread = threading.Thread(target=self._dispatch_loop,
                                            name="repro-serve-dispatch",
                                            daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def submit(self, request=None, **kwargs) -> concurrent.futures.Future:
        """Admit one request; returns its Future.

        Accepts a prebuilt ``SdtwRequest`` or the kwargs surface
        (``op='sdtw'`` default; unknown keys rejected loudly). Invalid
        arguments raise here — at the door — with exactly the front-door
        error messages; a full queue raises ``QueueFull`` (or, under the
        reject policy, sheds a pending lower-priority request — its
        future fails with ``QueueFull`` instead)."""
        if self._closed:
            raise RuntimeError("router is closed")
        if request is None:
            request = SdtwRequest.from_kwargs(**kwargs)
        elif kwargs:
            raise ValueError("pass an SdtwRequest or kwargs, not both")
        request.validate()
        if getattr(request, "explain", False):
            raise ValueError(
                "explain=True is not servable: a coalesced batch has no "
                "single per-request dispatch decision; call engine.sdtw "
                "directly for the DispatchDecision")
        if request.op == "search_topk" and request.cache is None:
            request = dataclasses.replace(request, cache=self.cache)
        trace = RequestTrace(op=request.op, nq=_request_nq(request))
        fut = concurrent.futures.Future()
        pending = batcher.Pending(request=request, future=fut, trace=trace)
        try:
            depth, shed = self._queue.put(pending,
                                          priority=request.priority,
                                          tenant=request.tenant,
                                          weight=trace.nq)
        except QueueFull:
            self.telemetry.record_reject()
            raise
        if shed is not None:
            self._fail_pending(
                shed,
                QueueFull("request shed from the admission queue by a "
                          "higher-priority arrival; retry later or raise "
                          "max_queue"))
            self.telemetry.record_shed()
        trace.queue_depth = depth
        self.telemetry.observe_depth(depth)
        return fut

    @staticmethod
    def _fail_pending(pending, exc):
        """Fail one admitted-but-undispatched request, tolerating a
        client that already cancelled its future."""
        if pending.future.set_running_or_notify_cancel():
            pending.trace.mark_complete(error=True)
            pending.future.set_exception(exc)

    def warmup(self, request=None, **kwargs) -> int:
        """Pre-compile one representative request on EVERY pool device
        (blocking, sequential) and prime the executable-affinity map.

        A serving process calls this before accepting traffic so no
        client request pays an XLA compile or queues behind the warm
        set's backlog-gated growth. Shape the request like the
        coalesced buckets your windows will form — e.g. a list of
        ``window_full_queries`` serving-length queries against the
        production reference. Returns the number of devices warmed.

        Also pre-*tunes*: every pow-2 bucket the request's queries
        dispatch as is resolved through the ``repro.tune`` oracle first
        (under ``tune='measure'`` the measured search runs here, at
        warmup — never on the request path), so the warmed executables
        are compiled for the exact tuned configurations traffic will
        hit."""
        if self._closed:
            raise RuntimeError("router is closed")
        if request is None:
            request = SdtwRequest.from_kwargs(**kwargs)
        elif kwargs:
            raise ValueError("pass an SdtwRequest or kwargs, not both")
        request.validate()
        if request.op == "search_topk" and request.cache is None:
            request = dataclasses.replace(request, cache=self.cache)
        from repro.tune import pretune_request
        pretune_request(request)
        return self._pool.warmup(request)

    # Blocking conveniences — the offline call signatures, served.
    def sdtw(self, queries, reference, qlens=None, **kw):
        return self.submit(queries=queries, reference=reference,
                           qlens=qlens, op="sdtw", **kw).result()

    def search_topk(self, queries, reference, k: int = 1, **kw):
        return self.submit(queries=queries, reference=reference,
                           top_k=k, op="search_topk", **kw).result()

    # ------------------------------------------------------------------
    # streaming tenants
    # ------------------------------------------------------------------

    def open_stream(self, feed_key, tenant, request:
                    Optional[StreamRequest] = None, **stream_kwargs):
        """Attach a streaming tenant to a reference feed (see
        ``StreamSessionPool``); search-style pruned sessions share the
        router's envelope cache unless they bring their own."""
        if request is None:
            if stream_kwargs.get("prune") and "cache" not in stream_kwargs:
                stream_kwargs["cache"] = self.cache
            request = StreamRequest.from_kwargs(**stream_kwargs)
        elif stream_kwargs:
            raise ValueError("pass a StreamRequest or stream kwargs, "
                             "not both")
        return self.sessions.attach(feed_key, tenant, request)

    def feed(self, feed_key, data) -> int:
        return self.sessions.feed(feed_key, data)

    # ------------------------------------------------------------------
    # dispatch
    # ------------------------------------------------------------------

    def drain(self, *, wait: bool = True) -> int:
        """Dispatch every pending request now (one microbatch window,
        capped at ``max_window_requests`` in effective-priority order);
        returns the number of requests dispatched. Groups go to the
        device pool; with ``wait`` (the default) the call blocks until
        the pool has answered every submitted group — the deterministic
        manual-mode workhorse. ``wait=False`` (the dispatch loop) lets
        the next window accrue while devices are still computing."""
        with self._dispatch_lock:
            window = self._queue.drain(self.config.max_window_requests)
            n = len(window)
            if window:
                groups = batcher.group_window(window,
                                              dedup=self.config.dedup)
                for grp in groups:
                    n_members = sum(1 for _ in batcher.group_members(grp))
                    self.telemetry.record_dispatch(
                        n_requests=n_members,
                        n_queries=sum(len(p.entries) for p in grp),
                        n_deduped=n_members - len(grp))
                    self._pool.submit(grp, self.telemetry)
        if wait:
            self._pool.join()
        return n

    def _dispatch_loop(self):
        cfg = self.config
        base = cfg.window_ms / 1000.0
        wmax = (cfg.window_max_ms / 1000.0 if cfg.window_max_ms is not None
                else 8.0 * base)
        window = base
        while not self._closed:
            if not self._queue.wait_nonempty(timeout=0.1):
                continue
            t_open = time.monotonic()
            full = self._queue.wait_weight(cfg.window_full_queries,
                                           t_open + window)
            duration = time.monotonic() - t_open
            n = self.drain(wait=False)
            self.telemetry.record_window(duration_s=duration,
                                         closed_early=full)
            if full:
                window = base            # heavy load: tight windows —
                                         # buckets fill on their own
            elif n <= 1:
                window = min(wmax, 2.0 * window)   # light load: stretch
                                                   # to buy coalescing
            else:
                window = base

    # ------------------------------------------------------------------
    # lifecycle / observability
    # ------------------------------------------------------------------

    def stats(self) -> StatsSnapshot:
        return self.telemetry.snapshot()

    def close(self, *, drain: bool = True):
        """Stop admitting, then settle every admitted request: with
        ``drain`` (the default) everything still queued is dispatched
        and answered; with ``drain=False`` still-queued futures fail
        with ``RuntimeError('router closed before dispatch')`` (counted
        as ``unserved_on_close``). Either way, groups already handed to
        the device pool run to completion — no future is ever left
        hanging."""
        if self._closed:
            return
        self._closed = True
        self._queue.close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        if drain:
            self.drain()
        else:
            orphans = self._queue.drain()
            for p in orphans:
                self._fail_pending(
                    p, RuntimeError("router closed before dispatch"))
            if orphans:
                self.telemetry.record_unserved(len(orphans))
        self._pool.join()
        self._pool.close()

    def __enter__(self) -> "Router":
        return self

    def __exit__(self, *exc):
        self.close()
