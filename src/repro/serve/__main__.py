"""``python -m repro.serve`` — closed-loop offered-load driver for the
serving tier.

Spawns N closed-loop client threads (each submits, waits for its
result, repeats) against one ``Router``, then dumps the telemetry
snapshot as JSON — the same numbers ``benchmarks/serve_bench.py`` turns
into p50/p99/goodput rows.

Example::

    python -m repro.serve --clients 8 --requests 16 --qlen 128 \
        --reflen 4096 --op sdtw --window-ms 5 --stats-json stats.json
"""
from __future__ import annotations

import argparse
import json
import sys
import threading

import numpy as np

from .queue import QueueFull
from .router import Router, RouterConfig


def _make_workload(rng, *, nq, qlen, reflen):
    reference = rng.standard_normal(reflen).astype(np.float32)
    queries = [rng.standard_normal((nq, qlen)).astype(np.float32)
               for _ in range(8)]
    return reference, queries


def run_load(router: Router, *, clients: int, requests: int, op: str,
             top_k, nq: int, qlen: int, reflen: int, seed: int = 0,
             priority_classes: int = 1):
    """Closed-loop load: each client thread submits ``requests`` calls
    back-to-back (tenant ``client{ci}``, priority ``ci %
    priority_classes``). Returns (completed, rejected)."""
    rng = np.random.default_rng(seed)
    reference, query_pool = _make_workload(rng, nq=nq, qlen=qlen,
                                           reflen=reflen)
    completed = [0] * clients
    rejected = [0] * clients

    def client(ci: int):
        for r in range(requests):
            q = query_pool[(ci + r) % len(query_pool)]
            try:
                if op == "search_topk":
                    router.search_topk(q, reference, k=top_k or 1,
                                       ref_key="bench-ref",
                                       tenant=f"client{ci}",
                                       priority=ci % priority_classes)
                else:
                    router.sdtw(q, reference, top_k=top_k,
                                tenant=f"client{ci}",
                                priority=ci % priority_classes)
                completed[ci] += 1
            except QueueFull:
                rejected[ci] += 1

    threads = [threading.Thread(target=client, args=(ci,))
               for ci in range(clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return sum(completed), sum(rejected)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="Closed-loop offered load against the sDTW serving "
                    "router; prints a telemetry snapshot as JSON.")
    ap.add_argument("--clients", type=int, default=4,
                    help="concurrent closed-loop clients (default 4)")
    ap.add_argument("--requests", type=int, default=8,
                    help="requests per client (default 8)")
    ap.add_argument("--op", choices=("sdtw", "search_topk"),
                    default="sdtw")
    ap.add_argument("--top-k", type=int, default=None,
                    help="top-K matches per query (default: distance only)")
    ap.add_argument("--nq", type=int, default=4,
                    help="queries per request (default 4)")
    ap.add_argument("--qlen", type=int, default=128)
    ap.add_argument("--reflen", type=int, default=4096)
    ap.add_argument("--window-ms", type=float, default=2.0,
                    help="base microbatch coalescing window (default 2 ms; "
                         "the window adapts — closes early when "
                         "--window-full queries are pending, stretches to "
                         "--window-max-ms under light load)")
    ap.add_argument("--window-max-ms", type=float, default=None,
                    help="stretch bound for the adaptive window "
                         "(default 8 x --window-ms)")
    ap.add_argument("--window-full", type=int, default=64,
                    help="pending-query count that closes a window early "
                         "(a pow-2 bucket target; default 64)")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="admission queue depth (default 256)")
    ap.add_argument("--admission", choices=("block", "reject"),
                    default="block")
    ap.add_argument("--devices", type=str, default=None,
                    help="device pool: 'all', an int (first-N local "
                         "devices), or unset for the process default")
    ap.add_argument("--priority-classes", type=int, default=1,
                    help="spread clients over N priority classes "
                         "(client i gets priority i %% N; default 1)")
    ap.add_argument("--tenant-quota", type=int, default=None,
                    help="max pending requests per tenant (default none)")
    ap.add_argument("--no-dedup", action="store_true",
                    help="disable in-window identical-request dedup")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stats-json", type=str, default=None,
                    help="also write the snapshot to this path")
    args = ap.parse_args(argv)

    devices = args.devices
    if devices is not None and devices != "all":
        devices = int(devices)
    config = RouterConfig(max_queue=args.max_queue,
                          window_ms=args.window_ms,
                          window_max_ms=args.window_max_ms,
                          window_full_queries=args.window_full,
                          admission=args.admission,
                          devices=devices,
                          tenant_quota=args.tenant_quota,
                          dedup=not args.no_dedup)
    with Router(config) as router:
        completed, rejected = run_load(
            router, clients=args.clients, requests=args.requests,
            op=args.op, top_k=args.top_k, nq=args.nq, qlen=args.qlen,
            reflen=args.reflen, seed=args.seed,
            priority_classes=max(1, args.priority_classes))
        snap = router.stats().as_dict()
    snap["offered"] = args.clients * args.requests
    snap["client_completed"] = completed
    snap["client_rejected"] = rejected
    out = json.dumps(snap, indent=2, sort_keys=True)
    print(out)
    if args.stats_json:
        with open(args.stats_json, "w") as f:
            f.write(out + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
