"""Bounded admission queue — the router's backpressure boundary.

A request is *admitted* (enqueued with its client future) or *refused*
at the door; once admitted it will always be answered (result,
exception, or — under the ``'reject'`` policy with priorities — a
``QueueFull`` delivered through its future when a higher-priority
arrival sheds it). Clients therefore handle ``QueueFull`` in exactly
two places: synchronously at submission, or as the failure of an
already-returned future. Two admission policies:

  * ``'reject'`` — a full queue sheds the **lowest-priority pending**
    request when the arrival outranks it (the shed item is returned to
    the caller, who fails its future), else raises ``QueueFull``
    immediately (load-shedding; the closed-loop benchmark measures
    goodput as completed/offered under this policy).
  * ``'block'``  — a full queue blocks the submitting thread until space
    frees or ``timeout`` elapses (then ``QueueFull``), propagating
    backpressure into the client. Blocking admission never sheds.

Scheduling: ``put`` records a ``priority`` (higher drains sooner) and a
``tenant`` (per-tenant pending quota via ``tenant_quota``; quota
overruns always reject — blocking on your *own* backlog would deadlock
a closed-loop client). ``drain`` pops in **effective-priority** order::

    effective(entry) = priority + age_seconds // aging_s

so with ``aging_s`` set (default 0.5 s) every parked request gains one
priority class per interval and low-priority tenants are
starvation-free: anything old enough eventually outranks fresh
high-priority traffic. Ties drain FIFO. ``aging_s=None`` disables
aging (strict priority). Shedding picks the *lowest* effective
priority, newest first, so aged requests are also shed last.

``weight`` (the request's query count) feeds ``wait_weight`` — the
adaptive coalescing window's "a power-of-two bucket has filled, close
now" signal.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Optional


class QueueFull(RuntimeError):
    """The admission queue refused a request (bounded depth reached)."""


class _Entry:
    __slots__ = ("item", "priority", "tenant", "weight", "seq", "t")

    def __init__(self, item, priority, tenant, weight, seq):
        self.item = item
        self.priority = priority
        self.tenant = tenant
        self.weight = weight
        self.seq = seq
        self.t = time.monotonic()


class AdmissionQueue:
    """Bounded priority queue of pending requests with block/reject
    admission, per-tenant quotas, and drain-time priority aging."""

    def __init__(self, maxsize: int = 256, *, admission: str = "block",
                 timeout: Optional[float] = None,
                 tenant_quota: Optional[int] = None,
                 aging_s: Optional[float] = 0.5):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        if admission not in ("block", "reject"):
            raise ValueError(f"admission must be 'block' or 'reject', got "
                             f"{admission!r}")
        if tenant_quota is not None and tenant_quota < 1:
            raise ValueError(f"tenant_quota must be >= 1, got "
                             f"{tenant_quota}")
        if aging_s is not None and aging_s <= 0:
            raise ValueError(f"aging_s must be > 0 (or None to disable "
                             f"aging), got {aging_s}")
        self.maxsize = maxsize
        self.admission = admission
        self.timeout = timeout
        self.tenant_quota = tenant_quota
        self.aging_s = aging_s
        self._entries: list[_Entry] = []
        self._weight = 0
        self._per_tenant: dict[Any, int] = {}
        self._seq = 0
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self._not_empty = threading.Condition(self._lock)
        self._closed = False

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def pending_weight(self) -> int:
        with self._lock:
            return self._weight

    def _effective(self, entry: _Entry, now: float) -> float:
        if self.aging_s is None:
            return entry.priority
        return entry.priority + int((now - entry.t) / self.aging_s)

    def _remove(self, entry: _Entry):
        self._entries.remove(entry)
        self._weight -= entry.weight
        n = self._per_tenant.get(entry.tenant, 0) - 1
        if n <= 0:
            self._per_tenant.pop(entry.tenant, None)
        else:
            self._per_tenant[entry.tenant] = n

    def put(self, item, *, priority: int = 0, tenant: Any = None,
            weight: int = 1):
        """Admit ``item``; returns ``(depth, shed_item)`` — the queue
        depth observed *after* admission (telemetry) and, under the
        reject policy, a previously admitted lower-priority item that
        was evicted to make room (``None`` otherwise; the caller owns
        failing its future). Raises ``QueueFull`` per the policy."""
        with self._not_full:
            if self._closed:
                raise RuntimeError("router is closed")
            if self.tenant_quota is not None \
                    and self._per_tenant.get(tenant, 0) >= self.tenant_quota:
                raise QueueFull(
                    f"tenant {tenant!r} quota reached ({self.tenant_quota} "
                    "pending); await completions or raise tenant_quota")
            shed = None
            if self.admission == "reject":
                if len(self._entries) >= self.maxsize:
                    now = time.monotonic()
                    victim = min(self._entries,
                                 key=lambda e: (self._effective(e, now),
                                                -e.seq))
                    if self._effective(victim, now) >= priority:
                        raise QueueFull(
                            f"admission queue full ({self.maxsize} "
                            "pending); retry later or raise max_queue")
                    self._remove(victim)
                    shed = victim.item
            else:
                ok = self._not_full.wait_for(
                    lambda: self._closed
                    or len(self._entries) < self.maxsize,
                    timeout=self.timeout)
                if not ok:
                    raise QueueFull(
                        f"admission queue full ({self.maxsize} pending) "
                        f"after blocking {self.timeout}s")
                if self._closed:
                    raise RuntimeError("router is closed")
            entry = _Entry(item, priority, tenant, weight, self._seq)
            self._seq += 1
            self._entries.append(entry)
            self._weight += entry.weight
            self._per_tenant[tenant] = self._per_tenant.get(tenant, 0) + 1
            depth = len(self._entries)
            self._not_empty.notify_all()
            return depth, shed

    def drain(self, max_items: Optional[int] = None) -> list:
        """Pop up to ``max_items`` pending items in effective-priority
        order (aged priority desc, then FIFO)."""
        with self._not_full:
            now = time.monotonic()
            order = sorted(self._entries,
                           key=lambda e: (-self._effective(e, now), e.seq))
            if max_items is not None:
                order = order[:max_items]
            for e in order:
                self._remove(e)
            if order:
                self._not_full.notify_all()
            return [e.item for e in order]

    def wait_nonempty(self, timeout: Optional[float] = None) -> bool:
        """Block until at least one item is pending (or the queue closes).
        Returns True if items are pending."""
        with self._not_empty:
            self._not_empty.wait_for(
                lambda: self._closed or len(self._entries) > 0,
                timeout=timeout)
            return len(self._entries) > 0

    def wait_weight(self, threshold: int, deadline: float) -> bool:
        """Block until the total pending weight (queries) reaches
        ``threshold``, the queue closes, or ``time.monotonic()`` passes
        ``deadline``. Returns True iff the threshold was reached — the
        adaptive window's early-close signal."""
        with self._not_empty:
            while self._weight < threshold and not self._closed:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._not_empty.wait(remaining)
            return self._weight >= threshold

    def close(self):
        """Wake every waiter; subsequent ``put`` raises."""
        with self._lock:
            self._closed = True
            self._not_full.notify_all()
            self._not_empty.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed
