"""Bounded admission queue — the router's backpressure boundary.

A request is *admitted* (enqueued with its client future) or *refused*
at the door; once admitted it will always be answered (result or
exception), so clients only need to handle ``QueueFull`` at submission.
Two admission policies:

  * ``'reject'`` — a full queue raises ``QueueFull`` immediately
    (load-shedding; the closed-loop benchmark measures goodput as
    completed/offered under this policy).
  * ``'block'``  — a full queue blocks the submitting thread until space
    frees or ``timeout`` elapses (then ``QueueFull``), propagating
    backpressure into the client.

The queue is deliberately FIFO and dumb: coalescing/priority decisions
belong to the batcher, which drains whole windows at a time.
"""
from __future__ import annotations

import collections
import threading
from typing import Optional


class QueueFull(RuntimeError):
    """The admission queue refused a request (bounded depth reached)."""


class AdmissionQueue:
    """Bounded FIFO of pending requests with block/reject admission."""

    def __init__(self, maxsize: int = 256, *, admission: str = "block",
                 timeout: Optional[float] = None):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        if admission not in ("block", "reject"):
            raise ValueError(f"admission must be 'block' or 'reject', got "
                             f"{admission!r}")
        self.maxsize = maxsize
        self.admission = admission
        self.timeout = timeout
        self._items = collections.deque()
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self._not_empty = threading.Condition(self._lock)
        self._closed = False

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    def put(self, item) -> int:
        """Admit ``item``; returns the queue depth observed *after*
        admission (telemetry). Raises ``QueueFull`` per the policy."""
        with self._not_full:
            if self.admission == "reject":
                if len(self._items) >= self.maxsize:
                    raise QueueFull(
                        f"admission queue full ({self.maxsize} pending); "
                        "retry later or raise max_queue")
            else:
                ok = self._not_full.wait_for(
                    lambda: self._closed
                    or len(self._items) < self.maxsize,
                    timeout=self.timeout)
                if not ok:
                    raise QueueFull(
                        f"admission queue full ({self.maxsize} pending) "
                        f"after blocking {self.timeout}s")
            if self._closed:
                raise RuntimeError("router is closed")
            self._items.append(item)
            depth = len(self._items)
            self._not_empty.notify()
            return depth

    def drain(self, max_items: Optional[int] = None) -> list:
        """Pop every pending item (up to ``max_items``), FIFO order."""
        with self._not_full:
            n = len(self._items) if max_items is None \
                else min(max_items, len(self._items))
            out = [self._items.popleft() for _ in range(n)]
            if out:
                self._not_full.notify_all()
            return out

    def wait_nonempty(self, timeout: Optional[float] = None) -> bool:
        """Block until at least one item is pending (or the queue closes).
        Returns True if items are pending."""
        with self._not_empty:
            self._not_empty.wait_for(
                lambda: self._closed or len(self._items) > 0,
                timeout=timeout)
            return len(self._items) > 0

    def close(self):
        """Wake every waiter; subsequent ``put`` raises."""
        with self._lock:
            self._closed = True
            self._not_full.notify_all()
            self._not_empty.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed
