"""``repro.serve`` — the admission-controlled serving tier over the
sDTW engine.

One router amortizes what every caller used to own alone: compiled
executables, the envelope cache, and the DP dispatch itself (concurrent
requests coalesce into the engine's ragged power-of-two buckets — one
dispatch per bucket per microbatch window). Queue elements are the
frozen ``SdtwRequest`` objects of ``repro.core.request``, so serve-tier
tenants and direct ``engine.sdtw``/``search_topk`` callers hit
byte-identical argument semantics and results.

``python -m repro.serve`` runs the closed-loop offered-load CLI.
"""
from .batcher import execute_group, group_window
from .pool import DevicePool
from .queue import AdmissionQueue, QueueFull
from .router import Router, RouterConfig
from .sessions import StreamSessionPool
from .telemetry import RequestTrace, StatsSnapshot, Telemetry

__all__ = [
    "AdmissionQueue",
    "DevicePool",
    "QueueFull",
    "RequestTrace",
    "Router",
    "RouterConfig",
    "StatsSnapshot",
    "StreamSessionPool",
    "Telemetry",
    "execute_group",
    "group_window",
]
