"""Per-request traces and router-level stats for the serve tier.

Every admitted request carries a ``RequestTrace`` through its life:
enqueue → dispatch (when the batcher pulled it into a merged engine
call) → complete (result or error delivered to the client future). The
``Telemetry`` aggregator folds finished traces into a running store the
router exposes as an immutable ``StatsSnapshot`` — the numbers NATSA-
style serving cares about: queue depth seen at admission, microbatch
occupancy (how many client requests each engine dispatch amortized),
and the latency split between waiting and computing.

All timestamps are ``time.monotonic()`` floats (seconds); snapshots
report microseconds, matching the benchmark harness row units.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional


def _now() -> float:
    return time.monotonic()


def percentile(values, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of a non-empty list."""
    if not values:
        return float("nan")
    vs = sorted(values)
    idx = min(len(vs) - 1, max(0, int(round(q / 100.0 * (len(vs) - 1)))))
    return float(vs[idx])


@dataclasses.dataclass
class RequestTrace:
    """Lifecycle timestamps + context for one admitted request."""
    op: str
    nq: int                          # queries carried by this request
    t_enqueue: float = dataclasses.field(default_factory=_now)
    t_dispatch: Optional[float] = None
    t_complete: Optional[float] = None
    queue_depth: int = 0             # depth observed at admission
    batch_requests: int = 0          # requests sharing the merged call
    batch_queries: int = 0           # total queries in the merged call
    error: bool = False

    def mark_dispatch(self, *, batch_requests: int, batch_queries: int):
        self.t_dispatch = _now()
        self.batch_requests = batch_requests
        self.batch_queries = batch_queries

    def mark_complete(self, *, error: bool = False):
        self.t_complete = _now()
        self.error = error

    @property
    def queue_us(self) -> float:
        if self.t_dispatch is None:
            return float("nan")
        return (self.t_dispatch - self.t_enqueue) * 1e6

    @property
    def latency_us(self) -> float:
        if self.t_complete is None:
            return float("nan")
        return (self.t_complete - self.t_enqueue) * 1e6


@dataclasses.dataclass(frozen=True)
class StatsSnapshot:
    """Immutable view of the router's counters at one instant."""
    completed: int
    errors: int
    rejected: int
    dispatches: int                 # merged engine calls issued
    coalesced_requests: int         # requests that shared a dispatch
    queries_served: int
    p50_latency_us: float
    p99_latency_us: float
    p50_queue_us: float
    max_queue_depth: int
    mean_batch_requests: float      # requests per dispatch (occupancy)
    mean_batch_queries: float       # queries per dispatch
    uptime_s: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class Telemetry:
    """Thread-safe aggregator of finished ``RequestTrace`` records."""

    def __init__(self):
        self._lock = threading.Lock()
        self._t0 = _now()
        self._latencies: list[float] = []
        self._queue_waits: list[float] = []
        self._completed = 0
        self._errors = 0
        self._rejected = 0
        self._dispatches = 0
        self._coalesced = 0
        self._queries = 0
        self._max_depth = 0
        self._batch_requests: list[int] = []
        self._batch_queries: list[int] = []

    def observe_depth(self, depth: int):
        with self._lock:
            self._max_depth = max(self._max_depth, depth)

    def record_reject(self):
        with self._lock:
            self._rejected += 1

    def record_dispatch(self, *, n_requests: int, n_queries: int):
        with self._lock:
            self._dispatches += 1
            self._batch_requests.append(n_requests)
            self._batch_queries.append(n_queries)
            if n_requests > 1:
                self._coalesced += n_requests

    def record_complete(self, trace: RequestTrace):
        with self._lock:
            self._completed += 1
            self._queries += trace.nq
            if trace.error:
                self._errors += 1
            self._latencies.append(trace.latency_us)
            if trace.t_dispatch is not None:
                self._queue_waits.append(trace.queue_us)

    def snapshot(self) -> StatsSnapshot:
        with self._lock:
            n_d = len(self._batch_requests)
            return StatsSnapshot(
                completed=self._completed,
                errors=self._errors,
                rejected=self._rejected,
                dispatches=self._dispatches,
                coalesced_requests=self._coalesced,
                queries_served=self._queries,
                p50_latency_us=percentile(self._latencies, 50),
                p99_latency_us=percentile(self._latencies, 99),
                p50_queue_us=percentile(self._queue_waits, 50),
                max_queue_depth=self._max_depth,
                mean_batch_requests=(sum(self._batch_requests) / n_d
                                     if n_d else float("nan")),
                mean_batch_queries=(sum(self._batch_queries) / n_d
                                    if n_d else float("nan")),
                uptime_s=_now() - self._t0)
