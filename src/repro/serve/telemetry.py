"""Per-request traces and router-level stats for the serve tier.

Every admitted request carries a ``RequestTrace`` through its life:
enqueue → dispatch (when the batcher pulled it into a merged engine
call) → complete (result or error delivered to the client future). The
``Telemetry`` aggregator folds finished traces into a running store the
router exposes as an immutable ``StatsSnapshot`` — the numbers NATSA-
style serving cares about: queue depth seen at admission, microbatch
occupancy (how many client requests each engine dispatch amortized),
and the latency split between waiting and computing.

Memory is bounded: the latency/queue-wait/batch-size sample stores are
ring buffers of the most recent ``window`` observations (default 8192),
so a long-running router neither leaks nor re-sorts an ever-growing
list at ``snapshot()``. Snapshot semantics under the bound:

  * counters (``completed``, ``errors``, ``rejected``, ``shed``,
    ``deduped``, …) and the ``mean_*`` fields are exact over the
    router's whole lifetime (running sums, never sampled);
  * the ``p50_*``/``p99_*`` percentiles are computed over the last
    ``window`` samples only (``latency_samples`` reports how many are
    currently held) — a sliding-window view, which is what a latency
    SLO wants anyway.

All timestamps are ``time.monotonic()`` floats (seconds); snapshots
report microseconds, matching the benchmark harness row units.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Optional

#: Default sample-window length for the percentile ring buffers.
DEFAULT_SAMPLE_WINDOW = 8192


def _now() -> float:
    return time.monotonic()


def percentile(values, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of a non-empty list."""
    if not values:
        return float("nan")
    vs = sorted(values)
    idx = min(len(vs) - 1, max(0, int(round(q / 100.0 * (len(vs) - 1)))))
    return float(vs[idx])


@dataclasses.dataclass
class RequestTrace:
    """Lifecycle timestamps + context for one admitted request."""
    op: str
    nq: int                          # queries carried by this request
    t_enqueue: float = dataclasses.field(default_factory=_now)
    t_dispatch: Optional[float] = None
    t_complete: Optional[float] = None
    queue_depth: int = 0             # depth observed at admission
    batch_requests: int = 0          # requests sharing the merged call
    batch_queries: int = 0           # total queries in the merged call
    error: bool = False

    def mark_dispatch(self, *, batch_requests: int, batch_queries: int):
        self.t_dispatch = _now()
        self.batch_requests = batch_requests
        self.batch_queries = batch_queries

    def mark_complete(self, *, error: bool = False):
        self.t_complete = _now()
        self.error = error

    @property
    def queue_us(self) -> float:
        if self.t_dispatch is None:
            return float("nan")
        return (self.t_dispatch - self.t_enqueue) * 1e6

    @property
    def latency_us(self) -> float:
        if self.t_complete is None:
            return float("nan")
        return (self.t_complete - self.t_enqueue) * 1e6


@dataclasses.dataclass(frozen=True)
class StatsSnapshot:
    """Immutable view of the router's counters at one instant."""
    completed: int
    errors: int
    rejected: int                   # refused at the door (QueueFull)
    shed: int                       # admitted, then evicted for a
                                    # higher-priority arrival (reject)
    deduped: int                    # answered from another request's
                                    # identical in-window engine call
    cancelled: int                  # client cancelled before delivery
    unserved_on_close: int          # failed by close(drain=False)
    dispatches: int                 # merged engine calls issued
    coalesced_requests: int         # requests that shared a dispatch
    queries_served: int
    p50_latency_us: float
    p99_latency_us: float
    mean_latency_us: float          # exact (running sum, not windowed)
    p50_queue_us: float
    max_queue_depth: int
    mean_batch_requests: float      # requests per dispatch (occupancy)
    mean_batch_queries: float       # queries per dispatch
    windows: int                    # coalescing windows dispatched
    window_early_closes: int        # windows closed by a full bucket
    mean_window_ms: float           # mean realized window duration
    latency_samples: int            # samples currently in the p50/p99
                                    # ring (≤ sample_window)
    sample_window: int              # ring-buffer bound (config)
    uptime_s: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class Telemetry:
    """Thread-safe aggregator of finished ``RequestTrace`` records.

    ``window`` bounds the percentile sample stores (see the module
    docstring for the exact snapshot semantics under the bound).
    """

    def __init__(self, *, window: int = DEFAULT_SAMPLE_WINDOW):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self._lock = threading.Lock()
        self._t0 = _now()
        self._window = int(window)
        self._latencies = collections.deque(maxlen=self._window)
        self._queue_waits = collections.deque(maxlen=self._window)
        self._completed = 0
        self._errors = 0
        self._rejected = 0
        self._shed = 0
        self._deduped = 0
        self._cancelled = 0
        self._unserved = 0
        self._dispatches = 0
        self._coalesced = 0
        self._queries = 0
        self._max_depth = 0
        self._latency_sum = 0.0
        self._batch_requests_sum = 0
        self._batch_queries_sum = 0
        self._windows = 0
        self._window_early = 0
        self._window_sum_s = 0.0

    def observe_depth(self, depth: int):
        with self._lock:
            self._max_depth = max(self._max_depth, depth)

    def record_reject(self):
        with self._lock:
            self._rejected += 1

    def record_shed(self):
        with self._lock:
            self._shed += 1

    def record_cancelled(self, trace: Optional[RequestTrace] = None):
        with self._lock:
            self._cancelled += 1

    def record_unserved(self, n: int = 1):
        with self._lock:
            self._unserved += n

    def record_dispatch(self, *, n_requests: int, n_queries: int,
                        n_deduped: int = 0):
        with self._lock:
            self._dispatches += 1
            self._batch_requests_sum += n_requests
            self._batch_queries_sum += n_queries
            self._deduped += n_deduped
            if n_requests > 1:
                self._coalesced += n_requests

    def record_complete(self, trace: RequestTrace):
        with self._lock:
            self._completed += 1
            self._queries += trace.nq
            if trace.error:
                self._errors += 1
            lat = trace.latency_us
            self._latency_sum += lat
            self._latencies.append(lat)
            if trace.t_dispatch is not None:
                self._queue_waits.append(trace.queue_us)

    def record_window(self, *, duration_s: float, closed_early: bool):
        with self._lock:
            self._windows += 1
            self._window_sum_s += duration_s
            if closed_early:
                self._window_early += 1

    def snapshot(self) -> StatsSnapshot:
        with self._lock:
            return StatsSnapshot(
                completed=self._completed,
                errors=self._errors,
                rejected=self._rejected,
                shed=self._shed,
                deduped=self._deduped,
                cancelled=self._cancelled,
                unserved_on_close=self._unserved,
                dispatches=self._dispatches,
                coalesced_requests=self._coalesced,
                queries_served=self._queries,
                p50_latency_us=percentile(self._latencies, 50),
                p99_latency_us=percentile(self._latencies, 99),
                mean_latency_us=(self._latency_sum / self._completed
                                 if self._completed else float("nan")),
                p50_queue_us=percentile(self._queue_waits, 50),
                max_queue_depth=self._max_depth,
                mean_batch_requests=(self._batch_requests_sum
                                     / self._dispatches
                                     if self._dispatches else float("nan")),
                mean_batch_queries=(self._batch_queries_sum
                                    / self._dispatches
                                    if self._dispatches else float("nan")),
                windows=self._windows,
                window_early_closes=self._window_early,
                mean_window_ms=(self._window_sum_s / self._windows * 1e3
                                if self._windows else float("nan")),
                latency_samples=len(self._latencies),
                sample_window=self._window,
                uptime_s=_now() - self._t0)
