"""Validate the cost model's *ranking* against measured bench rows.

The autotuner's analytical stage is only trusted for ordering (pick the
cheaper config), never for absolute microseconds — so that is exactly
what CI validates: every pair of measured ``BENCH_baseline.json`` rows
that map onto model-priceable configurations of the *same shape* must be
ordered the same way by the model.  Deterministic on both sides (the
model is closed-form, the baseline is committed), so this gates in CI
without timer noise.

Recognized row families (recorded on the CPU/interpret host):

  * ``sdtw_kernel/{rowscan_tropical|wavefront_paper_faithful|
    pallas_interpret}_b{B}_n{N}_m{M}`` — in-core impl ranking (the
    pallas row is priced at ``resolve_blocks``'s default interpret
    config, which is what that row ran).
  * ``sdtw_kernel/engine_chunked_b{B}_n{N}_m{M}_c{C}`` (non-span) —
    chunk-size ranking.

Usage (the CI step)::

    python -m repro.tune.validate BENCH_baseline.json \
        --min-agreement 0.6 --min-pairs 3
"""
from __future__ import annotations

import argparse
import itertools
import json
import re

from .cost import get_cost_model

_INCORE_RE = re.compile(
    r"sdtw_kernel/(rowscan_tropical|wavefront_paper_faithful|"
    r"pallas_interpret)_b(\d+)_n(\d+)_m(\d+)$")
_CHUNK_RE = re.compile(
    r"sdtw_kernel/engine_chunked_b(\d+)_n(\d+)_m(\d+)_c(\d+)$")
_IMPL_OF = {"rowscan_tropical": "rowscan",
            "wavefront_paper_faithful": "wavefront",
            "pallas_interpret": "pallas"}


def _model_us(model, impl: str, b: int, n: int, m: int) -> float:
    if impl == "rowscan":
        return model.rowscan_us(b, n, m)
    if impl == "wavefront":
        return model.wavefront_us(b, n, m)
    # The pallas_interpret row ran resolve_blocks' default config.
    from repro.kernels.sdtw import resolve_blocks
    bq, bm, scheme, rt = resolve_blocks(b, m, None, None, None, None, True)
    return model.pallas_us(b, n, m, bq, bm, scheme, rt)


def extract_pairs(rows, backend: str = "interpret"):
    """Comparable (model_us, measured_us, label) entries grouped by
    shape; returns the flat list of intra-group pairs."""
    model = get_cost_model(backend)
    groups: dict = {}
    for row in rows:
        name, us = row["name"], float(row["us_per_call"])
        m1 = _INCORE_RE.match(name)
        if m1:
            impl = _IMPL_OF[m1.group(1)]
            b, n, m = (int(m1.group(i)) for i in (2, 3, 4))
            groups.setdefault(("incore", b, n, m), []).append(
                (_model_us(model, impl, b, n, m), us, name))
            continue
        m2 = _CHUNK_RE.match(name)
        if m2:
            b, n, m, c = (int(m2.group(i)) for i in (1, 2, 3, 4))
            groups.setdefault(("chunk", b, n, m), []).append(
                (model.chunked_us(b, n, m, c), us, name))
    pairs = []
    for members in groups.values():
        pairs.extend(itertools.combinations(members, 2))
    return pairs


def validate_ranking(rows, *, backend: str = "interpret"):
    """Pairwise-majority check.  Returns ``(agree, total, report)`` where
    ``agree/total`` is the fraction of comparable same-shape pairs the
    model orders like the measurement (ties in either ordering count as
    agreement)."""
    pairs = extract_pairs(rows, backend)
    agree, report = 0, []
    for (mu_a, us_a, name_a), (mu_b, us_b, name_b) in pairs:
        model_sign = (mu_a > mu_b) - (mu_a < mu_b)
        meas_sign = (us_a > us_b) - (us_a < us_b)
        ok = model_sign == 0 or meas_sign == 0 or model_sign == meas_sign
        agree += ok
        report.append(
            f"{'ok       ' if ok else 'DISAGREES'} {name_a} vs {name_b}: "
            f"model {mu_a:.0f}us vs {mu_b:.0f}us, measured "
            f"{us_a:.0f}us vs {us_b:.0f}us")
    return agree, len(pairs), report


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="measured bench rows (JSON)")
    ap.add_argument("--backend", default="interpret")
    ap.add_argument("--min-agreement", type=float, default=0.6,
                    help="required pairwise-majority fraction")
    ap.add_argument("--min-pairs", type=int, default=3,
                    help="fail if fewer comparable pairs are found "
                         "(guards against the row names drifting away "
                         "from the recognizers)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        rows = json.load(f)
    agree, total, report = validate_ranking(rows, backend=args.backend)
    for line in report:
        print("  " + line)
    frac = agree / total if total else 0.0
    print(f"cost-model ranking: {agree}/{total} pairs agree "
          f"({frac:.0%}; need >= {args.min_agreement:.0%} over >= "
          f"{args.min_pairs} pairs)")
    if total < args.min_pairs:
        raise SystemExit(
            f"only {total} comparable pairs found (need "
            f"{args.min_pairs}) — did the bench row names drift?")
    if frac < args.min_agreement:
        raise SystemExit(
            f"cost-model ranking disagrees with the measured baseline: "
            f"{agree}/{total} = {frac:.0%} < {args.min_agreement:.0%}")
    print("cost-model ranking gate passed")


if __name__ == "__main__":
    main()
