"""Per-process LRU in front of the tuning table / cost model.

Every engine dispatch with ``tune != 'off'`` consults the oracle; the
oracle's own work (table lookup, candidate ranking) is cheap but not
free, and the serve tier calls it per coalesced group.  This bounded LRU
memoizes resolved decisions per (bucket key, mode, span) so the steady
state is one dict hit per dispatch.  ``Router.warmup`` pre-tunes the
declared buckets through the same entry point, so a warmed serving
process never ranks (let alone measures) on the request path.
"""
from __future__ import annotations

import threading
from collections import OrderedDict

_MAX_ENTRIES = 512
_lock = threading.Lock()
_lru: "OrderedDict[tuple, object]" = OrderedDict()
_hits = 0
_misses = 0


def cached(key: tuple, compute):
    """Return the memoized value for ``key``, computing (and caching) it
    on a miss.  Thread-safe; ``compute`` runs outside the lock (a
    concurrent duplicate compute is harmless — last write wins)."""
    global _hits, _misses
    with _lock:
        if key in _lru:
            _lru.move_to_end(key)
            _hits += 1
            return _lru[key]
        _misses += 1
    val = compute()
    with _lock:
        _lru[key] = val
        _lru.move_to_end(key)
        while len(_lru) > _MAX_ENTRIES:
            _lru.popitem(last=False)
    return val


def clear_tuning_cache() -> None:
    """Drop every memoized decision (tests / after table re-records)."""
    global _hits, _misses
    with _lock:
        _lru.clear()
        _hits = 0
        _misses = 0


def cache_info() -> dict:
    with _lock:
        return {"entries": len(_lru), "hits": _hits, "misses": _misses,
                "max_entries": _MAX_ENTRIES}


def cache_keys() -> list:
    with _lock:
        return list(_lru.keys())
