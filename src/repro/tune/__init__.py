"""repro.tune — cost-model-driven autotuning for kernel/dispatch config.

Two stages (see ``tuner``): an analytical ``KernelCostModel`` ranks
candidate configurations per (backend, metric, dtype, pow-2 shape
bucket); a short measured search optionally refines the top candidates,
with winners persisted in a versioned JSON ``TuningTable`` (shipped
defaults under ``tables/``) behind a per-process LRU (``cache``).

The engine consults this package end-to-end: ``choose_impl`` ranks the
in-core impls, ``resolve_blocks`` fills kernel blocks, the chunked and
sharded paths take ``chunk`` / ``n_micro`` from the same oracle, and
``Router.warmup`` pre-tunes declared buckets (``pretune_request``).
Explicit caller kwargs always win, and tuning is bitwise-safe by
construction: every knob it sets is one the engine's invariance tests
already prove cannot change int32 results — tuning changes speed, never
answers.  ``tune='off'`` keeps the legacy hand-tuned constants
everywhere.

``DispatchDecision`` is the observability record ``engine.sdtw(...,
explain=True)`` returns next to the result (and bench rows carry as a
``decision`` field): which impl/config won, why (model score vs table
hit vs explicit override), and the ranked alternatives.
"""
from __future__ import annotations

import dataclasses

from .cache import cache_info, cache_keys, clear_tuning_cache
from .cost import (KernelCostModel, TunedConfig, bucket_key,
                   get_cost_model, tuned_n_micro)
from .table import TuningTable, default_table, reset_tables
from .tuner import (Resolution, canonical_backend, measured_search,
                    pretune_request, rank_incore, record_table, resolve,
                    resolve_n_micro, tuned_blocks, tuned_chunk)

__all__ = [
    "DispatchDecision", "KernelCostModel", "Resolution", "TunedConfig",
    "TuningTable", "bucket_key", "cache_info", "cache_keys",
    "canonical_backend", "clear_tuning_cache", "default_table",
    "get_cost_model", "measured_search", "pretune_request", "rank_incore",
    "record_table", "reset_tables", "resolve", "resolve_n_micro",
    "tuned_blocks", "tuned_chunk", "tuned_n_micro",
]


@dataclasses.dataclass(frozen=True)
class DispatchDecision:
    """Why the engine ran what it ran — the ``explain=True`` payload.

    ``source`` taxonomy: ``'explicit'`` (caller forced the impl),
    ``'structural'`` (a hard dispatch rule — mesh/top-K/chunk/TPU/memory
    bound — fired before any scoring), ``'legacy'`` (``tune='off'``
    heuristics), ``'model'`` (cost-model ranking), ``'table:model'`` /
    ``'table:measured'`` / ``'table:default'`` (tuning-table hit,
    suffixed with the entry's own provenance), ``'measured'`` (fresh
    measured search this call).  ``config`` holds the resolved knobs the
    chosen path actually received (only the relevant ones);
    ``candidates`` is the model's ranked impl scoring when one ran.
    """
    impl: str
    source: str
    reason: str
    config: dict = dataclasses.field(default_factory=dict)
    score_us: float | None = None
    candidates: tuple = ()

    def token(self) -> str:
        """Compact ``source:impl`` form for bench-row derived fields."""
        return f"{self.source}:{self.impl}"
