"""Versioned JSON persistence for tuning winners (``TuningTable``).

A table maps bucket keys (``repro.tune.cost.bucket_key``) to
``TunedConfig`` entries.  On-disk schema::

    {"schema": "repro.tune/v1",
     "backend": "interpret",
     "provenance": "how/where the entries were recorded",
     "entries": {"<bucket key>": {"impl": ..., "block_q": ...,
                                  "source": "measured", "score_us": ...}}}

Robustness contract (tested): loading a corrupt, unreadable, or
wrong-schema file never raises — it warns and yields an *empty* table,
so a damaged table file degrades serving to pure model predictions
instead of taking the process down.  ``save()`` writes atomically
(temp file + rename).

Shipped defaults live under ``repro/tune/tables/{backend}.json`` and are
loaded once per process (``default_table``); re-record them with
``python -m repro.tune.tuner --backend <name> --out <path>``.
"""
from __future__ import annotations

import json
import os
import tempfile
import warnings
from typing import Optional

from .cost import TunedConfig

SCHEMA = "repro.tune/v1"
_TABLES_DIR = os.path.join(os.path.dirname(__file__), "tables")


class TuningTable:
    """An in-memory bucket-key -> ``TunedConfig`` map with JSON I/O."""

    def __init__(self, backend: str = "interpret", *,
                 provenance: str = "", entries: Optional[dict] = None):
        self.backend = backend
        self.provenance = provenance
        self._entries: dict[str, TunedConfig] = dict(entries or {})

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: str) -> bool:
        return key in self._entries

    def keys(self):
        return self._entries.keys()

    def get(self, key: str) -> Optional[TunedConfig]:
        return self._entries.get(key)

    def put(self, key: str, config: TunedConfig) -> None:
        self._entries[key] = config

    # -- persistence ----------------------------------------------------

    def to_json(self) -> dict:
        return {"schema": SCHEMA, "backend": self.backend,
                "provenance": self.provenance,
                "entries": {k: v.to_json()
                            for k, v in sorted(self._entries.items())}}

    def save(self, path: str) -> None:
        """Atomic write (temp + rename) so a crash mid-save can never
        leave a half-written table behind."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_json(), f, indent=1, sort_keys=True)
                f.write("\n")
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    @classmethod
    def load(cls, path: str, backend: str = "interpret") -> "TuningTable":
        """Load a table; ANY failure (missing file, corrupt JSON, wrong
        schema version, malformed entries) degrades to an empty table
        with a warning — tuning must never take the caller down."""
        try:
            with open(path) as f:
                raw = json.load(f)
        except FileNotFoundError:
            return cls(backend)
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
            warnings.warn(f"tuning table {path!r} is unreadable ({e}); "
                          f"falling back to the cost model", stacklevel=2)
            return cls(backend)
        if not isinstance(raw, dict) or raw.get("schema") != SCHEMA:
            warnings.warn(
                f"tuning table {path!r} has schema "
                f"{raw.get('schema') if isinstance(raw, dict) else type(raw).__name__!r}"
                f" (want {SCHEMA!r}); ignoring it", stacklevel=2)
            return cls(backend)
        entries = {}
        for key, val in (raw.get("entries") or {}).items():
            try:
                entries[key] = TunedConfig.from_json(dict(val))
            except (TypeError, ValueError):
                warnings.warn(f"tuning table {path!r}: dropping malformed "
                              f"entry {key!r}", stacklevel=2)
        return cls(raw.get("backend", backend),
                   provenance=raw.get("provenance", ""), entries=entries)


_DEFAULT_TABLES: dict = {}


def default_table(backend: str) -> TuningTable:
    """The process-wide table for a backend: the shipped
    ``tables/{backend}.json`` defaults (empty if none ship), loaded once.
    Measured winners recorded at runtime land in this object."""
    if backend not in _DEFAULT_TABLES:
        _DEFAULT_TABLES[backend] = TuningTable.load(
            os.path.join(_TABLES_DIR, f"{backend}.json"), backend)
    return _DEFAULT_TABLES[backend]


def reset_tables() -> None:
    """Drop the process table cache (tests; re-reads shipped files)."""
    _DEFAULT_TABLES.clear()
