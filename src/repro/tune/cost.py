"""The analytical stage of the autotuner: a per-config ``KernelCostModel``.

Every engine execution regime (rowscan / wavefront / chunked / pallas)
is priced in microseconds from the calibrated per-backend constants of
``repro.core.platforms.BackendModel``.  The terms, per regime:

  * ``rowscan``  — N sequential row steps, each a tropical associative
    scan over the full (nq, M) live row: ``N * (row_fixed +
    scan_elem * nq * M)``, with the scan-element cost inflating once the
    live rows outgrow the backend's cache knee.
  * ``wavefront`` — N+M-1 anti-diagonal steps, each touching nq * N
    cells: ``(N+M-1) * (wf_fixed + wf_elem * nq * N)``.  On XLA-CPU the
    per-step cost is ~100x below a rowscan row step, which is why the
    wavefront wins every measured in-core CPU shape (2.5-6.7x).
  * ``chunked``  — rowscan economics per tile plus a per-tile fixed cost
    and one boundary-column crossing per chunk: larger chunks amortize
    the N-row-steps-per-chunk overhead until the nq * chunk live rows
    fall out of cache.
  * ``pallas``   — per grid cell: launch/fill (``tile_fixed``), a per-row
    cost, and a per-cell cost with a scan-depth term — ``pass_us *
    log2(block_q * block_m)`` scan passes, weighted by the backend's
    scheme multiplier ('shift' Hillis-Steele is the cheap scheme on TPU,
    the work-efficient 'assoc' in interpret mode) — plus the HBM
    streaming term via ``launch.roofline.kernel_roofline`` and a padding
    -waste factor for batches that do not fill ``block_q``.  Configs
    whose VMEM working set ``block_q * (3*block_m + 3*N)`` words (span
    mode ``block_q * (6*block_m + 5*N)``) exceed the backend budget are
    rejected outright — the same formula ``kernels/sdtw/ops.py``
    documents.

The model's absolute numbers are rough; only its *ranking* is consumed
(and CI validates the ranking against the measured rows of
``BENCH_baseline.json`` — see ``repro.tune.validate``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.platforms import BackendModel, backend_model

#: Knobs a tuning decision may set.  ``None`` fields mean "not applicable
#: to the chosen impl" — the oracle only ever fills knobs the caller left
#: unset (explicit kwargs always win).
@dataclasses.dataclass(frozen=True)
class TunedConfig:
    impl: Optional[str] = None
    block_q: Optional[int] = None
    block_m: Optional[int] = None
    scan_scheme: Optional[str] = None
    row_tile: Optional[int] = None
    chunk: Optional[int] = None
    n_micro: Optional[int] = None
    score_us: Optional[float] = None
    source: str = "model"          # 'model' | 'measured' | 'default'

    def to_json(self) -> dict:
        d = {k: v for k, v in dataclasses.asdict(self).items()
             if v is not None}
        return d

    @classmethod
    def from_json(cls, d: dict) -> "TunedConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


def _pow2_bucket(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


def bucket_key(backend: str, metric: str, dtype: str,
               nq: int, n: int, m: int) -> str:
    """The (backend, metric, dtype, pow-2 shape bucket) table key.

    Shapes are bucketed to the next power of two — the same bucketing
    the engine's ragged dispatch uses — so the table stays O(log shape)
    instead of one entry per distinct size.
    """
    return (f"{backend}/{metric}/{dtype}/b{_pow2_bucket(max(1, nq))}"
            f"/n{_pow2_bucket(max(1, n))}/m{_pow2_bucket(max(1, m))}")


class KernelCostModel:
    """Prices engine configurations for one backend (see module doc)."""

    #: chunk sizes the chunked oracle ranks.
    CHUNK_CANDIDATES = (4096, 8192, 16384, 32768, 65536, 131072)
    #: reference-tile sizes the pallas oracle ranks (clamped to shape).
    BLOCK_M_CANDIDATES = (256, 512, 1024, 2048, 4096)

    def __init__(self, backend: "str | BackendModel" = "interpret"):
        self.backend = (backend if isinstance(backend, BackendModel)
                        else backend_model(backend))

    # -- the documented VMEM working-set formula ------------------------

    @staticmethod
    def vmem_words(block_q: int, block_m: int, n: int,
                   span: bool = False) -> int:
        """Accumulator words live per pallas grid cell — identical to the
        formula in the ``sdtw_pallas`` docstring (boundary column in
        persistent scratch + ~3 (plain) / ~6 (span) live row vectors,
        span mode adding the int32 start lanes)."""
        if span:
            return block_q * (6 * block_m + 5 * n)
        return block_q * (3 * block_m + 3 * n)

    # -- per-regime cost (microseconds) ---------------------------------

    def _scan_elem(self, live_elems: int) -> float:
        """Row-scan per-element cost, inflated past the cache knee."""
        be = self.backend
        over = max(0.0, math.log2(max(1, live_elems) / be.cache_elems))
        return be.scan_elem_us * (1.0 + 0.25 * over)

    def rowscan_us(self, nq: int, n: int, m: int) -> float:
        be = self.backend
        return be.call_fixed_us + n * (
            be.row_step_fixed_us + self._scan_elem(nq * m) * nq * m)

    def wavefront_us(self, nq: int, n: int, m: int) -> float:
        be = self.backend
        steps = n + m - 1
        return be.call_fixed_us + steps * (
            be.wf_step_fixed_us + be.wf_elem_us * nq * n)

    def chunked_us(self, nq: int, n: int, m: int, chunk: int) -> float:
        be = self.backend
        n_chunks = -(-m // chunk)
        per_row = be.row_step_fixed_us \
            + self._scan_elem(nq * chunk) * nq * chunk
        return (be.call_fixed_us + n_chunks * be.chunk_fixed_us
                + n_chunks * n * per_row)

    def pallas_us(self, nq: int, n: int, m: int, block_q: int,
                  block_m: int, scan_scheme: str, row_tile: int,
                  span: bool = False) -> float:
        """One pallas launch over the full grid; ``inf`` when the config
        busts the VMEM budget (never a candidate)."""
        be = self.backend
        if self.vmem_words(block_q, block_m, n, span) \
                > be.vmem_budget_words:
            return float("inf")
        q_tiles = -(-nq // block_q)
        m_tiles = -(-max(m, block_m) // block_m)
        tiles = q_tiles * m_tiles
        # Padding waste: cells are computed on the padded grid.
        cells = (q_tiles * block_q) * n * (m_tiles * block_m)
        passes = math.log2(max(2, block_q * block_m))
        elem = be.pallas_elem_us + be.pallas_pass_us * passes \
            * be.scheme_cost_mult(scan_scheme)
        # HBM streaming: the reference is re-read once per query tile,
        # queries once per reference tile, boundary column stays in VMEM
        # scratch (free); 4-byte accumulator words.
        hbm_bytes = 4 * (q_tiles * m + m_tiles * block_q * n)
        from repro.launch.roofline import kernel_roofline
        hbm_us = kernel_roofline(
            0, hbm_bytes, cells_per_s=1.0,
            hbm_bw=be.hbm_bw_bytes_per_s)[0] * 1e6
        rt_mult = 1.0 + 0.02 * max(0, 8 // max(1, row_tile) - 1)
        return (be.call_fixed_us + tiles * be.tile_fixed_us
                + tiles * n * be.pallas_row_fixed_us * rt_mult
                + cells * elem + hbm_us)

    # -- candidate enumeration / ranking --------------------------------

    def rank_impls(self, nq: int, n: int, m: int,
                   impls=("wavefront", "rowscan")) -> list:
        """Ranked ``[(impl, predicted_us), ...]``, cheapest first."""
        scored = []
        for impl in impls:
            if impl == "rowscan":
                us = self.rowscan_us(nq, n, m)
            elif impl == "wavefront":
                us = self.wavefront_us(nq, n, m)
            elif impl == "chunked":
                us = self.chunked_us(nq, n, m, self.best_chunk(nq, n, m))
            elif impl == "pallas":
                us = self.pallas_candidates(nq, n, m)[0][1]
            else:
                continue
            scored.append((impl, us))
        scored.sort(key=lambda t: t[1])
        return scored

    def chunk_candidates(self, nq: int, n: int, m: int) -> list:
        """Ranked ``[(chunk, predicted_us), ...]`` for the chunked path."""
        cands = sorted({min(c, _pow2_bucket(m))
                        for c in self.CHUNK_CANDIDATES})
        scored = [(c, self.chunked_us(nq, n, m, c)) for c in cands]
        scored.sort(key=lambda t: t[1])
        return scored

    def best_chunk(self, nq: int, n: int, m: int) -> int:
        return self.chunk_candidates(nq, n, m)[0][0]

    def pallas_candidates(self, nq: int, n: int, m: int,
                          span: bool = False) -> list:
        """Ranked ``[((block_q, block_m, scheme, row_tile), us), ...]``.

        The candidate set stays deliberately small (it seeds the measured
        stage): block_q from 1 up to the batch (interpret) or the sublane
        multiple 8 (TPU), block_m the pow-2 ladder clamped to the
        reference, both scan schemes, the backend's natural row_tile.
        """
        interpret = self.backend.name != "tpu"
        if interpret:
            bq_cands = sorted({bq for bq in (1, 2, 4, 8, 16, 32)
                               if bq <= max(1, nq)} | {min(32, max(1, nq))})
            rt = 1
        else:
            bq_cands = [8, 16]
            rt = 8
        bm_cands = sorted({min(bm, _pow2_bucket(m))
                           for bm in self.BLOCK_M_CANDIDATES})
        scored = []
        for bq in bq_cands:
            for bm in bm_cands:
                for scheme in ("assoc", "shift"):
                    us = self.pallas_us(nq, n, m, bq, bm, scheme, rt,
                                        span=span)
                    if math.isfinite(us):
                        scored.append(((bq, bm, scheme, rt), us))
        scored.sort(key=lambda t: t[1])
        if not scored:
            raise ValueError(
                f"no pallas config fits the VMEM budget for nq={nq} "
                f"n={n} m={m} (span={span})")
        return scored

    def best_pallas(self, nq: int, n: int, m: int,
                    span: bool = False) -> TunedConfig:
        (bq, bm, scheme, rt), us = self.pallas_candidates(
            nq, n, m, span=span)[0]
        return TunedConfig(impl="pallas", block_q=bq, block_m=bm,
                           scan_scheme=scheme, row_tile=rt, score_us=us)


def tuned_n_micro(nq: int, n_dp: int, n_mp: int) -> int:
    """Pipeline-fill microbatch count: as many microbatches per dp row as
    the systolic depth can overlap (``n_mp``) without any slot being pure
    padding — the fill/drain bubble is ``(n_mp - 1) / (n_micro + n_mp - 1)``
    of the schedule, so more (real) microbatches amortize it.  Mirrors
    ``distributed.sdtw_sharded.make_schedule``'s default so the engine
    can report (and the table can override) the choice explicitly."""
    return max(1, min(n_mp, -(-max(1, nq) // n_dp)))


_MODELS: dict = {}


def get_cost_model(backend: str) -> KernelCostModel:
    """Process-cached ``KernelCostModel`` per backend name."""
    if backend not in _MODELS:
        _MODELS[backend] = KernelCostModel(backend)
    return _MODELS[backend]
