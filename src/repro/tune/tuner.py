"""The two-stage autotuner and the oracle the engine consults.

Stage 1 (``mode='model'``, the default): the analytical
``KernelCostModel`` ranks candidate configurations for the request's
(backend, metric, dtype, pow-2 shape bucket); a shipped/recorded
``TuningTable`` entry overlays the prediction when one exists.  Stage 2
(``mode='measure'``): the top model candidates are re-ranked by a short
on-device measured search — median of ``reps`` timed runs, compile time
excluded by a warmup call — and the winner is persisted into the
process table (and the LRU), so the measurement runs once per bucket per
process.  ``mode='off'`` never reaches this module: the engine keeps its
legacy hand-tuned constants.

Resolution precedence, everywhere: explicit caller kwargs > measured
table entry > model-source table entry > cost-model prediction.

Measured search never runs on a jitted trace path: the engine resolves
``mode='measure'`` *before* dispatch, and the kernel-level consultation
(``resolve_blocks``) downgrades 'measure' to a table lookup — a
measurement inside ``jax.jit`` tracing would time tracing, not compute.

``python -m repro.tune.tuner --backend interpret --out tables/interpret.json``
re-records a shipped table (see README "Autotuning").
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import numpy as np

from .cache import cached
from .cost import (TunedConfig, bucket_key, get_cost_model, tuned_n_micro,
                   _pow2_bucket)
from .table import TuningTable, default_table

#: Measured-search bounds: candidates whose bucket exceeds this many DP
#: cells fall back to the model for that aspect (recording huge buckets
#: is a deliberate offline act, not a request-path surprise).
MEASURE_CAP_CELLS = 1 << 24
#: Timed repeats per candidate (median taken); one warmup run per
#: candidate excludes compile time.
MEASURE_REPS = 3


def canonical_backend(backend: Optional[str] = None) -> str:
    """Map a jax backend string to a tuning-backend name: 'tpu' keeps its
    own calibration; everything else executes via XLA-CPU semantics
    (pallas in interpret mode) and shares the 'interpret' family."""
    if backend is None:
        import jax
        backend = jax.default_backend()
    return "tpu" if backend == "tpu" else "interpret"


@dataclasses.dataclass(frozen=True)
class Resolution:
    """One resolved tuning decision for a bucket: the merged winning
    config, the model's impl ranking (for ``explain=``), and where the
    winner came from."""
    config: TunedConfig
    candidates: tuple      # (('wavefront', us), ('rowscan', us), ...)
    source: str            # 'model' | 'table:model' | 'table:measured'
                           # | 'measured'


def _overlay(base: TunedConfig, entry: TunedConfig) -> TunedConfig:
    """Table entry fields (non-None) win over the model prediction."""
    updates = {k: v for k, v in dataclasses.asdict(entry).items()
               if v is not None and k != "source"}
    return dataclasses.replace(base, **updates)


def resolve(nq: int, n: int, m: int, *, backend: Optional[str] = None,
            metric: str = "abs_diff", dtype: str = "int32",
            mode: str = "model", span: bool = False) -> Resolution:
    """The oracle: LRU -> table -> cost model (-> measured search under
    ``mode='measure'``).  Costs are evaluated at the bucket's pow-2
    shape so every shape in a bucket shares one decision."""
    backend = canonical_backend(backend)
    key = bucket_key(backend, metric, dtype, nq, n, m)

    def compute() -> Resolution:
        model = get_cost_model(backend)
        nb = _pow2_bucket(max(1, nq))
        nn = _pow2_bucket(max(1, n))
        nm = _pow2_bucket(max(1, m))
        ranked = tuple(model.rank_impls(nb, nn, nm))
        pal = model.best_pallas(nb, nn, nm, span=span)
        chunk = model.best_chunk(nb, nn, nm)
        cfg = TunedConfig(
            impl=ranked[0][0], block_q=pal.block_q, block_m=pal.block_m,
            scan_scheme=pal.scan_scheme, row_tile=pal.row_tile,
            chunk=chunk, score_us=ranked[0][1], source="model")
        source = "model"
        entry = default_table(backend).get(key)
        if entry is not None:
            cfg = _overlay(cfg, entry)
            source = f"table:{entry.source}"
        if mode == "measure" and (entry is None
                                  or entry.source != "measured"):
            cfg = measured_search(nb, nn, nm, backend=backend,
                                  metric=metric, dtype=dtype, span=span,
                                  seed_config=cfg)
            default_table(backend).put(key, cfg)
            source = "measured"
        return Resolution(dataclasses.replace(cfg, source=source),
                          ranked, source)

    return cached((key, span, mode), compute)


# ---------------------------------------------------------------------------
# Engine-facing oracle entry points
# ---------------------------------------------------------------------------

def tuned_blocks(b: int, m: int, *, n: int, backend: Optional[str] = None,
                 metric: str = "abs_diff", dtype: str = "int32",
                 mode: str = "model", span: bool = False) -> tuple:
    """Kernel block knobs for ``resolve_blocks``:
    ``(block_q, block_m, scan_scheme, row_tile)``.  'measure' downgrades
    to the table (see module doc — this is called at trace time)."""
    res = resolve(b, n, m, backend=backend, metric=metric, dtype=dtype,
                  mode="model" if mode == "measure" else mode, span=span)
    c = res.config
    return c.block_q, c.block_m, c.scan_scheme, c.row_tile


def tuned_chunk(nq: int, n: int, m: int, *,
                backend: Optional[str] = None, metric: str = "abs_diff",
                dtype: str = "int32", mode: str = "model") -> int:
    """Reference tile size for the chunked/sharded streaming paths."""
    return resolve(nq, n, m, backend=backend, metric=metric,
                   dtype=dtype, mode=mode).config.chunk


def rank_incore(nq: int, n: int, m: int, *,
                backend: Optional[str] = None, metric: str = "abs_diff",
                dtype: str = "int32", mode: str = "model") -> Resolution:
    """In-core impl choice (rowscan vs wavefront) for ``choose_impl``."""
    return resolve(nq, n, m, backend=backend, metric=metric,
                   dtype=dtype, mode=mode)


def resolve_n_micro(nq: int, n_dp: int, n_mp: int, *, n: int, m: int,
                    backend: Optional[str] = None,
                    metric: str = "abs_diff", dtype: str = "int32",
                    mode: str = "model") -> int:
    """Microbatch count for the sharded systolic schedule: a table entry
    wins (clamped to the schedule's validity envelope), else the
    pipeline-fill default."""
    fill = tuned_n_micro(nq, n_dp, n_mp)
    if mode == "off":
        return fill
    entry = resolve(nq, n, m, backend=backend, metric=metric,
                    dtype=dtype, mode=mode).config.n_micro
    if entry is None:
        return fill
    return max(1, min(int(entry), n_mp, max(1, nq) // max(1, n_dp) or 1))


# ---------------------------------------------------------------------------
# Stage 2: the measured search
# ---------------------------------------------------------------------------

def _bench_data(nq: int, n: int, m: int, dtype: str):
    rng = np.random.default_rng(1234 + nq + n + m)
    if dtype.startswith("int"):
        q = rng.integers(-100, 100, (nq, n)).astype(np.int32)
        r = rng.integers(-100, 100, (m,)).astype(np.int32)
    else:
        q = rng.standard_normal((nq, n)).astype(np.float32)
        r = rng.standard_normal((m,)).astype(np.float32)
    import jax.numpy as jnp
    return jnp.asarray(q), jnp.asarray(r)


def _time_median_us(fn, reps: int = MEASURE_REPS) -> float:
    """Median wall time of ``fn()`` in us; one untimed warmup call first
    so XLA compilation is excluded."""
    import jax
    jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def measured_search(nq: int, n: int, m: int, *, backend: str,
                    metric: str = "abs_diff", dtype: str = "int32",
                    span: bool = False,
                    seed_config: Optional[TunedConfig] = None,
                    reps: int = MEASURE_REPS, top: int = 3) -> TunedConfig:
    """Refine the model's top candidates on the actual device.

    Measures (independently, each aspect skipped when the bucket exceeds
    ``MEASURE_CAP_CELLS``): the in-core impl ranking, the top ``top``
    chunk sizes, and the top ``top`` pallas block configs.  Returns the
    merged ``TunedConfig(source='measured')``.  Runs eagerly — never
    call from inside a trace.
    """
    import functools
    model = get_cost_model(backend)
    cells = nq * n * m
    q, r = _bench_data(nq, n, m, dtype)
    cfg = seed_config or TunedConfig()
    best_impl, impl_us = cfg.impl, cfg.score_us

    if cells <= MEASURE_CAP_CELLS:
        from repro.core.sdtw import sdtw_batch
        timed = []
        for impl, _ in model.rank_impls(nq, n, m):
            us = _time_median_us(functools.partial(
                sdtw_batch, q, r, None, metric, impl), reps)
            timed.append((impl, us))
        timed.sort(key=lambda t: t[1])
        best_impl, impl_us = timed[0]

    best_chunk = cfg.chunk
    if m > 4096 and cells <= MEASURE_CAP_CELLS * 4:
        from repro.core.sdtw import sdtw_chunked
        cands = [c for c, _ in model.chunk_candidates(nq, n, m)[:top]]
        timed = [(c, _time_median_us(functools.partial(
            sdtw_chunked, q, r, None, metric, c), reps)) for c in cands]
        timed.sort(key=lambda t: t[1])
        best_chunk = timed[0][0]

    bq, bm, scheme, rt = (cfg.block_q, cfg.block_m, cfg.scan_scheme,
                          cfg.row_tile)
    if cells <= MEASURE_CAP_CELLS:
        from repro.kernels.sdtw import sdtw_pallas
        cands = [c for c, _ in
                 model.pallas_candidates(nq, n, m, span=span)[:top]]
        timed = []
        for (cbq, cbm, cscheme, crt) in cands:
            us = _time_median_us(functools.partial(
                sdtw_pallas, q, r, None, metric, block_q=cbq,
                block_m=cbm, scan_scheme=cscheme, row_tile=crt), reps)
            timed.append(((cbq, cbm, cscheme, crt), us))
        timed.sort(key=lambda t: t[1])
        (bq, bm, scheme, rt), _ = timed[0]

    return TunedConfig(impl=best_impl, block_q=bq, block_m=bm,
                       scan_scheme=scheme, row_tile=rt, chunk=best_chunk,
                       n_micro=cfg.n_micro, score_us=impl_us,
                       source="measured")


# ---------------------------------------------------------------------------
# Serve-tier pre-tuning (Router.warmup)
# ---------------------------------------------------------------------------

def pretune_request(request) -> int:
    """Resolve tuning decisions for every pow-2 bucket a request's query
    set will dispatch as, priming the LRU (and, under
    ``request.tune='measure'``, the process table) so the serve request
    path never ranks or measures.  Returns the number of buckets primed.
    """
    mode = getattr(request, "tune", "model")
    if mode == "off":
        return 0
    qs = request.queries
    ref = np.asarray(request.reference)
    m = ref.shape[-1]
    dtype = "int32"
    try:
        dtype = str(np.result_type(
            *( [np.asarray(x) for x in qs] if isinstance(qs, (list, tuple))
               else [np.asarray(qs)] ), ref))
    except TypeError:
        pass
    span = bool(request.return_spans)
    from repro.core.engine import bucketize
    if isinstance(qs, (list, tuple)):
        buckets = bucketize([len(np.asarray(x)) for x in qs])
        shapes = [(len(idxs), blen) for blen, idxs in buckets.items()]
    else:
        arr = np.asarray(qs)
        nq, n = (1, arr.shape[0]) if arr.ndim == 1 else arr.shape
        shapes = [(nq, n)]
    for nq, n in shapes:
        resolve(nq, n, m, metric=request.metric, dtype=dtype, mode=mode,
                span=span)
    return len(shapes)


# ---------------------------------------------------------------------------
# Table recording CLI
# ---------------------------------------------------------------------------

#: Shapes the shipped tables cover: the committed bench shapes plus the
#: smoke lane and the chunked-streaming bench bucket (impl/pallas
#: measurement is capped out there — only the chunk size is measured).
DEFAULT_RECORD_SHAPES = ((2, 16, 256), (4, 32, 1024), (8, 64, 4096),
                         (4, 32, 16384), (8, 16, 4096), (4, 32, 262144))


def record_table(backend: str, shapes=DEFAULT_RECORD_SHAPES, *,
                 reps: int = MEASURE_REPS,
                 provenance: str = "") -> TuningTable:
    """Measure every shape bucket and return a fresh ``TuningTable``."""
    table = TuningTable(backend, provenance=provenance)
    for nq, n, m in shapes:
        nb, nn, nm = (_pow2_bucket(nq), _pow2_bucket(n), _pow2_bucket(m))
        key = bucket_key(backend, "abs_diff", "int32", nq, n, m)
        model = get_cost_model(backend)
        ranked = model.rank_impls(nb, nn, nm)
        pal = model.best_pallas(nb, nn, nm)
        seed = TunedConfig(impl=ranked[0][0], block_q=pal.block_q,
                           block_m=pal.block_m,
                           scan_scheme=pal.scan_scheme,
                           row_tile=pal.row_tile,
                           chunk=model.best_chunk(nb, nn, nm),
                           score_us=ranked[0][1])
        cfg = measured_search(nb, nn, nm, backend=backend,
                              seed_config=seed, reps=reps)
        table.put(key, cfg)
        print(f"recorded {key}: {cfg.to_json()}")
    return table


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default=None,
                    help="tuning backend (default: current jax backend)")
    ap.add_argument("--out", required=True, help="table JSON path")
    ap.add_argument("--shapes", default=None,
                    help="semicolon-separated nq,n,m triples "
                         "(default: the committed bench shapes)")
    ap.add_argument("--reps", type=int, default=MEASURE_REPS)
    args = ap.parse_args(argv)
    backend = canonical_backend(args.backend)
    shapes = DEFAULT_RECORD_SHAPES
    if args.shapes:
        shapes = tuple(tuple(int(x) for x in s.split(","))
                       for s in args.shapes.split(";"))
    import platform
    table = record_table(
        backend, shapes, reps=args.reps,
        provenance=f"median-of-{args.reps} measured on "
                   f"{platform.machine()} ({backend})")
    table.save(args.out)
    print(f"wrote {len(table)} entries to {args.out}")


if __name__ == "__main__":
    main()
