"""Online sDTW monitoring over the chunk-carry protocol.

A ``StreamSession`` turns the engine's offline chunk loop inside out: the
*reference* is no longer a materialized array but an unbounded sequence of
chunks (an ECG electrode, a seismometer, a power meter — the paper's
continuous-monitoring workloads, §I/§V). The session holds a batch of
(possibly ragged) queries plus their DP carries — exactly the
``(boundary column[, start lane], best)`` tuples of
``repro.core.sdtw.sdtw_carry_init`` and, in match mode, the
``repro.core.topk`` heap — and ``session.feed(chunk)`` advances every
query by that chunk through the *same* ``sdtw_rowscan_chunk`` /
``sdtw_pallas`` code paths the offline engine runs. Because the carry
protocol is already chunk-size-invariant, any partition of the reference
fed through a session reproduces ``engine.sdtw`` distances, spans and
top-K *bitwise* (int32) — the differential property ``tests/test_stream.py``
enforces. On the Pallas impl, top-K heaps, threshold alerts and online
pruning all consume the kernel's in-kernel last-row capture (the per-tile
candidate row), folded with the identical ``topk_fold_lastrow`` merge the
rowscan path uses, so both impls produce the same bits; only per-query
exclusion zones still require ``impl='rowscan'``.

Mechanics that make streaming practical:

  * **One compiled shape per tile.** Fed chunks are buffered and the DP
    advances in fixed ``chunk``-sized tiles; the final partial tile is
    right-padded and masked via the DP's global-position ban
    (``m_total``), with the boundary column extracted at the *true* last
    column (the ``clen`` lane of ``sdtw_rowscan_chunk`` / the Pallas
    kernel's traced ``ref_len``) so a flushed session can keep streaming.
    Feed granularity is therefore decoupled from compile granularity.
  * **Online pruning.** With ``prune=True`` the session computes each
    tile's [min, max] envelope as it arrives, extends the shared
    ``EnvelopeCache`` under ``(ref_key, chunk)`` (an offline
    ``search_topk`` against the materialized reference later *hits* that
    entry), and runs the LB_Kim/LB_Keogh cascade against the current
    heap thresholds — a tile no query can improve on is skipped without
    touching the DP. Skipped tiles break the continuous carry, so — as in
    ``repro.search`` — surviving tiles are scored from a fresh carry
    warmed by a ``halo`` of buffered left-context tiles; the same
    ``span_cap`` caveat applies, and the admissibility of the bounds
    makes the pruned heap equal to the exact streamed heap whenever no
    relevant match's span exceeds the cap.
  * **Threshold alerts.** ``alert_threshold`` watches the per-tile
    candidate row (the cost of a match *ending* at each arriving sample):
    any query whose candidate drops to ``<= alert_threshold`` fires an
    ``AlertEvent`` (appended to ``session.alerts`` and passed to the
    ``on_alert`` callback) — feed anomaly templates as queries and the
    session becomes an online anomaly detector.
  * **Fault tolerance.** ``session.snapshot()`` returns a flat dict of
    numpy arrays (``np.savez``-able as-is); ``StreamSession.restore``
    rebuilds a session that continues bit-for-bit where the original
    would have — kill the process mid-stream, restore, keep feeding.

Results are read non-destructively: ``session.results()`` applies the
buffered tail to a *copy* of the carry, so polling mid-stream never
perturbs the tile alignment of the live session. ``session.flush()``
pushes the tail through destructively (the carry stays exact thanks to
``clen``); in pruned mode a flush is terminal, because a partial tile
breaks the halo-group alignment the pruning windows assume.

Exactness notes: distances, spans and the top-1 match are exact for every
feed partition and any interleaving of ``flush()`` calls. The k > 1 heap
inherits the documented greedy-merge semantics of the offline chunked
path: it is bitwise-reproducible for a given tile size and equals the
offline heap when tile boundaries match. A **mid-stream** ``flush()``
(exact mode, then feeding continues) shifts every later tile boundary,
as if the offline call had used a different chunking — the k > 1 heap
beyond top-1 may then legitimately differ from the aligned-boundary
result, so the first ``feed()`` after such a flush on a k > 1 session
raises a loud ``RuntimeWarning`` (``results()`` polls the tail without
moving boundaries and never warns; k = 1 / span / plain sessions stay
exact and stay silent). Pruned-mode flushes are terminal and cannot
shift anything.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import warnings
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as engine_mod
from repro.core.distances import accum_dtype, big
from repro.core.request import StreamRequest
from repro.core.sdtw import (default_excl_zone, sdtw_carry_init,
                             sdtw_chunk_batch, sdtw_chunk_batch_topk,
                             topk_fold_lastrow)
from repro.core.topk import topk_init
from repro.search import cache as cache_mod
from repro.search.lower_bounds import chunk_envelope, lb_cascade
from repro.search.search import DEFAULT_SPAN_FACTOR, _pruned_chunk_step

#: Default DP tile size — the engine's streaming default.
DEFAULT_STREAM_CHUNK = engine_mod.DEFAULT_CHUNK

_SNAP_VERSION = 1


@dataclasses.dataclass(frozen=True)
class AlertEvent:
    """One threshold crossing: query ``query`` matched the stream at cost
    ``distance`` ending at global sample ``end`` (span start ``start``;
    -1 when the session does not track starts). ``hits`` counts every
    sub-threshold end column inside the triggering tile
    ``[tile_start, tile_end)``; the reported (distance, end) is the best
    (leftmost on ties)."""
    query: int
    distance: float
    start: int
    end: int
    tile_start: int
    tile_end: int
    hits: int


@dataclasses.dataclass
class StreamResult:
    """Streamed match state after ``samples`` reference samples.

    ``distances`` is (nq,) — or (nq, k) in top-K mode, best first,
    BIG/-1-padded like ``repro.core.topk``. ``positions``/``starts`` are
    present when the session tracks ends/spans. Tile counters are
    per-*tile* across the whole (possibly multi-bucket) batch —
    ``tiles_pruned + tiles_processed == tiles_total`` always, unlike
    ``SearchResult``'s per-bucket chunk counters."""
    distances: object
    positions: object = None
    starts: object = None
    samples: int = 0
    tiles_total: int = 0
    tiles_pruned_kim: int = 0
    tiles_pruned_keogh: int = 0
    tiles_processed: int = 0

    @property
    def tiles_pruned(self) -> int:
        return self.tiles_pruned_kim + self.tiles_pruned_keogh

    @property
    def spans(self):
        """Stacked (start, end) spans, shape (..., 2)."""
        if self.starts is None or self.positions is None:
            raise ValueError("this session does not track spans — open it "
                             "with return_spans=True (or top_k=/prune=)")
        return np.stack([np.asarray(self.starts), np.asarray(self.positions)],
                        axis=-1)


@dataclasses.dataclass
class _Bucket:
    """One padded query bucket and its carry through the stream."""
    idxs: List[int]
    queries: jnp.ndarray        # (nb, blen)
    qlens: jnp.ndarray          # (nb,)
    lo: jnp.ndarray             # (nb,) banned-range lower bounds
    hi: jnp.ndarray
    zone: jnp.ndarray           # (nb,) top-K suppression radii
    carry: tuple                # chunk carry (+ heap in match mode)
    halo: int = 0               # pruned mode: left-context tiles
    thr: Optional[np.ndarray] = None  # pruned mode: per-query k-th best


@functools.partial(jax.jit, static_argnames=("metric",))
def _plain_step(queries, tile, qlens, carry, j0, m_total, clen, lo, hi, *,
                metric):
    return sdtw_chunk_batch(queries, tile, qlens, carry, j0, m_total,
                            metric, lo, hi, clen=clen)


@functools.partial(jax.jit, static_argnames=("metric", "block_q", "block_m",
                                             "k", "excl_span", "track",
                                             "want_lastrow", "with_heap"))
def _pallas_step(queries, tile, qlens, kcarry, heap, j0, clen, zone, *,
                 metric, block_q, block_m, k, excl_span, track,
                 want_lastrow, with_heap):
    """One streamed tile through the Pallas kernel: advance the kernel
    chunk carry and — when the session consumes candidate rows — fold the
    in-kernel last-row capture into the top-K heap with the identical
    per-tile ``topk_merge`` the rowscan path runs, so pallas sessions
    reproduce the offline chunked heap bitwise (int32)."""
    from repro.kernels.sdtw import sdtw_pallas
    out = sdtw_pallas(queries, tile, qlens, metric, block_q=block_q,
                      block_m=block_m, carry=kcarry, return_carry=True,
                      ref_offset=j0, ref_len=clen, track_start=track,
                      return_lastrow=want_lastrow)
    if not want_lastrow:
        _, kc = out
        return kc, None, None
    if track:
        _, kc, lrow, lstart = out
    else:
        _, kc, lrow = out
        lstart = None
    if with_heap:
        heap = topk_fold_lastrow(heap, lrow, lstart, j0, k, zone,
                                 excl_span)
        return kc + tuple(heap), lrow, lstart
    return kc, lrow, lstart


@functools.partial(jax.jit, static_argnames=("metric", "k", "excl_span",
                                             "track", "lastrow"))
def _heap_step(queries, tile, qlens, carry, j0, m_total, clen, lo, hi, zone,
               *, metric, k, excl_span, track, lastrow):
    out = sdtw_chunk_batch_topk(queries, tile, qlens, carry, j0, m_total,
                                metric, lo, hi, k, zone, excl_span, track,
                                clen=clen, return_lastrow=lastrow)
    if not lastrow:
        return out, None, None
    if track:
        return out[:6], out[6], out[7]
    return out[:5], out[5], None


class StreamSession:
    """Online sDTW monitor: a query batch streamed against an unbounded
    reference, one ``feed()`` at a time. See the module docstring for the
    protocol; ``engine.stream()`` is the front door."""

    def __init__(self, queries, *, qlens=None, metric: str = "abs_diff",
                 chunk: Optional[int] = None, impl: str = "rowscan",
                 top_k: Optional[int] = None, excl_zone=None,
                 excl_mode: str = "end", return_spans: bool = False,
                 return_positions: bool = False,
                 excl_lo=None, excl_hi=None,
                 prune: bool = False, span_cap: Optional[int] = None,
                 alert_threshold=None,
                 on_alert: Optional[Callable[[AlertEvent], None]] = None,
                 cache: Optional[cache_mod.EnvelopeCache] = None,
                 ref_key=None, block_q: Optional[int] = None,
                 block_m: Optional[int] = None):
        if impl not in ("rowscan", "pallas"):
            raise ValueError(f"impl must be 'rowscan' or 'pallas' for a "
                             f"stream session, got {impl!r}")
        # The session-argument checks live with the shared validator in
        # repro.core.request — one source for engine.stream(), the serve
        # tier, and direct construction, so the rules cannot drift.
        StreamRequest(
            queries=queries, qlens=qlens, metric=metric, impl=impl,
            chunk=chunk, top_k=top_k, excl_zone=excl_zone,
            excl_mode=excl_mode, return_spans=return_spans,
            return_positions=return_positions, excl_lo=excl_lo,
            excl_hi=excl_hi, prune=prune, span_cap=span_cap,
            alert_threshold=alert_threshold, on_alert=on_alert,
            cache=cache, ref_key=ref_key, block_q=block_q,
            block_m=block_m).validate_session()

        self.metric = metric
        self.impl = impl
        self.chunk = int(DEFAULT_STREAM_CHUNK if chunk is None else chunk)
        self.top_k = top_k
        self.excl_mode = excl_mode
        self.return_spans = bool(return_spans)
        self.return_positions = bool(return_positions)
        self.prune = bool(prune)
        self.alert_threshold = (None if alert_threshold is None
                                else float(alert_threshold))
        self.on_alert = on_alert
        self.ref_key = ref_key
        self.cache = cache_mod.DEFAULT_CACHE if cache is None else cache
        self.block_q = block_q
        self.block_m = block_m
        self.alerts: List[AlertEvent] = []

        self._derive_modes()
        self._dtype = None           # pinned by the first feed

        # --- bucket the query batch (ragged lists via the engine rules) --
        self._ragged = isinstance(queries, (list, tuple))
        if self._ragged:
            if qlens is not None:
                raise ValueError("qlens is implied by ragged (list) queries")
            qs = [np.asarray(q) for q in queries]
            if not qs:
                raise ValueError("need at least one query")
            self._nq = len(qs)
            self._single = False
            buckets = engine_mod.bucketize([len(q) for q in qs])
            bucket_arrays = []
            for blen, idxs in buckets.items():
                padded, lens = engine_mod.pad_ragged_bucket(qs, idxs, blen)
                bucket_arrays.append((idxs, padded, lens))
        else:
            q2 = np.asarray(queries)
            self._single = q2.ndim == 1
            if self._single:
                q2 = q2[None, :]
            self._nq = q2.shape[0]
            lens = (np.full((self._nq,), q2.shape[1], np.int32)
                    if qlens is None else np.asarray(qlens, np.int32))
            bucket_arrays = [(list(range(self._nq)), q2, lens)]

        lo_all = np.asarray(engine_mod._normalize_excl(excl_lo, self._nq))
        hi_all = np.asarray(engine_mod._normalize_excl(excl_hi, self._nq))
        if excl_zone is None:
            zone_all = None
        else:
            zone_all = np.broadcast_to(
                np.asarray(excl_zone, np.int32), (self._nq,))

        self._buckets: List[_Bucket] = []
        span_caps = []
        for idxs, padded, lens in bucket_arrays:
            n = padded.shape[1]
            if zone_all is None:
                zone = (np.asarray(default_excl_zone(lens))
                        if excl_mode == "end"
                        else np.zeros((len(idxs),), np.int32))
            else:
                zone = zone_all[np.asarray(idxs)]
            cap = (DEFAULT_SPAN_FACTOR * n if span_cap is None
                   else int(span_cap))
            span_caps.append(cap)
            halo = max(1, -(-cap // self.chunk)) if self.prune else 0
            b = _Bucket(idxs=list(idxs), queries=jnp.asarray(padded),
                        qlens=jnp.asarray(lens, jnp.int32),
                        lo=jnp.asarray(lo_all[np.asarray(idxs)]),
                        hi=jnp.asarray(hi_all[np.asarray(idxs)]),
                        zone=jnp.asarray(zone, jnp.int32),
                        carry=None, halo=halo)
            b.carry = self._fresh_carry(b)
            if self.prune:
                b.thr = np.full((len(idxs),), np.inf)
            self._buckets.append(b)
        self.span_cap = max(span_caps)
        self._max_halo = max(b.halo for b in self._buckets)

        # --- stream state ------------------------------------------------
        self._buf = np.zeros((0,), np.int32)
        self._offset = 0             # samples advanced through the DP
        self._finalized = False
        self._flush_shift_pending = False   # mid-stream flush happened
        self._ring: List[np.ndarray] = []   # pruned mode: last halo tiles
        self._env_tail: List[tuple] = []    # pruned mode: trailing envelopes
        # Full streamed envelope (accumulator dtype, one entry per tile) —
        # what cache.extend() has received so far. Snapshotted, so a
        # restore into a *fresh* cache can install the whole prefix
        # instead of extending from mid-stream (which would leave a
        # truncated envelope for offline reuse).
        self._env_mins: List[np.ndarray] = []
        self._env_maxs: List[np.ndarray] = []
        self.tiles_total = 0
        self.tiles_pruned_kim = 0
        self.tiles_pruned_keogh = 0
        self.tiles_processed = 0

    # ------------------------------------------------------------------
    # carry plumbing
    # ------------------------------------------------------------------

    def _derive_modes(self):
        """The mode lattice, mirroring sdtw_chunked: a heap rides the
        carry as soon as any positional output (or an alert feed) is
        consumed; the start lane only when spans/span-suppression need
        it. Derived in exactly one place so ``restore()`` can never
        unpack carries under a different layout than the session that
        snapshotted them.

        The pallas kernel tracks the top-1 (value, end, start) natively
        in its own carry, so a pallas session appends the heap triple to
        the kernel carry only for a real top-K, and asks the kernel for
        its in-kernel last-row capture exactly when a candidate row is
        consumed (top-K folding or threshold alerts)."""
        self._k = 1 if self.top_k is None else self.top_k
        if self.impl == "pallas":
            self._wants_heap = self.top_k is not None
            self._want_lastrow = (self.top_k is not None
                                  or self.alert_threshold is not None)
        else:
            self._wants_heap = (self.top_k is not None or self.return_spans
                                or self.return_positions
                                or self.alert_threshold is not None)
            self._want_lastrow = self.alert_threshold is not None
        self._track = self.return_spans or self.excl_mode == "span"

    def _acc(self, b: _Bucket):
        ref_dtype = self._dtype if self._dtype is not None \
            else np.asarray(b.queries).dtype
        return accum_dtype(jnp.result_type(np.asarray(b.queries).dtype,
                                           ref_dtype))

    def _fresh_carry(self, b: _Bucket):
        nb, n = b.queries.shape
        acc = self._acc(b)
        if self.prune:
            # Pruned mode scores surviving tiles from fresh halo-warmed
            # carries (on either impl) — the session carry is the heap.
            return topk_init(nb, self._k, acc)
        if self.impl == "pallas":
            if self._dtype is None:
                return None          # accumulator unknown until first feed
            from repro.kernels.sdtw import pallas_carry_init
            kc = pallas_carry_init(nb, n, acc, track_start=self._track)
            if self._wants_heap:
                return kc + topk_init(nb, self._k, acc)
            return kc
        if self._wants_heap:
            return (sdtw_carry_init(nb, n, acc, track_start=self._track)
                    + topk_init(nb, self._k, acc))
        return sdtw_carry_init(nb, n, acc)

    # ------------------------------------------------------------------
    # feeding
    # ------------------------------------------------------------------

    @property
    def samples_seen(self) -> int:
        """Reference samples fed so far (including the buffered tail)."""
        return self._offset + int(self._buf.shape[0])

    def feed(self, data) -> "StreamSession":
        """Append reference samples; advance the DP by every whole tile."""
        if self._finalized:
            raise RuntimeError("session is finalized (a pruned-mode flush "
                               "is terminal); snapshot/restore to branch "
                               "earlier")
        data = np.asarray(data)
        if data.ndim != 1:
            raise ValueError(f"feed() takes a 1-D chunk, got shape "
                             f"{data.shape}")
        if data.shape[0] == 0:
            return self
        if self._flush_shift_pending:
            self._flush_shift_pending = False
            if self.top_k is not None and self._k > 1:
                warnings.warn(
                    "feeding a k>1 session after a mid-stream flush(): the "
                    "partial tile shifted every later merge boundary, so "
                    "heap entries beyond top-1 may differ from an "
                    "aligned-boundary (offline or unflushed) run — the "
                    "top-1 distance/span stays exact. Poll results() "
                    "instead of flush() to read the tail without moving "
                    "boundaries.", RuntimeWarning, stacklevel=2)
        if self._dtype is None:
            self._dtype = data.dtype
            self._buf = np.zeros((0,), data.dtype)
            if self._offset == 0:
                # The carry's accumulator dtype depends on the stream's —
                # rebuild the untouched fresh carries now that it is known.
                for b in self._buckets:
                    b.carry = self._fresh_carry(b)
        elif data.dtype != self._dtype:
            raise ValueError(f"stream dtype changed mid-flight: "
                             f"{self._dtype} -> {data.dtype}")
        self._buf = np.concatenate([self._buf, data])
        while self._buf.shape[0] >= self.chunk:
            tile, self._buf = (self._buf[:self.chunk],
                               self._buf[self.chunk:])
            self._advance(tile, self.chunk)
        return self

    def flush(self) -> "StreamSession":
        """Destructively push the buffered tail through the DP.

        Exact mode keeps streaming afterwards (the carry exits at the true
        boundary); pruned mode finalizes the session — a partial tile
        breaks the halo-window alignment the bounds assume."""
        if self._buf.shape[0]:
            tail, self._buf = self._buf, self._buf[:0]
            padded = np.zeros((self.chunk,), tail.dtype)
            padded[:tail.shape[0]] = tail
            self._advance(padded, int(tail.shape[0]))
            if self.prune:
                self._finalized = True
            elif tail.shape[0] % self.chunk:
                # Exact mode keeps streaming, but the partial tile moved
                # every later tile boundary — the next feed() warns when
                # a k>1 heap rides this session (see module docstring).
                self._flush_shift_pending = True
        return self

    def _advance(self, tile_np: np.ndarray, clen: int):
        """Advance every bucket by one (possibly right-padded) tile."""
        j0 = self._offset
        if self.prune:
            self._advance_pruned(tile_np, clen, j0)
        else:
            tile = jnp.asarray(tile_np)
            for b in self._buckets:
                out = self._step_exact(b, tile, j0, clen, b.carry)
                b.carry, lrow, lstart = out
                if self.alert_threshold is not None:
                    self._emit_alerts(b, lrow, lstart, j0, clen)
            self.tiles_processed += 1      # exact mode runs every tile
        self.tiles_total += 1
        self._offset += clen

    def _step_exact(self, b: _Bucket, tile, j0: int, clen: int, carry):
        """One exact-mode tile for one bucket — pure in ``carry``."""
        j0_t = jnp.int32(j0)
        m_tot = jnp.int32(j0 + clen)
        cl = jnp.int32(clen)
        if self.impl == "pallas":
            kc = carry[:-3] if self._wants_heap else carry
            heap = carry[-3:] if self._wants_heap else None
            new, lrow, lstart = _pallas_step(
                b.queries, tile, b.qlens, kc, heap, j0_t, cl, b.zone,
                metric=self.metric, block_q=self.block_q,
                block_m=self.block_m, k=self._k,
                excl_span=self.excl_mode == "span", track=self._track,
                want_lastrow=self._want_lastrow,
                with_heap=self._wants_heap)
            return new, lrow, lstart
        if self._wants_heap:
            return _heap_step(b.queries, tile, b.qlens, carry, j0_t, m_tot,
                              cl, b.lo, b.hi, b.zone, metric=self.metric,
                              k=self._k, excl_span=self.excl_mode == "span",
                              track=self._track,
                              lastrow=self._want_lastrow)
        return (_plain_step(b.queries, tile, b.qlens, carry, j0_t, m_tot,
                            cl, b.lo, b.hi, metric=self.metric),
                None, None)

    def _emit_alerts(self, b: _Bucket, lrow, lstart, j0: int, clen: int):
        thr = self.alert_threshold
        lr = np.asarray(lrow)[:, :clen]
        ls = None if lstart is None else np.asarray(lstart)[:, :clen]
        hits = lr <= thr
        for row, orig in enumerate(b.idxs):
            cols = np.nonzero(hits[row])[0]
            if not cols.size:
                continue
            best_col = int(cols[np.argmin(lr[row, cols])])
            ev = AlertEvent(
                query=orig, distance=lr[row, best_col].item(),
                start=int(ls[row, best_col]) if ls is not None else -1,
                end=j0 + best_col, tile_start=j0, tile_end=j0 + clen,
                hits=int(cols.size))
            self.alerts.append(ev)
            if self.on_alert is not None:
                self.on_alert(ev)

    # ------------------------------------------------------------------
    # online pruning (LB cascade against the live heap thresholds)
    # ------------------------------------------------------------------

    def _advance_pruned(self, tile_np: np.ndarray, clen: int, j0: int):
        env_mins, env_maxs = chunk_envelope(jnp.asarray(tile_np[:clen]),
                                            self.chunk)
        if self.ref_key is not None:
            # The full-prefix copy exists only for the cache handoff (and
            # its snapshot/restore story) — a keyless session keeps just
            # the trailing bound window, so unbounded streams stay O(halo).
            self._env_mins.append(np.asarray(env_mins))
            self._env_maxs.append(np.asarray(env_maxs))
            self.cache.extend((self.ref_key, False), self.chunk,
                              self._env_mins[-1], self._env_maxs[-1],
                              at=self.tiles_total)
        self._env_tail.append((float(np.asarray(env_mins)[0]),
                               float(np.asarray(env_maxs)[0])))
        self._env_tail = self._env_tail[-(self._max_halo + 1):]
        # Per-*tile* telemetry (tiles_pruned + tiles_processed ==
        # tiles_total even for ragged multi-bucket batches): the tile
        # counts as processed if any bucket's DP ran, else it is
        # attributed to the cheapest bound that discharged every bucket.
        decisions = []
        for b in self._buckets:
            decision, heap = self._step_pruned(b, tile_np, clen, j0,
                                               (b.thr, b.carry))
            decisions.append(decision)
            if decision == "processed":
                b.carry = heap
                b.thr = np.asarray(heap[0][:, -1], np.float64)
        if "processed" in decisions:
            self.tiles_processed += 1
        elif "keogh" in decisions:
            self.tiles_pruned_keogh += 1
        else:
            self.tiles_pruned_kim += 1
        # The halo ring keeps raw context for future surviving tiles.
        self._ring.append(np.asarray(tile_np))
        self._ring = self._ring[-max(1, self._max_halo):]

    def _tile_bounds(self, b: _Bucket, win):
        mins = jnp.asarray([w[0] for w in win], jnp.float32)
        maxs = jnp.asarray([w[1] for w in win], jnp.float32)
        kim, keogh = lb_cascade(b.queries, b.qlens, mins, maxs, b.halo,
                                self.metric)
        return np.asarray(kim)[:, -1], np.asarray(keogh)[:, -1]

    def _step_pruned(self, b: _Bucket, tile_np, clen: int, j0: int, state):
        """Bound-check one tile for one bucket; score it if it survives.

        Pure in ``state = (thr, heap)`` — the peek path calls it with
        copies. Returns (decision, new_heap) with decision in
        {'kim', 'keogh', 'processed'}."""
        thr, heap = state
        win = self._env_tail[-(b.halo + 1):]
        kim, keogh = self._tile_bounds(b, win)
        if np.all(kim >= thr):
            return "kim", heap
        if np.all(keogh >= thr):
            return "keogh", heap
        group = np.zeros(((b.halo + 1) * self.chunk,), tile_np.dtype)
        ctx = self._ring[-b.halo:] if b.halo else []
        if ctx:
            ctx_flat = np.concatenate(ctx)
            group[b.halo * self.chunk - ctx_flat.shape[0]:
                  b.halo * self.chunk] = ctx_flat
        group[b.halo * self.chunk:] = tile_np
        hd, hp, hs = _pruned_chunk_step(
            b.queries, b.qlens, jnp.asarray(group), heap[0], heap[1],
            heap[2], jnp.int32(j0 - b.halo * self.chunk),
            jnp.int32(j0 + clen), b.lo, b.hi, b.zone, metric=self.metric,
            chunk=self.chunk, halo=b.halo, k=self._k,
            excl_span=self.excl_mode == "span",
            engine_impl=self.impl)
        return "processed", (hd, hp, hs)

    # ------------------------------------------------------------------
    # results
    # ------------------------------------------------------------------

    def results(self) -> StreamResult:
        """Current match state — *non-destructive*: the buffered tail is
        applied to a copy of the carry, so the live session's tile
        alignment is untouched and results() can be polled freely."""
        carries = {}
        tail = self._buf
        for bi, b in enumerate(self._buckets):
            carry = b.carry
            if tail.shape[0]:
                padded = np.zeros((self.chunk,), tail.dtype)
                padded[:tail.shape[0]] = tail
                if self.prune:
                    # Peek with copies of (thr, heap); ring/cache untouched.
                    saved_env = list(self._env_tail)
                    env = chunk_envelope(jnp.asarray(tail), self.chunk)
                    self._env_tail = (saved_env
                                      + [(float(np.asarray(env[0])[0]),
                                          float(np.asarray(env[1])[0]))]
                                      )[-(self._max_halo + 1):]
                    try:
                        _, carry = self._step_pruned(
                            b, padded, int(tail.shape[0]), self._offset,
                            (b.thr, carry))
                    finally:
                        self._env_tail = saved_env
                else:
                    carry, _, _ = self._step_exact(
                        b, jnp.asarray(padded), self._offset,
                        int(tail.shape[0]), carry)
            carries[bi] = carry
        return self._assemble(carries)

    def _assemble(self, carries) -> StreamResult:
        kk = self._k
        out_d = [None] * self._nq
        out_p = [None] * self._nq
        out_s = [None] * self._nq
        wants_pos = (self._wants_heap or self.impl == "pallas") and \
            (self.top_k is not None or self.return_positions
             or self.return_spans)
        for bi, b in enumerate(self._buckets):
            carry = carries[bi]
            if self.prune:
                d, p, s = (np.asarray(x) for x in carry)
            elif self.impl == "pallas":
                if carry is None:
                    acc = self._acc(b)
                    nb = b.queries.shape[0]
                    d = np.full((nb, kk), big(acc), acc)
                    p = np.full((nb, kk), -1, np.int32)
                    s = np.full((nb, kk), -1, np.int32)
                elif self._wants_heap:
                    d, p, s = (np.asarray(x) for x in carry[-3:])
                else:
                    if self._track:
                        _, _, d, p, s = (np.asarray(x) for x in carry)
                    else:
                        _, d, p = (np.asarray(x) for x in carry)
                        s = np.full_like(p, -1)
                    d, p, s = d[:, None], p[:, None], s[:, None]  # (nb, 1)
            elif self._wants_heap:
                d, p, s = (np.asarray(x) for x in carry[-3:])
            else:
                d = np.asarray(carry[-1])[:, None]
                p = s = np.full_like(d, -1, dtype=np.int32)
            for row, orig in enumerate(b.idxs):
                out_d[orig] = d[row]
                out_p[orig] = p[row]
                out_s[orig] = s[row]
        dists = np.stack(out_d)
        poss = np.stack(out_p)
        starts = np.stack(out_s)
        if self.top_k is None:          # unstacked top-1 / plain
            dists, poss, starts = dists[:, 0], poss[:, 0], starts[:, 0]
        else:
            dists, poss, starts = dists[:, :kk], poss[:, :kk], starts[:, :kk]
        if self._single:
            dists, poss, starts = dists[0], poss[0], starts[0]
        return StreamResult(
            distances=dists,
            positions=poss if wants_pos else None,
            starts=starts if (wants_pos and (self._track or self.prune))
            else None,
            samples=self.samples_seen,
            tiles_total=self.tiles_total,
            tiles_pruned_kim=self.tiles_pruned_kim,
            tiles_pruned_keogh=self.tiles_pruned_keogh,
            tiles_processed=self.tiles_processed)

    # ------------------------------------------------------------------
    # snapshot / restore (fault-tolerant serving)
    # ------------------------------------------------------------------

    def snapshot(self) -> dict:
        """Serialize the full session state as a flat dict of numpy
        arrays — ``np.savez(path, **snap)``-ready. ``restore()`` rebuilds
        a session that continues bit-for-bit."""
        meta = dict(
            version=_SNAP_VERSION, metric=self.metric, impl=self.impl,
            chunk=self.chunk, top_k=self.top_k, excl_mode=self.excl_mode,
            return_spans=self.return_spans,
            return_positions=self.return_positions, prune=self.prune,
            span_cap=self.span_cap,
            alert_threshold=self.alert_threshold,
            ref_key=self.ref_key if isinstance(self.ref_key, (str, int,
                                                              type(None)))
            else None,
            offset=self._offset, finalized=self._finalized,
            flush_shift=self._flush_shift_pending,
            block_q=self.block_q, block_m=self.block_m,
            dtype=None if self._dtype is None else np.dtype(
                self._dtype).name,
            nq=self._nq, single=self._single, ragged=self._ragged,
            tiles=[self.tiles_total, self.tiles_pruned_kim,
                   self.tiles_pruned_keogh, self.tiles_processed],
            env_tail=list(getattr(self, "_env_tail", [])),
            n_buckets=len(self._buckets),
            bucket_idxs=[b.idxs for b in self._buckets],
            bucket_halos=[b.halo for b in self._buckets],
            carry_lens=[0 if b.carry is None else len(b.carry)
                        for b in self._buckets],
            n_ring=len(self._ring),
        )
        snap = {"meta": np.array(json.dumps(meta)),
                "buffer": np.asarray(self._buf)}
        if self._env_mins:
            snap["env_mins"] = np.concatenate(self._env_mins)
            snap["env_maxs"] = np.concatenate(self._env_maxs)
        for t, tile in enumerate(self._ring):
            snap[f"ring{t}"] = np.asarray(tile)
        for bi, b in enumerate(self._buckets):
            snap[f"b{bi}_queries"] = np.asarray(b.queries)
            snap[f"b{bi}_qlens"] = np.asarray(b.qlens)
            snap[f"b{bi}_lo"] = np.asarray(b.lo)
            snap[f"b{bi}_hi"] = np.asarray(b.hi)
            snap[f"b{bi}_zone"] = np.asarray(b.zone)
            if b.thr is not None:
                snap[f"b{bi}_thr"] = np.asarray(b.thr)
            if b.carry is not None:
                for ci, leaf in enumerate(b.carry):
                    snap[f"b{bi}_carry{ci}"] = np.asarray(leaf)
        return snap

    @classmethod
    def restore(cls, snap, *, on_alert=None, cache=None,
                ref_key=None) -> "StreamSession":
        """Rebuild a session from ``snapshot()`` output (or a loaded
        ``np.load`` of it). ``on_alert``/``cache`` are not serialized —
        pass them again; ``ref_key`` overrides the snapshotted key (e.g.
        when the cache identity changed across processes)."""
        meta = json.loads(str(np.asarray(snap["meta"])[()]))
        if meta["version"] != _SNAP_VERSION:
            raise ValueError(f"snapshot version {meta['version']} not "
                             f"supported (expected {_SNAP_VERSION})")
        self = cls.__new__(cls)
        self.metric = meta["metric"]
        self.impl = meta["impl"]
        self.chunk = meta["chunk"]
        self.top_k = meta["top_k"]
        self.excl_mode = meta["excl_mode"]
        self.return_spans = meta["return_spans"]
        self.return_positions = meta["return_positions"]
        self.prune = meta["prune"]
        self.span_cap = meta["span_cap"]
        self.alert_threshold = meta["alert_threshold"]
        self.ref_key = meta["ref_key"] if ref_key is None else ref_key
        self.cache = cache_mod.DEFAULT_CACHE if cache is None else cache
        self.on_alert = on_alert
        self.block_q = meta["block_q"]
        self.block_m = meta["block_m"]
        self.alerts = []
        self._derive_modes()
        self._nq = meta["nq"]
        self._single = meta["single"]
        self._ragged = meta["ragged"]
        self._offset = meta["offset"]
        self._finalized = meta["finalized"]
        self._flush_shift_pending = meta.get("flush_shift", False)
        self._dtype = (None if meta["dtype"] is None
                       else np.dtype(meta["dtype"]))
        (self.tiles_total, self.tiles_pruned_kim, self.tiles_pruned_keogh,
         self.tiles_processed) = meta["tiles"]
        self._env_tail = [tuple(e) for e in meta["env_tail"]]
        self._buf = np.asarray(snap["buffer"])
        if "env_mins" in snap:
            self._env_mins = [np.asarray(snap["env_mins"])]
            self._env_maxs = [np.asarray(snap["env_maxs"])]
            if self.ref_key is not None:
                # Install the snapshotted prefix so a fresh cache in a new
                # process sees the whole stream, not a mid-stream
                # continuation of an entry it never had — but never
                # truncate a live entry that is already further along
                # (e.g. restore() branching inside the original process).
                ck = (self.ref_key, False)
                cur = self.cache.peek(ck, self.chunk)
                if cur is None or (len(np.asarray(cur[0]))
                                   < len(self._env_mins[0])):
                    self.cache.put(ck, self.chunk, snap["env_mins"],
                                   snap["env_maxs"])
        else:
            self._env_mins, self._env_maxs = [], []
        self._ring = [np.asarray(snap[f"ring{t}"])
                      for t in range(meta["n_ring"])]
        self._buckets = []
        for bi in range(meta["n_buckets"]):
            ncar = meta["carry_lens"][bi]
            carry = (tuple(jnp.asarray(snap[f"b{bi}_carry{ci}"])
                           for ci in range(ncar)) if ncar else None)
            b = _Bucket(
                idxs=list(meta["bucket_idxs"][bi]),
                queries=jnp.asarray(snap[f"b{bi}_queries"]),
                qlens=jnp.asarray(snap[f"b{bi}_qlens"]),
                lo=jnp.asarray(snap[f"b{bi}_lo"]),
                hi=jnp.asarray(snap[f"b{bi}_hi"]),
                zone=jnp.asarray(snap[f"b{bi}_zone"]),
                carry=carry, halo=meta["bucket_halos"][bi],
                thr=(np.asarray(snap[f"b{bi}_thr"])
                     if f"b{bi}_thr" in snap else None))
            self._buckets.append(b)
        self._max_halo = max(b.halo for b in self._buckets)
        return self
