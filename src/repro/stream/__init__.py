"""Online sDTW monitoring over the chunk-carry protocol.

``StreamSession`` consumes the reference as an unbounded chunk sequence,
advancing every query's DP carry through the same rowscan / Pallas chunk
paths the offline engine runs — distances, spans and top-K matches are
bitwise-identical to ``engine.sdtw`` for any feed partition.
``ShardedStreamSession`` feeds per-device chunk streams through the
ppermute systolic carry. ``engine.stream()`` is the front door. ``StreamProfile`` is the
incremental matrix profile: each fed sample extends the reference
*and* admits new self-join windows.
"""
from .profile import StreamProfile
from .session import (DEFAULT_STREAM_CHUNK, AlertEvent, StreamResult,
                      StreamSession)
from .sharded import ShardedStreamSession

__all__ = [
    "StreamSession", "ShardedStreamSession", "StreamResult", "AlertEvent",
    "StreamProfile",
    "DEFAULT_STREAM_CHUNK",
]
