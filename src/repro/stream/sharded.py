"""Sharded streaming: per-device chunk streams through the ppermute carry.

A ``ShardedStreamSession`` is the multi-device sibling of
``StreamSession``: fed reference samples are buffered into *macro-chunks*
of ``ndev * chunk`` samples, each macro-chunk is split across the mesh
(device d owns its contiguous ``chunk``-sized slice), and the chunk carry
— boundary column, start lane, running best, top-K heap — crosses devices
inside the same ``lax.ppermute`` systolic pipeline the offline sharded
driver uses (``repro.distributed.sdtw_sharded``). Between feeds the
harvested per-microbatch carries live with the session, so an unbounded
reference streams through a fixed 8-device pipeline in bounded memory.

Because device order equals reference order and every device advances its
slice in the same ``chunk`` tiles, the heap-merge partition is identical
to a single-process ``StreamSession(chunk=chunk)`` — §11 of
``tests/_distributed_check.py`` asserts the two are bitwise-equal in both
exclusion modes.

The final partial macro-chunk is right-padded and masked via the DP's
global-position ban: folded distances/spans/heaps stay exact, but the
exiting boundary column is poisoned by the pad, so ``flush()`` finalizes
the session (unlike the single-process session, whose per-tile ``clen``
boundary extraction keeps a flushed stream alive)."""
from __future__ import annotations

import json
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.distances import accum_dtype
from repro.core.sdtw import sdtw_carry_init
from repro.core.topk import topk_init
from repro.distributed.sdtw_sharded import (PipelineSchedule, default_mesh,
                                            make_schedule, sdtw_sharded_feed)
from repro.distributed.sharding import pipeline_axes

from .session import DEFAULT_STREAM_CHUNK, StreamResult, _SNAP_VERSION


class ShardedStreamSession:
    """Online sDTW monitor with the arriving reference sharded across a
    mesh axis. Padded 2-D query batches only (bucket ragged sets into
    separate sessions); no pruning (the LB cascade is host-side) and no
    alert callbacks (the candidate row never leaves the devices)."""

    def __init__(self, queries, *, qlens=None, metric: str = "abs_diff",
                 mesh=None, axis: str = "ref", dp_axis: Optional[str] = None,
                 chunk: Optional[int] = None, n_micro: Optional[int] = None,
                 top_k: Optional[int] = None, excl_zone=None,
                 excl_mode: str = "end", return_spans: bool = False,
                 return_positions: bool = False,
                 excl_lo=None, excl_hi=None):
        if isinstance(queries, (list, tuple)):
            raise ValueError("sharded sessions take a padded 2-D batch; "
                             "bucket ragged query sets into separate "
                             "sessions")
        if excl_mode not in ("end", "span"):
            raise ValueError(f"excl_mode must be 'end' or 'span', got "
                             f"{excl_mode!r}")
        if excl_zone is not None and np.ndim(excl_zone) != 0:
            raise ValueError("sharded sessions take a scalar excl_zone "
                             "(or None for the per-query default)")
        self.mesh = default_mesh(axis) if mesh is None else mesh
        self.axis = axis
        self.metric = metric
        self.chunk = int(DEFAULT_STREAM_CHUNK if chunk is None else chunk)
        if self.chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")
        self.top_k = top_k
        self.excl_mode = excl_mode
        self.return_spans = bool(return_spans)
        self.return_positions = bool(return_positions)

        queries = jnp.asarray(queries)
        single = queries.ndim == 1
        if single:
            queries = queries[None, :]
        self._single = single
        nq, n = queries.shape
        self._nq, self._n = nq, n
        if qlens is None:
            qlens = jnp.full((nq,), n, jnp.int32)
        else:
            qlens = jnp.asarray(qlens, jnp.int32)
        lo = (jnp.full((nq,), -1, jnp.int32) if excl_lo is None
              else jnp.broadcast_to(jnp.asarray(excl_lo, jnp.int32), (nq,)))
        hi = (jnp.full((nq,), -1, jnp.int32) if excl_hi is None
              else jnp.broadcast_to(jnp.asarray(excl_hi, jnp.int32), (nq,)))

        # Microbatch layout — the same schedule the offline driver uses.
        self._sched = make_schedule(self.mesh, nq, ref_axis=axis,
                                    dp_axis=dp_axis, n_micro=n_micro)
        self.dp_axis = self._sched.dp_axis
        self.n_dp = self._sched.n_dp
        self.ndev = self._sched.n_mp           # systolic pipeline depth
        self.macro = self.ndev * self.chunk
        self.n_micro, self.mb = self._sched.n_micro, self._sched.mb
        self._q_micro = self._sched.pack(queries)
        self._ql_micro = self._sched.pack(qlens, fill=1)
        self._lo_micro = self._sched.pack(lo, fill=-1)
        self._hi_micro = self._sched.pack(hi, fill=-1)

        self._derive_modes()
        # zone pinning mirrors sdtw_sharded: None derives per query in the
        # pipeline body (half true length; 0 in span mode).
        if not self._wants_heap:
            self._zone = 0
        elif excl_zone is not None:
            self._zone = int(excl_zone)
        else:
            self._zone = None if excl_mode == "end" else 0

        self._carry = None           # built on first feed (needs dtype)
        self._buf = np.zeros((0,), np.int32)
        self._dtype = None
        self._offset = 0
        self._finalized = False
        self.tiles_total = 0

    def _derive_modes(self):
        """Mode lattice shared by ``__init__`` and ``restore()`` — one
        derivation, so a restored session unpacks the harvested carries
        under the layout that wrote them."""
        self._wants_heap = (self.top_k is not None or self.return_spans
                            or self.return_positions)
        self._k = 1 if self.top_k is None else self.top_k
        self._track = self.return_spans or self.excl_mode == "span"

    def _fresh_carry(self, ref_dtype):
        acc = accum_dtype(jnp.result_type(
            np.asarray(self._q_micro).dtype, ref_dtype))
        fresh = sdtw_carry_init(self.mb, self._n, acc,
                                track_start=self._wants_heap and
                                self._track)
        if self._wants_heap:
            fresh = fresh + topk_init(self.mb, self._k, acc)
        return tuple(jnp.broadcast_to(x, (self._sched.slots,) + x.shape)
                     for x in fresh)

    @property
    def samples_seen(self) -> int:
        return self._offset + int(self._buf.shape[0])

    def feed(self, data) -> "ShardedStreamSession":
        """Append reference samples; advance by every whole macro-chunk."""
        if self._finalized:
            raise RuntimeError("session is finalized (a sharded flush is "
                               "terminal — the padded macro-chunk poisons "
                               "the exiting boundary column)")
        data = np.asarray(data)
        if data.ndim != 1:
            raise ValueError(f"feed() takes a 1-D chunk, got {data.shape}")
        if data.shape[0] == 0:
            return self
        if self._dtype is None:
            self._dtype = data.dtype
            self._buf = np.zeros((0,), data.dtype)
            self._carry = self._fresh_carry(data.dtype)
        elif data.dtype != self._dtype:
            raise ValueError(f"stream dtype changed mid-flight: "
                             f"{self._dtype} -> {data.dtype}")
        self._buf = np.concatenate([self._buf, data])
        while self._buf.shape[0] >= self.macro:
            macro, self._buf = (self._buf[:self.macro],
                                self._buf[self.macro:])
            self._carry = self._advance(self._carry, macro, self.macro)
            self._offset += self.macro
            self.tiles_total += self.ndev
        return self

    def flush(self) -> "ShardedStreamSession":
        """Push the buffered tail through as a padded, masked macro-chunk.

        Terminal: distances/spans/heap fold exactly, the boundary column
        does not survive the pad."""
        if self._buf.shape[0]:
            tail, self._buf = self._buf, self._buf[:0]
            self._carry = self._advance(self._carry, tail,
                                        int(tail.shape[0]))
            self._offset += int(tail.shape[0])
            self.tiles_total += -(-int(tail.shape[0]) // self.chunk)
            self._finalized = True
        return self

    def _advance(self, carry, chunk_np: np.ndarray, clen: int):
        padded = np.zeros((self.macro,), chunk_np.dtype)
        padded[:clen] = chunk_np[:clen]
        return sdtw_sharded_feed(
            jnp.asarray(padded), self._q_micro, self._ql_micro,
            self._lo_micro, self._hi_micro, carry,
            self._offset, self._offset + clen, mesh=self.mesh,
            axis=self.axis, dp_axis=self.dp_axis,
            chunk=self.chunk, metric=self.metric,
            top_k=self._k if self._wants_heap else None,
            excl_zone=self._zone, excl_span=self.excl_mode == "span",
            track_start=self._track)

    def results(self) -> StreamResult:
        """Current match state; non-destructive — a buffered tail is
        applied to a copy of the carry."""
        carry = self._carry
        if carry is not None and self._buf.shape[0]:
            carry = self._advance(carry, self._buf, int(self._buf.shape[0]))
        kk = self._k
        flat = self._sched.slots * self.mb
        if carry is None:
            d = np.full((flat, kk), np.inf)
            p = np.full((flat, kk), -1, np.int32)
            s = np.full((flat, kk), -1, np.int32)
        elif self._wants_heap:
            d, p, s = (np.asarray(x).reshape(flat, kk) for x in carry[-3:])
        else:
            d = np.asarray(carry[-1]).reshape(flat, 1)
            p = s = np.full((flat, 1), -1, np.int32)
        d, p, s = d[:self._nq], p[:self._nq], s[:self._nq]
        if self.top_k is None:
            d, p, s = d[:, 0], p[:, 0], s[:, 0]
        if self._single:
            d, p, s = d[0], p[0], s[0]
        wants_pos = self._wants_heap and (
            self.top_k is not None or self.return_positions
            or self.return_spans)
        return StreamResult(
            distances=d,
            positions=p if wants_pos else None,
            starts=s if (wants_pos and self._track) else None,
            samples=self.samples_seen,
            tiles_total=self.tiles_total,
            tiles_processed=self.tiles_total)

    # ------------------------------------------------------------------
    # snapshot / restore
    # ------------------------------------------------------------------

    def snapshot(self) -> dict:
        """Flat numpy dict (``np.savez``-ready); ``restore()`` rebuilds
        against the same (or an equally-shaped) mesh."""
        meta = dict(
            version=_SNAP_VERSION, kind="sharded", metric=self.metric,
            axis=self.axis, ndev=self.ndev, chunk=self.chunk,
            dp_axis=self.dp_axis, n_dp=self.n_dp,
            n_micro=self.n_micro, mb=self.mb, nq=self._nq, n=self._n,
            single=self._single, top_k=self.top_k,
            excl_mode=self.excl_mode, return_spans=self.return_spans,
            return_positions=self.return_positions,
            zone=self._zone, offset=self._offset,
            finalized=self._finalized, tiles_total=self.tiles_total,
            dtype=None if self._dtype is None else np.dtype(
                self._dtype).name,
            carry_len=0 if self._carry is None else len(self._carry))
        snap = {"meta": np.array(json.dumps(meta)),
                "buffer": np.asarray(self._buf),
                "q_micro": np.asarray(self._q_micro),
                "ql_micro": np.asarray(self._ql_micro),
                "lo_micro": np.asarray(self._lo_micro),
                "hi_micro": np.asarray(self._hi_micro)}
        if self._carry is not None:
            for ci, leaf in enumerate(self._carry):
                snap[f"carry{ci}"] = np.asarray(leaf)
        return snap

    @classmethod
    def restore(cls, snap, *, mesh=None) -> "ShardedStreamSession":
        meta = json.loads(str(np.asarray(snap["meta"])[()]))
        if meta.get("kind") != "sharded":
            raise ValueError("not a sharded-session snapshot")
        if meta["version"] != _SNAP_VERSION:
            raise ValueError(f"snapshot version {meta['version']} not "
                             f"supported")
        self = cls.__new__(cls)
        self.mesh = default_mesh(meta["axis"]) if mesh is None else mesh
        self.axis = meta["axis"]
        dpax, mpax = pipeline_axes(self.mesh, ref_axis=self.axis,
                                   dp_axis=meta.get("dp_axis"))
        n_dp = self.mesh.shape[dpax] if dpax is not None else 1
        n_mp = self.mesh.shape[mpax]
        if n_mp != meta["ndev"] or n_dp != meta.get("n_dp", 1):
            raise ValueError(
                f"snapshot was taken on a ({meta.get('n_dp', 1)}, "
                f"{meta['ndev']}) (dp, mp) layout, mesh resolves to "
                f"({n_dp}, {n_mp})")
        self.dp_axis, self.n_dp, self.ndev = dpax, n_dp, n_mp
        self.metric = meta["metric"]
        self.chunk = meta["chunk"]
        self.macro = self.ndev * self.chunk
        self.top_k = meta["top_k"]
        self.excl_mode = meta["excl_mode"]
        self.return_spans = meta["return_spans"]
        self.return_positions = meta["return_positions"]
        self.n_micro, self.mb = meta["n_micro"], meta["mb"]
        self._nq, self._n = meta["nq"], meta["n"]
        # Rebuild the exact layout the snapshot was written under (not via
        # make_schedule — its defaults may have changed across versions).
        self._sched = PipelineSchedule(dpax, mpax, n_dp, n_mp,
                                       self.n_micro, self.mb, self._nq)
        self._single = meta["single"]
        self._derive_modes()
        self._zone = meta["zone"]
        self._offset = meta["offset"]
        self._finalized = meta["finalized"]
        self.tiles_total = meta["tiles_total"]
        self._dtype = (None if meta["dtype"] is None
                       else np.dtype(meta["dtype"]))
        self._buf = np.asarray(snap["buffer"])
        self._q_micro = jnp.asarray(snap["q_micro"])
        self._ql_micro = jnp.asarray(snap["ql_micro"])
        self._lo_micro = jnp.asarray(snap["lo_micro"])
        self._hi_micro = jnp.asarray(snap["hi_micro"])
        self._carry = (tuple(jnp.asarray(snap[f"carry{ci}"])
                             for ci in range(meta["carry_len"]))
                       if meta["carry_len"] else None)
        return self
