"""Incremental matrix profile: the self-join that grows with the stream.

``StreamProfile`` is the online counterpart of
``repro.search.profile.matrix_profile``: reference samples arrive through
``feed()``, and each arrival plays *both* self-join roles —

  * it **extends the reference**: every already-admitted window's
    nearest-neighbor heap advances over the new samples through the same
    per-tile ``sdtw_chunk_batch_topk`` step the offline chunked engine
    and ``StreamSession`` run (``_heap_step``), so only the affected
    profile entries move — windows whose heaps the new tile cannot
    improve are untouched by the fold;
  * it **admits new query windows**: once the stream covers samples
    ``[s, s + window)``, the window starting at ``s`` joins the batch.
    A fresh window must scan the *entire* history (a motif's other half
    may lie arbitrarily far in the past), so admissions replay the
    recorded tile sequence for the new rows only — existing rows never
    recompute.

Exactness: the per-window nearest neighbor is a top-1 heap, and the
streamed top-1 is exact under *any* feed partition (see ``repro.core.
topk``) — so ``results()`` is int32-bitwise-equal to
``matrix_profile(series_so_far, ..., prune=False)`` regardless of how
the stream was sliced or how often ``flush()`` was called (no k>1
merge-boundary caveat can arise: the motif/discord ``k`` is a host-side
reduction over the finished profile, not a streamed heap).

Costs, for T processed tiles and nw admitted windows: state is
O(nw · window) carries + O(M) sample history (kept for admissions);
admission catch-up replays O(T) tiles per admission event, O(T²) tile
steps over the stream's lifetime in the worst case (stride=1, tiny
chunk). For long streams pick ``stride`` (admissions per tile drop) and
a large ``chunk``; or run the offline ``matrix_profile`` which batches
all windows. The window batch is capacity-padded to powers of two
(amortized-doubling), so the jitted tile step compiles O(log nw) times;
padding rows carry a fully-banned exclusion range and stay at the
``(BIG, -1, -1)`` heap sentinel, masked like any invalid window.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.distances import INT_FAR, accum_dtype
from repro.core.sdtw import sdtw_carry_init, self_join_exclusion
from repro.core.topk import topk_init
from repro.search.profile import ProfileResult, _assemble_profile
from repro.stream.session import DEFAULT_STREAM_CHUNK, _heap_step

#: Smallest capacity of the admitted-window batch (power-of-two growth).
MIN_CAPACITY = 16


class StreamProfile:
    """Online sDTW matrix profile of an unbounded, growing series.

    ``feed(samples)`` appends to the series; ``results()`` returns the
    current ``ProfileResult`` (non-destructive — includes the buffered
    tail without disturbing tile alignment); ``flush()`` pushes the tail
    through destructively (exact: the top-1 heaps are partition-
    invariant, so flushing never changes what ``results()`` reports).
    """

    def __init__(self, window: int, stride: int = 1, k: int = 1, *,
                 metric: str = "abs_diff", chunk: Optional[int] = None,
                 excl_zone: Optional[int] = None):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if stride < 1:
            raise ValueError(f"stride must be >= 1, got {stride}")
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        self.window = int(window)
        self.stride = int(stride)
        self.k = int(k)
        self.metric = metric
        self.chunk = int(DEFAULT_STREAM_CHUNK if chunk is None else chunk)
        self.zone = window // 2 if excl_zone is None else int(excl_zone)
        if self.zone < 0:
            raise ValueError(f"excl_zone must be >= 0, got {excl_zone}")

        self._dtype = None            # pinned by the first feed
        self._buf = np.zeros((0,), np.int32)
        self._offset = 0              # samples advanced through the DP
        # Processed-tile record for admission catch-up: (padded tile,
        # true length, global start). Replayed verbatim so a late window
        # sees exactly the tile partition the live batch saw.
        self._tiles: List[Tuple[np.ndarray, int, int]] = []
        self._hist = np.zeros((0,), np.int32)   # amortized-doubling
        self._hist_len = 0
        self.tiles_processed = 0

        self._n = 0                   # admitted windows
        self._cap = 0
        self._q = None                # (cap, window) np window slab
        self._lo = self._hi = None    # (cap,) banned ranges (np)
        self._carry = None            # jnp (bcol, bstart, best, heap...)

    # ------------------------------------------------------------------
    # feeding
    # ------------------------------------------------------------------

    @property
    def samples_seen(self) -> int:
        """Samples fed so far (including the buffered tail)."""
        return self._offset + int(self._buf.shape[0])

    @property
    def windows_admitted(self) -> int:
        return self._n

    def feed(self, data) -> "StreamProfile":
        """Append series samples; advance the DP by every whole tile."""
        data = np.asarray(data)
        if data.ndim != 1:
            raise ValueError(f"feed() takes a 1-D chunk, got shape "
                             f"{data.shape}")
        if data.shape[0] == 0:
            return self
        if self._dtype is None:
            self._dtype = data.dtype
            self._buf = np.zeros((0,), data.dtype)
            self._hist = np.zeros((self.chunk,), data.dtype)
        elif data.dtype != self._dtype:
            raise ValueError(f"stream dtype changed mid-flight: "
                             f"{self._dtype} -> {data.dtype}")
        self._buf = np.concatenate([self._buf, data])
        while self._buf.shape[0] >= self.chunk:
            tile, self._buf = (self._buf[:self.chunk],
                               self._buf[self.chunk:])
            self._advance(tile, self.chunk)
        return self

    def flush(self) -> "StreamProfile":
        """Destructively push the buffered tail through the DP. Exact —
        top-1 heaps are feed-partition-invariant — and the session keeps
        streaming (the recorded partial tile replays with its true
        length for every later admission)."""
        if self._buf.shape[0]:
            tail, self._buf = self._buf, self._buf[:0]
            padded = np.zeros((self.chunk,), tail.dtype)
            padded[:tail.shape[0]] = tail
            self._advance(padded, int(tail.shape[0]))
        return self

    def _advance(self, tile_np: np.ndarray, clen: int):
        """One (possibly right-padded) tile: extend the reference for the
        admitted batch, then admit windows the tile completed."""
        j0 = self._offset
        if self._hist_len + clen > self._hist.shape[0]:
            grown = np.zeros((max(self._hist.shape[0] * 2,
                                  self._hist_len + clen),), self._hist.dtype)
            grown[:self._hist_len] = self._hist[:self._hist_len]
            self._hist = grown
        self._hist[self._hist_len:self._hist_len + clen] = tile_np[:clen]
        self._hist_len += clen
        self._tiles.append((np.asarray(tile_np), clen, j0))
        if self._n:
            self._carry = self._step(self._q, self._lo, self._hi,
                                     self._carry, tile_np, clen, j0)
        self.tiles_processed += 1
        self._offset += clen
        self._admit()

    def _step(self, q, lo, hi, carry, tile_np, clen: int, j0: int):
        """One jitted tile step over a capacity-padded batch."""
        cap = q.shape[0]
        return _heap_step(
            jnp.asarray(q), jnp.asarray(tile_np),
            jnp.full((cap,), self.window, jnp.int32), carry,
            jnp.int32(j0), jnp.int32(j0 + clen), jnp.int32(clen),
            jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32),
            jnp.zeros((cap,), jnp.int32), metric=self.metric, k=1,
            excl_span=False, track=True, lastrow=False)[0]

    # ------------------------------------------------------------------
    # window admission
    # ------------------------------------------------------------------

    def _pending_starts(self, covered: int) -> np.ndarray:
        """Starts of windows fully contained in ``covered`` samples but
        not yet admitted."""
        first = self._n * self.stride
        last = covered - self.window          # inclusive bound on starts
        if last < first:
            return np.zeros((0,), np.int64)
        return np.arange(first, last + 1, self.stride, dtype=np.int64)

    def _banned_rows(self, cap: int, starts: np.ndarray):
        """(lo, hi) slabs: real rows get the sample-unit trivial-match
        band, padding rows ban every column (their heaps stay sentinel)."""
        lo = np.zeros((cap,), np.int32)
        hi = np.full((cap,), INT_FAR, np.int32)
        if starts.size:
            rlo, rhi = self_join_exclusion(starts, self.window, self.zone)
            lo[:starts.size] = np.asarray(rlo)
            hi[:starts.size] = np.asarray(rhi)
        return lo, hi

    def _window_slab(self, cap: int, starts: np.ndarray,
                     hist: Optional[np.ndarray] = None) -> np.ndarray:
        if hist is None:
            hist = self._hist[:self._hist_len]
        q = np.zeros((cap, self.window), self._dtype)
        col = np.arange(self.window, dtype=np.int64)
        if starts.size:
            q[:starts.size] = hist[starts[:, None] + col[None, :]]
        return q

    def _fresh_carry(self, cap: int):
        acc = accum_dtype(self._dtype)
        return (sdtw_carry_init(cap, self.window, acc, track_start=True)
                + topk_init(cap, 1, acc))

    def _catchup(self, starts: np.ndarray, tiles, hist=None):
        """Replay the recorded tile sequence for a batch of fresh
        windows; returns the finished capacity-padded carry (rows
        ``[0, len(starts))`` are the real ones)."""
        cap = max(MIN_CAPACITY, 1 << max(0, int(starts.size) - 1)
                  .bit_length())
        q = self._window_slab(cap, starts, hist)
        lo, hi = self._banned_rows(cap, starts)
        carry = self._fresh_carry(cap)
        for tile_np, clen, j0 in tiles:
            carry = self._step(q, lo, hi, carry, tile_np, clen, j0)
        return carry

    def _grow(self, need: int):
        """Double the admitted batch's capacity to hold ``need`` rows,
        padding every carry leaf with its fresh-init value."""
        new_cap = MIN_CAPACITY
        while new_cap < need:
            new_cap *= 2
        if new_cap == self._cap:
            return
        starts = np.arange(self._n, dtype=np.int64) * self.stride
        q = self._window_slab(new_cap, starts)
        lo, hi = self._banned_rows(new_cap, starts)
        fresh = self._fresh_carry(new_cap)
        if self._carry is None:
            carry = fresh
        else:
            carry = tuple(f.at[:self._cap].set(c)
                          for f, c in zip(fresh, self._carry))
        self._q, self._lo, self._hi, self._carry = q, lo, hi, carry
        self._cap = new_cap

    def _admit(self):
        starts = self._pending_starts(self._offset)
        if not starts.size:
            return
        caught = self._catchup(starts, self._tiles)
        self._grow(self._n + starts.size)
        lo, hi = self_join_exclusion(starts, self.window, self.zone)
        sl = slice(self._n, self._n + starts.size)
        col = np.arange(self.window, dtype=np.int64)
        self._q[sl] = self._hist[:self._hist_len][
            starts[:, None] + col[None, :]]
        self._lo[sl] = np.asarray(lo)
        self._hi[sl] = np.asarray(hi)
        self._carry = tuple(
            main.at[sl].set(new[:starts.size])
            for main, new in zip(self._carry, caught))
        self._n += int(starts.size)

    # ------------------------------------------------------------------
    # results
    # ------------------------------------------------------------------

    def results(self) -> ProfileResult:
        """The profile over everything fed so far — non-destructive: the
        buffered tail is applied to a *copy* of the carries (and windows
        it completes are caught up on the side), so polling never
        perturbs the live session's tile alignment."""
        tiles = list(self._tiles)
        carry = self._carry
        tail = self._buf
        if tail.shape[0]:
            padded = np.zeros((self.chunk,), tail.dtype)
            padded[:tail.shape[0]] = tail
            tiles.append((padded, int(tail.shape[0]), self._offset))
            if self._n:
                carry = self._step(self._q, self._lo, self._hi, carry,
                                   padded, int(tail.shape[0]),
                                   self._offset)
        n_live = self._n
        rows_d: List[np.ndarray] = []
        rows_p: List[np.ndarray] = []
        rows_s: List[np.ndarray] = []
        if n_live:
            rows_d.append(np.asarray(carry[3])[:n_live, 0])
            rows_p.append(np.asarray(carry[4])[:n_live, 0])
            rows_s.append(np.asarray(carry[5])[:n_live, 0])
        pending = self._pending_starts(self.samples_seen)
        if pending.size:
            hist = np.concatenate([self._hist[:self._hist_len], self._buf])
            caught = self._catchup(pending, tiles, hist)
            rows_d.append(np.asarray(caught[3])[:pending.size, 0])
            rows_p.append(np.asarray(caught[4])[:pending.size, 0])
            rows_s.append(np.asarray(caught[5])[:pending.size, 0])
        nw = n_live + int(pending.size)
        acc = accum_dtype(self._dtype if self._dtype is not None
                          else np.int32)
        if nw:
            nn_d = np.concatenate(rows_d)
            nn_p = np.concatenate(rows_p).astype(np.int64)
            nn_s = np.concatenate(rows_s).astype(np.int64)
        else:
            nn_d = np.zeros((0,), acc)
            nn_p = nn_s = np.zeros((0,), np.int64)
        starts = np.arange(nw, dtype=np.int64) * self.stride
        t = self.tiles_processed + (1 if tail.shape[0] else 0)
        return _assemble_profile(self.window, self.stride, self.k, starts,
                                 nn_d, nn_s, nn_p, self.zone, self.chunk,
                                 (t, 0, 0, t))
