"""Batched top-K pruned subsequence search — the query-answering front door.

``engine.sdtw()`` computes "how far is this query from its best alignment";
``search_topk()`` answers the paper's actual question (§I, §V): *where are
the K best matches of each query in this reference, and are they distinct
events?* It composes, in order:

  1. ragged-query bucketing (reused from the engine),
  2. optional z-normalization (global reference, per-query moments),
  3. the lower-bound cascade of ``repro.search.lower_bounds`` over the
     cached per-chunk envelope (``repro.search.cache``),
  4. chunk-level pruning: a reference chunk is dispatched to the DP only if
     some query's bound says it could still improve that query's heap,
  5. exact chunked DP with the top-K heap riding the boundary carry
     (``repro.core.sdtw.sdtw_segment_topk``), warmed up by a ``halo`` of
     left-context chunks so pruning never truncates an alignment.

Pruning semantics — two deviations from the exact streamed path:

  * **Span cap**: a match whose alignment path covers more than
    ``span_cap`` reference columns (default 2N; raise it or pass
    ``prune=False`` to lift) may be missed or scored from truncated
    context — reported *starts* inherit the same bound (a start earlier
    than the halo window cannot be observed). Under the cap, the top-1
    *distance* is exactly ``engine.sdtw()``'s answer (bitwise for int32).
    This caveat covers **profile mode** too: a pruned
    ``repro.search.profile.matrix_profile`` runs every window batch
    through this path, so a nearest neighbor aligned over more than
    ``span_cap`` (default ``2 * window``) columns may be missed there —
    ``matrix_profile(prune=False)`` (and the streaming
    ``StreamProfile``, which is always exact) lift it.
  * **Greedy order**: surviving chunks are visited in bound order, not
    reference order, so for k > 1 the exclusion-zone suppression can
    resolve differently than the streamed path — the reported set beyond
    top-1 is a best-effort greedy set (every entry is still a genuine
    alignment distance at a genuine end position, and an equally good or
    better pick at each greedy step), and exact distance ties can report
    a different (equally optimal) end position.

With ``prune=False`` the call lowers straight onto the engine's streaming
top-K path and both caveats vanish. Chunks are pruned only when *no*
query in the batch can improve — the batch shares the DP dispatch, as in
the engine.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.distances import accum_dtype
from repro.core.request import SdtwRequest
from repro.core.sdtw import (default_excl_zone, sdtw_carry_init,
                             sdtw_chunk_batch_topk, sdtw_segment,
                             topk_fold_lastrow)
from repro.core.topk import topk_init

from . import cache as cache_mod
from .lower_bounds import lb_cascade, znorm, znorm_padded

#: Default warping-span cap, in query lengths.
DEFAULT_SPAN_FACTOR = 2

#: Smallest pruning tile — below this the per-chunk dispatch overhead
#: exceeds the DP it would skip.
MIN_CHUNK = 64


@dataclasses.dataclass
class SearchResult:
    """Top-K matches plus pruning telemetry for one ``search_topk`` call."""
    distances: object           # (nq, k) best-first; BIG-padded
    positions: object           # (nq, k) global end indices; -1-padded
    chunk: int                  # pruning tile size used
    starts: object = None       # (nq, k) global start indices; -1-padded
    chunks_total: int = 0      # candidate chunks across all buckets
    chunks_pruned_kim: int = 0    # skipped on the constant-time bound
    chunks_pruned_keogh: int = 0  # skipped on the envelope bound
    chunks_processed: int = 0     # dispatched to the DP

    @property
    def chunks_pruned(self) -> int:
        return self.chunks_pruned_kim + self.chunks_pruned_keogh

    @property
    def spans(self):
        """(nq, k, 2) stacked (start, end) spans."""
        return jnp.stack([self.starts, self.positions], axis=-1)


def _pow2_at_least(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


def default_chunk(m: int, n: int) -> int:
    """Pruning tile heuristic: ≥ MIN_CHUNK, ≥ the query (so one chunk can
    hold a whole match), ~eighth of the reference (so there is something
    to prune), capped at the engine's streaming default."""
    return max(MIN_CHUNK,
               min(engine.DEFAULT_CHUNK,
                   _pow2_at_least(max(n, m // 8))))


@functools.partial(jax.jit, static_argnames=("metric", "chunk", "halo", "k",
                                             "excl_span", "engine_impl"))
def _pruned_chunk_step(queries, qlens, seg, heap_d, heap_p, heap_s, j0,
                       m_total, excl_lo, excl_hi, excl_zone, *, metric,
                       chunk, halo, k, excl_span,
                       engine_impl: str = "rowscan"):
    """Score one surviving chunk and fold its candidates into the heap.

    ``seg`` is the chunk plus ``halo`` left-context chunks; the DP runs
    from a fresh carry at the group start (columns before the reference,
    j < 0, are masked), and only the *target* chunk's last-row candidates
    are harvested — the halo exists purely to warm the boundary carry
    (value *and* start-pointer lanes, so candidate spans beginning inside
    the halo are exact) so any match with span ≤ halo·chunk is scored
    with full context.

    ``engine_impl='pallas'`` scores the whole halo group in one kernel
    call using the in-kernel last-row capture (the group's leading pad /
    trailing overhang are masked via the kernel's traced ``ref_lead`` /
    ``ref_len`` window) and folds the identical candidate row with the
    identical per-chunk ``topk_merge`` — int32 heaps are bitwise-equal to
    the rowscan variant. Requires no per-query exclusion zones (the
    caller checks).
    """
    nq, n = queries.shape
    acc = accum_dtype(jnp.result_type(queries, seg))
    if engine_impl == "pallas":
        from repro.kernels.sdtw import sdtw_pallas
        seg_len = seg.shape[0]
        _, lrow, lstart = sdtw_pallas(
            queries, seg, qlens, metric, track_start=True,
            return_lastrow=True, ref_offset=j0,
            ref_len=jnp.clip(m_total - j0, 0, seg_len),
            ref_lead=jnp.maximum(0, -j0))
        return topk_fold_lastrow(
            (heap_d.astype(acc), heap_p, heap_s),
            lrow[:, halo * chunk:], lstart[:, halo * chunk:],
            j0 + halo * chunk, k, excl_zone, excl_span)
    carry = sdtw_carry_init(nq, n, acc, track_start=True)
    if halo:
        carry = sdtw_segment(queries, seg[:halo * chunk], qlens, carry, j0,
                             m_total, metric, chunk, excl_lo, excl_hi)
    carry = carry + (heap_d.astype(acc), heap_p, heap_s)
    _, _, _, heap_d, heap_p, heap_s = sdtw_chunk_batch_topk(
        queries, seg[halo * chunk:], qlens, carry, j0 + halo * chunk,
        m_total, metric, excl_lo, excl_hi, k, excl_zone,   # (nq,) zone
        excl_span, track_start=True)
    return heap_d, heap_p, heap_s


def _search_padded(queries, reference, qlens, *, k, metric, chunk, prune,
                   halo, excl_zone, excl_mode, excl_lo, excl_hi, env,
                   engine_impl="rowscan"):
    """Pruned search for one padded (nq, N) bucket. Returns
    (dists, positions, starts, stats_tuple)."""
    nq, n = queries.shape
    m = reference.shape[0]
    acc = accum_dtype(jnp.result_type(queries, reference))
    n_chunks = -(-m // chunk)

    if not prune:
        d, s, p = engine.sdtw(queries, reference, qlens, metric=metric,
                              impl="chunked", chunk=chunk, top_k=k,
                              excl_zone=excl_zone, excl_lo=excl_lo,
                              excl_hi=excl_hi, excl_mode=excl_mode,
                              return_spans=True)
        return d, p, s, (n_chunks, 0, 0, n_chunks)

    if qlens is None:
        qlens = jnp.full((nq,), n, jnp.int32)
    excl_lo = jnp.asarray(engine._normalize_excl(excl_lo, nq))
    excl_hi = jnp.asarray(engine._normalize_excl(excl_hi, nq))
    if excl_zone is None:
        zone = (default_excl_zone(qlens) if excl_mode == "end"
                else jnp.zeros((nq,), jnp.int32))
    else:
        zone = jnp.full((nq,), int(excl_zone), jnp.int32)

    mins, maxs = env
    kim, keogh = lb_cascade(queries, qlens, mins, maxs, halo, metric)
    kim = np.asarray(kim)
    keogh = np.asarray(keogh)

    # Right-pad to a chunk multiple, left-pad a halo of masked columns so
    # every chunk group has the same static shape (j < 0 is banned in the
    # DP's global-position mask).
    r_pad = jnp.pad(reference, (0, n_chunks * chunk - m))
    r_ext = jnp.pad(r_pad, (halo * chunk, 0))

    heap_d, heap_p, heap_s = topk_init(nq, k, acc)
    pruned_kim = pruned_keogh = processed = 0
    # Most promising chunks first: thresholds tighten fastest, later
    # chunks die on the cheap bound. The k-th-best threshold only moves
    # when a chunk is actually processed, so the device→host fetch
    # happens per *processed* chunk, not per candidate.
    thr = np.asarray(heap_d[:, -1], np.float64)
    order = np.argsort(keogh.min(axis=0), kind="stable")
    for c in order:
        if np.all(kim[:, c] >= thr):
            pruned_kim += 1
            continue
        if np.all(keogh[:, c] >= thr):
            pruned_keogh += 1
            continue
        processed += 1
        group = r_ext[c * chunk:(c + halo + 1) * chunk]  # static shape ∀ c
        heap_d, heap_p, heap_s = _pruned_chunk_step(
            queries, qlens, group, heap_d, heap_p, heap_s,
            jnp.int32((c - halo) * chunk), jnp.int32(m), excl_lo, excl_hi,
            zone, metric=metric, chunk=chunk, halo=halo, k=k,
            excl_span=(excl_mode == "span"), engine_impl=engine_impl)
        thr = np.asarray(heap_d[:, -1], np.float64)
    return heap_d, heap_p, heap_s, (n_chunks, pruned_kim, pruned_keogh,
                                    processed)


def search_topk(queries, reference, k: int = 1, *, qlens=None,
                metric: str = "abs_diff", chunk: Optional[int] = None,
                prune: bool = True, span_cap: Optional[int] = None,
                excl_zone: Optional[int] = None, excl_mode: str = "end",
                normalize: bool = False,
                excl_lo=None, excl_hi=None, mesh=None, ref_axis: str = "ref",
                cache: Optional[cache_mod.EnvelopeCache] = None,
                ref_key=None, engine_impl: str = "auto") -> SearchResult:
    """Top-K subsequence matches of each query in ``reference``.

    Args:
      queries:   (nq, N) padded array, one (N,) query, or a ragged list.
      reference: (M,) reference sequence.
      k:         matches per query.
      qlens:     true lengths for padded 2-D input.
      metric:    'abs_diff' | 'square_diff'.
      chunk:     pruning tile size (default: ``default_chunk``).
      prune:     apply the LB cascade; ``False`` = exact engine streaming.
      span_cap:  max alignment span (columns) the pruned path scores with
                 full context; default ``2 * N`` (the same cap bounds a
                 pruned ``matrix_profile``'s nearest neighbors — see the
                 module docstring).
      excl_zone: suppression radius between reported matches (default:
                 half of each query's true length — or 0 with
                 ``excl_mode='span'``).
      excl_mode: 'end' suppresses matches whose ends are within
                 ``excl_zone`` (matrix-profile convention); 'span'
                 suppresses matches whose ``[start, end]`` spans overlap
                 (widened by ``excl_zone``) — reported events share no
                 reference samples.
      normalize: z-normalize reference (globally) and queries (per true
                 length) first; output distances are then in z-space.
      excl_lo/excl_hi: banned reference column range per query.
      mesh:      shard the reference axis instead of pruning (the sharded
                 engine streams every chunk; the cascade is host-side and
                 single-process, so mesh and prune are mutually exclusive).
      cache:     ``EnvelopeCache`` for the per-reference envelope
                 (default: the module-level ``DEFAULT_CACHE``).
      ref_key:   stable cache key for the reference (recommended).
      engine_impl: DP backend for scoring surviving chunks: 'rowscan'
                 (the chunked tile loop) or 'pallas' (the kernel's
                 in-kernel last-row capture — int32 heaps bitwise-equal
                 to rowscan). 'auto' picks pallas on a TPU backend when
                 no per-query exclusion zones are set.

    Returns a ``SearchResult``; distances/positions/starts are (nq, k)
    (or (k,) for a single 1-D query), best first, ``(BIG, -1, -1)``-padded
    when fewer than k sufficiently-distinct matches exist. ``starts`` is
    the DP start-pointer lane: the row-0 reference column where each
    match's alignment begins, so ``(starts[i, j], positions[i, j])`` is
    the j-th best matched span of query i.

    ``excl_zone`` semantics (incl. the per-query default) are documented
    once on ``repro.core.request`` — this front door is a thin shim over
    ``SdtwRequest(op='search_topk')``.
    """
    return SdtwRequest(
        op="search_topk", queries=queries, reference=reference, top_k=k,
        qlens=qlens, metric=metric, chunk=chunk, prune=prune,
        span_cap=span_cap, excl_zone=excl_zone, excl_mode=excl_mode,
        normalize=normalize, excl_lo=excl_lo, excl_hi=excl_hi, mesh=mesh,
        ref_axis=ref_axis, cache=cache, ref_key=ref_key,
        engine_impl=engine_impl).run()


def _execute_search(req: SdtwRequest) -> SearchResult:
    """The search dispatcher behind ``SdtwRequest.run()`` — the request
    is already validated/normalized."""
    (queries, reference, k, qlens, metric, chunk, prune, span_cap,
     excl_zone, excl_mode, normalize, excl_lo, excl_hi, mesh, ref_axis,
     cache, ref_key, engine_impl) = (
        req.queries, req.reference, req.top_k, req.qlens, req.metric,
        req.chunk, req.prune, req.span_cap, req.excl_zone, req.excl_mode,
        req.normalize, req.excl_lo, req.excl_hi, req.mesh, req.ref_axis,
        req.cache, req.ref_key, req.engine_impl)

    has_excl = excl_lo is not None or excl_hi is not None
    if engine_impl == "auto":
        engine_impl = ("pallas" if jax.default_backend() == "tpu"
                       and not has_excl else "rowscan")
    reference = jnp.asarray(reference)
    if normalize:
        reference = znorm(reference)
    m = reference.shape[0]
    cache = cache_mod.DEFAULT_CACHE if cache is None else cache

    ragged = isinstance(queries, (list, tuple))
    if ragged:
        qs = [np.asarray(q) for q in queries]
        buckets = engine.bucketize([len(q) for q in qs])
        nq = len(qs)
        lo_all = np.asarray(engine._normalize_excl(excl_lo, nq))
        hi_all = np.asarray(engine._normalize_excl(excl_hi, nq))
    else:
        queries = jnp.asarray(queries)
        single = queries.ndim == 1
        if single:
            queries = queries[None, :]
        nq = queries.shape[0]
        buckets = {queries.shape[1]: list(range(nq))}
        qs = None
        lo_all = hi_all = None

    out_d = [None] * nq
    out_p = [None] * nq
    out_s = [None] * nq
    totals = [0, 0, 0, 0]
    used_chunk = None
    for blen, idxs in buckets.items():
        if ragged:
            padded, lens = engine.pad_ragged_bucket(qs, idxs, blen)
            bq = jnp.asarray(padded)
            bql = jnp.asarray(lens)
            blo = jnp.asarray(lo_all[idxs])
            bhi = jnp.asarray(hi_all[idxs])
        else:
            bq, bql, blo, bhi = queries, qlens, excl_lo, excl_hi
        if normalize:
            bq = znorm_padded(
                bq, jnp.full((len(idxs),), blen, jnp.int32)
                if bql is None else bql)

        n = bq.shape[1]
        c = default_chunk(m, n) if chunk is None else int(chunk)
        used_chunk = c if used_chunk is None else max(used_chunk, c)
        cap = DEFAULT_SPAN_FACTOR * n if span_cap is None else int(span_cap)
        halo = max(1, -(-cap // c))

        if mesh is not None:
            d, s, p = engine.sdtw(bq, reference, bql, metric=metric,
                                  mesh=mesh, ref_axis=ref_axis, chunk=c,
                                  top_k=k, excl_zone=excl_zone,
                                  excl_mode=excl_mode, excl_lo=blo,
                                  excl_hi=bhi, return_spans=True)
            stats = (-(-m // c), 0, 0, -(-m // c))
        else:
            # The cached envelope belongs to the array actually searched —
            # a normalized search must not share entries with a raw one
            # under the same user key.
            env_key = (None if ref_key is None
                       else (ref_key, bool(normalize)))
            env = cache.envelope(reference, c, key=env_key) if prune \
                else None
            d, p, s, stats = _search_padded(
                bq, reference, bql, k=k, metric=metric, chunk=c,
                prune=prune, halo=halo, excl_zone=excl_zone,
                excl_mode=excl_mode, excl_lo=blo, excl_hi=bhi, env=env,
                engine_impl=engine_impl)
        for t in range(4):
            totals[t] += stats[t]
        d = np.asarray(d)
        p = np.asarray(p)
        s = np.asarray(s)
        for j, i in enumerate(idxs):
            out_d[i] = d[j]
            out_p[i] = p[j]
            out_s[i] = s[j]

    dists = jnp.asarray(np.stack(out_d))
    poss = jnp.asarray(np.stack(out_p))
    starts = jnp.asarray(np.stack(out_s))
    if not ragged and single:
        dists, poss, starts = dists[0], poss[0], starts[0]
    return SearchResult(distances=dists, positions=poss, starts=starts,
                        chunk=used_chunk,
                        chunks_total=totals[0], chunks_pruned_kim=totals[1],
                        chunks_pruned_keogh=totals[2],
                        chunks_processed=totals[3])
