"""Lower-bound cascade for pruned subsequence search.

The search front door (``repro.search.search_topk``) decides per reference
chunk whether the DP can possibly produce a match better than the current
top-K worst. Two bounds, cheapest first (the TC-DTW / UCR-suite recipe,
arXiv 2101.07731, adapted to *unconstrained-warping* subsequence DTW):

``lb_kim``   — constant work per (query, chunk): only the first and last
              query points. The last query point of a match ending at
              column j must align to r[j] itself, so its distance to the
              chunk's [min, max] envelope is a bound; the first query
              point must align somewhere in the match's column window, so
              its distance to the *windowed* envelope is a bound. Their
              sum is admissible (distinct DP cells) for queries of length
              ≥ 2; for length-1 queries only the last-point term applies.

``lb_keogh`` — O(N) work per (query, chunk): every query point must align
              to some column of the match window, so each contributes its
              distance to the windowed [min, max] envelope; the last point
              tightens to the chunk envelope. LB_Keogh dominates LB_Kim
              (it includes LB_Kim's terms), so the cascade order is purely
              a cost ladder.

Both bounds assume the match's *warping span* — the number of reference
columns its alignment path covers — is at most ``span_cap`` columns
(window = ``halo`` chunks to the left + the chunk itself, with
``halo * chunk >= span_cap - 1``). Unconstrained sDTW admits paths of
unbounded span, but a span longer than the query means reference points
deleted at cost, so real matches concentrate near span ≈ N;
``search_topk`` defaults to a generous ``span_cap = 2N`` and documents
the cap as the single approximation of the pruned path. The admissibility
property (a bound never exceeds the true cost of any span-capped match
ending in the chunk) is tested against a brute-force windowed-DP oracle
in ``tests/test_search.py``.

Bounds are computed with vectorized jnp ops — no sequential dependency,
unlike the DP they gate — in float32, then shaved by ``LB_SAFETY`` to
absorb float-sum rounding before being compared against DP distances.

Z-normalization: ``znorm`` / ``znorm_padded`` normalize the reference
(globally) and each query (over its true length) before search when
``search_topk(normalize=True)`` — the classic trick to make shape, not
offset/scale, drive the match. Per-window normalization (full UCR suite)
would need a different DP and is out of scope; global normalization keeps
the engine's DP and the bounds exact w.r.t. the normalized series.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.distances import METRICS, accum_dtype, big

# Multiplicative shave applied to float32 bound sums so accumulated
# rounding can never push an admissible bound above the true DP cost.
LB_SAFETY = 1.0 - 1e-5


def znorm(x, eps: float = 1e-8):
    """Z-normalize a 1-D (or trailing-axis batched) series in float32."""
    x = jnp.asarray(x, jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    sd = jnp.std(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.maximum(sd, eps)


def znorm_padded(queries, qlens, eps: float = 1e-8):
    """Mask-aware z-norm for a (nq, N) padded batch: moments over the true
    length only; padded tail stays zero."""
    q = jnp.asarray(queries, jnp.float32)
    nq, n = q.shape
    valid = jnp.arange(n)[None, :] < jnp.asarray(qlens)[:, None]
    cnt = jnp.maximum(jnp.sum(valid, axis=1, keepdims=True), 1)
    mu = jnp.sum(jnp.where(valid, q, 0.0), axis=1, keepdims=True) / cnt
    var = jnp.sum(jnp.where(valid, (q - mu) ** 2, 0.0), axis=1,
                  keepdims=True) / cnt
    z = (q - mu) / jnp.maximum(jnp.sqrt(var), eps)
    return jnp.where(valid, z, 0.0)


def chunk_envelope(reference, chunk: int):
    """Per-chunk [min, max] of the reference — the envelope the bounds eat.

    Returns (mins (T,), maxs (T,)) in the accumulator dtype, T = ceil(M /
    chunk); tail padding is ignored via ±BIG fill. This is the per-
    reference precomputation ``repro.search.cache.EnvelopeCache`` stores.
    """
    reference = jnp.asarray(reference)
    m = reference.shape[0]
    acc = accum_dtype(reference.dtype)
    BIG = big(acc)
    t = -(-m // chunk)
    r = jnp.pad(reference.astype(acc), (0, t * chunk - m))
    mask = (jnp.arange(t * chunk) < m).reshape(t, chunk)
    r = r.reshape(t, chunk)
    mins = jnp.min(jnp.where(mask, r, BIG), axis=1)
    maxs = jnp.max(jnp.where(mask, r, -BIG), axis=1)
    return mins, maxs


def windowed_envelope(mins, maxs, halo: int):
    """Envelope over chunks [t - halo, t] for each t (the match window).

    Out-of-range chunks contribute nothing (±BIG fill), so early chunks
    get the correctly narrower window.
    """
    acc = mins.dtype
    BIG = big(acc)
    t = mins.shape[0]
    wmin, wmax = mins, maxs
    for s in range(1, halo + 1):
        pad = min(s, t)
        sh_min = jnp.concatenate([jnp.full((pad,), BIG, acc), mins])[:t]
        sh_max = jnp.concatenate([jnp.full((pad,), -BIG, acc), maxs])[:t]
        wmin = jnp.minimum(wmin, sh_min)
        wmax = jnp.maximum(wmax, sh_max)
    return wmin, wmax


def _interval_dist(q, lo, hi, metric: str):
    """Pointwise distance from value(s) q to the interval [lo, hi] — the
    smallest possible metric distance to any point inside it."""
    gap = jnp.maximum(jnp.maximum(lo - q, q - hi), 0.0)
    if metric == "square_diff":
        return gap * gap
    return gap


def lb_cascade(queries, qlens, mins, maxs, halo: int,
               metric: str = "abs_diff"):
    """LB_Kim and LB_Keogh for every (query, chunk) pair.

    Args:
      queries: (nq, N) padded batch; qlens (nq,) true lengths.
      mins/maxs: (T,) per-chunk envelope from ``chunk_envelope``.
      halo:    window radius in chunks (ceil(span_cap / chunk)).
      metric:  'abs_diff' | 'square_diff'.

    Returns (lb_kim (nq, T), lb_keogh (nq, T)) in float32, shaved by
    ``LB_SAFETY``; ``lb_keogh >= lb_kim`` elementwise by construction.
    Memory: the Keogh term materialises an (nq, N, T) intermediate — fine
    for serving-sized batches; shard the chunk axis upstream if T is huge.
    """
    if metric not in METRICS:
        raise ValueError(f"unknown metric {metric!r}; expected {METRICS}")
    q = jnp.asarray(queries, jnp.float32)
    nq, n = q.shape
    qlens = jnp.asarray(qlens, jnp.int32)
    cmin = jnp.asarray(mins, jnp.float32)[None, :]       # chunk envelope
    cmax = jnp.asarray(maxs, jnp.float32)[None, :]
    wmin, wmax = windowed_envelope(jnp.asarray(mins, jnp.float32),
                                   jnp.asarray(maxs, jnp.float32), halo)
    wmin, wmax = wmin[None, :], wmax[None, :]            # match window

    q_last = jnp.take_along_axis(q, (qlens - 1)[:, None], axis=1)  # (nq, 1)
    last_term = _interval_dist(q_last, cmin, cmax, metric)         # (nq, T)
    first_term = _interval_dist(q[:, :1], wmin, wmax, metric)      # (nq, T)
    lb_kim = jnp.where((qlens == 1)[:, None], last_term,
                       first_term + last_term)

    # Every query point before the last aligns inside the window.
    contrib = _interval_dist(q[:, :, None], wmin[:, None, :],
                             wmax[:, None, :], metric)   # (nq, N, T)
    mid_mask = jnp.arange(n)[None, :] < (qlens - 1)[:, None]
    mid = jnp.sum(jnp.where(mid_mask[:, :, None], contrib, 0.0), axis=1)
    lb_keogh = mid + last_term

    return lb_kim * LB_SAFETY, lb_keogh * LB_SAFETY
