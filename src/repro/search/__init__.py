"""Top-K pruned subsequence search on the unified sDTW engine.

``search_topk`` is the query-answering layer: lower-bound pruning
(LB_Kim / LB_Keogh over a cached per-chunk envelope) in front of the
engine's chunk-carry DP, returning the K best, exclusion-zone-distinct
match end positions per query. ``matrix_profile`` rides it for the
self-join: the full sDTW matrix profile of a series under bounded
memory, with motif pairs and top-K discords.
"""
from .cache import DEFAULT_CACHE, EnvelopeCache
from .lower_bounds import (chunk_envelope, lb_cascade, windowed_envelope,
                           znorm, znorm_padded)
from .profile import ProfileResult, matrix_profile
from .search import SearchResult, default_chunk, search_topk

__all__ = [
    "search_topk", "SearchResult", "default_chunk",
    "matrix_profile", "ProfileResult",
    "EnvelopeCache", "DEFAULT_CACHE",
    "chunk_envelope", "windowed_envelope", "lb_cascade",
    "znorm", "znorm_padded",
]
