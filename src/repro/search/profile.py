"""The sDTW matrix profile: self-join motifs and discords at scale.

The paper's headline scenario (§I, §V) is anomaly discovery in long
recordings — ECG, seismology — and the matrix profile is the standard
product for it: for every sliding window of a series, the distance to its
nearest *non-trivial* match elsewhere in the same series. Low
nearest-neighbor distance = a repeated pattern (motif); high = a
subsequence unlike anything else (discord / anomaly).

``matrix_profile`` composes the machinery already in the stack instead of
adding a second DP:

  * windows follow ``repro.core.sdtw.self_join_windows``' convention
    (starts ``arange(0, M - window + 1, stride)`` in sample units) but
    are sliced per bounded **batch** — at no point are all O(M) windows
    (an O(M·window) array for stride=1) materialized at once, and nothing
    is ever O(M²);
  * trivial-match suppression is ``self_join_exclusion`` — banned
    reference columns in **sample** units (stride-invariant), flowing
    through the engine's per-query ``excl_lo``/``excl_hi`` masks;
  * each batch runs through ``search_topk`` with the LB_Kim/LB_Keogh
    cascade; the reference envelope is computed once and shared across
    all batches through a single ``EnvelopeCache`` entry (the chunk size
    is pinned up front so every batch maps to the same cache key);
  * motif pairs (mutually nearest, exclusion-distinct) and top-K
    discords are host-side greedy reductions over the finished profile
    (``repro.core.topk.mutual_nearest_pairs`` / ``discord_select``).

Exactness: with ``prune=False`` every per-window nearest-neighbor
(distance, start, end) triple is the engine's exact streamed answer —
int32-bitwise against the brute-force all-pairs oracle (the acceptance
gate in ``benchmarks/profile_bench.py`` and ``tests/test_profile.py``).
With ``prune=True`` two caveats apply: distances inherit ``search_topk``'s
span-cap caveat — a nearest neighbor whose alignment spans more than
``span_cap`` reference columns (default ``2 * window``) may be missed or
scored from truncated context; for profile windows (span ≈ window) the
default cap is generous, and pruned distances are bitwise-exact on every
tested shape. And when two spans *tie* on distance, the pruned path may
report a different (equally optimal) witness span than the unpruned
leftmost-end convention: pruning admissibly skips chunks that merely tie
the incumbent, and batch composition decides which tying chunks get
dispatched at all.

Memory: O(batch · window) for the query slabs + O(M) for the series and
its envelope + O(nw) for the profile itself.

``repro.stream.profile.StreamProfile`` is the incremental variant —
appending samples extends the reference *and* admits new windows — and
``matsa(mode='self_join')`` routes through here by default.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.distances import accum_dtype, big
from repro.core.sdtw import self_join_exclusion
from repro.core.topk import discord_select, mutual_nearest_pairs

from . import cache as cache_mod
from .search import default_chunk, search_topk


@dataclasses.dataclass
class ProfileResult:
    """The matrix profile of one series plus its motif/discord reductions.

    Per-window arrays are (nw,), indexed by window number (window i
    starts at sample ``starts[i] = i * stride``):

      * ``nn_dist``: accumulator-dtype distance to the window's nearest
        admissible neighbor — ``BIG`` (int32 ceiling / inf) when the
        exclusion band leaves no admissible reference column (check
        ``valid``; such windows are *never* selected as motifs or
        discords, so the padding sentinel cannot masquerade as the
        largest anomaly).
      * ``nn_start`` / ``nn_end``: the matched span in global sample
        positions (the DP start-pointer lane / last-row end); -1 when
        invalid.
      * ``nn_window``: nearest window index ``round(nn_start / stride)``
        clipped to [0, nw) — the self-join neighbor used for the
        mutual-nearest motif test; -1 when invalid.

    Motifs and discords are (k,) greedy selections (see
    ``repro.core.topk``): ``motif_a``/``motif_b`` are window indices with
    ``motif_dist`` the cheaper direction's distance, padded (-1, -1,
    inf); ``discord_idx``/``discord_dist`` are padded (-1, -inf).

    Tile telemetry sums ``search_topk``'s counters over all batches.
    """
    window: int
    stride: int
    k: int
    starts: np.ndarray
    nn_dist: np.ndarray
    nn_start: np.ndarray
    nn_end: np.ndarray
    nn_window: np.ndarray
    motif_a: np.ndarray
    motif_b: np.ndarray
    motif_dist: np.ndarray
    discord_idx: np.ndarray
    discord_dist: np.ndarray
    excl_zone: int = 0
    chunk: int = 0
    chunks_total: int = 0
    chunks_pruned_kim: int = 0
    chunks_pruned_keogh: int = 0
    chunks_processed: int = 0

    @property
    def chunks_pruned(self) -> int:
        return self.chunks_pruned_kim + self.chunks_pruned_keogh

    @property
    def valid(self) -> np.ndarray:
        """(nw,) bool: windows with an admissible nearest neighbor —
        False rows carry (BIG, -1, -1, -1) padding, masked out of every
        motif/discord selection."""
        return self.nn_end >= 0

    @property
    def motifs(self):
        """Non-padding motif pairs as [(a, b, dist)] python tuples."""
        keep = self.motif_a >= 0
        return [(int(a), int(b), float(d)) for a, b, d in
                zip(self.motif_a[keep], self.motif_b[keep],
                    self.motif_dist[keep])]

    @property
    def discords(self):
        """Non-padding discords as [(idx, dist)] python tuples."""
        keep = self.discord_idx >= 0
        return [(int(i), float(d)) for i, d in
                zip(self.discord_idx[keep], self.discord_dist[keep])]

    @property
    def spans(self) -> np.ndarray:
        """(nw, 2) stacked (nn_start, nn_end) spans; (-1, -1) rows are
        invalid windows."""
        return np.stack([self.nn_start, self.nn_end], axis=-1)


def _assemble_profile(window, stride, k, starts, nn_dist, nn_start, nn_end,
                      excl_zone, chunk, stats) -> ProfileResult:
    """Mask sentinels, derive neighbor window indices, run the motif and
    discord reductions — shared by the batch and streaming variants so
    the two can only differ in how the nn arrays were produced."""
    starts = np.asarray(starts, np.int64)
    nn_dist = np.asarray(nn_dist)
    nn_start = np.asarray(nn_start, np.int64)
    nn_end = np.asarray(nn_end, np.int64)
    nw = starts.shape[0]
    ceiling = big(nn_dist.dtype)
    valid = (nn_end >= 0) & (nn_dist < ceiling)
    # Invalid rows get the canonical padding triple so no half-set
    # sentinel (a BIG distance with a live position, or vice versa) can
    # leak into downstream consumers.
    nn_start = np.where(valid, nn_start, -1)
    nn_end = np.where(valid, nn_end, -1)
    nn_window = np.where(
        valid,
        np.clip((nn_start + stride // 2) // stride, 0, nw - 1), -1)
    dist_f = np.where(valid, nn_dist.astype(np.float64), np.inf)
    ma, mb, md = mutual_nearest_pairs(dist_f, nn_window, starts, k,
                                      excl_zone)
    di, dd = discord_select(dist_f, starts, k, excl_zone)
    return ProfileResult(
        window=int(window), stride=int(stride), k=int(k), starts=starts,
        nn_dist=nn_dist, nn_start=nn_start, nn_end=nn_end,
        nn_window=nn_window, motif_a=ma, motif_b=mb, motif_dist=md,
        discord_idx=di, discord_dist=dd, excl_zone=int(excl_zone),
        chunk=int(chunk), chunks_total=stats[0],
        chunks_pruned_kim=stats[1], chunks_pruned_keogh=stats[2],
        chunks_processed=stats[3])


def matrix_profile(series, window: int, stride: int = 1, k: int = 1, *,
                   metric: str = "abs_diff", chunk: Optional[int] = None,
                   prune: bool = True, span_cap: Optional[int] = None,
                   excl_zone: Optional[int] = None, batch: int = 256,
                   cache: Optional[cache_mod.EnvelopeCache] = None,
                   ref_key=None,
                   engine_impl: str = "auto") -> ProfileResult:
    """Full sDTW matrix profile of ``series`` against itself.

    Args:
      series:    (M,) the series; every length-``window`` sliding window
                 (step ``stride``) is matched against the whole series.
      window:    subsequence length (the profile's "m" parameter).
      stride:    window step in samples — stride > 1 thins the *query*
                 side only; every window still searches the full series.
      k:         motif pairs / discords to report (per-window NN is
                 always top-1).
      metric:    'abs_diff' | 'square_diff'.
      chunk:     pruning tile (default ``default_chunk(M, window)``) —
                 pinned once so all batches share one envelope entry.
      prune:     LB_Kim/LB_Keogh cascade (see module docstring for the
                 span-cap caveat); ``False`` = exact engine streaming.
      span_cap:  pruned-path alignment-span cap (default ``2 * window``).
      excl_zone: trivial-match radius in **samples** (default
                 ``window // 2``): window at sample s bans reference
                 columns ``[s - excl_zone, s + window + excl_zone)`` and
                 the same radius separates reported motifs/discords.
      batch:     windows per ``search_topk`` call — the memory knob:
                 peak extra memory is O(batch · window).
      cache/ref_key: envelope reuse across *calls* (per-call reuse across
                 batches is automatic — a content fingerprint is derived
                 once when no key is given).
      engine_impl: DP backend for surviving chunks ('auto'/'rowscan'/
                 'pallas' — pallas requires no exclusion, so the profile
                 forces rowscan under 'auto').

    Returns a ``ProfileResult``. Never materializes O(M²) — see the
    module docstring for the memory bound.
    """
    series = np.asarray(series)
    if series.ndim != 1:
        raise ValueError(f"series must be 1-D, got shape {series.shape}")
    m = series.shape[0]
    if not 1 <= window <= m:
        raise ValueError(f"window must be in [1, {m}], got {window}")
    if stride < 1:
        raise ValueError(f"stride must be >= 1, got {stride}")
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    zone = window // 2 if excl_zone is None else int(excl_zone)
    if zone < 0:
        raise ValueError(f"excl_zone must be >= 0, got {excl_zone}")

    starts = np.arange(0, m - window + 1, stride, dtype=np.int64)
    nw = starts.shape[0]
    c = default_chunk(m, window) if chunk is None else int(chunk)
    cache = cache_mod.DEFAULT_CACHE if cache is None else cache
    ref = jnp.asarray(series)
    if ref_key is None and prune:
        # Fingerprint once — every batch then shares the same
        # (key, chunk) envelope entry without re-sampling the series.
        ref_key = cache_mod.EnvelopeCache._fingerprint(ref)

    acc = accum_dtype(ref.dtype)
    nn_dist = np.full((nw,), big(acc), acc)
    nn_start = np.full((nw,), -1, np.int64)
    nn_end = np.full((nw,), -1, np.int64)
    stats = [0, 0, 0, 0]
    col = np.arange(window, dtype=np.int64)
    for b0 in range(0, nw, batch):
        sl = slice(b0, min(b0 + batch, nw))
        s_b = starts[sl]
        windows_b = series[s_b[:, None] + col[None, :]]
        lo_b, hi_b = self_join_exclusion(s_b, window, zone)
        res = search_topk(
            windows_b, ref, 1, metric=metric, chunk=c, prune=prune,
            span_cap=span_cap, excl_lo=lo_b, excl_hi=hi_b, cache=cache,
            ref_key=ref_key, engine_impl=engine_impl)
        nn_dist[sl] = np.asarray(res.distances)[:, 0]
        nn_end[sl] = np.asarray(res.positions)[:, 0]
        nn_start[sl] = np.asarray(res.starts)[:, 0]
        stats[0] += res.chunks_total
        stats[1] += res.chunks_pruned_kim
        stats[2] += res.chunks_pruned_keogh
        stats[3] += res.chunks_processed
    return _assemble_profile(window, stride, k, starts, nn_dist, nn_start,
                             nn_end, zone, c, stats)
