"""Per-reference envelope cache for the serving loop.

A deployment serves many query batches against few, long-lived references
(the paper's scenario: a fixed 1.8M-point ECG record, streams of incoming
queries). The pruning cascade's only per-reference precomputation — the
per-chunk [min, max] envelope — is therefore cached across requests.

Keys: callers SHOULD pass a stable ``key=`` (e.g. a dataset name). Without
one, a content fingerprint is derived from the array's shape, dtype and a
sample of its values — cheap (no full host transfer of a multi-million-
point reference) and deterministic, but, like any sample-based
fingerprint, collidable by adversarial inputs; the explicit key is the
production path.
"""
from __future__ import annotations

import hashlib

import jax.numpy as jnp
import numpy as np

from .lower_bounds import chunk_envelope


class EnvelopeCache:
    """Maps (reference key, chunk size) → per-chunk envelope arrays."""

    def __init__(self):
        self._store = {}
        self.hits = 0
        self.misses = 0

    def envelope(self, reference, chunk: int, key=None):
        """Cached ``chunk_envelope(reference, chunk)``."""
        full_key = (self._fingerprint(reference) if key is None else key,
                    int(chunk))
        hit = self._store.get(full_key)
        if hit is not None:
            self.hits += 1
            return hit
        self.misses += 1
        env = chunk_envelope(reference, chunk)
        self._store[full_key] = env
        return env

    def clear(self):
        self._store.clear()

    def __len__(self):
        return len(self._store)

    @staticmethod
    def _fingerprint(reference):
        m = int(reference.shape[0])
        # Strided sample covers the whole array (a mutated middle changes
        # the key), plus dense head/tail and global sum/min/max reductions
        # — all computed device-side, only ~1 KB crosses to host. Still a
        # sample, hence the explicit-key recommendation above.
        stride = max(1, m // 256)
        sample = np.asarray(reference[::stride][:257])
        head = np.asarray(reference[: min(64, m)])
        tail = np.asarray(reference[max(0, m - 64):])
        moments = np.asarray([
            np.asarray(jnp.sum(reference, dtype=jnp.float32)),
            np.asarray(jnp.min(reference)).astype(np.float32),
            np.asarray(jnp.max(reference)).astype(np.float32)])
        h = hashlib.sha1()
        h.update(str((m, str(reference.dtype), stride)).encode())
        for part in (sample, head, tail, moments):
            h.update(part.tobytes())
        return h.hexdigest()


#: Module-level default used by ``search_topk`` when no cache is passed —
#: gives repeat requests against the same reference envelope reuse for free.
DEFAULT_CACHE = EnvelopeCache()
