"""Per-reference envelope cache for the serving loop.

A deployment serves many query batches against few, long-lived references
(the paper's scenario: a fixed 1.8M-point ECG record, streams of incoming
queries). The pruning cascade's only per-reference precomputation — the
per-chunk [min, max] envelope — is therefore cached across requests.

Keys: callers SHOULD pass a stable ``key=`` (e.g. a dataset name). Without
one, a content fingerprint is derived from the array's shape, dtype and a
sample of its values — cheap (no full host transfer of a multi-million-
point reference) and deterministic, but, like any sample-based
fingerprint, collidable by adversarial inputs; the explicit key is the
production path.
"""
from __future__ import annotations

import hashlib

import jax.numpy as jnp
import numpy as np

from .lower_bounds import chunk_envelope


class EnvelopeCache:
    """Maps (reference key, chunk size) → per-chunk envelope arrays."""

    def __init__(self):
        self._store = {}
        self.hits = 0
        self.misses = 0

    def envelope(self, reference, chunk: int, key=None):
        """Cached ``chunk_envelope(reference, chunk)``.

        A cached entry only counts as a hit when its tile count matches
        this reference's — a streamed entry that stopped mid-reference
        (or was corrupted by a mis-keyed writer) must not gate pruning
        over chunks it never saw; it is recomputed and replaced instead.
        """
        full_key = (self._fingerprint(reference) if key is None else key,
                    int(chunk))
        t = -(-int(reference.shape[0]) // int(chunk))
        hit = self._store.get(full_key)
        if hit is not None and len(np.asarray(hit[0])) == t:
            self.hits += 1
            return hit
        self.misses += 1
        env = chunk_envelope(reference, chunk)
        self._store[full_key] = env
        return env

    def extend(self, key, chunk: int, mins, maxs, at=None):
        """Append per-chunk envelope rows under ``(key, chunk)``.

        The streaming session calls this as reference chunks arrive, so
        the envelope an offline ``search_topk`` against the materialized
        reference would compute is already cached when the stream ends —
        ``envelope()`` then hits instead of recomputing. ``mins``/``maxs``
        are (t,) per-chunk values in chunk order (exactly what
        ``chunk_envelope`` produces for those tiles). A streamed envelope
        requires an explicit key: the fingerprint path needs the whole
        array, which a stream never materializes.

        ``at`` is the writer's global tile index for ``mins[0]``: when the
        entry already holds ``at`` tiles the rows append; when it holds
        *more*, another session already streamed this prefix and the rows
        are dropped (idempotent re-streams — a second monitor on the same
        key must not double the entry); when it holds *fewer* there is a
        gap, and the entry is dropped entirely rather than left to serve
        out-of-place bounds (``envelope()`` recomputes on demand).
        """
        if key is None:
            raise ValueError("extend() requires an explicit key — a stream "
                             "has no materialized array to fingerprint")
        full_key = (key, int(chunk))
        mins = np.asarray(mins)
        maxs = np.asarray(maxs)
        cur = self._store.get(full_key)
        cur_len = 0 if cur is None else len(np.asarray(cur[0]))
        if at is not None:
            if cur_len > int(at):
                return                     # prefix already present
            if cur_len < int(at):
                self._store.pop(full_key, None)   # gap — drop, recompute
                return
        if cur is not None:
            mins = np.concatenate([np.asarray(cur[0]), mins])
            maxs = np.concatenate([np.asarray(cur[1]), maxs])
        self._store[full_key] = (mins, maxs)

    def peek(self, key, chunk: int):
        """The cached entry under ``(key, chunk)``, or None — does not
        compute and does not count as a hit/miss."""
        return self._store.get((key, int(chunk)))

    def put(self, key, chunk: int, mins, maxs):
        """Install an envelope wholesale under ``(key, chunk)``, replacing
        any partial entry — the restore path of a streamed session, whose
        snapshot carries the authoritative prefix (a fresh cache in a new
        process must not be *extended* from mid-stream)."""
        if key is None:
            raise ValueError("put() requires an explicit key")
        self._store[(key, int(chunk))] = (np.asarray(mins),
                                          np.asarray(maxs))

    def clear(self):
        self._store.clear()

    def __len__(self):
        return len(self._store)

    @staticmethod
    def _fingerprint(reference):
        m = int(reference.shape[0])
        # Strided sample covers the whole array (a mutated middle changes
        # the key), plus dense head/tail and global sum/min/max reductions
        # — all computed device-side, only ~1 KB crosses to host. Still a
        # sample, hence the explicit-key recommendation above.
        stride = max(1, m // 256)
        sample = np.asarray(reference[::stride][:257])
        head = np.asarray(reference[: min(64, m)])
        tail = np.asarray(reference[max(0, m - 64):])
        moments = np.asarray([
            np.asarray(jnp.sum(reference, dtype=jnp.float32)),
            np.asarray(jnp.min(reference)).astype(np.float32),
            np.asarray(jnp.max(reference)).astype(np.float32)])
        h = hashlib.sha1()
        h.update(str((m, str(reference.dtype), stride)).encode())
        for part in (sample, head, tail, moments):
            h.update(part.tobytes())
        return h.hexdigest()


#: Module-level default used by ``search_topk`` when no cache is passed —
#: gives repeat requests against the same reference envelope reuse for free.
DEFAULT_CACHE = EnvelopeCache()
