"""Deterministic, shard-aware, resumable data pipeline.

Every batch is a pure function of (seed, step) — no iterator state to
checkpoint, so restart/elastic-resume just continues at the right step and
reproduces the exact stream (the fault-tolerance integration test relies on
this). Generation is numpy (host-side), mirroring a real ingestion pipeline
feeding device buffers.

Two sources:
  * SyntheticLM      — token/label batches (or embedding batches for the
                       stub-frontend archs).
  * TSAFilteredLM    — the paper's Fig. 2 flow: a synthetic sensor stream is
                       windowed, scored with sDTW against a reference motif
                       (repro.core.matsa), and only anomalous windows — the
                       interesting ones — are quantised into tokens for the
                       model. TSA acts as the cheap filter in front of the
                       expensive model, exactly the deployment the paper
                       motivates.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    seq_len: int = 128
    global_batch: int = 8
    vocab: int = 256
    embeddings_dim: int = 0     # >0 → produce embedding batches (stub frontends)


class SyntheticLM:
    """Markov-ish synthetic token stream (stateless; batch = f(seed, step))."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int, shard: int = 0, num_shards: int = 1):
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        b_local = cfg.global_batch // num_shards
        rng = np.random.Generator(np.random.Philox(
            key=cfg.seed, counter=[0, 0, step, shard]))
        if cfg.embeddings_dim:
            emb = rng.normal(0, 1, (b_local, cfg.seq_len, cfg.embeddings_dim))
            labels = rng.integers(0, cfg.vocab, (b_local, cfg.seq_len))
            return {"embeddings": emb.astype(np.float32),
                    "labels": labels.astype(np.int32)}
        # structured stream: noisy sinusoid quantised to the vocab — gives
        # the model something learnable (examples show loss decreasing).
        t = np.arange(cfg.seq_len + 1)[None, :] + rng.integers(
            0, 10_000, (b_local, 1))
        wave = (np.sin(2 * np.pi * t / 17.0) + np.sin(2 * np.pi * t / 5.0))
        noise = rng.normal(0, 0.1, wave.shape)
        toks = np.clip(((wave + noise + 2.2) / 4.4 * (cfg.vocab - 1)), 0,
                       cfg.vocab - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class TSAFilteredLM:
    """sDTW-filtered sensor stream → token batches (paper Fig. 2).

    Windows whose best-alignment distance against the reference motif exceeds
    the threshold (anomalies/discords) are kept for the model; normal windows
    are discarded before any expensive compute.
    """

    def __init__(self, cfg: DataConfig, anomaly_threshold: float = None,
                 window: Optional[int] = None):
        from repro.core import matsa, synthetic_timeseries
        self.cfg = cfg
        self.window = window or cfg.seq_len
        self._matsa = matsa
        rng = np.random.default_rng(cfg.seed)
        self.reference = synthetic_timeseries(rng, 4096, anomaly_rate=0.0,
                                              dtype=np.float32)
        self.threshold = anomaly_threshold
        self.filter_stats = {"seen": 0, "kept": 0}

    def batch_at(self, step: int, shard: int = 0, num_shards: int = 1):
        from repro.core import synthetic_timeseries
        cfg = self.cfg
        b_local = cfg.global_batch // num_shards
        rng = np.random.Generator(np.random.Philox(
            key=cfg.seed + 1, counter=[0, 0, step, shard]))
        keep, raw = [], []
        # Oversample windows; sDTW-filter down to the anomalous ones.
        while len(keep) < b_local:
            n_cand = max(2 * b_local, 8)
            series = synthetic_timeseries(rng, n_cand * self.window,
                                          anomaly_rate=0.5, dtype=np.float32)
            wins = series[:n_cand * self.window].reshape(n_cand, self.window)
            res = self._matsa(self.reference, wins,
                              dist_metric="abs_diff")
            d = np.asarray(res.distances)
            thr = self.threshold if self.threshold is not None else \
                float(np.median(d))
            self.filter_stats["seen"] += n_cand
            for i in np.argsort(-d):
                if d[i] > thr and len(keep) < b_local:
                    keep.append(wins[i])
                    self.filter_stats["kept"] += 1
        x = np.stack(keep)                                 # (b, window)
        lo, hi = np.percentile(x, [1, 99])
        toks = np.clip((x - lo) / max(hi - lo, 1e-9), 0, 1)
        toks = (toks * (cfg.vocab - 1)).astype(np.int32)
        toks = toks[:, :cfg.seq_len + 1]
        if toks.shape[1] < cfg.seq_len + 1:
            reps = -(-(cfg.seq_len + 1) // toks.shape[1])
            toks = np.tile(toks, (1, reps))[:, :cfg.seq_len + 1]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
