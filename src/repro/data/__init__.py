from .pipeline import DataConfig, SyntheticLM, TSAFilteredLM

__all__ = ["DataConfig", "SyntheticLM", "TSAFilteredLM"]
