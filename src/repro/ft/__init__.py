from .runner import FailureInjector, RunnerConfig, TrainingRunner

__all__ = ["TrainingRunner", "RunnerConfig", "FailureInjector"]
