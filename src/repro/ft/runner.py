"""Fault-tolerant training runner: checkpoint/restart, failure injection,
straggler watchdog, deterministic resume.

The runner treats a training step as a transaction: on any step failure
(device loss, preemption — simulated via an injectable ``FailureInjector``)
it restores the newest complete checkpoint and replays from there. Because
the data pipeline is a pure function of (seed, step) (data/pipeline.py), the
recovered run is bit-identical to an uninterrupted one — asserted by the
integration tests.

Straggler mitigation: per-step wall-times feed an EWMA; steps slower than
``straggler_factor``× the EWMA are logged and counted (on real fleets this
signal drives hot-spare promotion / data re-assignment; here it is exercised
by injecting artificial delays).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

import jax

from repro import checkpoint as ckpt

log = logging.getLogger("repro.ft")


class FailureInjector:
    """Deterministically raise at given steps (once each) — simulates
    preemption/node loss for the restart tests."""

    def __init__(self, fail_at: tuple[int, ...] = ()):
        self.fail_at = set(fail_at)
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int = 100
    ckpt_every: int = 10
    keep_last: int = 3
    straggler_factor: float = 3.0
    max_restarts: int = 10


class TrainingRunner:
    def __init__(self, train_step: Callable, data, state, ckpt_dir: str,
                 cfg: RunnerConfig = RunnerConfig(),
                 injector: Optional[FailureInjector] = None,
                 shard: int = 0, num_shards: int = 1,
                 delay_hook: Optional[Callable[[int], float]] = None):
        self.train_step = train_step
        self.data = data
        self.state = state
        self.ckpt_dir = ckpt_dir
        self.cfg = cfg
        self.injector = injector
        self.shard, self.num_shards = shard, num_shards
        self.delay_hook = delay_hook
        self.metrics_log: list[dict] = []
        self.straggler_steps: list[int] = []
        self.restarts = 0
        self._ewma = None

    # -- persistence ------------------------------------------------------
    def _save(self, step: int):
        ckpt.save(self.ckpt_dir, step, self.state,
                  extra={"step": step}, keep_last=self.cfg.keep_last)

    def _restore(self) -> int:
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            return 0
        self.state, extra, _ = ckpt.restore(self.ckpt_dir, self.state)
        log.warning("restored checkpoint at step %d", step)
        return extra["step"] + 1 if "step" in extra else step + 1

    # -- watchdog ---------------------------------------------------------
    def _watch(self, step: int, dt: float):
        if self._ewma is None:
            self._ewma = dt
        if dt > self.cfg.straggler_factor * self._ewma and step > 2:
            self.straggler_steps.append(step)
            log.warning("straggler: step %d took %.3fs (ewma %.3fs)",
                        step, dt, self._ewma)
        self._ewma = 0.9 * self._ewma + 0.1 * dt

    # -- main loop --------------------------------------------------------
    def run(self) -> dict:
        step = self._restore() if ckpt.latest_step(self.ckpt_dir) is not None \
            else 0
        while step < self.cfg.total_steps:
            try:
                t0 = time.perf_counter()
                if self.delay_hook is not None:
                    time.sleep(self.delay_hook(step))
                if self.injector is not None:
                    self.injector.maybe_fail(step)
                batch = self.data.batch_at(step, self.shard, self.num_shards)
                batch = jax.tree.map(jax.numpy.asarray, batch)
                self.state, metrics = self.train_step(self.state, batch)
                dt = time.perf_counter() - t0
                self._watch(step, dt)
                self.metrics_log.append(
                    {"step": step,
                     **{k: float(v) for k, v in metrics.items()}})
                if (step + 1) % self.cfg.ckpt_every == 0:
                    self._save(step)
                step += 1
            except RuntimeError as e:
                self.restarts += 1
                log.warning("step %d failed (%s); restart %d", step, e,
                            self.restarts)
                if self.restarts > self.cfg.max_restarts:
                    raise
                step = self._restore()
        self._save(self.cfg.total_steps - 1)
        return {"state": self.state, "metrics": self.metrics_log,
                "restarts": self.restarts,
                "stragglers": self.straggler_steps}
