"""Sharded, elastic, atomic checkpointing (no external deps).

Layout:
    <dir>/step_000123/
        manifest.json       tree structure + shapes + dtypes + mesh metadata
        arr_00000.npy ...   one file per leaf (host-gathered)
    <dir>/LATEST            text file naming the newest complete step

Properties needed at scale and implemented here:
  * atomicity — written to a tmp dir, fsync'd, then renamed; LATEST updated
    last. A crash mid-save never corrupts the previous checkpoint (the
    fault-tolerance tests kill a run mid-training and restart from LATEST).
  * elasticity — leaves are saved as full (unsharded) host arrays plus the
    *logical* sharding spec; restore() device_puts onto whatever mesh the
    restarted job has, so pod count can change between runs.
  * async save — a background thread does the file I/O after host-gather, so
    the train loop only blocks for the device→host copy.
  * retention — keep_last N checkpoints are retained, older ones pruned.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Optional

import jax
import numpy as np

from repro.compat import tree_flatten_with_path


def _flatten_with_paths(tree):
    flat, treedef = tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, jax.tree.structure(tree)


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None,
         keep_last: int = 3, async_io: bool = True) -> str:
    """Checkpoint a pytree (params/opt/data state). Returns the final path."""
    paths, leaves, _ = _flatten_with_paths(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]

    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"

    def write():
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "extra": extra or {}, "leaves": []}
        for i, (p, a) in enumerate(zip(paths, host_leaves)):
            fn = f"arr_{i:05d}.npy"
            np.save(os.path.join(tmp, fn), a)
            manifest["leaves"].append(
                {"path": p, "file": fn, "shape": list(a.shape),
                 "dtype": str(a.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
            f.write(os.path.basename(final))
        os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
                   os.path.join(ckpt_dir, "LATEST"))
        _prune(ckpt_dir, keep_last)

    os.makedirs(ckpt_dir, exist_ok=True)
    if async_io:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        t.join()  # single-host container: join immediately but keep the
        # code path identical to the overlapped production variant.
    else:
        write()
    return final


def _prune(ckpt_dir: str, keep_last: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    latest = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, like, step: Optional[int] = None,
            shardings=None):
    """Restore a pytree structured like ``like``.

    ``shardings``: optional matching pytree of NamedSharding — leaves are
    device_put with them (elastic restore onto a new mesh)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = [np.load(os.path.join(d, leaf["file"]))
              for leaf in manifest["leaves"]]
    treedef = jax.tree.structure(like)
    tree = treedef.unflatten(arrays)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s) if s is not None else
            jax.numpy.asarray(a), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, manifest["extra"], step
