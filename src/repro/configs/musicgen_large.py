"""musicgen-large — decoder-only LM over EnCodec audio tokens.

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048 [arXiv:2306.05284].
Backbone only: the EnCodec frontend is a stub; input_specs() provides
precomputed frame embeddings (frontend="stub").
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    head_dim=64,
    frontend="stub",
    tie_embeddings=False,
    source="arXiv:2306.05284",
))
