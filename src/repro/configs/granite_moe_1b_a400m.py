"""granite-moe-1b-a400m — 32-expert top-8 MoE decoder.

24L d_model=1024 16H (GQA kv=8) per-expert d_ff=512 vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base].
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    n_experts=32,
    topk=8,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
))
