"""qwen3-moe-30b-a3b — 128-expert top-8 MoE decoder.

48L d_model=2048 32H (GQA kv=4) per-expert d_ff=768 vocab=151936
[hf:Qwen/Qwen3-30B-A3B].
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab=151936,
    head_dim=128,
    n_experts=128,
    topk=8,
    source="hf:Qwen/Qwen3-30B-A3B",
))
