"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; every assigned input
shape is a ``ShapeSpec``. The dry-run iterates the full cross product;
smoke tests use ``reduced()`` configs of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int                   # dense FF dim (per-expert dim for MoE)
    vocab: int
    head_dim: int = 0           # 0 → d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # MoE
    n_experts: int = 0
    topk: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (Zamba2-style): one shared attention block every `attn_every`
    # SSM layers (shared weights across invocations)
    attn_every: int = 0
    # modality frontend: "none" (token ids) | "stub" (precomputed embeddings)
    frontend: str = "none"
    tie_embeddings: bool = True
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.n_heads == 0:
            return 0
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic archs only (SSM / hybrid). Pure full-attention archs
        skip long_500k — recorded in DESIGN.md §Arch-applicability."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, v = self.d_model, self.vocab
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        hd = self.resolved_head_dim
        per_layer = 0
        attn = 0
        if self.n_heads:
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            attn = q + kv + o + (self.n_heads * hd + 2 * self.n_kv_heads * hd
                                 if self.qkv_bias else 0)
        dense_ff = 3 * d * self.d_ff          # SwiGLU gate/up/down
        ssm = 0
        if self.has_ssm:
            di, st, nh = self.d_inner, self.ssm_state, self.n_ssm_heads
            ssm = (d * (2 * di + 2 * st + nh)      # in_proj (z,x,B,C,dt)
                   + self.ssm_conv * (di + 2 * st)  # conv
                   + 2 * nh                         # A, D
                   + di                             # gated norm
                   + di * d)                        # out_proj
        if self.family == "ssm":
            per_layer = ssm + 2 * d               # norms
        elif self.family == "hybrid":
            per_layer = ssm + 2 * d
            n_groups = self.n_layers // self.attn_every
            n += attn + dense_ff + 2 * d          # one shared block
        elif self.has_moe:
            per_layer = (attn + d * self.n_experts                 # router
                         + self.n_experts * 3 * d * self.d_ff + 2 * d)
        else:
            per_layer = attn + dense_ff + 2 * d
        n += self.n_layers * per_layer + d        # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k of n_experts)."""
        if not self.has_moe:
            return self.param_count()
        full = self.param_count()
        moe_all = self.n_layers * self.n_experts * 3 * self.d_model * self.d_ff
        moe_active = self.n_layers * self.topk * 3 * self.d_model * self.d_ff
        return full - moe_all + moe_active

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=max(2, self.attn_every or 2),
            d_model=64,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=96 if self.d_ff else 0,
            vocab=256,
            head_dim=16 if self.n_heads else 0,
            n_experts=min(self.n_experts, 4),
            topk=min(self.topk, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=16 if self.has_ssm else self.ssm_head_dim,
            ssm_chunk=8,
            attn_every=2 if self.attn_every else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    from . import ALL_ARCHS  # noqa: F401 — populate registry
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_archs() -> dict[str, ArchConfig]:
    from . import ALL_ARCHS  # noqa: F401
    return dict(_REGISTRY)


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skips long_500k for quadratic archs
    (and records the skip) unless include_skipped."""
    out = []
    for name, cfg in all_archs().items():
        for sname, shape in SHAPES.items():
            skip = (sname == "long_500k" and not cfg.supports_long_context)
            if include_skipped or not skip:
                out.append((cfg, shape, skip))
    return out
