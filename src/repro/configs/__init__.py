"""Assigned architecture configs (one module per arch) + registry."""
from .base import SHAPES, ArchConfig, ShapeSpec, all_archs, cells, get_arch

from . import (mamba2_780m, phi3_medium_14b, llama3_2_1b, qwen1_5_32b,
               granite_34b, qwen3_moe_30b_a3b, granite_moe_1b_a400m,
               zamba2_2_7b, musicgen_large, internvl2_2b)

ALL_ARCHS = [
    mamba2_780m.CONFIG,
    phi3_medium_14b.CONFIG,
    llama3_2_1b.CONFIG,
    qwen1_5_32b.CONFIG,
    granite_34b.CONFIG,
    qwen3_moe_30b_a3b.CONFIG,
    granite_moe_1b_a400m.CONFIG,
    zamba2_2_7b.CONFIG,
    musicgen_large.CONFIG,
    internvl2_2b.CONFIG,
]

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "get_arch", "all_archs",
           "cells", "ALL_ARCHS"]
