"""internvl2-2b — InternViT + InternLM2; we implement the LM backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553 [arXiv:2404.16821].
The InternViT vision frontend is a stub; input_specs() provides precomputed
patch embeddings (frontend="stub").
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    head_dim=128,
    frontend="stub",
    source="arXiv:2404.16821",
))
