"""zamba2-2.7b — hybrid: Mamba2 backbone + shared attention blocks.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000 ssm_state=64
[arXiv:2411.15242]. One shared attention+FF block (shared weights) is
invoked every 6 SSM layers (9 invocations) — our simplification of Zamba2's
shared-block scheme (the real model adds per-invocation LoRA deltas;
recorded in DESIGN.md).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    head_dim=80,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    source="arXiv:2411.15242",
))
