from .adamw import (OptConfig, adamw_update, clip_by_global_norm, global_norm,
                    init_opt, schedule)

__all__ = ["OptConfig", "init_opt", "adamw_update", "schedule",
           "clip_by_global_norm", "global_norm"]
