"""AdamW + global-norm clipping + warmup-cosine schedule (pure JAX).

Optimizer state is fp32 regardless of compute dtype (DESIGN.md §7). The
update is fully pytree-generic and shards trivially: m/v inherit the param
sharding, so optimizer memory is evenly distributed by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step):
    """Linear warmup → cosine decay to min_lr_frac·lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init_opt(params) -> dict:
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros(), "v": zeros(), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(cfg: OptConfig, params, grads, opt_state):
    """One AdamW step → (new_params, new_opt_state, stats)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p2 = p32 - lr * (delta + cfg.weight_decay * p32)
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, stats
