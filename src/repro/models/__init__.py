from .model import (DEFAULT_RUN, RunConfig, decode_step, forward, init_cache,
                    init_lm, loss_fn, prefill)

__all__ = ["RunConfig", "DEFAULT_RUN", "init_lm", "forward", "loss_fn",
           "init_cache", "prefill", "decode_step"]
