"""GQA attention: training/prefill (memory-bounded online softmax) + decode.

Three training-path modes (selected per shape in the launch config; all are
numerically identical and oracle-checked against each other):

  * "dense"      — full S×S masked einsum. Cheapest HLO, fine for S ≤ 4k.
  * "chunked"    — lax.scan over KV chunks with online softmax (flash-style
                   rescaling). Memory O(S·ck) instead of O(S²); computes the
                   full rectangle, so ~2× the causal FLOPs (the masked half
                   is wasted) — the baseline the §Perf log hillclimbs.
  * "triangular" — python-unrolled query blocks with static prefix KV slices:
                   exact causal FLOPs, bigger HLO. The beyond-baseline option.

Decode: single-token query against a (possibly sequence-sharded) KV cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .layers import apply_rope, normal_init

NEG_INF = -1e30


def init_attention(key, cfg, dtype=jnp.float32):
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": normal_init(ks[0], (d, h * dh), d, dtype),
        "wk": normal_init(ks[1], (d, hkv * dh), d, dtype),
        "wv": normal_init(ks[2], (d, hkv * dh), d, dtype),
        "wo": normal_init(ks[3], (h * dh, d), h * dh, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def _project_qkv(params, cfg, x, positions, axes):
    b, s, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if axes is not None:
        tq = axes.tp_if_divisible(h)
        tkv = axes.tp_if_divisible(hkv)
        q = axes.constrain(q, "dp", None, tq, None)
        k = axes.constrain(k, "dp", None, tkv, None)
        v = axes.constrain(v, "dp", None, tkv, None)
    return q, k, v


def _gqa_scores(q, k, scale):
    """q: (B,Sq,H,D), k: (B,Sk,Hkv,D) → scores (B,Hkv,G,Sq,Sk) fp32."""
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k,
                      preferred_element_type=jnp.float32) * scale


def _gqa_values(probs, v):
    """probs: (B,Hkv,G,Sq,Sk), v: (B,Sk,Hkv,D) → (B,Sq,H,D)."""
    b, hkv, g, sq, sk = probs.shape
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, hkv * g, -1)


def _dense_attention(q, k, v, scale):
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    scores = _gqa_scores(q, k, scale)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    scores = jnp.where(qpos >= kpos, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_values(probs, v)


def _chunked_attention(q, k, v, scale, chunk: int):
    """Online-softmax scan over KV chunks (memory-bounded)."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    n_chunks = sk // chunk
    kc = k.reshape(b, n_chunks, chunk, hkv, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, hkv, dh).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(sq)

    def step(carry, xs):
        m, l, acc = carry
        kj, vj, j = xs
        scores = _gqa_scores(q, kj, scale)                  # (B,Hkv,G,Sq,ck)
        kpos = j * chunk + jnp.arange(chunk)
        mask = qpos[:, None] >= kpos[None, :]
        scores = jnp.where(mask, scores, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(v.dtype), vj)
        acc_new = acc * alpha[..., None].astype(acc.dtype) + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, dh), v.dtype)
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0),
                              (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh)


def _triangular_attention(q, k, v, scale, chunk: int):
    """Python-unrolled query blocks with static causal-prefix KV slices:
    exact causal FLOPs (no masked-half waste)."""
    b, sq, h, dh = q.shape
    outs = []
    for i in range(sq // chunk):
        qi = lax.slice_in_dim(q, i * chunk, (i + 1) * chunk, axis=1)
        kv_end = (i + 1) * chunk
        ki = lax.slice_in_dim(k, 0, kv_end, axis=1)
        vi = lax.slice_in_dim(v, 0, kv_end, axis=1)
        scores = _gqa_scores(qi, ki, scale)
        qpos = i * chunk + jnp.arange(chunk)
        kpos = jnp.arange(kv_end)
        scores = jnp.where(qpos[:, None] >= kpos[None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        outs.append(_gqa_values(probs, vi))
    return jnp.concatenate(outs, axis=1)


def _pad_heads_for_tp(q, k, v, cfg, axes):
    """Pad KV heads (and q-head groups with them) up to TP divisibility.

    GSPMD cannot shard phi3's 40/10 heads on a 16-way axis and falls back to
    REPLICATING attention across the model axis (16× flops — measured in the
    dry-run baseline, useful-ratio 0.09). Zero-padding to the next multiple
    costs ≤1.6× on the padded heads but shards perfectly. Padded heads are
    appended at the tail of the kv-major layout, so slicing the output back
    is a contiguous cut.
    """
    b, s, h, dh = q.shape
    hkv = k.shape[2]
    tp = axes.tp_size
    g = h // hkv
    hkv_p = -(-hkv // tp) * tp
    qg = q.reshape(b, s, hkv, g, dh)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, hkv_p - hkv), (0, 0), (0, 0)))
    q = qg.reshape(b, s, hkv_p * g, dh)
    k = jnp.pad(k, ((0, 0), (0, 0), (0, hkv_p - hkv), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, hkv_p - hkv), (0, 0)))
    q = axes.constrain(q, "dp", None, "tp", None)
    k = axes.constrain(k, "dp", None, "tp", None)
    v = axes.constrain(v, "dp", None, "tp", None)
    return q, k, v, (hkv, hkv_p, g)


def _unpad_heads(out, pad_info):
    hkv, hkv_p, g = pad_info
    b, s, _, dh = out.shape
    out = out.reshape(b, s, hkv_p, g, dh)[:, :, :hkv]
    return out.reshape(b, s, hkv * g, dh)


def attention(params, cfg, x, positions, axes=None, mode: str = "dense",
              chunk: int = 1024, pad_heads: bool = False):
    """Causal self-attention over a full sequence (train / prefill)."""
    dh = cfg.resolved_head_dim
    scale = 1.0 / np.sqrt(dh)
    q, k, v = _project_qkv(params, cfg, x, positions, axes)
    kv_for_cache = (k, v)   # real (unpadded) heads — what prefill stores
    pad_info = None
    if (pad_heads and axes is not None and axes.tp
            and (cfg.n_heads % axes.tp_size or
                 cfg.n_kv_heads % axes.tp_size)):
        q, k, v, pad_info = _pad_heads_for_tp(q, k, v, cfg, axes)
    s = x.shape[1]
    chunk = min(chunk, s)
    if mode == "dense" or s <= chunk:
        out = _dense_attention(q, k, v, scale)
    else:
        pad = (-s) % chunk  # padded tail is "future" → causally masked out
        if pad:
            q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if mode == "chunked":
            out = _chunked_attention(q, k, v, scale, chunk)
        elif mode == "triangular":
            out = _triangular_attention(q, k, v, scale, chunk)
        else:
            raise ValueError(f"unknown attention mode {mode!r}")
        out = out[:, :s]
    if pad_info is not None:
        out = _unpad_heads(out, pad_info)
    if axes is not None:
        out = axes.constrain(out, "dp", None, axes.tp_if_divisible(cfg.n_heads),
                             None)
    return out.reshape(*x.shape[:2], -1) @ params["wo"], kv_for_cache


def decode_attention(params, cfg, x, cache_k, cache_v, pos, axes=None):
    """Single-token decode against a KV cache.

    x: (B, 1, d); cache_k/v: (B, S_max, Hkv, Dh); pos: (B,) current lengths.
    Returns (out (B, 1, d), new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    scale = 1.0 / np.sqrt(dh)
    q, k, v = _project_qkv(params, cfg, x, pos[:, None], axes)
    # Insert the new KV at position `pos` (per-example).
    # Scatter the new token's K/V in place (only B rows written — the cache
    # buffer is loop-carried and donated, so XLA updates it in situ instead
    # of rewriting/copying the full [B,S,Hkv,Dh] cache each step).
    bidx = jnp.arange(b)
    cache_k = cache_k.at[bidx, pos].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, pos].set(v[:, 0].astype(cache_v.dtype))
    # fp8/int8 caches: compute scores in the query dtype.
    scores = _gqa_scores(q, cache_k.astype(q.dtype), scale)  # (B,Hkv,G,1,S)
    kpos = jnp.arange(cache_k.shape[1])
    mask = kpos[None, :] <= pos[:, None]                   # (B, S)
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_values(probs, cache_v.astype(q.dtype))
    out = out.reshape(b, 1, -1) @ params["wo"]
    return out, cache_k, cache_v
