"""Model zoo: assembles any ArchConfig into a trainable/servable LM.

Families: dense (llama/phi/qwen/granite), moe (qwen3/granite MoE), ssm
(mamba2), hybrid (zamba2: SSM backbone + one shared attention block invoked
every ``attn_every`` layers), audio/vlm (dense backbone, stub frontend —
inputs may be precomputed embeddings instead of token ids).

All layer stacks run under ``lax.scan`` over stacked parameters (bounded HLO
for 88-layer configs — required for the 80-compile dry-run) with optional
rematerialisation. Compute in bf16, params fp32 (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (cross_entropy_loss, embed, init_embedding, init_swiglu,
                     rms_norm, swiglu, unembed)


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Execution knobs (orthogonal to the architecture)."""
    compute_dtype: Any = jnp.bfloat16
    remat: str = "full"          # none | full | dots
    attn_mode: str = "dense"     # dense | chunked | triangular
    attn_chunk: int = 1024
    cache_dtype: Any = jnp.bfloat16
    # scan_layers=False unrolls the layer stack. The dry-run uses the
    # unrolled form because XLA's HloCostAnalysis counts a while-loop body
    # ONCE (trip count unknown) — with lax.scan the reported flops/collective
    # bytes would be ~n_layers× too low. Production training keeps scan.
    scan_layers: bool = True
    # Zero-pad attention heads to TP divisibility (§Perf lever for archs
    # whose head counts don't divide the model axis — see attention.py).
    pad_heads: bool = False

    def checkpoint(self, fn):
        if self.remat == "none":
            return fn
        if self.remat == "full":
            return jax.checkpoint(fn)
        if self.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        raise ValueError(self.remat)


DEFAULT_RUN = RunConfig()


def _scan(run: RunConfig, body, carry, xs, length: int):
    """lax.scan or an unrolled python loop with identical semantics
    (carry, stacked ys)."""
    if run.scan_layers:
        return lax.scan(body, carry, xs)
    ys = []
    for i in range(length):
        xi = jax.tree.map(lambda p: p[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, cfg):
    """One layer's params (structure depends on family)."""
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if cfg.family == "ssm" or cfg.family == "hybrid":
        return {"ssm": ssm_mod.init_ssm(ks[0], cfg),
                "ln": jnp.ones((d,), jnp.float32)}
    block = {"attn": attn_mod.init_attention(ks[0], cfg),
             "ln1": jnp.ones((d,), jnp.float32),
             "ln2": jnp.ones((d,), jnp.float32)}
    if cfg.has_moe:
        block["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        block["mlp"] = init_swiglu(ks[1], d, cfg.d_ff)
    return block


def _init_shared_block(key, cfg):
    """Zamba2's shared attention+FF block (one set of weights)."""
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    return {"attn": attn_mod.init_attention(ks[0], cfg),
            "mlp": init_swiglu(ks[1], d, cfg.d_ff),
            "ln1": jnp.ones((d,), jnp.float32),
            "ln2": jnp.ones((d,), jnp.float32)}


def init_lm(cfg, key):
    k_emb, k_blocks, k_shared, k_unemb = jax.random.split(key, 4)
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    params = {
        "embed": init_embedding(k_emb, cfg.vocab, cfg.d_model),
        "blocks": jax.vmap(lambda k: _init_block(k, cfg))(block_keys),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.family == "hybrid":
        params["shared"] = _init_shared_block(k_shared, cfg)
    if not cfg.tie_embeddings:
        params["unembed"] = init_embedding(k_unemb, cfg.vocab, cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _dense_block(cfg, run, axes, bp, x, positions):
    h, _ = attn_mod.attention(bp["attn"], cfg, rms_norm(x, bp["ln1"], cfg.norm_eps),
                              positions, axes, run.attn_mode, run.attn_chunk,
                              run.pad_heads)
    x = x + h
    if cfg.has_moe:
        h, aux = moe_mod.moe_mlp(bp["moe"], cfg,
                                 rms_norm(x, bp["ln2"], cfg.norm_eps), axes)
    else:
        h = swiglu(rms_norm(x, bp["ln2"], cfg.norm_eps), **bp["mlp"], axes=axes)
        aux = jnp.float32(0.0)
    return x + h, aux


def _ssm_block(cfg, run, axes, bp, x):
    return x + ssm_mod.ssm_forward(bp["ssm"], cfg,
                                   rms_norm(x, bp["ln"], cfg.norm_eps), axes)


def _shared_block(cfg, run, axes, sp, x, positions):
    h, _ = attn_mod.attention(sp["attn"], cfg, rms_norm(x, sp["ln1"], cfg.norm_eps),
                              positions, axes, run.attn_mode, run.attn_chunk,
                              run.pad_heads)
    x = x + h
    h = swiglu(rms_norm(x, sp["ln2"], cfg.norm_eps), **sp["mlp"], axes=axes)
    return x + h


def _cast_params(params, dtype):
    """bf16 compute copies of the fp32 master params (cast is differentiable:
    grads accumulate back into fp32)."""
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating)
        else a, params)


def _embed_inputs(cfg, params, batch, run):
    if "embeddings" in batch:
        x = batch["embeddings"].astype(run.compute_dtype)
    else:
        x = embed(params["embed"], batch["tokens"], run.compute_dtype)
    return x


def forward(cfg, params, batch, axes=None, run: RunConfig = DEFAULT_RUN):
    """Full-sequence forward → (logits fp32 (B,S,V), aux_loss)."""
    params = _cast_params(params, run.compute_dtype)
    x = _embed_inputs(cfg, params, batch, run)
    b, s, _ = x.shape
    if axes is not None:
        x = axes.constrain(x, "dp", None, None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    if cfg.family == "ssm":
        def body(x, bp):
            return _ssm_block(cfg, run, axes, bp, x), None
        x, _ = _scan(run, run.checkpoint(body), x, params["blocks"],
                     cfg.n_layers)
        aux = jnp.float32(0.0)
    elif cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        gblocks = jax.tree.map(
            lambda p: p.reshape(groups, cfg.attn_every, *p.shape[1:]),
            params["blocks"])
        shared = params["shared"]

        def group_body(x, gp):
            x = _shared_block(cfg, run, axes, shared, x, positions)
            def inner(x, bp):
                return _ssm_block(cfg, run, axes, bp, x), None
            x, _ = _scan(run, inner, x, gp, cfg.attn_every)
            return x, None
        x, _ = _scan(run, run.checkpoint(group_body), x, gblocks, groups)
        aux = jnp.float32(0.0)
    else:
        def body(carry, bp):
            x, aux = carry
            x, a = _dense_block(cfg, run, axes, bp, x, positions)
            return (x, aux + a), None
        (x, aux), _ = _scan(run, run.checkpoint(body),
                            (x, jnp.float32(0.0)), params["blocks"],
                            cfg.n_layers)
        aux = aux / cfg.n_layers

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["unembed"] if "unembed" in params else params["embed"]
    logits = unembed(table, x, axes)
    return logits, aux


def loss_fn(cfg, params, batch, axes=None, run: RunConfig = DEFAULT_RUN):
    logits, aux = forward(cfg, params, batch, axes, run)
    ce = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    loss = ce + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, run: RunConfig = DEFAULT_RUN):
    """Empty serving cache sized for `max_len` context."""
    cache = {"pos": jnp.zeros((batch,), jnp.int32)}
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim

    def stacked_ssm():
        st = ssm_mod.init_ssm_state(cfg, batch, run.cache_dtype)
        return jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers, *a.shape), a.dtype), st)

    if cfg.family == "ssm":
        cache["ssm"] = stacked_ssm()
    elif cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        cache["ssm"] = stacked_ssm()
        cache["shared_k"] = jnp.zeros((groups, batch, max_len, hkv, dh),
                                      run.cache_dtype)
        cache["shared_v"] = jnp.zeros_like(cache["shared_k"])
    else:
        cache["k"] = jnp.zeros((cfg.n_layers, batch, max_len, hkv, dh),
                               run.cache_dtype)
        cache["v"] = jnp.zeros_like(cache["k"])
    return cache


def decode_step(cfg, params, tokens, cache, axes=None,
                run: RunConfig = DEFAULT_RUN):
    """One decoding step. tokens: (B,) int32 → (logits (B,V), new cache)."""
    params = _cast_params(params, run.compute_dtype)
    pos = cache["pos"]
    x = embed(params["embed"], tokens[:, None], run.compute_dtype)
    if axes is not None:
        x = axes.constrain(x, "dp", None, None)

    # Caches are loop-CARRIED (not scanned xs/ys): with donated buffers the
    # while-loop updates them in place — no cache-sized double buffers. Layer
    # params/cache slices are indexed by the loop counter.
    import numpy as np

    def at(tree, l):
        return jax.tree.map(lambda p: p[l], tree)

    def put(tree, sub, l):
        return jax.tree.map(lambda p, s: p.at[l].set(s), tree, sub)

    if cfg.family in ("ssm", "hybrid"):
        def ssm_at(x, ssm_all, l):
            bp = at(params["blocks"], l)
            st = at(ssm_all, l)
            xin = rms_norm(x, bp["ln"], cfg.norm_eps)
            h, st2 = ssm_mod.ssm_decode_step(bp["ssm"], cfg, xin, st, axes)
            return x + h, put(ssm_all, st2, l)

        if cfg.family == "ssm":
            def body(carry, l):
                x, ssm_all = carry
                x, ssm_all = ssm_at(x, ssm_all, l)
                return (x, ssm_all), None
            (x, new_ssm), _ = _scan(run, body, (x, cache["ssm"]),
                                    np.arange(cfg.n_layers), cfg.n_layers)
            cache = dict(cache, ssm=new_ssm, pos=pos + 1)
        else:
            groups = cfg.n_layers // cfg.attn_every
            shared = params["shared"]

            def group_body(carry, g):
                x, ssm_all, k_all, v_all = carry
                xin = rms_norm(x, shared["ln1"], cfg.norm_eps)
                h, ck, cv = attn_mod.decode_attention(
                    shared["attn"], cfg, xin, k_all[g], v_all[g], pos, axes)
                k_all = k_all.at[g].set(ck)
                v_all = v_all.at[g].set(cv)
                x = x + h
                x = x + swiglu(rms_norm(x, shared["ln2"], cfg.norm_eps),
                               **shared["mlp"], axes=axes)

                def inner(carry2, j):
                    x2, ssm_all2 = carry2
                    x2, ssm_all2 = ssm_at(x2, ssm_all2,
                                          g * cfg.attn_every + j)
                    return (x2, ssm_all2), None
                (x, ssm_all), _ = _scan(run, inner, (x, ssm_all),
                                        np.arange(cfg.attn_every),
                                        cfg.attn_every)
                return (x, ssm_all, k_all, v_all), None

            (x, new_ssm, new_k, new_v), _ = _scan(
                run, group_body,
                (x, cache["ssm"], cache["shared_k"], cache["shared_v"]),
                np.arange(groups), groups)
            cache = dict(cache, ssm=new_ssm, shared_k=new_k, shared_v=new_v,
                         pos=pos + 1)
    else:
        def body(carry, l):
            x, aux, k_all, v_all = carry
            bp = at(params["blocks"], l)
            xin = rms_norm(x, bp["ln1"], cfg.norm_eps)
            h, ck, cv = attn_mod.decode_attention(bp["attn"], cfg, xin,
                                                  k_all[l], v_all[l], pos,
                                                  axes)
            k_all = k_all.at[l].set(ck)
            v_all = v_all.at[l].set(cv)
            x = x + h
            xin = rms_norm(x, bp["ln2"], cfg.norm_eps)
            if cfg.has_moe:
                h, a = moe_mod.moe_mlp(bp["moe"], cfg, xin, axes)
                aux = aux + a
            else:
                h = swiglu(xin, **bp["mlp"], axes=axes)
            return (x + h, aux, k_all, v_all), None

        (x, _, new_k, new_v), _ = _scan(
            run, body, (x, jnp.float32(0.0), cache["k"], cache["v"]),
            np.arange(cfg.n_layers), cfg.n_layers)
        cache = dict(cache, k=new_k, v=new_v, pos=pos + 1)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["unembed"] if "unembed" in params else params["embed"]
    logits = unembed(table, x, axes)[:, 0]
    return logits, cache


def prefill(cfg, params, batch, max_len: int, axes=None,
            run: RunConfig = DEFAULT_RUN):
    """Process a full prompt; returns (last-token logits (B,V), cache).

    For attention archs the KV cache is built by re-projecting K/V per layer
    (same weights, one pass); SSM archs carry their recurrent state."""
    params = _cast_params(params, run.compute_dtype)
    x = _embed_inputs(cfg, params, batch, run)
    b, s, _ = x.shape
    if axes is not None:
        x = axes.constrain(x, "dp", None, None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    cache = init_cache(cfg, b, max_len, run)
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim

    def pad_kv(k):
        return jnp.zeros((b, max_len, hkv, dh), run.cache_dtype
                         ).at[:, :s].set(k.astype(run.cache_dtype))

    if cfg.family == "ssm":
        def body(x, xs_):
            bp, st = xs_
            xin = rms_norm(x, bp["ln"], cfg.norm_eps)
            h, st2 = ssm_mod.ssm_forward(bp["ssm"], cfg, xin, axes, st)
            return x + h, st2
        x, new_ssm = _scan(run, run.checkpoint(body), x,
                           (params["blocks"], cache["ssm"]), cfg.n_layers)
        cache = dict(cache, ssm=new_ssm)
    elif cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        gblocks = jax.tree.map(
            lambda p: p.reshape(groups, cfg.attn_every, *p.shape[1:]),
            params["blocks"])
        gssm = jax.tree.map(
            lambda p: p.reshape(groups, cfg.attn_every, *p.shape[1:]),
            cache["ssm"])
        shared = params["shared"]

        def group_body(x, xs_):
            gp, st = xs_
            xin = rms_norm(x, shared["ln1"], cfg.norm_eps)
            h, kv = attn_mod.attention(shared["attn"], cfg, xin, positions,
                                       axes, run.attn_mode, run.attn_chunk,
                                       run.pad_heads)
            x = x + h
            x = x + swiglu(rms_norm(x, shared["ln2"], cfg.norm_eps),
                           **shared["mlp"], axes=axes)
            def inner2(x, xs2):
                bp, st_l = xs2
                xin = rms_norm(x, bp["ln"], cfg.norm_eps)
                h, st2 = ssm_mod.ssm_forward(bp["ssm"], cfg, xin, axes, st_l)
                return x + h, st2
            x, st2 = _scan(run, inner2, x, (gp, st), cfg.attn_every)
            return x, (st2, pad_kv(kv[0]), pad_kv(kv[1]))

        x, (new_ssm, ks, vs) = _scan(run, run.checkpoint(group_body), x,
                                     (gblocks, gssm), groups)
        new_ssm = jax.tree.map(
            lambda p: p.reshape(cfg.n_layers, *p.shape[2:]), new_ssm)
        cache = dict(cache, ssm=new_ssm, shared_k=ks, shared_v=vs)
    else:
        def body_kv(carry, bp):
            x, aux = carry
            xin = rms_norm(x, bp["ln1"], cfg.norm_eps)
            h, (kk, vv) = attn_mod.attention(bp["attn"], cfg, xin, positions,
                                             axes, run.attn_mode,
                                             run.attn_chunk, run.pad_heads)
            x = x + h
            xin = rms_norm(x, bp["ln2"], cfg.norm_eps)
            if cfg.has_moe:
                h, a = moe_mod.moe_mlp(bp["moe"], cfg, xin, axes)
                aux = aux + a
            else:
                h = swiglu(xin, **bp["mlp"], axes=axes)
                a = jnp.float32(0.0)
            return (x + h, aux + a), (pad_kv(kk), pad_kv(vv))
        (x, _), (ks, vs) = _scan(run, run.checkpoint(body_kv),
                                 (x, jnp.float32(0.0)), params["blocks"],
                                 cfg.n_layers)
        cache = dict(cache, k=ks, v=vs)

    cache = dict(cache, pos=jnp.full((b,), s, jnp.int32))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["unembed"] if "unembed" in params else params["embed"]
    logits = unembed(table, x[:, -1:], axes)[:, 0]
    return logits, cache
