"""Token-choice top-k MoE with expert parallelism (EP).

Two communication layouts over the TP ("model") axis, one math:

  * "a2a" (train/prefill): tokens are sharded over (data × model); each shard
    routes its local tokens into per-expert capacity buckets [E, c, d] and a
    tiled ``lax.all_to_all`` over the model axis delivers each expert's
    buckets to its owner shard (experts are sharded over "model"). Expert
    FFNs run as batched einsums; the inverse all_to_all returns outputs to
    the token owners. Communication per token ≈ 2·k·cf·d instead of a full
    gather — the textbook MoE dispatch, expressed in shard_map.

  * "replicated" (decode, S == 1): tokens are sharded over data only; each
    model shard evaluates just its local experts on all its tokens and a
    psum over "model" combines contributions. For tiny token counts this is
    strictly cheaper than a2a.

With mesh=None (CPU smoke tests) the same core runs unsharded (tp=1, a2a =
identity), so the distributed paths are oracle-checked against the local one
by construction.

Capacity semantics: per-(source-shard, expert) capacity
c = ceil(T_local · k · cf / E); overflow slots are dropped (Switch-style,
no gate renormalisation after drop). Gates are top-k-normalised (Qwen3's
norm_topk_prob). Router in fp32 + Switch aux load-balance loss.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from .layers import normal_init


def init_moe(key, cfg, dtype=jnp.float32):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": normal_init(ks[0], (d, e), d, dtype),
        "w_gate": normal_init(ks[1], (e, d, ff), d, dtype),
        "w_up": normal_init(ks[2], (e, d, ff), d, dtype),
        "w_down": normal_init(ks[3], (e, ff, d), ff, dtype),
    }


def _route(x, router_w, n_experts, topk):
    """Router: fp32 softmax → top-k (normalised gates) + aux loss terms."""
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = lax.top_k(probs, topk)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    # Switch aux loss: E * sum_e f_e * p_e  (f = fraction routed, p = mean prob)
    t = x.shape[0]
    counts = jnp.sum(jax.nn.one_hot(expert_ids, n_experts, dtype=jnp.float32),
                     axis=(0, 1))
    f = counts / jnp.maximum(t * topk, 1)
    p = jnp.mean(probs, axis=0)
    aux = n_experts * jnp.sum(f * p)
    return gate_vals, expert_ids, aux


def _bucketize(x_flat, expert_ids, gate_vals, n_buckets, capacity,
               expert_offset=0):
    """Scatter token slots into per-expert capacity buckets.

    Returns (buckets [n_buckets, c, d], slot refs for the return trip).
    Overflow / out-of-range slots are dropped via masked .add (zero
    contribution; positions are unique per kept slot so .add == .set).
    """
    t, k = expert_ids.shape
    d = x_flat.shape[-1]
    slot_expert = expert_ids.reshape(-1) - expert_offset       # (t*k,)
    slot_token = jnp.repeat(jnp.arange(t), k)
    in_range = (slot_expert >= 0) & (slot_expert < n_buckets)
    e_idx = jnp.where(in_range, slot_expert, 0)
    # Rank of each slot within its expert group (stable, slot-index order).
    counts = jnp.bincount(e_idx * in_range + n_buckets * (~in_range),
                          length=n_buckets + 1)[:n_buckets]
    order = jnp.argsort(jnp.where(in_range, e_idx, n_buckets), stable=True)
    starts = jnp.cumsum(counts) - counts
    sorted_e = e_idx[order]
    pos_sorted = jnp.arange(t * k) - starts[sorted_e]
    pos = jnp.zeros((t * k,), jnp.int32).at[order].set(pos_sorted)
    keep = in_range & (pos < capacity)
    pos_c = jnp.minimum(pos, capacity - 1)
    contrib = x_flat[slot_token] * keep[:, None].astype(x_flat.dtype)
    buckets = jnp.zeros((n_buckets, capacity, d), x_flat.dtype)
    buckets = buckets.at[e_idx, pos_c].add(contrib)
    return buckets, (e_idx, pos_c, keep, slot_token,
                     gate_vals.reshape(-1))


def _unbucketize(buckets, slot_refs, t):
    e_idx, pos_c, keep, slot_token, slot_gate = slot_refs
    y_slots = buckets[e_idx, pos_c]                            # (t*k, d)
    w = (slot_gate * keep).astype(y_slots.dtype)[:, None]
    return jax.ops.segment_sum(y_slots * w, slot_token, num_segments=t)


def _expert_ffn(xin, w_gate, w_up, w_down):
    """Batched-per-expert SwiGLU: xin (E_local, T_e, d)."""
    dt = xin.dtype
    h = jax.nn.silu(jnp.einsum("etd,edf->etf", xin, w_gate.astype(dt)))
    h = h * jnp.einsum("etd,edf->etf", xin, w_up.astype(dt))
    return jnp.einsum("etf,efd->etd", h, w_down.astype(dt))


def _capacity(t_local: int, topk: int, n_experts: int, cf: float) -> int:
    return max(1, math.ceil(t_local * topk * cf / n_experts))


def moe_mlp(params, cfg, x, axes=None):
    """MoE FF block. x: (B, S, d) → ((B, S, d), aux_loss)."""
    b, s, d = x.shape
    e, k, cf = cfg.n_experts, cfg.topk, cfg.capacity_factor

    if axes is None or axes.mesh is None or axes.tp is None:
        c = _capacity(b * s, k, e, cf)
        x_flat = x.reshape(-1, d)
        gates, ids, aux = _route(x_flat, params["router"], e, k)
        buckets, refs = _bucketize(x_flat, ids, gates, e, c)
        y = _expert_ffn(buckets, params["w_gate"], params["w_up"],
                        params["w_down"])
        out = _unbucketize(y, refs, b * s)
        return out.reshape(b, s, d), aux

    mesh = axes.mesh
    tp = axes.tp
    tp_size = axes.tp_size
    dp_spec = axes.dp if axes.dp else None
    all_axes = tuple(mesh.axis_names)
    if e % tp_size:
        raise ValueError(f"n_experts={e} must divide TP size {tp_size}")
    e_local = e // tp_size
    use_a2a = s % tp_size == 0 and s > 1

    if use_a2a:
        t_local = (b * s) // (_prod(mesh, axes.dp) * tp_size)
        c = _capacity(t_local, k, e, cf)
        x_spec = P(dp_spec, tp, None)
    else:
        t_local = (b * s) // max(1, _prod(mesh, axes.dp))
        c = _capacity(t_local, k, e, cf)
        x_spec = P(dp_spec, None, None)

    w_expert_spec = P(tp, None, None)

    def body(x_l, router_w, w_g, w_u, w_d):
        bl, sl, _ = x_l.shape
        t = bl * sl
        x_flat = x_l.reshape(t, d)
        gates, ids, aux = _route(x_flat, router_w, e, k)
        if use_a2a:
            buckets, refs = _bucketize(x_flat, ids, gates, e, c)
            recv = lax.all_to_all(buckets, tp, split_axis=0, concat_axis=0,
                                  tiled=True)                  # (tp*E_l, c, d)
            xin = (recv.reshape(tp_size, e_local, c, d)
                   .transpose(1, 0, 2, 3).reshape(e_local, tp_size * c, d))
            y = _expert_ffn(xin, w_g, w_u, w_d)
            y = (y.reshape(e_local, tp_size, c, d).transpose(1, 0, 2, 3)
                 .reshape(tp_size * e_local, c, d))
            yback = lax.all_to_all(y, tp, split_axis=0, concat_axis=0,
                                   tiled=True)                 # (E, c, d)
            out = _unbucketize(yback, refs, t)
        else:
            shard = lax.axis_index(tp)
            buckets, refs = _bucketize(x_flat, ids, gates, e_local, c,
                                       expert_offset=shard * e_local)
            y = _expert_ffn(buckets, w_g, w_u, w_d)
            out = _unbucketize(y, refs, t)
            out = lax.psum(out, tp)
        aux = lax.pmean(aux, all_axes)
        return out.reshape(bl, sl, d), aux

    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(), w_expert_spec, w_expert_spec, w_expert_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    return out, aux


def _prod(mesh, names) -> int:
    p = 1
    for n in names:
        p *= mesh.shape[n]
    return p
