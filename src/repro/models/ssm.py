"""Mamba2 — state-space duality (SSD) layer [arXiv:2405.21060].

Training uses the chunked SSD algorithm: within a chunk the recurrence is
evaluated in its "dual" quadratic attention-like form (MXU-friendly batched
matmuls); across chunks a lax.scan carries the SSM state. Decode is the pure
recurrence (O(1) state per token — this is why the SSM archs run the
long_500k cell).

Simplifications vs the reference implementation (recorded in DESIGN.md):
n_groups = 1 (B/C shared across heads), no dt clamping, depthwise conv done
as shift-sum (width 4). The chunked path and the step-by-step recurrence are
cross-validated in tests (same math, different factorisation).

Recurrence (per head h, state size N, head dim P):
    h_t = exp(A_h·dt_t) · h_{t-1} + dt_t · B_t ⊗ x_t        h: (P, N)
    y_t = C_t · h_t + D_h · x_t
followed by a gated RMSNorm (y ⊙ silu(z)) and the output projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .layers import normal_init, rms_norm


def init_ssm(key, cfg, dtype=jnp.float32):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h, w = cfg.n_ssm_heads, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    return {
        "in_proj": normal_init(ks[0], (d, 2 * di + 2 * n + h), d, dtype),
        "conv_x": normal_init(ks[1], (w, di), w, dtype),
        "conv_b": normal_init(ks[2], (w, n), w, dtype),
        "conv_c": normal_init(ks[3], (w, n), w, dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(dtype)),
        "D": jnp.ones((h,), dtype),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": normal_init(ks[5], (di, d), di, dtype),
    }


def _split_proj(cfg, proj):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z = proj[..., :di]
    xs = proj[..., di:2 * di]
    b = proj[..., 2 * di:2 * di + n]
    c = proj[..., 2 * di + n:2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n:]
    return z, xs, b, c, dt


def _causal_conv(x, w, state=None):
    """Depthwise causal conv via shift-sum. x: (B, S, C), w: (W, C).

    state: (B, W-1, C) trailing context from previous tokens (decode); when
    given, returns (out, new_state)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(width))
    if state is None:
        return jax.nn.silu(out)
    return jax.nn.silu(out), xp[:, -(width - 1):]


def _ssd_chunked(cfg, xh, dt, a, b, c):
    """Chunked SSD scan.

    xh: (B,S,H,P), dt/a: (B,S,H) fp32 (a = A·dt ≤ 0), b/c: (B,S,N) fp32.
    Returns y: (B,S,H,P) plus final state (B,H,P,N).
    """
    bs, s, h, p = xh.shape
    n = b.shape[-1]
    L = min(cfg.ssm_chunk, s)
    if s % L:
        # Pad with identity steps (dt=0 → a=0, zero input → state preserved,
        # padded outputs sliced off below).
        pad = L - s % L
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    s_orig, s = s, xh.shape[1]
    nc = s // L
    xc = xh.reshape(bs, nc, L, h, p)
    dtc = dt.reshape(bs, nc, L, h)
    ac = a.reshape(bs, nc, L, h)
    bc = b.reshape(bs, nc, L, n)
    cc = c.reshape(bs, nc, L, n)

    cs = jnp.cumsum(ac, axis=2)                      # inclusive (B,nc,L,H)
    seg_end = cs[:, :, -1:, :]                       # total chunk decay

    # ---- intra-chunk (quadratic dual form) ----
    g = jnp.einsum("bctn,bcsn->bcts", cc, bc)        # (B,nc,L,L)
    # Mask BEFORE the exp (exp(+large)·0 would produce NaN grads).
    darg = cs[:, :, :, None, :] - cs[:, :, None, :, :]            # t,s,H
    tri = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.exp(jnp.where(tri[None, None, :, :, None], darg, -1e30))
    scores = g[..., None] * decay * dtc[:, :, None, :, :]         # (B,nc,t,s,H)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", scores, xc.astype(jnp.float32))

    # ---- per-chunk input state (contribution entering the carried state) ----
    decay_out = jnp.exp(seg_end - cs)                # (B,nc,L,H)
    w_in = decay_out * dtc                           # (B,nc,L,H)
    state_in = jnp.einsum("bcsh,bcshp,bcsn->bchpn",
                          w_in, xc.astype(jnp.float32), bc)

    # ---- scan over chunks: prefix states ----
    seg_decay = jnp.exp(seg_end[:, :, 0, :])         # (B,nc,H)

    def chunk_step(hprev, xs_):
        sd, sin = xs_                                # (B,H), (B,H,P,N)
        hnew = sd[:, :, None, None] * hprev + sin
        return hnew, hprev                           # emit state BEFORE chunk

    h0 = jnp.zeros((bs, h, p, n), jnp.float32)
    hfin, hprefix = lax.scan(
        chunk_step, h0,
        (seg_decay.transpose(1, 0, 2), state_in.transpose(1, 0, 2, 3, 4)))
    hprefix = hprefix.transpose(1, 0, 2, 3, 4)       # (B,nc,H,P,N)

    # ---- inter-chunk: y_inter[t] = exp(cs_t) · C_t · h_chunk_start ----
    y_inter = jnp.einsum("bctn,bchpn->bcthp", cc, hprefix) * \
        jnp.exp(cs)[..., None]
    y = (y_intra + y_inter).reshape(bs, s, h, p)[:, :s_orig]
    return y, hfin


def ssm_forward(params, cfg, x, axes=None, state=None):
    """Full-sequence SSD layer. x: (B,S,d) → (B,S,d).

    state: optional dict(h, conv_x, conv_b, conv_c) for chunked serving;
    when provided, returns (out, new_state)."""
    bs, s, d = x.shape
    h, p, n = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    proj = x @ params["in_proj"]
    z, xs_raw, b_raw, c_raw, dt = _split_proj(cfg, proj)
    xs = _causal_conv(xs_raw, params["conv_x"])
    b = _causal_conv(b_raw, params["conv_b"])
    c = _causal_conv(c_raw, params["conv_c"])
    if axes is not None:
        tdi = axes.tp_if_divisible(cfg.d_inner)
        xs = axes.constrain(xs, "dp", None, tdi)
        z = axes.constrain(z, "dp", None, tdi)

    dtf = jax.nn.softplus(dt.astype(jnp.float32)
                          + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["A_log"].astype(jnp.float32)) * dtf      # (B,S,H)
    xh = xs.reshape(bs, s, h, p)
    y, hfin = _ssd_chunked(cfg, xh, dtf, a, b.astype(jnp.float32),
                           c.astype(jnp.float32))
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * \
        xh.astype(jnp.float32)
    y = y.reshape(bs, s, h * p).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if state is not None:
        # Conv continuation state: last W-1 *pre-conv* inputs.
        w = cfg.ssm_conv
        tail = jnp.concatenate([xs_raw, b_raw, c_raw], axis=-1)[:, -(w - 1):]
        tail = tail.astype(state["conv"].dtype)
        return out, dict(state, h=hfin, conv=tail)
    return out


def ssm_decode_step(params, cfg, x, state, axes=None):
    """Single-token recurrence. x: (B,1,d); state: {h (B,H,P,N) fp32,
    conv (B, W-1, d_inner+2N)} → (out (B,1,d), new_state)."""
    bs = x.shape[0]
    h, p, n = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di, w = cfg.d_inner, cfg.ssm_conv
    proj = x @ params["in_proj"]
    z, xs, b, c, dt = _split_proj(cfg, proj)

    conv_state = state["conv"]                      # (B, W-1, di+2n)
    sx, sb, sc = (conv_state[..., :di], conv_state[..., di:di + n],
                  conv_state[..., di + n:])
    xs, sx = _causal_conv(xs, params["conv_x"], sx)
    b, sb = _causal_conv(b, params["conv_b"], sb)
    c, sc = _causal_conv(c, params["conv_c"], sc)
    new_conv = jnp.concatenate([sx, sb, sc], axis=-1)

    dtf = jax.nn.softplus(dt.astype(jnp.float32)
                          + params["dt_bias"].astype(jnp.float32))  # (B,1,H)
    decay = jnp.exp(-jnp.exp(params["A_log"].astype(jnp.float32)) * dtf)
    xh = xs.reshape(bs, h, p).astype(jnp.float32)
    bf = b[:, 0].astype(jnp.float32)                # (B,N)
    cf = c[:, 0].astype(jnp.float32)
    hs = state["h"]
    hs = decay[:, 0, :, None, None] * hs + \
        (dtf[:, 0, :, None, None] * xh[..., None]) * bf[:, None, None, :]
    y = jnp.einsum("bn,bhpn->bhp", cf, hs)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(bs, 1, h * p).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    out = y @ params["out_proj"]
    return out, {"h": hs, "conv": new_conv}


def init_ssm_state(cfg, batch: int, dtype=jnp.bfloat16):
    h, p, n = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return {
        "h": jnp.zeros((batch, h, p, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1,
                           cfg.d_inner + 2 * cfg.ssm_state), dtype),
    }
