"""Shared neural-net layers (pure JAX, pytree params, no framework deps).

Numerics policy (DESIGN.md §7): params fp32, compute bf16 (cast at block
entry), reductions/norms in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def normal_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / np.sqrt(max(1, fan_in))
    return scale * jax.random.normal(key, shape, dtype)


def rms_norm(x, weight, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down, axes=None):
    """SwiGLU FF. TP: gate/up column-parallel, down row-parallel."""
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    if axes is not None:
        h = axes.constrain(h, "dp", None, "tp")
    return h @ w_down


def init_swiglu(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": normal_init(k1, (d_model, d_ff), d_model, dtype),
        "w_up": normal_init(k2, (d_model, d_ff), d_model, dtype),
        "w_down": normal_init(k3, (d_ff, d_model), d_ff, dtype),
    }


# ---------------------------------------------------------------------------
# Rotary position embeddings (llama-style, rotate-half).
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta), jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding (vocab sharded over the TP axis).
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32):
    return {"table": 0.02 * jax.random.normal(key, (vocab, d_model), dtype)}


def embed(params, tokens, compute_dtype=jnp.bfloat16):
    return params["table"].astype(compute_dtype)[tokens]


def unembed(params, x, axes=None):
    """Logits in fp32 (vocab-sharded over TP)."""
    logits = x.astype(jnp.float32) @ params["table"].astype(jnp.float32).T
    if axes is not None:
        logits = axes.constrain(logits, "dp", None, "tp")
    return logits


def cross_entropy_loss(logits, labels, mask=None):
    """Token-mean cross entropy; logits fp32 (B, S, V), labels (B, S)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
