"""Input specs + parameter sharding rules + step builders for every
(architecture × shape × mesh) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero device allocation) for every model input; ``state_specs``
does the same for params/optimizer via jax.eval_shape. ``build_cell``
assembles the jitted step function with in/out shardings for the dry-run.

Sharding rules (DESIGN.md §6):
  * train params+optimizer: 2-D "fsdp × tp" sharding — contraction dims over
    the data-parallel axes (ZeRO-3 style; XLA inserts the per-layer
    all-gathers), parallel dims over "model" (Megatron TP).
  * serve params: TP-only (no per-step weight gathers), bf16.
  * KV caches: batch over dp when batch ≥ |data|, else sequence-parallel
    (long_500k: the 500k-token cache is sharded along sequence — SP).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import tree_flatten_with_path
from repro.configs import ArchConfig, ShapeSpec
from repro.distributed import Axes
from repro.models import RunConfig, decode_step, init_cache, init_lm, prefill
from repro.models.model import loss_fn
from repro.optim import OptConfig
from repro.train import TrainConfig, init_train_state, make_train_step


# ---------------------------------------------------------------------------
# Parameter sharding rules (path-name based)
# ---------------------------------------------------------------------------

def _axis_size(axes: Axes, handle) -> int:
    if handle is None or axes.mesh is None:
        return 1
    names = handle if isinstance(handle, tuple) else (handle,)
    size = 1
    for n in names:
        size *= axes.mesh.shape[n]
    return size


def _leaf_spec(path_names, shape, axes: Axes, mode: str):
    """PartitionSpec dims for one param leaf, by name + rank.

    Every dim is divisibility-guarded: jit *argument* shardings (unlike
    with_sharding_constraint) hard-require even division, and e.g. mamba2's
    50280-token vocab does not divide a 16-way axis — such dims replicate.
    """
    name = path_names[-1]
    fsdp = (axes.dp if axes.dp else None) if mode == "train" else None
    tp = axes.tp
    stacked = "blocks" in path_names           # leading layer-stack dim
    rank = len(shape) - (1 if stacked else 0)
    dim_shape = shape[1:] if stacked else shape

    def spec(*dims):
        dims = tuple(d if (d is not None and
                           dim_shape[i] % _axis_size(axes, d) == 0) else None
                     for i, d in enumerate(dims))
        dims = (None,) + dims if stacked else dims
        assert len(dims) == len(shape), (path_names, shape, dims)
        return P(*dims)

    if name == "table":                         # [V, d]
        return spec(tp, fsdp)
    if name in ("wq", "wk", "wv"):              # [d, X]
        return spec(fsdp, tp)
    if name in ("bq", "bk", "bv"):              # [X]
        return spec(tp)
    if name == "wo":                            # [X, d]
        return spec(tp, fsdp)
    if name in ("w_gate", "w_up"):
        if rank == 3:                           # MoE [E, d, ff]
            return spec(tp, fsdp, None)
        return spec(fsdp, tp)                   # dense [d, ff]
    if name == "w_down":
        if rank == 3:                           # MoE [E, ff, d]
            return spec(tp, None, fsdp)
        return spec(tp, fsdp)                   # dense [ff, d]
    if name == "router":                        # [d, E]
        return spec(fsdp, None)
    if name == "in_proj":                       # [d, 2di+2n+h]
        return spec(tp, fsdp)
    if name == "out_proj":                      # [di, d]
        return spec(tp, fsdp)
    if name == "conv_x":                        # [w, di]
        return spec(None, tp)
    if name in ("conv_b", "conv_c"):            # [w, n]
        return spec(None, None)
    if name in ("dt_bias", "A_log", "D"):       # [h]
        return spec(tp)
    if name == "norm_w":                        # [di]
        return spec(tp)
    if name in ("ln", "ln1", "ln2", "final_norm"):
        return spec(None)
    if rank == 0:                               # scalars (opt step etc.)
        return P()
    # Fallback: replicate.
    return spec(*([None] * rank))


def _path_names(path):
    return tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def tree_specs(tree, axes: Axes, mode: str):
    """PartitionSpec tree matching an eval_shape'd param/opt pytree."""
    flat, treedef = tree_flatten_with_path(tree)
    specs = [_leaf_spec(_path_names(p), l.shape, axes, mode)
             for p, l in flat]
    return treedef.unflatten(specs)


def tree_shardings(tree, axes: Axes, mode: str):
    if axes.mesh is None:
        return None
    return jax.tree.map(lambda s: NamedSharding(axes.mesh, s),
                        tree_specs(tree, axes, mode))


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs — no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeSpec, run: RunConfig):
    """Model inputs for a cell, as ShapeDtypeStructs."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train" or shape.kind == "prefill":
        if cfg.frontend == "stub":
            batch = {"embeddings": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                        jnp.bfloat16),
                     "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if shape.kind == "prefill":
            batch.pop("labels")
        return batch
    # decode: one new token against a full cache
    return {"tokens": jax.ShapeDtypeStruct((b,), jnp.int32)}


def batch_spec_tree(cfg, shape, axes: Axes):
    dp = axes.dp if axes.dp else None
    def one(leaf_path, sds):
        name = _path_names(leaf_path)[-1]
        if name == "embeddings":
            return P(dp, None, None)
        if name in ("tokens", "labels"):
            return P(dp, None) if len(sds.shape) == 2 else P(dp)
        return P(*([None] * len(sds.shape)))
    flat, treedef = tree_flatten_with_path(input_specs(cfg, shape,
                                                           RunConfig()))
    return treedef.unflatten([one(p, l) for p, l in flat])


def cache_spec_tree(cfg, shape, axes: Axes, cache_tree,
                    kv_layout: str = "dh"):
    """KV/SSM cache shardings. Batch over dp when divisible, else SP over
    the sequence axis; kv-heads over tp when divisible, otherwise either the
    head_dim ("dh", default) or the sequence ("seq") carries the model axis
    — a §Perf lever: dh-sharding psums the full scores row per layer, seq-
    sharding psums only softmax stats + values (distributed flash-decode)."""
    dp = axes.dp if axes.dp else None
    tp = axes.tp
    dp_size = 1
    if axes.mesh is not None:
        for a in (axes.dp or ()):
            dp_size *= axes.mesh.shape[a]
    batch_shardable = shape.global_batch % max(dp_size, 1) == 0 and \
        shape.global_batch >= dp_size

    def one(path, leaf):
        name = _path_names(path)[-1]
        rank = len(leaf.shape)
        if name in ("k", "v", "shared_k", "shared_v"):
            # [L_or_G, B, S, Hkv, Dh]. KV memory is the decode-cell budget
            # driver (qwen1.5 decode_32k: 2.7 TB global) — when kv-heads
            # don't divide TP, shard head_dim instead (always 128·k): the
            # per-step scatter stays shard-local (sequence-dim sharding made
            # the scatter cross-shard → cache-sized partitioner temps) and
            # the scores contraction psums a small [B,H,S] partial.
            tkv = axes.tp_if_divisible(cfg.n_kv_heads)
            tdh = axes.tp_if_divisible(cfg.resolved_head_dim)
            if batch_shardable:
                if tkv:
                    return P(None, dp, None, tkv, None)
                if kv_layout == "seq":
                    return P(None, dp, tp, None, None)
                return P(None, dp, None, None, tdh)
            return P(None, None, axes.sp, tkv,
                     None if tkv else tdh)            # sequence parallel
        if name == "h":                                # [L, B, H, P, N]
            th = axes.tp_if_divisible(cfg.n_ssm_heads)
            if batch_shardable:
                return P(None, dp, th, None, None)
            return P(None, None, th, None, None)
        if name == "conv":                             # [L, B, W-1, ch]
            if batch_shardable:
                return P(None, dp, None, None)
            return P(*([None] * rank))
        if name == "pos":
            return P(dp) if batch_shardable else P(None)
        return P(*([None] * rank))

    flat, treedef = tree_flatten_with_path(cache_tree)
    return treedef.unflatten([one(p, l) for p, l in flat])


# ---------------------------------------------------------------------------
# Cell assembly
# ---------------------------------------------------------------------------

def run_config_for(shape: ShapeSpec, overrides: Optional[dict] = None
                   ) -> RunConfig:
    # scan_layers=False: accurate per-layer cost/collective accounting in the
    # dry-run (HloCostAnalysis counts while-loop bodies once — see RunConfig).
    base = dict(compute_dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16,
                scan_layers=False)
    if shape.kind == "train":
        base.update(remat="full", attn_mode="chunked", attn_chunk=2048)
    elif shape.kind == "prefill":
        base.update(remat="none", attn_mode="chunked", attn_chunk=1024)
    else:
        base.update(remat="none", attn_mode="dense")
    base.update(overrides or {})
    return RunConfig(**base)


def _maybe_fp8_cache(cfg, shape, axes: Axes, run: RunConfig) -> RunConfig:
    """fp8 KV cache when bf16 would blow the per-chip HBM budget
    (qwen1.5-32b decode_32k: 5.5 TB global KV in bf16 > 4 TB fleet HBM)."""
    if not cfg.n_heads:
        return run
    n_chips = 1 if axes.mesh is None else axes.mesh.devices.size
    n_attn = cfg.n_layers if cfg.family != "hybrid" \
        else cfg.n_layers // cfg.attn_every
    kv_bytes = (2 * n_attn * shape.global_batch * shape.seq_len
                * cfg.n_kv_heads * cfg.resolved_head_dim * 2) / n_chips
    if kv_bytes > 8e9:
        return dataclasses.replace(run, cache_dtype=jnp.float8_e4m3fn)
    return run


@dataclasses.dataclass
class Cell:
    """A lowered/compilable (arch × shape × mesh) unit."""
    fn: Any                    # jitted function
    args: tuple                # ShapeDtypeStructs
    description: str


def _sds_tree(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def build_cell(cfg: ArchConfig, shape: ShapeSpec, axes: Axes,
               run_overrides: Optional[dict] = None,
               tcfg: Optional[TrainConfig] = None,
               serve_param_mode: str = "train",
               kv_layout: str = "dh") -> Cell:
    """serve_param_mode: "train" (2-D fsdp×tp — fits everything, gathers
    weights per step) or "serve" (TP-only — no gathers; §Perf lever for
    models whose TP-sharded bf16 params fit beside the KV cache)."""
    run = run_config_for(shape, run_overrides)
    mesh = axes.mesh
    ns = lambda spec: NamedSharding(mesh, spec) if mesh is not None else None

    if shape.kind == "train":
        tcfg = tcfg or TrainConfig(opt=OptConfig())
        params_sds = jax.eval_shape(
            lambda: init_lm(cfg, jax.random.PRNGKey(0)))
        state_sds = jax.eval_shape(
            lambda: init_train_state(cfg, init_lm(cfg, jax.random.PRNGKey(0)),
                                     tcfg))
        state_spec = tree_specs(state_sds, axes, "train")
        batch_sds = input_specs(cfg, shape, run)
        batch_spec = batch_spec_tree(cfg, shape, axes)
        step = make_train_step(cfg, run, tcfg, axes)
        metric_names = ["ce", "aux", "loss", "grad_norm", "lr"]
        out_spec = (state_spec, {k: P() for k in metric_names})
        fn = jax.jit(
            step,
            in_shardings=(jax.tree.map(ns, state_spec,
                                       is_leaf=lambda x: isinstance(x, P)),
                          jax.tree.map(ns, batch_spec,
                                       is_leaf=lambda x: isinstance(x, P))),
            out_shardings=jax.tree.map(ns, out_spec,
                                       is_leaf=lambda x: isinstance(x, P)),
            donate_argnums=(0,),
        )
        return Cell(fn, (state_sds, batch_sds),
                    f"train_step {cfg.name} {shape.name}")

    # Serving cells use bf16 params. Baseline sharding is 2-D (fsdp × tp),
    # same as training: the 32B-class archs do not fit TP-only next to a
    # 32k-context KV cache (qwen1.5: 4 GB params + 10 GB KV per chip).
    # TP-only ("serve" mode) is the no-per-step-gather variant used by the
    # §Perf hillclimb where memory allows.
    params_sds = jax.eval_shape(lambda: init_lm(cfg, jax.random.PRNGKey(0)))
    params_sds = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            l.shape, jnp.bfloat16 if l.dtype == jnp.float32 else l.dtype),
        params_sds)
    param_spec = tree_specs(params_sds, axes, serve_param_mode)
    param_sh = jax.tree.map(ns, param_spec, is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "prefill":
        batch_sds = input_specs(cfg, shape, run)
        batch_spec = batch_spec_tree(cfg, shape, axes)
        max_len = shape.seq_len
        cache_sds = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, max_len, run))
        cache_spec = cache_spec_tree(cfg, shape, axes, cache_sds, kv_layout)
        dp = axes.dp if axes.dp else None

        def prefill_step(params, batch):
            return prefill(cfg, params, batch, max_len, axes, run)

        fn = jax.jit(
            prefill_step,
            in_shardings=(param_sh,
                          jax.tree.map(ns, batch_spec,
                                       is_leaf=lambda x: isinstance(x, P))),
            out_shardings=(ns(P(dp, None)),
                           jax.tree.map(ns, cache_spec,
                                        is_leaf=lambda x: isinstance(x, P))),
        )
        return Cell(fn, (params_sds, batch_sds),
                    f"prefill_step {cfg.name} {shape.name}")

    # decode
    run = _maybe_fp8_cache(cfg, shape, axes, run)
    cache_sds = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len, run))
    cache_spec = cache_spec_tree(cfg, shape, axes, cache_sds, kv_layout)
    cache_sh = jax.tree.map(ns, cache_spec, is_leaf=lambda x: isinstance(x, P))
    tok_sds = input_specs(cfg, shape, run)["tokens"]
    dp = axes.dp if axes.dp else None
    dp_size = 1
    if axes.mesh is not None:
        for a in (axes.dp or ()):
            dp_size *= axes.mesh.shape[a]
    tok_spec = P(dp) if shape.global_batch % max(dp_size, 1) == 0 and \
        shape.global_batch >= dp_size else P(None)

    def serve_step(params, tokens, cache):
        logits, cache = decode_step(cfg, params, tokens, cache, axes, run)
        return jnp.argmax(logits, -1).astype(jnp.int32), cache

    fn = jax.jit(
        serve_step,
        in_shardings=(param_sh, ns(tok_spec), cache_sh),
        out_shardings=(ns(tok_spec), cache_sh),
        donate_argnums=(2,),
    )
    return Cell(fn, (params_sds, tok_sds, cache_sds),
                f"serve_step {cfg.name} {shape.name}")
