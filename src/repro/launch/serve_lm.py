"""LM serving driver: batched prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve_lm --arch mamba2-780m \
        --preset reduced --batch 4 --prompt-len 32 --gen 16

(Moved from ``repro.launch.serve``; the unqualified name now belongs to
the TSA serving tier — ``python -m repro.serve``.)
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import RunConfig, init_lm, prefill
from repro.train import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--preset", default="reduced", choices=["reduced", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.preset == "reduced":
        cfg = cfg.reduced()
    run = RunConfig(remat="none")
    key = jax.random.PRNGKey(args.seed)
    params = init_lm(cfg, key)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    max_len = args.prompt_len + args.gen + 1

    t0 = time.perf_counter()
    logits, cache = prefill(cfg, params, {"tokens": prompts}, max_len,
                            run=run)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    serve = jax.jit(make_serve_step(cfg, run, sample=args.sample))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        rng = jax.random.fold_in(key, i) if args.sample else None
        tok, _, cache = serve(params, tok, cache, rng)
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    gen = jnp.stack(outs, 1)
    print(json.dumps({
        "arch": cfg.name, "batch": args.batch,
        "prefill_ms": round(t_prefill * 1e3, 1),
        "decode_ms_per_token": round(t_decode * 1e3 / max(args.gen - 1, 1), 2),
        "tokens_per_s": round(args.batch * (args.gen - 1) / t_decode, 1),
        "sample_output": [int(x) for x in gen[0][:8]],
    }))


if __name__ == "__main__":
    main()
