"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — dryrun.py must set
``--xla_force_host_platform_device_count`` before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips; "pod" is the pure-DP
    cross-pod axis (lowest bandwidth → hierarchical gradient reduction)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / examples), e.g. ((1, 2), ("data", "model"))."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def get_mesh(shape=None, axis_names=None, *, devices=None):
    """(dp, mp) scaling mesh for the sharded sDTW engine — the redco-style
    builder from ``repro.distributed.sharding`` (int / tuple / -1-wildcard
    shapes), re-exported here next to the production LM meshes."""
    from repro.distributed.sharding import get_mesh as _get_mesh
    return _get_mesh(shape, axis_names, devices=devices)
