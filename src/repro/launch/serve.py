"""Deprecated alias — the LM decode driver moved to
``repro.launch.serve_lm``; the sDTW serving tier is ``repro.serve``
(``python -m repro.serve``)."""
from __future__ import annotations

import warnings

from repro.launch.serve_lm import main

__all__ = ["main"]

warnings.warn(
    "repro.launch.serve moved to repro.launch.serve_lm (LM decode "
    "driver); for time-series serving use `python -m repro.serve`",
    DeprecationWarning, stacklevel=2)

if __name__ == "__main__":
    main()
