"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --preset reduced --steps 50 --mesh 1x1 --data tsa --ckpt /tmp/ck

On the container this runs reduced configs on the single CPU device; on a
fleet the same entrypoint runs the full config on the production mesh (the
mesh is just a flag). Fault tolerance (checkpoint/restart, stragglers) comes
from repro.ft.TrainingRunner; the data pipeline is deterministic and
shard-aware so restarts resume exactly.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax

from repro.configs import get_arch
from repro.data import DataConfig, SyntheticLM, TSAFilteredLM
from repro.distributed import Axes
from repro.ft import FailureInjector, RunnerConfig, TrainingRunner
from repro.launch.mesh import make_mesh
from repro.launch.specs import tree_shardings
from repro.models import RunConfig, init_lm
from repro.optim import OptConfig
from repro.train import TrainConfig, init_train_state, make_train_step


def build(arch: str, preset: str, mesh_spec: str, *, seq_len: int,
          global_batch: int, lr: float, steps: int, microbatches: int,
          compression: str | None, data_kind: str, seed: int):
    cfg = get_arch(arch)
    if preset == "reduced":
        cfg = cfg.reduced()
    mesh = None
    if mesh_spec and mesh_spec != "1x1":
        dims = tuple(int(x) for x in mesh_spec.split("x"))
        names = ("pod", "data", "model")[-len(dims):]
        mesh = make_mesh(dims, names)
    axes = Axes.from_mesh(mesh)
    run = RunConfig(remat="none" if preset == "reduced" else "full",
                    attn_mode="dense" if seq_len <= 2048 else "chunked")
    tcfg = TrainConfig(
        opt=OptConfig(lr=lr, warmup_steps=max(2, steps // 20),
                      total_steps=steps),
        microbatches=microbatches,
        grad_compression=compression)
    dcfg = DataConfig(seed=seed, seq_len=seq_len, global_batch=global_batch,
                      vocab=cfg.vocab,
                      embeddings_dim=cfg.d_model if cfg.frontend == "stub"
                      else 0)
    data = (TSAFilteredLM(dcfg) if data_kind == "tsa" else SyntheticLM(dcfg))

    params = init_lm(cfg, jax.random.PRNGKey(seed))
    state = init_train_state(cfg, params, tcfg)
    if mesh is not None:
        shardings = tree_shardings(
            jax.eval_shape(lambda: state), axes, "train")
        state = jax.tree.map(jax.device_put, state, shardings)
    step = jax.jit(make_train_step(cfg, run, tcfg, axes))
    return cfg, data, state, step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--preset", default="reduced",
                    choices=["reduced", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="1x1", help="e.g. 4x2 or 2x16x16")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default=None,
                    choices=[None, "int8_ef"])
    ap.add_argument("--data", default="synthetic",
                    choices=["synthetic", "tsa"])
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject failures at these steps (FT demo)")
    args = ap.parse_args()

    cfg, data, state, step = build(
        args.arch, args.preset, args.mesh, seq_len=args.seq_len,
        global_batch=args.global_batch, lr=args.lr, steps=args.steps,
        microbatches=args.microbatches, compression=args.compression,
        data_kind=args.data, seed=args.seed)

    runner = TrainingRunner(
        step, data, state, args.ckpt,
        RunnerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every),
        injector=FailureInjector(tuple(args.fail_at)) if args.fail_at
        else None)
    out = runner.run()
    first, last = out["metrics"][0], out["metrics"][-1]
    print(json.dumps({
        "arch": cfg.name, "steps": len(out["metrics"]),
        "restarts": out["restarts"], "stragglers": out["stragglers"],
        "first_loss": round(first["loss"], 4),
        "last_loss": round(last["loss"], 4),
    }))


if __name__ == "__main__":
    main()
