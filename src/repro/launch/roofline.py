"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Accounting conventions (important — recorded with every artifact):
  * ``compiled.cost_analysis()`` runs on the *partitioned* (per-device)
    module → flops / bytes are per-chip. The compute term is therefore
    flops / peak_flops (the "chips ×" in the global formula cancels).
  * collective bytes are summed from result shapes of every
    all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute in ``compiled.as_text()`` (per-device program →
    per-chip bytes on the wire); term = bytes / link_bw. ``-done`` halves of
    async pairs are skipped to avoid double counting.
  * MODEL_FLOPS is the analytic useful-work estimate (6·N·D dense training /
    2·N_active·D forward + exact-causal attention + SSD terms); the ratio
    MODEL_FLOPS / (chips · HLO_FLOPs) exposes remat/padding/masked-half
    waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

V5E = dict(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^=]*?\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-chip bytes moved by each collective type (result-shape proxy)."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = {k: 0 for k in out}
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, op, phase = m.groups()
        if phase == "-done":
            continue
        out[op] += _shape_bytes(shape_txt)
        counts[op] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    coll_bytes_per_chip: float
    n_chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops_per_chip * self.n_chips
        return self.model_flops / total if total else float("nan")

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful flops / (chips · peak · bound_time)."""
        denom = self.n_chips * V5E["peak_flops"] * self.bound_time_s
        return self.model_flops / denom if denom else float("nan")

    def to_dict(self):
        return {**dataclasses.asdict(self),
                "dominant": self.dominant,
                "bound_time_s": self.bound_time_s,
                "useful_flops_ratio": self.useful_flops_ratio,
                "roofline_fraction": self.roofline_fraction}


def roofline(flops_per_chip: float, bytes_per_chip: float,
             coll_bytes_per_chip: float, model_flops: float,
             n_chips: int, hw=None) -> RooflineTerms:
    hw = hw or V5E
    return RooflineTerms(
        compute_s=flops_per_chip / hw["peak_flops"],
        memory_s=bytes_per_chip / hw["hbm_bw"],
        collective_s=coll_bytes_per_chip / hw["ici_bw"],
        model_flops=model_flops,
        hlo_flops_per_chip=flops_per_chip,
        hlo_bytes_per_chip=bytes_per_chip,
        coll_bytes_per_chip=coll_bytes_per_chip,
        n_chips=n_chips)


def kernel_roofline(cells: float, hbm_bytes: float, *,
                    cells_per_s: float, hbm_bw: Optional[float] = None):
    """Two-term roofline bound for one sDTW kernel configuration.

    Unlike :func:`roofline` (which extracts terms from compiled HLO), this
    prices an *analytic* configuration before anything is compiled — the
    autotuner (``repro.tune.cost``) calls it per candidate: ``cells`` DP
    cells at the backend's sustained ``cells_per_s`` versus ``hbm_bytes``
    of streaming traffic at ``hbm_bw``. Returns
    ``(bound_time_s, dominant)`` where dominant is 'compute' or 'memory'.
    """
    hbm_bw = V5E["hbm_bw"] if hbm_bw is None else hbm_bw
    compute_s = cells / cells_per_s if cells_per_s else 0.0
    memory_s = hbm_bytes / hbm_bw if hbm_bw else 0.0
    return (max(compute_s, memory_s),
            "compute" if compute_s >= memory_s else "memory")


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS per cell
# ---------------------------------------------------------------------------

def model_flops(cfg, shape) -> float:
    """Useful-work FLOPs for one step of this cell (whole mesh)."""
    b, s = shape.global_batch, shape.seq_len
    v, d = cfg.vocab, cfg.d_model
    n_active = cfg.active_param_count()
    # Embedding lookups are gather (0 flops); logits matmul is real.
    n_mm = n_active - (0 if cfg.tie_embeddings else v * d)

    n_attn = 0
    if cfg.n_heads:
        n_attn = (cfg.n_layers if cfg.family != "hybrid"
                  else cfg.n_layers // cfg.attn_every)
    hd = cfg.resolved_head_dim
    attn_fwd_per_tok = 2 * (s / 2) * cfg.n_heads * hd * 2 * n_attn \
        if shape.kind != "decode" else 0   # exact causal: S/2 avg context

    ssd_fwd_per_tok = 0.0
    if cfg.has_ssm:
        L, n_state, di = cfg.ssm_chunk, cfg.ssm_state, cfg.d_inner
        # G=CBᵀ, scores·X, state-in, y_inter per layer
        ssd_fwd_per_tok = (2 * L * n_state + 2 * L * di
                           + 4 * n_state * di) * cfg.n_layers

    if shape.kind == "train":
        tokens = b * s
        return (6 * n_mm + 3 * (attn_fwd_per_tok + ssd_fwd_per_tok)) * tokens
    if shape.kind == "prefill":
        tokens = b * s
        return (2 * n_mm + attn_fwd_per_tok + ssd_fwd_per_tok) * tokens
    # decode: context-length attention + recurrent SSD update
    attn_dec = 4 * s * cfg.n_heads * hd * n_attn if cfg.n_heads else 0
    ssd_dec = 6 * cfg.d_inner * cfg.ssm_state * cfg.n_layers \
        if cfg.has_ssm else 0
    return (2 * n_mm + attn_dec + ssd_dec) * b
