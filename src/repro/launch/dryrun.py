# The dry-run needs 512 placeholder host devices BEFORE any jax init —
# these two lines must stay the very first statements of this module.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

# Multi-pod dry-run: .lower().compile() every (arch × shape × mesh) cell on
# the production mesh and record memory/cost/collective analysis for the
# roofline (EXPERIMENTS.md §Dry-run / §Roofline). CPU devices stand in for
# TPU chips; compilation exercises the full SPMD partitioner, so sharding
# mismatches / OOMs / unsupported collectives fail HERE, not on the fleet.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
#       --shape train_4k [--multi-pod] [--out experiments/dryrun]
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import SHAPES, all_archs, get_arch
from repro.distributed import Axes
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import collective_bytes, model_flops, roofline
from repro.launch.specs import build_cell


def _memory_record(compiled) -> dict:
    try:
        mem = compiled.memory_analysis()
        return {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        }
    except Exception as e:  # unsupported on some backends
        return {"error": str(e)}


def _acct_extrapolate(cfg, shape, axes, overrides, serve_param_mode,
                      kv_layout, mesh):
    """Two-point extrapolated accounting for expensive cells.

    Compile the model UNROLLED at `unit` and `2·unit` layers (unit = one
    hybrid group, else one layer); with U_a = out + body and
    U_b = out + 2·body, the full-depth totals are
    total = (2−s)·U_a·… i.e. out + s·body, body = U_b − U_a, out = 2U_a − U_b,
    where s = n_layers/unit. Applies to flops, bytes-accessed, and per-type
    collective bytes. Exact up to XLA fusing "out" slightly differently
    between the two compiles; records carry accounting="extrapolated".
    """
    unit = cfg.attn_every if cfg.family == "hybrid" else 1
    scale = cfg.n_layers // unit

    def one(n_layers):
        c2 = dataclasses.replace(cfg, n_layers=n_layers)
        with mesh:
            cell = build_cell(c2, shape, axes, overrides,
                              serve_param_mode=serve_param_mode,
                              kv_layout=kv_layout)
            compiled = cell.fn.lower(*cell.args).compile()
        cost = compiled.cost_analysis() or {}
        coll = collective_bytes(compiled.as_text())
        return (float(cost.get("flops", 0.0)),
                float(cost.get("bytes accessed", 0.0)), coll)

    fa, ba, ca = one(unit)
    fb, bb, cb = one(2 * unit)

    def extra(a, b):
        return max(0.0, (2 - scale) * a + (scale - 1) * b)

    flops = extra(fa, fb)
    bytes_acc = extra(ba, bb)
    coll = {"bytes": {k: int(extra(ca["bytes"][k], cb["bytes"][k]))
                      for k in ca["bytes"]},
            "counts": {k: int(extra(ca["counts"][k], cb["counts"][k]))
                       for k in ca["counts"]}}
    coll["total_bytes"] = sum(coll["bytes"].values())
    return flops, bytes_acc, coll


def dry_run_cell(arch_name: str, shape_name: str, multi_pod: bool,
                 run_overrides=None, mesh=None, save_hlo: str = None,
                 serve_param_mode: str = "train",
                 kv_layout: str = "dh", acct: str = "unrolled",
                 microbatches: int = 1) -> dict:
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    rec = {"arch": arch_name, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "status": "skipped", "reason": None}
    if shape_name == "long_500k" and not cfg.supports_long_context:
        rec["reason"] = ("full-attention arch: no sub-quadratic path at 500k "
                        "context (DESIGN.md §Arch-applicability)")
        return rec
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    axes = Axes.from_mesh(mesh)
    n_chips = mesh.devices.size
    # The roofline table reads single-pod artifacts only; the multi-pod pass
    # proves the "pod" axis shards — scanned layers keep its compiles cheap
    # (accounting there is not consumed).
    if multi_pod and (run_overrides is None
                      or "scan_layers" not in run_overrides):
        run_overrides = dict(run_overrides or {}, scan_layers=True)
    tcfg = None
    if microbatches > 1:
        from repro.train import TrainConfig
        tcfg = TrainConfig(microbatches=microbatches)
        rec["microbatches"] = microbatches
    try:
        t0 = time.time()
        # Accounting pass: loop-free attention ("dense") so HloCostAnalysis
        # sees every flop — the chunked production path hides its KV-block
        # loop body behind a while (counted once). Dense computes the same
        # full S² rectangle as chunked, so the count matches the production
        # baseline's true flops (incl. the masked-half waste).
        acct_overrides = dict(run_overrides or {})
        acct_overrides.setdefault("attn_mode", "dense")
        hlo = None
        if acct == "extrapolated" and not acct_overrides.get("scan_layers"):
            flops, bytes_acc, coll = _acct_extrapolate(
                cfg, shape, axes, acct_overrides, serve_param_mode,
                kv_layout, mesh)
            mem_rec = {"note": "from memory_analysis_scanned"}
            description = f"{shape.kind}_step {cfg.name} {shape.name}"
            mem_scanned = None
        else:
            with mesh:
                cell = build_cell(cfg, shape, axes, acct_overrides,
                                  tcfg=tcfg,
                                  serve_param_mode=serve_param_mode,
                                  kv_layout=kv_layout)
                lowered = cell.fn.lower(*cell.args)
                compiled = lowered.compile()
            cost = compiled.cost_analysis() or {}
            flops = float(cost.get("flops", 0.0))
            bytes_acc = float(cost.get("bytes accessed", 0.0))
            mem_rec = _memory_record(compiled)
            hlo = compiled.as_text()
            coll = collective_bytes(hlo)
            description = cell.description
            mem_scanned = dict(mem_rec) if \
                (run_overrides or {}).get("scan_layers") else None

        # Memory proof-of-fit uses the production (scanned-layers) form:
        # the unrolled variant stacks per-layer cache/activation temps that
        # scan+donation elide.
        if mem_scanned is None:
            scanned_overrides = dict(run_overrides or {}, scan_layers=True)
            with mesh:
                cell_s = build_cell(cfg, shape, axes, scanned_overrides,
                                    tcfg=tcfg,
                                    serve_param_mode=serve_param_mode,
                                    kv_layout=kv_layout)
                compiled_s = cell_s.fn.lower(*cell_s.args).compile()
            mem_scanned = _memory_record(compiled_s)
            if hlo is None:
                hlo = compiled_s.as_text()
        fits = None
        if isinstance(mem_scanned.get("temp_bytes"), int):
            live = (mem_scanned.get("argument_bytes", 0)
                    + mem_scanned.get("output_bytes", 0)
                    + mem_scanned.get("temp_bytes", 0)
                    - mem_scanned.get("alias_bytes", 0))
            fits = bool(live <= 16e9)
            mem_scanned["live_bytes"] = int(live)
            mem_scanned["fits_16gb_hbm"] = fits

        if save_hlo and hlo is not None:
            with open(save_hlo, "w") as f:
                f.write(hlo)
        mf = model_flops(cfg, shape)
        terms = roofline(flops, bytes_acc, coll["total_bytes"], mf, n_chips)
        rec.update(
            status="ok",
            accounting=acct,
            description=description,
            compile_s=round(time.time() - t0, 2),
            cost_analysis={"flops": flops, "bytes accessed": bytes_acc},
            memory_analysis=mem_rec,
            memory_analysis_scanned=mem_scanned,
            collectives=coll,
            roofline=terms.to_dict(),
            hlo_bytes=len(hlo) if hlo else 0,
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo")
    ap.add_argument("--attn-mode", default=None,
                    help="override attention mode (dense|chunked|triangular)")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--pad-heads", action="store_true")
    ap.add_argument("--serve-params", default="train",
                    choices=["train", "serve"],
                    help="decode/prefill param sharding: 2-D (train) or "
                         "TP-only (serve)")
    ap.add_argument("--kv-layout", default="dh", choices=["dh", "seq"],
                    help="model-axis placement for indivisible-kv caches")
    ap.add_argument("--acct", default="unrolled",
                    choices=["unrolled", "extrapolated"],
                    help="flop/collective accounting: full unrolled compile "
                         "or 2-point layer extrapolation (fast)")
    args = ap.parse_args()

    overrides = {}
    if args.attn_mode:
        overrides["attn_mode"] = args.attn_mode
    if args.remat:
        overrides["remat"] = args.remat
    if args.pad_heads:
        overrides["pad_heads"] = True

    cells = []
    if args.all:
        for a in all_archs():
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_err = n_skip = 0
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "2x16x16" if multi_pod else "16x16"
        for a, s in cells:
            rec = dry_run_cell(a, s, multi_pod, overrides or None, mesh=mesh,
                               save_hlo=args.save_hlo,
                               serve_param_mode=args.serve_params,
                               kv_layout=args.kv_layout, acct=args.acct,
                               microbatches=args.microbatches or 1)
            fn = os.path.join(args.out, f"{mesh_name}__{a}__{s}.json")
            with open(fn, "w") as f:
                json.dump(rec, f, indent=1)
            tag = rec["status"].upper()
            n_ok += tag == "OK"
            n_err += tag == "ERROR"
            n_skip += tag == "SKIPPED"
            extra = ""
            if rec["status"] == "ok":
                r = rec["roofline"]
                extra = (f"compile={rec['compile_s']}s "
                         f"dom={r['dominant']} "
                         f"terms(c/m/x)={r['compute_s']:.2e}/"
                         f"{r['memory_s']:.2e}/{r['collective_s']:.2e}s "
                         f"useful={r['useful_flops_ratio']:.2f}")
            elif rec["status"] == "error":
                extra = rec["error"][:160]
            print(f"[{tag:7s}] {mesh_name} {a:24s} {s:12s} {extra}",
                  flush=True)
    print(f"done: ok={n_ok} err={n_err} skipped={n_skip}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
