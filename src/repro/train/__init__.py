from .train_step import TrainConfig, init_train_state, make_eval_step, make_train_step
from .serve_step import generate, make_prefill_step, make_serve_step

__all__ = ["TrainConfig", "init_train_state", "make_train_step",
           "make_eval_step", "make_prefill_step", "make_serve_step",
           "generate"]
