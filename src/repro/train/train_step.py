"""Training step factory: remat + microbatch gradient accumulation +
optional int8 gradient compression, assembled for any (arch × mesh).

Compute/communication overlap: with ``microbatches > 1`` the per-microbatch
backward produces *local* (batch-sharded) gradient contributions that XLA
reduces lazily — the data-parallel all-reduce is only forced at the
accumulation boundary (one reduction per step, overlapped with the last
microbatch's compute by the scheduler). This is the standard accumulate-
then-reduce overlap; the dry-run HLO shows a single fused reduce per tensor.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.collectives import compress_with_feedback, init_feedback
from repro.models import loss_fn
from repro.optim import OptConfig, adamw_update, init_opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    microbatches: int = 1
    grad_compression: Optional[str] = None  # None | "int8_ef"


def init_train_state(cfg, params, tcfg: TrainConfig):
    state = {"params": params, "opt": init_opt(params)}
    if tcfg.grad_compression == "int8_ef":
        state["feedback"] = init_feedback(params)
    return state


def make_train_step(cfg, run, tcfg: TrainConfig, axes=None):
    """Returns train_step(state, batch) → (state, metrics). jit-ready."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, axes, run), has_aux=True)(params)
        metrics = dict(metrics, loss=loss)
        return grads, metrics

    def accumulate(params, batch):
        k = tcfg.microbatches
        if k == 1:
            return grads_of(params, batch)
        split = jax.tree.map(
            lambda a: a.reshape(k, a.shape[0] // k, *a.shape[1:]), batch)

        def mb_step(carry, mb):
            acc, met = carry
            g, m = grads_of(params, mb)
            acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), acc, g)
            met = jax.tree.map(lambda a, b: a + b, met, m)
            return (acc, met), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        met0 = {"ce": jnp.float32(0), "aux": jnp.float32(0),
                "loss": jnp.float32(0)}
        (grads, metrics), _ = lax.scan(mb_step, (zeros, met0), split)
        grads = jax.tree.map(lambda g: g / k, grads)
        metrics = jax.tree.map(lambda m: m / k, metrics)
        return grads, metrics

    def train_step(state, batch):
        grads, metrics = accumulate(state["params"], batch)
        if tcfg.grad_compression == "int8_ef":
            grads, new_fb = compress_with_feedback(grads, state["feedback"])
        params, opt, stats = adamw_update(
            tcfg.opt, state["params"], grads, state["opt"])
        new_state = {"params": params, "opt": opt}
        if tcfg.grad_compression == "int8_ef":
            new_state["feedback"] = new_fb
        metrics = dict(metrics, **stats)
        return new_state, metrics

    return train_step


def make_eval_step(cfg, run, axes=None):
    def eval_step(params, batch):
        loss, metrics = loss_fn(cfg, params, batch, axes, run)
        return dict(metrics, loss=loss)
    return eval_step
