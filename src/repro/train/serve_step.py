"""Serving steps: batched prefill + single-token decode (greedy/sampled).

``serve_step`` is the unit the decode-shape dry-runs lower: one new token
against a KV/SSM cache of the full context length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import decode_step, prefill


def make_prefill_step(cfg, run, max_len: int, axes=None):
    def prefill_step(params, batch):
        return prefill(cfg, params, batch, max_len, axes, run)
    return prefill_step


def make_serve_step(cfg, run, axes=None, sample: bool = False,
                    temperature: float = 1.0):
    def serve_step(params, tokens, cache, rng=None):
        logits, cache = decode_step(cfg, params, tokens, cache, axes, run)
        if sample:
            next_tok = jax.random.categorical(rng, logits / temperature, -1)
        else:
            next_tok = jnp.argmax(logits, axis=-1)
        return next_tok.astype(jnp.int32), logits, cache
    return serve_step


def generate(cfg, params, prompt_tokens, n_steps: int, run, axes=None,
             max_len: int = None, rng=None, sample: bool = False):
    """Greedy/sampled generation loop (host-driven; used by examples)."""
    b, s = prompt_tokens.shape
    max_len = max_len or (s + n_steps)
    logits, cache = prefill(cfg, params, {"tokens": prompt_tokens}, max_len,
                            axes, run)
    serve = make_serve_step(cfg, run, axes, sample)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    for i in range(n_steps - 1):
        step_rng = None if rng is None else jax.random.fold_in(rng, i)
        tok, _, cache = serve(params, tok, cache, step_rng)
        out.append(tok)
    return jnp.stack(out, axis=1)
