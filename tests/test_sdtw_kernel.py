"""Pallas sDTW kernel: interpret-mode allclose sweeps vs the test oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis dev dependency")
from hypothesis import given, settings, strategies as st

from oracle import sdtw_ref

from repro.kernels.sdtw import sdtw_pallas

SHAPES = [
    # (B, N, M, block_q, block_m) — covers single/multi tile, odd sizes,
    # padding in both grid dimensions.
    (1, 1, 1, 1, 8),
    (3, 5, 17, 2, 8),
    (4, 9, 70, 2, 16),
    (5, 12, 257, 4, 64),
    (8, 33, 1030, 8, 256),
]


@pytest.mark.parametrize("b,n,m,bq,bm", SHAPES)
@pytest.mark.parametrize("metric", ["abs_diff", "square_diff"])
@pytest.mark.parametrize("dtype", [np.int32, np.float32])
def test_kernel_shape_dtype_sweep(b, n, m, bq, bm, metric, dtype, rng):
    q = rng.integers(-40, 40, (b, n)).astype(dtype)
    r = rng.integers(-40, 40, m).astype(dtype)
    got = np.asarray(sdtw_pallas(jnp.asarray(q), jnp.asarray(r),
                                 metric=metric, block_q=bq, block_m=bm))
    want = np.array([sdtw_ref(q[i], r, metric) for i in range(b)])
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_kernel_bf16_inputs(rng):
    q = rng.integers(-8, 8, (2, 6)).astype(np.float32)
    r = rng.integers(-8, 8, 40).astype(np.float32)
    got = np.asarray(sdtw_pallas(jnp.asarray(q, jnp.bfloat16),
                                 jnp.asarray(r, jnp.bfloat16),
                                 block_q=2, block_m=16))
    want = np.array([sdtw_ref(q[i], r) for i in range(2)])
    np.testing.assert_allclose(got, want, rtol=2e-2)


def test_kernel_variable_qlens(rng):
    q = rng.integers(-40, 40, (6, 12)).astype(np.int32)
    r = rng.integers(-40, 40, 53).astype(np.int32)
    qlens = np.array([12, 1, 5, 7, 3, 9], np.int32)
    got = np.asarray(sdtw_pallas(jnp.asarray(q), jnp.asarray(r),
                                 jnp.asarray(qlens), block_q=2, block_m=16))
    want = np.array([sdtw_ref(q[i, :qlens[i]], r) for i in range(6)])
    np.testing.assert_allclose(got, want)


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 5), st.integers(1, 9), st.integers(1, 40),
       st.integers(0, 1000))
def test_hyp_kernel_matches_oracle(b, n, m, seed):
    rng = np.random.default_rng(seed)
    q = rng.integers(-30, 30, (b, n)).astype(np.int32)
    r = rng.integers(-30, 30, m).astype(np.int32)
    got = np.asarray(sdtw_pallas(jnp.asarray(q), jnp.asarray(r),
                                 block_q=2, block_m=8))
    want = np.array([sdtw_ref(q[i], r) for i in range(b)])
    np.testing.assert_allclose(got, want)


def test_kernel_block_shape_invariance(rng):
    """Tiling must not change the result (boundary-carry correctness)."""
    q = rng.integers(-40, 40, (4, 10)).astype(np.int32)
    r = rng.integers(-40, 40, 96).astype(np.int32)
    outs = [np.asarray(sdtw_pallas(jnp.asarray(q), jnp.asarray(r),
                                   block_q=bq, block_m=bm))
            for bq, bm in [(1, 8), (2, 16), (4, 32), (4, 96), (2, 128)]]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)
