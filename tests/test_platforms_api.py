"""Seed-module coverage: ``repro.core.platforms`` and
``repro.core.matsa_api`` — the two modules that shipped with zero tests.

The platform models are analytic (cells/s + watts), so their sanity
properties are sharp: strictly positive costs, exact linearity in every
workload dimension, utilization inside (0, 1]. The matsa() host API is
checked against the engine it routes through and the numpy oracle.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (PLATFORMS, Workload, load_real_workload_shapes,
                        matsa, sdtw, synthetic_timeseries)
from repro.core.platforms import PlatformModel
from repro.core.sdtw_ref import sdtw_ref

W0 = Workload(ref_size=10_000, query_size=100, num_queries=64)


# ---------------------------------------------------------------------------
# PlatformModel sanity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(PLATFORMS))
def test_platform_costs_positive_and_consistent(name):
    p = PLATFORMS[name]
    t = p.exec_time_s(W0)
    e = p.energy_j(W0)
    assert t > 0 and e > 0
    assert np.isclose(e, t * p.watts)
    cells = W0.num_queries * W0.query_size * W0.ref_size
    assert np.isclose(p.energy_per_cell_j() * cells, e)


@pytest.mark.parametrize("name", sorted(PLATFORMS))
def test_platform_utilization_sane(name):
    """Sustained throughput stays at or below peak — every baseline is a
    real machine under its roofline (§II-D). UPMEM is modeled
    compute-bound *at* its DPU peak, so its rounded constants land at
    ~1.06 rather than exactly 1; everyone else sits far below."""
    u = PLATFORMS[name].utilization()
    if name == "upmem":
        assert 0.9 < u < 1.1, u
    else:
        assert 0 < u <= 0.1, (name, u)


@pytest.mark.parametrize("name", sorted(PLATFORMS))
@pytest.mark.parametrize("dim", ["ref_size", "query_size", "num_queries"])
def test_platform_monotone_in_workload(name, dim):
    """Cost is (exactly) linear in each workload dimension — doubling
    work doubles time and energy, and never less."""
    import dataclasses
    p = PLATFORMS[name]
    w2 = dataclasses.replace(W0, **{dim: getattr(W0, dim) * 2})
    assert p.exec_time_s(w2) >= p.exec_time_s(W0)
    assert np.isclose(p.exec_time_s(w2), 2 * p.exec_time_s(W0))
    assert np.isclose(p.energy_j(w2), 2 * p.energy_j(W0))


def test_upmem_energy_beats_gpu():
    """The calibration constraint baked into platforms.py: UPMEM energy =
    0.63x GPU (§II-D's measured 37% reduction)."""
    ratio = (PLATFORMS["upmem"].energy_per_cell_j()
             / PLATFORMS["gpu"].energy_per_cell_j())
    assert abs(ratio - 0.63) < 0.02


# ---------------------------------------------------------------------------
# matsa() host API
# ---------------------------------------------------------------------------

def test_matsa_query_filtering_matches_engine(rng):
    q = rng.integers(-40, 40, (4, 8)).astype(np.int32)
    r = rng.integers(-40, 40, 64).astype(np.int32)
    res = matsa(r, q)
    want = sdtw(jnp.asarray(q), jnp.asarray(r))
    np.testing.assert_array_equal(np.asarray(res.distances),
                                  np.asarray(want))
    assert res.anomalies is None and res.window_starts is None


def test_matsa_ragged_query_sizes_match_oracle(rng):
    q = rng.integers(-20, 20, (3, 10)).astype(np.int32)
    sizes = np.asarray([4, 10, 7])
    r = rng.integers(-20, 20, 50).astype(np.int32)
    res = matsa(r, q, query_sizes=sizes)
    want = np.asarray([sdtw_ref(q[i, :sizes[i]], r) for i in range(3)])
    np.testing.assert_array_equal(np.asarray(res.distances), want)


def test_matsa_anomaly_threshold(rng):
    q = rng.integers(-40, 40, (6, 8)).astype(np.int32)
    r = rng.integers(-40, 40, 64).astype(np.int32)
    res = matsa(r, q, anomaly_threshold=0)
    d = np.asarray(res.distances)
    thr = int(np.median(d))
    res = matsa(r, q, anomaly_threshold=thr)
    np.testing.assert_array_equal(np.asarray(res.anomalies), d > thr)
    assert np.asarray(res.anomalies).dtype == bool


def test_matsa_self_join_exclusion(rng):
    r = rng.integers(-1000, 1000, 48).astype(np.int32)
    free = matsa(r, mode="self_join", window=8, stride=4, exclusion=False)
    # without the exclusion zone every window matches itself at cost 0
    np.testing.assert_array_equal(np.asarray(free.distances),
                                  np.zeros_like(free.distances))
    excl = matsa(r, mode="self_join", window=8, stride=4)
    assert free.distances.shape == excl.distances.shape
    assert np.all(np.asarray(excl.distances)
                  >= np.asarray(free.distances))
    np.testing.assert_array_equal(np.asarray(excl.window_starts),
                                  np.arange(0, 41, 4))


def test_matsa_argument_errors(rng):
    r = rng.integers(-5, 5, 32).astype(np.int32)
    with pytest.raises(ValueError, match="mode"):
        matsa(r, mode="nope")
    with pytest.raises(ValueError, match="window"):
        matsa(r, mode="self_join")
    with pytest.raises(ValueError, match="queries"):
        matsa(r, mode="query_filtering")


def test_load_real_workload_shapes_table5():
    shapes = load_real_workload_shapes()
    assert set(shapes) == {"Human", "Song", "Penguin", "Seismology",
                           "Power", "ECG"}
    ecg = shapes["ECG"]
    assert ecg["ref_size"] == 1_800_000 and ecg["query_size"] == 512
    for s in shapes.values():
        assert s["ref_size"] > 0 and s["query_size"] > 0
        assert s["num_queries"] > 0


def test_synthetic_timeseries_deterministic():
    a = synthetic_timeseries(np.random.default_rng(5), 512,
                             anomaly_rate=0.1)
    b = synthetic_timeseries(np.random.default_rng(5), 512,
                             anomaly_rate=0.1)
    np.testing.assert_array_equal(a, b)
    assert a.dtype == np.int32 and a.shape == (512,)
    f = synthetic_timeseries(np.random.default_rng(5), 64, dtype=np.float32)
    assert f.dtype == np.float32
