"""Golden span/distance fixture generator.

``compute()`` produces every fixture array from a fixed seed; running this
file writes them to ``sdtw_spans_v1.npz``. The committed ``.npz`` is
asserted *bitwise* in CI (``test_spans_paths.py::test_golden_spans_bitwise``)
so silent numeric drift across jax/XLA upgrades — the class of breakage
PR 1 repaired — fails loudly instead of shipping. Regenerate only when the
engine's semantics intentionally change, and say why in the commit.

Run:  PYTHONPATH=src python tests/golden/make_golden.py
"""
import pathlib

import numpy as np

SEED = 20260731
OUT = pathlib.Path(__file__).parent / "sdtw_spans_v1.npz"


def compute():
    import jax.numpy as jnp

    from repro.core import sdtw

    rng = np.random.default_rng(SEED)
    out = {}
    for dtype, tag in ((np.int32, "i32"), (np.float32, "f32")):
        q = rng.integers(-40, 40, (4, 10)).astype(dtype)
        r = rng.integers(-40, 40, 257).astype(dtype)
        out[f"{tag}_queries"] = q
        out[f"{tag}_reference"] = r
        for metric in ("abs_diff", "square_diff"):
            d, s, e = sdtw(jnp.asarray(q), jnp.asarray(r), metric=metric,
                           impl="chunked", chunk=32, return_spans=True)
            out[f"{tag}_{metric}_dists"] = np.asarray(d)
            out[f"{tag}_{metric}_starts"] = np.asarray(s)
            out[f"{tag}_{metric}_ends"] = np.asarray(e)
            dr, sr, er = sdtw(jnp.asarray(q), jnp.asarray(r), metric=metric,
                              impl="rowscan", return_spans=True)
            out[f"{tag}_{metric}_rowscan_dists"] = np.asarray(dr)
            out[f"{tag}_{metric}_rowscan_starts"] = np.asarray(sr)
            out[f"{tag}_{metric}_rowscan_ends"] = np.asarray(er)
        dk, sk, ek = sdtw(jnp.asarray(q), jnp.asarray(r), top_k=3,
                          excl_zone=5, return_spans=True)
        out[f"{tag}_topk_dists"] = np.asarray(dk)
        out[f"{tag}_topk_starts"] = np.asarray(sk)
        out[f"{tag}_topk_ends"] = np.asarray(ek)
    return out


if __name__ == "__main__":
    arrays = compute()
    np.savez(OUT, **arrays)
    print(f"wrote {OUT} ({len(arrays)} arrays)")
