"""Golden span/distance fixture generator.

``compute()`` produces every fixture array from a fixed seed; running this
file writes them to ``sdtw_spans_v1.npz``. ``compute_stream()`` does the
same for the streaming subsystem (``sdtw_stream_v1.npz``): distances,
spans, top-K heaps and pruned-stream heaps produced by feeding a fixed
partition through ``engine.stream``. The committed ``.npz`` files are
asserted *bitwise* in CI (``test_spans_paths.py::test_golden_spans_bitwise``,
``test_stream.py::test_golden_stream_bitwise``) so silent numeric drift
across jax/XLA upgrades — the class of breakage PR 1 repaired — fails
loudly instead of shipping. Regenerate only when the engine's semantics
intentionally change, and say why in the commit.

Run:  PYTHONPATH=src python tests/golden/make_golden.py
"""
import pathlib

import numpy as np

SEED = 20260731
OUT = pathlib.Path(__file__).parent / "sdtw_spans_v1.npz"
STREAM_OUT = pathlib.Path(__file__).parent / "sdtw_stream_v1.npz"

#: The fixed feed partition of the 257-sample golden reference — mixed
#: tiny/aligned/unaligned chunks so the fixture exercises the buffering.
STREAM_PARTS = (37, 1, 64, 100, 55)


def compute():
    import jax.numpy as jnp

    from repro.core import sdtw

    rng = np.random.default_rng(SEED)
    out = {}
    for dtype, tag in ((np.int32, "i32"), (np.float32, "f32")):
        q = rng.integers(-40, 40, (4, 10)).astype(dtype)
        r = rng.integers(-40, 40, 257).astype(dtype)
        out[f"{tag}_queries"] = q
        out[f"{tag}_reference"] = r
        for metric in ("abs_diff", "square_diff"):
            d, s, e = sdtw(jnp.asarray(q), jnp.asarray(r), metric=metric,
                           impl="chunked", chunk=32, return_spans=True)
            out[f"{tag}_{metric}_dists"] = np.asarray(d)
            out[f"{tag}_{metric}_starts"] = np.asarray(s)
            out[f"{tag}_{metric}_ends"] = np.asarray(e)
            dr, sr, er = sdtw(jnp.asarray(q), jnp.asarray(r), metric=metric,
                              impl="rowscan", return_spans=True)
            out[f"{tag}_{metric}_rowscan_dists"] = np.asarray(dr)
            out[f"{tag}_{metric}_rowscan_starts"] = np.asarray(sr)
            out[f"{tag}_{metric}_rowscan_ends"] = np.asarray(er)
        dk, sk, ek = sdtw(jnp.asarray(q), jnp.asarray(r), top_k=3,
                          excl_zone=5, return_spans=True)
        out[f"{tag}_topk_dists"] = np.asarray(dk)
        out[f"{tag}_topk_starts"] = np.asarray(sk)
        out[f"{tag}_topk_ends"] = np.asarray(ek)
    return out


def compute_stream():
    import jax.numpy as jnp

    from repro.core import stream
    from repro.core.sdtw import sdtw_chunked

    rng = np.random.default_rng(SEED)
    out = {}
    for dtype, tag in ((np.int32, "i32"), (np.float32, "f32")):
        q = rng.integers(-40, 40, (4, 10)).astype(dtype)
        r = rng.integers(-40, 40, 257).astype(dtype)
        out[f"{tag}_queries"] = q
        out[f"{tag}_reference"] = r

        def run(**kw):
            s = stream(q, chunk=32, **kw)
            off = 0
            for p in STREAM_PARTS:
                s.feed(r[off:off + p])
                off += p
            return s.results()

        res = run(return_spans=True)
        out[f"{tag}_dists"] = np.asarray(res.distances)
        out[f"{tag}_starts"] = np.asarray(res.starts)
        out[f"{tag}_ends"] = np.asarray(res.positions)
        for mode in ("end", "span"):
            res = run(top_k=3, excl_zone=5, excl_mode=mode,
                      return_spans=True)
            out[f"{tag}_topk_{mode}_dists"] = np.asarray(res.distances)
            out[f"{tag}_topk_{mode}_starts"] = np.asarray(res.starts)
            out[f"{tag}_topk_{mode}_ends"] = np.asarray(res.positions)
        res = run(top_k=3, excl_zone=5, prune=True, return_spans=True)
        out[f"{tag}_pruned_dists"] = np.asarray(res.distances)
        out[f"{tag}_pruned_starts"] = np.asarray(res.starts)
        out[f"{tag}_pruned_ends"] = np.asarray(res.positions)
        # Offline cross-check baked into the fixture: the streamed heap is
        # the chunked engine's heap (same tile size), recorded once here so
        # a drifting offline path cannot silently drag the fixture along.
        kd, ks, ke = sdtw_chunked(jnp.asarray(q), jnp.asarray(r), chunk=32,
                                  top_k=3, excl_zone=5, return_spans=True)
        assert np.array_equal(np.asarray(kd),
                              out[f"{tag}_topk_end_dists"])
        assert np.array_equal(np.asarray(ks),
                              out[f"{tag}_topk_end_starts"])
        assert np.array_equal(np.asarray(ke),
                              out[f"{tag}_topk_end_ends"])
    return out


if __name__ == "__main__":
    arrays = compute()
    np.savez(OUT, **arrays)
    print(f"wrote {OUT} ({len(arrays)} arrays)")
    arrays = compute_stream()
    np.savez(STREAM_OUT, **arrays)
    print(f"wrote {STREAM_OUT} ({len(arrays)} arrays)")
