"""The autotuner: table persistence, oracle correctness, bitwise safety.

Three contracts under test:

  1. **Persistence** — ``TuningTable`` survives a save/load round trip,
     rejects wrong schema versions and corrupt files by *degrading to
     empty with a warning* (a broken table must never take the engine
     down), and drops malformed entries individually.
  2. **Oracle** — resolution precedence (explicit kwargs > table >
     model), the LRU in front of it, ``choose_impl``'s model ranking vs
     its legacy rules, and the cost model's ranking agreement with the
     committed measured baseline (the same gate CI runs via
     ``repro.tune.validate``).
  3. **Bitwise safety** — every knob the tuner sets (impl, blocks, scan
     scheme, chunk, n_micro) is speed-only: tuned results are
     bitwise-identical (int32) to ``tune='off'`` across impl × metric ×
     spans × top-K.
"""
import json
import os
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import choose_impl, choose_impl_explained, sdtw
from repro.kernels.sdtw import resolve_blocks
from repro.tune import (DispatchDecision, KernelCostModel, TunedConfig,
                        TuningTable, bucket_key, cache_info, cache_keys,
                        clear_tuning_cache, default_table, get_cost_model,
                        pretune_request, resolve, resolve_n_micro,
                        tuned_blocks, tuned_chunk, tuned_n_micro)
from repro.tune.validate import validate_ranking

BASELINE = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_baseline.json")


@pytest.fixture(autouse=True)
def _fresh_lru():
    clear_tuning_cache()
    yield
    clear_tuning_cache()


# ---------------------------------------------------------------------------
# 1. TuningTable persistence
# ---------------------------------------------------------------------------

def test_table_round_trip(tmp_path):
    t = TuningTable("interpret", provenance="test")
    key = bucket_key("interpret", "abs_diff", "int32", 4, 32, 1024)
    cfg = TunedConfig(impl="wavefront", block_q=4, block_m=512,
                      scan_scheme="assoc", row_tile=1, chunk=8192,
                      score_us=123.0, source="measured")
    t.put(key, cfg)
    path = str(tmp_path / "t.json")
    t.save(path)
    back = TuningTable.load(path, "interpret")
    assert len(back) == 1 and key in back
    assert back.get(key) == cfg
    assert back.provenance == "test"


def test_table_missing_file_is_empty(tmp_path):
    t = TuningTable.load(str(tmp_path / "nope.json"), "interpret")
    assert len(t) == 0


def test_table_wrong_schema_recovers(tmp_path):
    path = str(tmp_path / "t.json")
    with open(path, "w") as f:
        json.dump({"schema": "repro.tune/v999", "backend": "interpret",
                   "entries": {}}, f)
    with pytest.warns(UserWarning, match="schema"):
        t = TuningTable.load(path, "interpret")
    assert len(t) == 0


def test_table_corrupt_json_recovers(tmp_path):
    path = str(tmp_path / "t.json")
    with open(path, "w") as f:
        f.write("{not json at all")
    with pytest.warns(UserWarning):
        t = TuningTable.load(path, "interpret")
    assert len(t) == 0


def test_table_malformed_entry_dropped(tmp_path):
    good_key = bucket_key("interpret", "abs_diff", "int32", 2, 16, 256)
    path = str(tmp_path / "t.json")
    with open(path, "w") as f:
        json.dump({"schema": "repro.tune/v1", "backend": "interpret",
                   "entries": {good_key: {"impl": "wavefront"},
                               "bad": "not a dict"}}, f)
    with pytest.warns(UserWarning, match="entr"):
        t = TuningTable.load(path, "interpret")
    assert len(t) == 1
    assert t.get(good_key).impl == "wavefront"


def test_tuned_config_json_round_trip():
    cfg = TunedConfig(impl="pallas", block_q=8, block_m=512,
                      scan_scheme="shift", row_tile=8, source="model")
    assert TunedConfig.from_json(cfg.to_json()) == cfg
    # None fields are omitted on the wire and restored as None
    assert "chunk" not in cfg.to_json()


def test_shipped_tables_load():
    for backend in ("interpret", "tpu"):
        with warnings.catch_warnings():
            warnings.simplefilter("error")      # no warning allowed
            t = default_table(backend)
        assert len(t) > 0, backend
        for key in t.keys():
            assert key.startswith(backend + "/")


# ---------------------------------------------------------------------------
# 2. The oracle
# ---------------------------------------------------------------------------

def test_lru_caches_resolutions():
    resolve(4, 32, 1024, backend="interpret")
    info0 = cache_info()
    resolve(4, 32, 1024, backend="interpret")       # same bucket -> hit
    resolve(3, 20, 600, backend="interpret")        # same pow-2 bucket
    info1 = cache_info()
    assert info1["hits"] >= info0["hits"] + 2
    assert info1["misses"] == info0["misses"]


def test_resolution_precedence_explicit_wins():
    # Table entry exists for this bucket (shipped) — explicit still wins.
    bq, bm, scheme, rt = resolve_blocks(4, 16384, 16, 256, "shift", 2,
                                        True, n=32, tune="model")
    assert (bq, bm, scheme, rt) == (16, 256, "shift", 2)
    # Unset knobs come from the oracle, not the legacy fill.
    auto = resolve_blocks(4, 16384, None, None, None, None, True,
                          n=32, tune="model")
    entry = default_table("interpret").get(
        bucket_key("interpret", "abs_diff", "int32", 4, 32, 16384))
    if entry is not None:                       # shipped table covers it
        assert auto == (entry.block_q, entry.block_m, entry.scan_scheme,
                        entry.row_tile)


def test_tune_off_keeps_legacy_blocks():
    legacy = resolve_blocks(4, 16384, None, None, None, None, True)
    off = resolve_blocks(4, 16384, None, None, None, None, True,
                         n=32, tune="off")
    assert legacy == off


def test_choose_impl_legacy_pins():
    # tune defaults to 'off' here: the legacy rules stay bit-for-bit.
    assert choose_impl(4, 32, 4096, backend="cpu") == "rowscan"
    assert choose_impl(4, 32, 60, backend="cpu") == "wavefront"
    assert choose_impl(4, 32, 1 << 18, backend="cpu") == "chunked"


def test_choose_impl_model_ranks_incore():
    impl, source, reason, cands = choose_impl_explained(
        4, 32, 4096, backend="cpu", tune="model")
    assert impl in ("rowscan", "wavefront")
    assert source in ("model", "table:model", "table:measured",
                      "table:default", "measured")
    assert cands, "model ranking should be attached"
    if source == "model":
        assert impl == cands[0][0]
    # structural rules stay ahead of the model
    assert choose_impl(4, 32, 4096, backend="cpu", tune="model",
                       chunk=1024) == "chunked"
    assert choose_impl(4, 32, 1 << 18, backend="cpu",
                       tune="model") == "chunked"
    assert choose_impl(4, 32, 4096, backend="tpu", tune="model") == "pallas"


def test_model_ranking_agrees_with_committed_baseline():
    """The same gate CI runs: pairwise ranking agreement between the
    analytical model and the committed measured rows."""
    with open(BASELINE) as f:
        rows = json.load(f)
    agree, total, report = validate_ranking(rows, backend="interpret")
    assert total >= 3, "bench row names drifted away from the validators"
    frac = agree / total
    assert frac >= 0.6, "\n".join(report)


def test_cost_model_oracle_sanity():
    model = get_cost_model("interpret")
    # best_chunk is a real candidate
    assert model.best_chunk(4, 32, 1 << 18) in \
        KernelCostModel.CHUNK_CANDIDATES
    # best_pallas respects the VMEM budget (plain and span mode)
    for span in (False, True):
        cfg = model.best_pallas(8, 64, 4096, span=span)
        assert model.vmem_words(cfg.block_q, cfg.block_m, 64, span) \
            <= model.backend.vmem_budget_words
    # span working set is strictly larger
    assert model.vmem_words(8, 512, 64, True) > \
        model.vmem_words(8, 512, 64, False)
    # tuned_chunk comes from the candidate ladder
    assert tuned_chunk(4, 32, 1 << 18, backend="interpret") in \
        KernelCostModel.CHUNK_CANDIDATES
    # n_micro default mirrors the schedule's pipeline fill
    assert resolve_n_micro(16, 2, 4, n=32, m=1024,
                           backend="interpret") == tuned_n_micro(16, 2, 4)
    assert tuned_n_micro(16, 2, 4) == max(1, min(4, -(-16 // 2)))


def test_pretune_primes_the_lru():
    from repro.core.request import SdtwRequest
    rng = np.random.default_rng(0)
    qs = [rng.integers(-50, 50, (L,)).astype(np.int32)
          for L in (10, 33, 70)]
    ref = rng.integers(-50, 50, (512,)).astype(np.int32)
    req = SdtwRequest(queries=qs, reference=ref)
    n = pretune_request(req)
    assert n == 3                      # three pow-2 buckets
    assert len(cache_keys()) >= 3
    # tune='off' requests prime nothing
    clear_tuning_cache()
    assert pretune_request(SdtwRequest(queries=qs, reference=ref,
                                       tune="off")) == 0
    assert len(cache_keys()) == 0


# ---------------------------------------------------------------------------
# 3. Bitwise safety + explain
# ---------------------------------------------------------------------------

def _mk(rng, nq=3, n=24, m=700):
    q = jnp.asarray(rng.integers(-60, 60, (nq, n)).astype(np.int32))
    r = jnp.asarray(rng.integers(-60, 60, (m,)).astype(np.int32))
    return q, r


@pytest.mark.parametrize("metric", ["abs_diff", "square_diff"])
@pytest.mark.parametrize("impl", ["auto", "rowscan", "wavefront",
                                  "pallas", "chunked"])
def test_tuned_bitwise_invariance(rng, metric, impl):
    """tune='model' vs tune='off' across impl x metric: identical int32
    results on every execution path."""
    q, r = _mk(rng)
    kw = dict(metric=metric, impl=impl)
    if impl == "chunked":
        kw["chunk"] = 128
    a = np.asarray(sdtw(q, r, tune="off", **kw))
    b = np.asarray(sdtw(q, r, tune="model", **kw))
    np.testing.assert_array_equal(a, b)


def test_tuned_bitwise_spans_and_topk(rng):
    q, r = _mk(rng, m=2048)
    for kw in (dict(return_spans=True),
               dict(return_positions=True),
               dict(top_k=3, chunk=256),
               dict(top_k=2, chunk=256, return_spans=True,
                    excl_mode="span")):
        a = sdtw(q, r, tune="off", **kw)
        b = sdtw(q, r, tune="model", **kw)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_tuned_bitwise_ragged(rng):
    qs = [np.asarray(q) for q in
          (rng.integers(-60, 60, 10), rng.integers(-60, 60, 33),
           rng.integers(-60, 60, 70))]
    qs = [q.astype(np.int32) for q in qs]
    r = jnp.asarray(rng.integers(-60, 60, 700).astype(np.int32))
    a = np.asarray(sdtw(qs, r, tune="off"))
    b = np.asarray(sdtw(qs, r, tune="model"))
    np.testing.assert_array_equal(a, b)


def test_explain_decision_contents(rng):
    q, r = _mk(rng)
    out, dec = sdtw(q, r, explain=True)
    assert isinstance(dec, DispatchDecision)
    assert dec.impl in ("rowscan", "wavefront")
    assert dec.source in ("model", "table:model", "table:measured",
                          "table:default")
    assert ":" in dec.token() and dec.token().endswith(dec.impl)
    assert dec.candidates, "in-core ranking should be attached"
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(sdtw(q, r)))
    # forced impl -> explicit source, no candidates
    _, dec2 = sdtw(q, r, impl="rowscan", explain=True)
    assert (dec2.impl, dec2.source) == ("rowscan", "explicit")
    # chunked decision reports the tuned chunk
    _, dec3 = sdtw(q, jnp.asarray(
        np.tile(np.asarray(r), 400)[: 1 << 18]), explain=True)
    assert dec3.impl == "chunked" and dec3.config.get("chunk") >= 4096
    # pallas decision reports the resolved block config
    _, dec4 = sdtw(q, r, impl="pallas", explain=True)
    assert set(dec4.config) >= {"block_q", "block_m", "scan_scheme"}
    # ragged lists cannot be explained
    with pytest.raises(ValueError, match="ragged"):
        sdtw([np.asarray(q)[0]], r, explain=True)


def test_explain_rejected_by_serve():
    from repro.core.request import SdtwRequest
    from repro.serve import Router
    rng = np.random.default_rng(0)
    q = rng.integers(-50, 50, (2, 16)).astype(np.int32)
    r = rng.integers(-50, 50, (256,)).astype(np.int32)
    with Router(auto_dispatch=False) as router:
        with pytest.raises(ValueError, match="explain"):
            router.submit(SdtwRequest(queries=q, reference=r,
                                      explain=True))


def test_tune_validated_at_the_door():
    with pytest.raises(ValueError, match="tune must be one of"):
        sdtw(np.zeros((1, 4), np.int32), np.zeros(8, np.int32),
             tune="bogus")


def test_router_warmup_pretunes(rng):
    from repro.serve import Router
    q, r = _mk(rng, nq=2, n=16, m=256)
    with Router(auto_dispatch=False) as router:
        router.warmup(queries=np.asarray(q), reference=np.asarray(r))
        assert len(cache_keys()) >= 1
        fut = router.submit(queries=np.asarray(q), reference=np.asarray(r))
        router.drain()
        np.testing.assert_array_equal(
            np.asarray(fut.result()),
            np.asarray(sdtw(q, r, tune="off")))
