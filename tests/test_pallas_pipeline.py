"""Device-resident Pallas chunk pipeline: differential suite.

The three execution shapes of ``impl='pallas'`` + ``chunk=`` — the fused
single-launch grid, the device-side ``lax.scan`` over static slices, and
the legacy host-side launch loop — must be *bitwise-identical* (int32) to
each other and to the chunked rowscan path across metric × dtype × spans ×
top-K × carry-resume, for any partition of the reference. Also covers the
single-compile guarantee (the ragged-tail recompile bugfix), the in-kernel
last-row capture against the rowscan candidate row, and the scan-scheme /
row-tile / block-shape invariances of the optimized kernel interior.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from oracle import sdtw_ref

from repro.core import sdtw
from repro.core.engine import (_pallas_host_loop, _pallas_scan_streamed,
                               _pallas_streamed)
from repro.core.sdtw import sdtw_rowscan_chunk
from repro.kernels.sdtw import pallas_carry_init, resolve_blocks, sdtw_pallas

B, N, M = 3, 9, 151      # M = 9*16 + 7: ragged tail at chunk=16


def _mk(rng, dtype, b=B, n=N, m=M):
    q = rng.integers(-40, 40, (b, n)).astype(dtype)
    r = rng.integers(-40, 40, m).astype(dtype)
    return jnp.asarray(q), jnp.asarray(r), q, r


def _run_path(path, q, r, chunk, **kw):
    if path == "fused":
        return sdtw(q, r, impl="pallas", chunk=chunk, **kw)
    if path == "scan":
        return _pallas_scan_streamed(
            q, r, None, kw.pop("metric", "abs_diff"), chunk=chunk,
            block_q=None, block_m=None,
            return_positions=kw.get("return_positions", False),
            return_spans=kw.get("return_spans", False))
    return _pallas_host_loop(
        q, r, None, kw.pop("metric", "abs_diff"), chunk,
        return_positions=kw.get("return_positions", False),
        return_spans=kw.get("return_spans", False))


@pytest.mark.parametrize("metric", ["abs_diff", "square_diff"])
@pytest.mark.parametrize("dtype", [np.int32, np.float32])
def test_three_paths_match_chunked(metric, dtype, rng):
    qj, rj, q, r = _mk(rng, dtype)
    want = np.asarray(sdtw(qj, rj, impl="chunked", chunk=16, metric=metric))
    oracle = np.array([sdtw_ref(q[i], r, metric) for i in range(B)])
    for path in ("fused", "scan", "host"):
        got = np.asarray(_run_path(path, qj, rj, 16, metric=metric))
        if dtype == np.int32:
            np.testing.assert_array_equal(got, want, err_msg=path)
            np.testing.assert_array_equal(got, oracle, err_msg=path)
        else:
            np.testing.assert_allclose(got, want, rtol=1e-5, err_msg=path)


def test_three_paths_spans_positions_bitwise(rng):
    qj, rj, _, _ = _mk(rng, np.int32)
    d0, s0, e0 = (np.asarray(x) for x in
                  sdtw(qj, rj, impl="chunked", chunk=16, return_spans=True))
    for path in ("fused", "scan", "host"):
        d, s, e = (np.asarray(x) for x in
                   _run_path(path, qj, rj, 16, return_spans=True))
        np.testing.assert_array_equal(d, d0, err_msg=path)
        np.testing.assert_array_equal(s, s0, err_msg=path)
        np.testing.assert_array_equal(e, e0, err_msg=path)
        dp, ep = (np.asarray(x) for x in
                  _run_path(path, qj, rj, 16, return_positions=True))
        np.testing.assert_array_equal(dp, d0, err_msg=path)
        np.testing.assert_array_equal(ep, e0, err_msg=path)


def test_chunk_partition_invariance(rng):
    """Any chunk size — including chunk=1, chunk > M, and random ragged
    partitions via the carry — gives the same bits on every path."""
    qj, rj, q, r = _mk(rng, np.int32, m=97)
    want = np.asarray(sdtw(qj, rj, impl="chunked", chunk=8192))
    for chunk in (1, 7, 16, 97, 1024):
        for path in ("fused", "scan", "host"):
            got = np.asarray(_run_path(path, qj, rj, chunk))
            np.testing.assert_array_equal(got, want,
                                          err_msg=f"{path} c={chunk}")
    # random partitions via explicit carry-resume through the kernel
    for seed in range(3):
        prng = np.random.default_rng(seed)
        cuts = np.sort(prng.choice(np.arange(1, 97), size=4, replace=False))
        parts = np.split(r, cuts)
        carry = pallas_carry_init(B, N, np.int32)
        off = 0
        width = max(len(p) for p in parts)
        for p in parts:
            pad = np.zeros((width,), p.dtype)
            pad[:len(p)] = p
            _, carry = sdtw_pallas(qj, jnp.asarray(pad), None, "abs_diff",
                                   carry=carry, ref_offset=off,
                                   ref_len=len(p), return_carry=True)
            off += len(p)
        np.testing.assert_array_equal(np.asarray(carry[1]), want,
                                      err_msg=f"partition {cuts}")


def test_carry_resume_track_matches_offline(rng):
    """Span-mode carry-resume across slices == offline spans (int32)."""
    qj, rj, _, r = _mk(rng, np.int32)
    d0, s0, e0 = (np.asarray(x) for x in
                  sdtw(qj, rj, impl="chunked", chunk=16, return_spans=True))
    carry = pallas_carry_init(B, N, np.int32, track_start=True)
    for off in range(0, M, 64):
        sl = r[off:off + 64]
        cl = len(sl)
        sl = np.pad(sl, (0, 64 - cl))
        _, carry = sdtw_pallas(qj, jnp.asarray(sl), None, "abs_diff",
                               carry=carry, ref_offset=off, ref_len=cl,
                               return_carry=True, track_start=True)
    _, _, d, e, s = (np.asarray(x) for x in carry)
    np.testing.assert_array_equal(d, d0)
    np.testing.assert_array_equal(s, s0)
    np.testing.assert_array_equal(e, e0)


def test_host_loop_single_compile(rng):
    """The ragged-tail bugfix: the per-slice loop pads the tail to the
    static chunk shape and passes the traced ref_len, so an M with a
    ragged tail compiles the kernel exactly once (the old code recompiled
    per distinct tail length)."""
    # unique shapes so earlier tests cannot have warmed this cache entry
    q = jnp.asarray(rng.integers(-40, 40, (2, 11)).astype(np.int32))
    r = jnp.asarray(rng.integers(-40, 40, 83).astype(np.int32))
    base = sdtw_pallas._cache_size()
    got = np.asarray(_pallas_host_loop(q, r, None, "abs_diff", 16))
    assert sdtw_pallas._cache_size() - base == 1
    # a second, differently-ragged reference reuses the same executable
    r2 = jnp.asarray(rng.integers(-40, 40, 69).astype(np.int32))
    _pallas_host_loop(q, r2, None, "abs_diff", 16)
    assert sdtw_pallas._cache_size() - base == 1
    want = np.asarray(sdtw(q, r, impl="chunked", chunk=16))
    np.testing.assert_array_equal(got, want)


def test_scan_path_single_compile(rng):
    """The device-side scan is one jitted program per call shape — calling
    it again (even with different data) adds no compiles."""
    q = jnp.asarray(rng.integers(-40, 40, (2, 13)).astype(np.int32))
    r = jnp.asarray(rng.integers(-40, 40, 107).astype(np.int32))
    base = _pallas_scan_streamed._cache_size()
    _pallas_scan_streamed(q, r, None, "abs_diff", chunk=16, block_q=None,
                          block_m=None, return_positions=False,
                          return_spans=False)
    assert _pallas_scan_streamed._cache_size() - base == 1
    r2 = jnp.asarray(rng.integers(-40, 40, 107).astype(np.int32))
    _pallas_scan_streamed(q, r2, None, "abs_diff", chunk=16, block_q=None,
                          block_m=None, return_positions=False,
                          return_spans=False)
    assert _pallas_scan_streamed._cache_size() - base == 1


def test_fused_dispatcher_thresholds(rng):
    """The pallas+chunk dispatcher: device-resident refs take the fused
    single-launch path, oversize refs the device-side scan — same bits."""
    import repro.core.engine as eng
    qj, rj, _, _ = _mk(rng, np.int32)
    want = np.asarray(_pallas_streamed(qj, rj, None, "abs_diff", 16, None,
                                       None, False))
    old = eng.PALLAS_FUSED_MAX
    try:
        eng.PALLAS_FUSED_MAX = 8     # force the scan path
        got = np.asarray(_pallas_streamed(qj, rj, None, "abs_diff", 16,
                                          None, None, False))
    finally:
        eng.PALLAS_FUSED_MAX = old
    np.testing.assert_array_equal(got, want)


def test_lastrow_matches_rowscan_chunk(rng):
    """In-kernel last-row capture == the rowscan candidate row, plain and
    span-tracked, with a chunk carry and a masked window."""
    qj, rj, q, r = _mk(rng, np.int32, m=70)
    qlens = np.array([N, 3, 7], np.int32)
    res, lrow, lstart = sdtw_pallas(qj, rj, jnp.asarray(qlens),
                                    track_start=True, return_lastrow=True)
    for i in range(B):
        bc, bs, be, lr, ls = sdtw_rowscan_chunk(
            jnp.asarray(q[i]), rj, jnp.full((N,), 2 ** 29, jnp.int32),
            jnp.int32(2 ** 29), qlen=int(qlens[i]), return_lastrow=True,
            bstart=jnp.full((N,), 2 ** 31 - 1, jnp.int32))
        np.testing.assert_array_equal(np.asarray(lr), np.asarray(lrow)[i])
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(lstart)[i])


def test_lastrow_lead_and_len_window(rng):
    """ref_lead / ref_len mask the candidate row exactly like the rowscan
    global-position ban (the pruned-search halo contract)."""
    qj, rj, q, r = _mk(rng, np.int32, m=64)
    res, lrow = sdtw_pallas(qj, rj, return_lastrow=True, ref_lead=10,
                            ref_len=50)
    lrow = np.asarray(lrow)
    assert (lrow[:, :10] >= 2 ** 29).all()
    assert (lrow[:, 50:] >= 2 ** 29).all()
    assert (lrow[:, 10:50] < 2 ** 29).any()
    # columns 10..50 must carry exactly the DP of the sub-reference
    # r[10:50] started fresh (a banned leading band behaves like the
    # implicit BIG columns before the reference starts)
    for i in range(B):
        want = np.asarray(sdtw_pallas(qj[i:i + 1], rj[10:50],
                                      return_lastrow=True)[1])[0]
        np.testing.assert_array_equal(lrow[i, 10:50], want)


@pytest.mark.parametrize("scheme", ["shift", "assoc"])
@pytest.mark.parametrize("row_tile", [1, 2, 4, 9])
def test_scheme_row_tile_invariance(scheme, row_tile, rng):
    """The kernel interior knobs (scan scheme, row unrolling, block shape)
    must never change the int32 bits — they only change the schedule."""
    qj, rj, q, r = _mk(rng, np.int32, m=70)
    want = np.asarray(sdtw_pallas(qj, rj))            # auto config
    got = np.asarray(sdtw_pallas(qj, rj, block_q=2, block_m=16,
                                 scan_scheme=scheme, row_tile=row_tile))
    np.testing.assert_array_equal(got, want)
    d, s, e = sdtw_pallas(qj, rj, return_spans=True)
    d2, s2, e2 = sdtw_pallas(qj, rj, return_spans=True, block_q=2,
                             block_m=32, scan_scheme=scheme,
                             row_tile=row_tile)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(d2))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(e), np.asarray(e2))


def test_resolve_blocks_contract():
    """Auto-tuning fits the batch off-TPU and keeps aligned TPU defaults."""
    bq, bm, scheme, rt = resolve_blocks(4, 1 << 18, None, None, None, None,
                                        interpret=True)
    assert bq == 4 and scheme == "assoc" and rt == 1
    assert bm >= 512 and bm * bq <= (1 << 21)
    # the working-set budget must hold for non-power-of-two batches too
    for b in (3, 6, 24, 31):
        bq, bm, _, _ = resolve_blocks(b, 1 << 22, None, None, None, None,
                                      interpret=True)
        assert bm * bq <= (1 << 21), (b, bq, bm)
    bq, bm, scheme, rt = resolve_blocks(4, 1 << 18, None, None, None, None,
                                        interpret=False)
    assert (bq, bm, scheme, rt) == (8, 512, "shift", 8)
    # explicit values pass through untouched
    assert resolve_blocks(4, 100, 2, 64, "shift", 3, True) == (2, 64,
                                                              "shift", 3)


def test_resolve_blocks_budget_property():
    """The interpret working-set budget holds for EVERY explicit block_q,
    not just the auto-fitted ones: the old code floored the per-row
    quotient at 512, so block_q > 4096 pushed ``block_q * block_m`` past
    ``INTERPRET_ELEM_BUDGET`` (an 8 MB live-row array became 16+ MB)."""
    from repro.kernels.sdtw.ops import INTERPRET_ELEM_BUDGET
    for bq in (1, 2, 3, 7, 32, 100, 1024, 4096, 4097, 8192,
               1 << 15, 1 << 17):
        for m in (16, 100, 4096, 1 << 18, 1 << 22):
            got_bq, bm, _, _ = resolve_blocks(bq, m, bq, None, None, None,
                                              True)
            assert got_bq == bq
            assert bm >= 16
            assert bq * bm <= INTERPRET_ELEM_BUDGET, (bq, m, bm)
            assert bm & (bm - 1) == 0, (bq, m, bm)   # power of two


def test_search_pallas_engine_matches_rowscan(rng):
    """Pruned top-K search scored on the kernel's last-row capture ==
    rowscan survivors, bitwise, with genuine pruning happening."""
    from repro.search import search_topk
    from repro.search.cache import EnvelopeCache
    n, m = 16, 2048
    # piecewise level-shifted noise — the regime envelope pruning targets
    levels = rng.integers(-1500, 1500, m // 128)
    r = np.concatenate([lvl + rng.normal(0, 40, 128)
                        for lvl in levels]).astype(np.int32)
    q = np.stack([r[200:200 + n], r[700:700 + n] + 1]).astype(np.int32)
    qj, rj = jnp.asarray(q), jnp.asarray(r)
    a = search_topk(qj, rj, k=2, chunk=64, engine_impl="rowscan",
                    cache=EnvelopeCache(), ref_key="a")
    b = search_topk(qj, rj, k=2, chunk=64, engine_impl="pallas",
                    cache=EnvelopeCache(), ref_key="b")
    assert a.chunks_pruned > 0 and b.chunks_pruned > 0
    np.testing.assert_array_equal(np.asarray(a.distances),
                                  np.asarray(b.distances))
    np.testing.assert_array_equal(np.asarray(a.positions),
                                  np.asarray(b.positions))
    np.testing.assert_array_equal(np.asarray(a.starts),
                                  np.asarray(b.starts))
    with pytest.raises(ValueError, match="exclusion"):
        search_topk(qj, rj, engine_impl="pallas", excl_lo=0, excl_hi=4)


def test_stream_pallas_heap_alerts_prune(rng):
    """Pallas stream sessions (top-K, alerts, pruning) == rowscan sessions
    == the offline chunked heap, bitwise."""
    from repro.core import stream
    from repro.core.sdtw import sdtw_chunked
    from repro.search.cache import EnvelopeCache
    n, m, tile = 12, 512, 64
    levels = rng.integers(-800, 800, m // 64)
    r = np.concatenate([lvl + rng.normal(0, 30, 64)
                        for lvl in levels]).astype(np.int32)
    q = np.stack([r[300:300 + n],                      # planted: alerts fire
                  rng.integers(-40, 40, n).astype(np.int32)])
    qj = jnp.asarray(q)

    def feed_all(s):
        for off in range(0, m, 48):                    # unaligned arrivals
            s.feed(r[off:off + 48])
        return s

    for kw in (dict(top_k=3), dict(top_k=2, excl_mode="span"),
               dict(top_k=2, return_spans=True)):
        ra = feed_all(stream(qj, chunk=tile, impl="rowscan", **kw)).results()
        rb = feed_all(stream(qj, chunk=tile, impl="pallas", **kw)).results()
        np.testing.assert_array_equal(np.asarray(ra.distances),
                                      np.asarray(rb.distances))
        np.testing.assert_array_equal(np.asarray(ra.positions),
                                      np.asarray(rb.positions))

    sa = feed_all(stream(qj, chunk=tile, impl="rowscan", alert_threshold=0))
    sb = feed_all(stream(qj, chunk=tile, impl="pallas", alert_threshold=0))
    sa.flush(), sb.flush()
    assert sa.alerts and sa.alerts == sb.alerts        # the planted query

    sa = feed_all(stream(qj, chunk=tile, impl="rowscan", top_k=2,
                         prune=True, cache=EnvelopeCache(), ref_key="k"))
    sb = feed_all(stream(qj, chunk=tile, impl="pallas", top_k=2,
                         prune=True, cache=EnvelopeCache(), ref_key="k"))
    ra, rb = sa.results(), sb.results()
    assert ra.tiles_pruned == rb.tiles_pruned
    np.testing.assert_array_equal(np.asarray(ra.distances),
                                  np.asarray(rb.distances))
    np.testing.assert_array_equal(np.asarray(ra.positions),
                                  np.asarray(rb.positions))

    # offline equality for the pallas heap
    s = feed_all(stream(qj, chunk=tile, impl="pallas", top_k=3)).flush()
    out = s.results()
    kd, kp = sdtw_chunked(qj, jnp.asarray(r), chunk=tile, top_k=3)
    np.testing.assert_array_equal(np.asarray(out.distances), np.asarray(kd))
    np.testing.assert_array_equal(np.asarray(out.positions), np.asarray(kp))


# ---------------------------------------------------------------------------
# Property: random chunk partitions (hypothesis when available; the body is
# also swept manually above in test_chunk_partition_invariance).
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 4), st.integers(1, 8), st.integers(2, 60),
           st.integers(1, 61), st.integers(0, 1000))
    def test_hyp_any_chunk_any_path(b, n, m, chunk, seed):
        prng = np.random.default_rng(seed)
        q = prng.integers(-30, 30, (b, n)).astype(np.int32)
        r = prng.integers(-30, 30, m).astype(np.int32)
        qj, rj = jnp.asarray(q), jnp.asarray(r)
        want = np.array([sdtw_ref(q[i], r) for i in range(b)])
        for path in ("fused", "scan", "host"):
            got = np.asarray(_run_path(path, qj, rj, chunk))
            np.testing.assert_array_equal(got, want, err_msg=path)
