"""Subprocess body for multi-device CPU tests (run with
XLA_FLAGS=--xla_force_host_platform_device_count=8).

Asserts:
  1. sharded train_step loss == unsharded loss (llama reduced, mesh 2x2)
  2. MoE EP (a2a over model axis) == mesh-free reference path
  3. MoE decode (replicated+psum path) == mesh-free reference path
  4. compressed_psum mean ≈ true mean within int8 quantisation error
  5. multi-pod mesh (2,2,2) train_step compiles & runs
  6. elastic checkpoint restore onto a different mesh
  7. GPipe pipeline == sequential execution
  8. sharded sDTW (ppermute boundary-column exchange) == numpy oracle
  9. sharded top-K heap == single-process streamer bitwise
  10. sharded spans + top-K span heap (start-pointer lane through the
      ppermute carry) == single-process bitwise, both suppression modes
  11. sharded streaming session (per-device chunk streams through the
      ppermute carry, carries handed back between feeds) == single-process
      StreamSession bitwise, both suppression modes + snapshot/restore
  12. 2D (dp, mp) mesh == 1D mesh == single-device engine bitwise for
      batch / top-K both modes / spans / streaming; schedule invariance
      across n_micro and ragged tails; bounded pipeline-cache compile
      counts

``--sdtw-mesh dp,mp`` runs only the sDTW sections (8-11 equivalents) on
that mesh shape and prints DISTRIBUTED_SDTW_OK — the CI distributed job
sweeps (1,8) / (2,4) / (4,2) through it.
"""
import argparse
import os

assert "--xla_force_host_platform_device_count=8" in os.environ["XLA_FLAGS"]

_ap = argparse.ArgumentParser()
_ap.add_argument("--sdtw-mesh", default=None,
                 help="dp,mp — run only the sDTW sections on that mesh")
SDTW_MESH = _ap.parse_args().sdtw_mesh
if SDTW_MESH is not None:
    SDTW_MESH = tuple(int(x) for x in SDTW_MESH.split(","))

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

assert len(jax.devices()) == 8
KEY = jax.random.PRNGKey(0)

if SDTW_MESH is None:
    from repro import checkpoint as ckpt
    from repro.compat import shard_map
    from repro.configs import get_arch
    from repro.distributed import Axes
    from repro.distributed.collectives import compressed_psum
    from repro.launch.mesh import make_mesh
    from repro.launch.specs import tree_shardings
    from repro.models import RunConfig, init_lm, loss_fn
    from repro.models.moe import moe_mlp
    from repro.optim import OptConfig
    from repro.train import TrainConfig, init_train_state, make_train_step

    RUN = RunConfig(remat="none", attn_mode="dense",
                    compute_dtype=jnp.float32)

    # --- 1. sharded == unsharded train loss ------------------------------
    cfg = get_arch("llama3.2-1b").reduced()
    params = init_lm(cfg, KEY)
    batch = {"tokens": jax.random.randint(KEY, (4, 16), 0, cfg.vocab),
             "labels": jax.random.randint(KEY, (4, 16), 0, cfg.vocab)}
    loss_ref, _ = loss_fn(cfg, params, batch, None, RUN)

    mesh = make_mesh((2, 2), ("data", "model"))
    axes = Axes.from_mesh(mesh)
    with mesh:
        loss_sh, _ = jax.jit(
            lambda p, b: loss_fn(cfg, p, b, axes, RUN))(params, batch)
    np.testing.assert_allclose(float(loss_sh), float(loss_ref), rtol=2e-5)
    print("1 OK: sharded loss matches", float(loss_sh))

    # --- 2/3. MoE EP paths == reference ----------------------------------
    # capacity_factor high enough that nothing drops: capacity dropping is
    # per-source-shard in the EP path vs global in the reference path, so
    # the paths are only bitwise-comparable in the no-drop regime.
    mcfg = dataclasses.replace(get_arch("qwen3-moe-30b-a3b").reduced(),
                               n_experts=4, topk=2, capacity_factor=4.0)
    mp = init_lm(mcfg, KEY)
    moe_params = jax.tree.map(lambda p: p[0], mp["blocks"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, mcfg.d_model),
                          jnp.float32)
    out_ref, aux_ref = moe_mlp(moe_params, mcfg, x, None)
    with mesh:
        out_a2a, aux_a2a = jax.jit(
            lambda p, v: moe_mlp(p, mcfg, v, axes))(moe_params, x)
    np.testing.assert_allclose(np.asarray(out_a2a), np.asarray(out_ref),
                               atol=2e-5)
    # aux is computed per shard then pmean'd: mean of per-shard E·Σf_e·p_e
    # is a (standard) approximation of the global aux — close, not
    # identical.
    np.testing.assert_allclose(float(aux_a2a), float(aux_ref), rtol=0.1)
    print("2 OK: MoE a2a path matches reference")

    xd = x[:, :1]  # S=1 → replicated/psum decode path
    out_ref_d, _ = moe_mlp(moe_params, mcfg, xd, None)
    with mesh:
        out_rep, _ = jax.jit(
            lambda p, v: moe_mlp(p, mcfg, v, axes))(moe_params, xd)
    np.testing.assert_allclose(np.asarray(out_rep), np.asarray(out_ref_d),
                               atol=2e-5)
    print("3 OK: MoE replicated decode path matches reference")

    # --- 4. compressed psum ----------------------------------------------
    vals = jax.random.normal(jax.random.PRNGKey(2), (8, 64), jnp.float32)
    flat_mesh = make_mesh((8,), ("d",))
    with flat_mesh:
        got = jax.jit(shard_map(
            lambda v: compressed_psum(v[0], "d")[None],
            mesh=flat_mesh, in_specs=P("d", None), out_specs=P("d", None),
            check_vma=False))(vals)
    want = jnp.mean(vals, axis=0)
    scale = float(jnp.max(jnp.abs(vals))) / 127.0
    assert float(jnp.max(jnp.abs(got[0] - want))) < scale
    print("4 OK: compressed_psum within quantisation error")

    # --- 4b. pad_heads path (kv=2 heads on a 4-way model axis) ------------
    mesh24 = make_mesh((2, 4), ("data", "model"))
    axes24 = Axes.from_mesh(mesh24)
    assert cfg.n_kv_heads % 4 != 0   # exercises the padding branch
    run_pad = dataclasses.replace(RUN, pad_heads=True)
    with mesh24:
        loss_pad, _ = jax.jit(
            lambda p, b: loss_fn(cfg, p, b, axes24, run_pad))(params, batch)
    np.testing.assert_allclose(float(loss_pad), float(loss_ref), rtol=2e-5)
    print("4b OK: pad_heads path matches reference", float(loss_pad))

    # --- 5. multi-pod mesh train step ------------------------------------
    pod_mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    pod_axes = Axes.from_mesh(pod_mesh)
    assert pod_axes.dp == ("pod", "data")
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3))
    state = init_train_state(cfg, params, tcfg)
    with pod_mesh:
        shardings = tree_shardings(jax.eval_shape(lambda: state), pod_axes,
                                   "train")
        state_sh = jax.tree.map(jax.device_put, state, shardings)
        step = jax.jit(make_train_step(cfg, RUN, tcfg, pod_axes))
        state2, metrics = step(state_sh, batch)
    np.testing.assert_allclose(float(metrics["loss"]), float(loss_ref),
                               rtol=2e-5)
    print("5 OK: multi-pod train step, loss", float(metrics["loss"]))

    # --- 6. elastic restore onto a different mesh ------------------------
    tmp = tempfile.mkdtemp()
    ckpt.save(tmp, 0, state2, extra={"step": 0})
    new_mesh = make_mesh((4, 2), ("data", "model"))
    new_axes = Axes.from_mesh(new_mesh)
    with new_mesh:
        new_sh = tree_shardings(jax.eval_shape(lambda: state), new_axes,
                                "train")
        restored, _, _ = ckpt.restore(tmp, state, shardings=new_sh)
        step2 = jax.jit(make_train_step(cfg, RUN, tcfg, new_axes))
        state3, metrics3 = step2(restored, batch)
    assert np.isfinite(float(metrics3["loss"]))
    print("6 OK: elastic restore onto 4x2 mesh, loss",
          float(metrics3["loss"]))

    # --- 7. pipeline parallelism == sequential ---------------------------
    from repro.distributed.pipeline import pipeline_apply, split_stages

    L, D = 8, 16
    keys = jax.random.split(jax.random.PRNGKey(3), L)
    layer_params = {"w": jnp.stack([
        0.3 * jax.random.normal(k, (D, D)) for k in keys])}

    def block(lp, x):
        return jnp.tanh(x @ lp["w"])

    xm = jax.random.normal(jax.random.PRNGKey(4), (6, 4, D))  # 6 microbatch
    # sequential reference
    seq = xm
    for i in range(L):
        seq = jax.vmap(lambda x: block({"w": layer_params["w"][i]}, x))(seq)

    pp_mesh = make_mesh((4,), ("stage",))
    staged = split_stages(layer_params, 4)
    with pp_mesh:
        got = pipeline_apply(block, staged, xm, pp_mesh, "stage")
    np.testing.assert_allclose(np.asarray(got), np.asarray(seq), atol=1e-5)
    print("7 OK: GPipe pipeline matches sequential execution")

# --- sDTW sections (8-12): shared check body, parameterized over mesh -----
from repro.core import sdtw as engine_sdtw
from repro.core import stream as open_stream
from repro.core.sdtw import sdtw_chunked
from repro.core.sdtw_ref import sdtw_ref
from repro.distributed import get_mesh
from repro.distributed.sdtw_sharded import (_cache_size,
                                            clear_pipeline_cache,
                                            default_mesh)
from repro.stream import ShardedStreamSession

rng8 = np.random.default_rng(42)


def check_sdtw(sdtw_mesh, tag):
    """Batch, top-K (both exclusion modes), spans, and a sharded stream on
    ``sdtw_mesh`` — every lane bitwise against the single-device engine
    (and the batch lane against the numpy oracle). The body every mesh
    shape must pass unchanged."""
    # batch vs oracle, int32 bitwise + float32 tolerance
    for dtype in (np.int32, np.float32):
        qs8 = rng8.integers(-40, 40, (8, 6)).astype(dtype)
        r8 = rng8.integers(-40, 40, 97).astype(dtype)  # 97: ragged over 8
        got8 = np.asarray(engine_sdtw(jnp.asarray(qs8), jnp.asarray(r8),
                                      mesh=sdtw_mesh, chunk=8))
        want8 = np.array([sdtw_ref(qs8[i], r8) for i in range(8)])
        if dtype == np.int32:
            np.testing.assert_array_equal(got8, want8)
        else:
            np.testing.assert_allclose(got8, want8, rtol=1e-5)
    print(f"{tag}: batch matches oracle")

    # top-K merge (heap rides the systolic carry)
    qs9 = rng8.integers(-40, 40, (8, 6)).astype(np.int32)
    r9 = rng8.integers(-40, 40, 97).astype(np.int32)
    sd, sp = engine_sdtw(jnp.asarray(qs9), jnp.asarray(r9), mesh=sdtw_mesh,
                         chunk=8, top_k=3, excl_zone=4)
    cd, cp = sdtw_chunked(jnp.asarray(qs9), jnp.asarray(r9), chunk=8,
                          top_k=3, excl_zone=4)
    np.testing.assert_array_equal(np.asarray(sd), np.asarray(cd))
    np.testing.assert_array_equal(np.asarray(sp), np.asarray(cp))
    d9, p9 = engine_sdtw(jnp.asarray(qs9), jnp.asarray(r9), mesh=sdtw_mesh,
                         chunk=8, return_positions=True)
    np.testing.assert_array_equal(np.asarray(d9), np.asarray(cd)[:, 0])
    np.testing.assert_array_equal(np.asarray(p9), np.asarray(cp)[:, 0])
    print(f"{tag}: top-K heap matches single-process bitwise")

    # spans (start-pointer lane) + top-K spans, both suppression modes
    qs10 = rng8.integers(-8, 8, (8, 6)).astype(np.int32)  # tie-heavy range
    r10 = rng8.integers(-8, 8, 97).astype(np.int32)
    sd10, ss10, se10 = engine_sdtw(jnp.asarray(qs10), jnp.asarray(r10),
                                   mesh=sdtw_mesh, chunk=8,
                                   return_spans=True)
    cd10, cs10, ce10 = sdtw_chunked(jnp.asarray(qs10), jnp.asarray(r10),
                                    chunk=8, return_spans=True)
    np.testing.assert_array_equal(np.asarray(sd10), np.asarray(cd10))
    np.testing.assert_array_equal(np.asarray(ss10), np.asarray(cs10))
    np.testing.assert_array_equal(np.asarray(se10), np.asarray(ce10))
    for mode in ("end", "span"):
        tk_s = engine_sdtw(jnp.asarray(qs10), jnp.asarray(r10),
                           mesh=sdtw_mesh, chunk=8, top_k=3, excl_zone=4,
                           excl_mode=mode, return_spans=True)
        tk_c = sdtw_chunked(jnp.asarray(qs10), jnp.asarray(r10), chunk=8,
                            top_k=3, excl_zone=4, excl_mode=mode,
                            return_spans=True)
        for a, b in zip(tk_s, tk_c):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print(f"{tag}: spans + top-K span heap match single-process bitwise")

    # streaming session == single-process StreamSession
    qs11 = rng8.integers(-8, 8, (8, 6)).astype(np.int32)
    r11 = rng8.integers(-8, 8, 97).astype(np.int32)

    sh11 = open_stream(qs11, mesh=sdtw_mesh, chunk=4)
    sp11 = open_stream(qs11, chunk=4)
    for off in range(0, 97, 17):
        sh11.feed(r11[off:off + 17])
        sp11.feed(r11[off:off + 17])
    np.testing.assert_array_equal(np.asarray(sh11.results().distances),
                                  np.asarray(sp11.results().distances))
    np.testing.assert_array_equal(
        np.asarray(sh11.results().distances),
        np.asarray(engine_sdtw(jnp.asarray(qs11), jnp.asarray(r11),
                               chunk=4, impl="chunked")))

    for mode in ("end", "span"):
        sh = open_stream(qs11, mesh=sdtw_mesh, chunk=4, top_k=3,
                         excl_zone=4, excl_mode=mode, return_spans=True)
        sp = open_stream(qs11, chunk=4, top_k=3, excl_zone=4,
                         excl_mode=mode, return_spans=True)
        for off in range(0, 97, 13):
            sh.feed(r11[off:off + 13])
            sp.feed(r11[off:off + 13])
        a, b = sh.results(), sp.results()
        for x, y in ((a.distances, b.distances), (a.starts, b.starts),
                     (a.positions, b.positions)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"mode={mode}")
        tk = sdtw_chunked(jnp.asarray(qs11), jnp.asarray(r11), chunk=4,
                          top_k=3, excl_zone=4, excl_mode=mode,
                          return_spans=True)
        np.testing.assert_array_equal(np.asarray(a.distances),
                                      np.asarray(tk[0]))
        np.testing.assert_array_equal(np.asarray(a.starts),
                                      np.asarray(tk[1]))
        np.testing.assert_array_equal(np.asarray(a.positions),
                                      np.asarray(tk[2]))

    # Snapshot mid-stream, restore, keep feeding: bitwise-identical tail.
    sh = open_stream(qs11, mesh=sdtw_mesh, chunk=4, top_k=3,
                     return_spans=True)
    sh.feed(r11[:64])
    sh2 = ShardedStreamSession.restore(sh.snapshot(), mesh=sdtw_mesh)
    sh.feed(r11[64:])
    sh2.feed(r11[64:])
    a, b = sh.results(), sh2.results()
    for x, y in ((a.distances, b.distances), (a.starts, b.starts),
                 (a.positions, b.positions)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    print(f"{tag}: sharded stream matches single-process session bitwise, "
          f"both modes + snapshot/restore")


if SDTW_MESH is not None:
    check_sdtw(get_mesh(SDTW_MESH), f"sdtw mesh {SDTW_MESH}")
    print("DISTRIBUTED_SDTW_OK")
    raise SystemExit(0)

# --- 8-11. sDTW on the classic 1-D ("ref",) mesh --------------------------
ref_mesh = default_mesh("ref")
assert ref_mesh.shape["ref"] == 8
check_sdtw(ref_mesh, "8-11 OK (1D ref mesh)")

# --- 12. 2D (dp, mp) mesh == 1D == single-device; schedule invariance -----
mesh24 = get_mesh((2, 4))
check_sdtw(mesh24, "12 OK (2D (2,4) mesh)")

# Schedule invariance: bitwise-identical int32 results across n_micro
# (including 2*ndev) and a ragged tail (nq=17 not divisible by anything
# swept), on 1D and 2D meshes.
qs12 = rng8.integers(-40, 40, (17, 6)).astype(np.int32)
r12 = rng8.integers(-40, 40, 97).astype(np.int32)
want12 = np.asarray(sdtw_chunked(jnp.asarray(qs12), jnp.asarray(r12),
                                 chunk=8, top_k=3, excl_zone=4,
                                 return_spans=True))
mesh1d = get_mesh((8,))
for m12, micros in ((mesh1d, (1, 2, 8, 16)), (mesh24, (1, 2, 4, 8))):
    for nm in micros:
        got12 = np.asarray(engine_sdtw(
            jnp.asarray(qs12), jnp.asarray(r12), mesh=m12, chunk=8,
            n_micro=nm, top_k=3, excl_zone=4, return_spans=True))
        np.testing.assert_array_equal(
            got12, want12, err_msg=f"mesh={m12.shape} n_micro={nm}")
print("12 OK: schedule-invariant across n_micro sweeps + ragged tail")

# Bounded pipeline cache: same config compiles once; keyed on the mesh
# fingerprint, not the live Mesh object.
clear_pipeline_cache()
assert _cache_size() == 0
engine_sdtw(jnp.asarray(qs12), jnp.asarray(r12), mesh=mesh24, chunk=8)
n_after_one = _cache_size()
assert n_after_one == 1, n_after_one
engine_sdtw(jnp.asarray(qs12), jnp.asarray(r12), mesh=mesh24, chunk=8)
assert _cache_size() == n_after_one          # cache hit, no recompile
engine_sdtw(jnp.asarray(qs12), jnp.asarray(r12), mesh=get_mesh((2, 4)),
            chunk=8)
assert _cache_size() == n_after_one          # equal fingerprint, same entry
print("12 OK: pipeline cache bounded + fingerprint-keyed")

print("DISTRIBUTED_ALL_OK")
