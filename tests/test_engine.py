"""The unified sDTW engine: dispatch rules, ragged bucketing, chunked
reference streaming (oracle sweeps incl. boundary/saturation adversaries),
and the Pallas chunk-carry protocol."""
import jax.numpy as jnp
import numpy as np
import pytest

from oracle import sdtw_end, sdtw_matrix, sdtw_ref

from repro.core import choose_impl, sdtw, sdtw_batch, sdtw_chunked
from repro.core.distances import INT_BIG
from repro.core.engine import CHUNK_THRESHOLD, MIN_BUCKET, bucketize
from repro.kernels.sdtw import sdtw_pallas


# ---------------------------------------------------------------------------
# Chunked reference streaming vs the numpy oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["abs_diff", "square_diff"])
@pytest.mark.parametrize("dtype", [np.int32, np.float32])
def test_chunked_matches_oracle(metric, dtype, rng):
    """chunk ≪ M, M not a multiple of the chunk — the acceptance sweep:
    bitwise for int32, rtol 1e-5 for float32, both metrics."""
    nq, n, m, chunk = 4, 9, 151, 16          # 151 = 9*16 + 7
    q = rng.integers(-40, 40, (nq, n)).astype(dtype)
    r = rng.integers(-40, 40, m).astype(dtype)
    got = np.asarray(sdtw(jnp.asarray(q), jnp.asarray(r), impl="chunked",
                          chunk=chunk, metric=metric))
    want = np.array([sdtw_ref(q[i], r, metric) for i in range(nq)])
    if dtype == np.int32:
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5)


def test_chunk_size_invariance(rng):
    """Tiling must not change the answer — including chunk > M, chunk | M,
    chunk ∤ M, and chunk = 1 (pure column streaming)."""
    q = rng.integers(-40, 40, (3, 8)).astype(np.int32)
    r = rng.integers(-40, 40, 137).astype(np.int32)
    outs = [np.asarray(sdtw_chunked(jnp.asarray(q), jnp.asarray(r),
                                    chunk=c)) for c in (1, 5, 8, 137, 1024)]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


def test_chunk_boundary_mid_warp_path(rng):
    """An exact subsequence match straddling several chunk boundaries must
    still be found with distance 0 (the warp path crosses tiles)."""
    r = rng.integers(-50, 50, 100).astype(np.int32)
    q = r[37:59]                              # spans chunks of size 8
    got = float(sdtw(jnp.asarray(q), jnp.asarray(r), impl="chunked", chunk=8))
    assert got == 0.0
    assert sdtw_ref(q, r) == 0.0


def test_int32_saturation_across_chunk_boundary(rng):
    """Saturated (≥ INT_BIG) partial paths crossing a chunk boundary must
    stay saturated — never wrap — and must not perturb the true optimum."""
    m, chunk = 48, 16
    # Per-cell square_diff = (2e4)^2 = 4e8 < INT_BIG ≈ 5.4e8, so a single
    # cell is exact but any 2-cell path saturates — the largest regime the
    # int32 lattice supports (pointwise distances themselves must fit).
    r = np.full(m, 10_000, np.int32)
    q = np.full(6, -10_000, np.int32)
    # Plant an exact match right after a chunk boundary so the optimal path
    # is finite while every other path has long saturated.
    r[17:23] = q
    got = int(sdtw(jnp.asarray(q), jnp.asarray(r), impl="chunked",
                   chunk=chunk, metric="square_diff"))
    assert got == 0
    # And with no match planted, the result is the saturation ceiling (not a
    # wrapped negative / garbage value).
    r_bad = np.full(m, 10_000, np.int32)
    sat = int(sdtw(jnp.asarray(jnp.asarray(q)), jnp.asarray(r_bad),
                   impl="chunked", chunk=chunk, metric="square_diff"))
    assert sat == INT_BIG
    # Cross-check against the unchunked rowscan (identical lattice).
    unchunked = int(sdtw(jnp.asarray(q), jnp.asarray(r_bad), impl="rowscan",
                         metric="square_diff"))
    assert sat == unchunked


def test_chunked_qlens_and_exclusion(rng):
    q = rng.integers(-40, 40, (3, 10)).astype(np.int32)
    r = rng.integers(-40, 40, 61).astype(np.int32)
    qlens = jnp.asarray([10, 3, 7], jnp.int32)
    lo = jnp.asarray([5, -1, 20], jnp.int32)
    hi = jnp.asarray([25, -1, 40], jnp.int32)
    got = np.asarray(sdtw(jnp.asarray(q), jnp.asarray(r), qlens,
                          impl="chunked", chunk=8, excl_lo=lo, excl_hi=hi))
    want = np.asarray(sdtw_batch(jnp.asarray(q), jnp.asarray(r), qlens,
                                 "abs_diff", "rowscan", lo, hi))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Pallas chunk-carry protocol (interpret mode)
# ---------------------------------------------------------------------------

def test_pallas_carry_chaining_bitwise(rng):
    """Two carry-chained pallas calls over reference slices == one call ==
    the numpy oracle, bitwise (int32). Slice point deliberately not a
    multiple of block_m."""
    b, n, m, split = 3, 7, 53, 21
    q = rng.integers(-40, 40, (b, n)).astype(np.int32)
    r = rng.integers(-40, 40, m).astype(np.int32)
    qj, rj = jnp.asarray(q), jnp.asarray(r)
    one = np.asarray(sdtw_pallas(qj, rj, block_q=2, block_m=8))
    _, carry = sdtw_pallas(qj, rj[:split], block_q=2, block_m=8,
                           return_carry=True)
    two = np.asarray(sdtw_pallas(qj, rj[split:], block_q=2, block_m=8,
                                 carry=carry))
    want = np.array([sdtw_ref(q[i], r) for i in range(b)])
    np.testing.assert_array_equal(one, want)
    np.testing.assert_array_equal(two, want)


# ---------------------------------------------------------------------------
# Ragged-batch bucketed dispatch
# ---------------------------------------------------------------------------

def test_bucketize_grid():
    buckets = bucketize([1, 3, 16, 17, 100, 16, 2])
    assert set(buckets) == {MIN_BUCKET, 32, 128}
    assert buckets[MIN_BUCKET] == [0, 1, 2, 5, 6]
    assert buckets[32] == [3]
    assert buckets[128] == [4]


def test_ragged_mixed_dtypes_promote(rng):
    """A bucket holding int32 and float32 queries must compute in the
    promoted dtype, not silently truncate floats to the first query's."""
    r = rng.integers(-10, 10, 40).astype(np.float32)
    qi = rng.integers(-10, 10, 4).astype(np.int32)
    qf = (rng.integers(-10, 10, 3) + 0.5).astype(np.float32)
    got = np.asarray(sdtw([qi, qf], jnp.asarray(r)))
    want = np.array([sdtw_ref(qi, r), sdtw_ref(qf, r)])
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_ragged_batch_matches_per_query(rng):
    """Bucketed dispatch must equal per-query calls exactly, in the caller's
    original order."""
    r = rng.integers(-50, 50, 90).astype(np.int32)
    lengths = [3, 17, 8, 120, 64, 5, 16, 33]
    ragged = [rng.integers(-50, 50, L).astype(np.int32) for L in lengths]
    got = np.asarray(sdtw(ragged, jnp.asarray(r)))
    want = np.array([float(sdtw(jnp.asarray(q), jnp.asarray(r)))
                     for q in ragged])
    np.testing.assert_array_equal(got, want)
    oracle = np.array([sdtw_ref(q, r) for q in ragged])
    np.testing.assert_array_equal(got, oracle)


# ---------------------------------------------------------------------------
# Dispatch rules + escape hatch
# ---------------------------------------------------------------------------

def test_auto_dispatch_rules():
    assert choose_impl(8, 16, 4096, backend="cpu") == "rowscan"
    assert choose_impl(8, 64, 100, backend="cpu") == "wavefront"
    assert choose_impl(8, 16, CHUNK_THRESHOLD, backend="cpu") == "chunked"
    assert choose_impl(8, 16, 64, backend="cpu", chunk=16) == "chunked"
    assert choose_impl(8, 16, 4096, backend="tpu") == "pallas"
    # The kernel's tile grid streams long references itself on TPU…
    assert choose_impl(8, 16, CHUNK_THRESHOLD, backend="tpu") == "pallas"
    # …but an explicit chunk= always forces streaming,
    assert choose_impl(8, 16, CHUNK_THRESHOLD, backend="tpu",
                       chunk=1024) == "chunked"
    # and exclusion zones fall off the kernel path.
    assert choose_impl(8, 16, CHUNK_THRESHOLD, backend="tpu",
                       has_exclusion=True) == "chunked"
    assert choose_impl(8, 16, 4096, backend="tpu",
                       has_exclusion=True) == "rowscan"
    assert choose_impl(8, 16, 4096, backend="cpu", mesh=object()) == "sharded"


def test_one_sided_exclusion_rejected():
    with pytest.raises(ValueError, match="together"):
        sdtw(jnp.zeros((2, 4), jnp.int32), jnp.zeros(8, jnp.int32), excl_lo=5)


def test_impl_escape_hatch_agrees(rng):
    q = rng.integers(-40, 40, (4, 8)).astype(np.int32)
    r = rng.integers(-40, 40, 70).astype(np.int32)
    want = np.array([sdtw_ref(q[i], r) for i in range(4)])
    for impl, kw in (("rowscan", {}), ("wavefront", {}),
                     ("pallas", {}), ("pallas", {"chunk": 16}),
                     ("chunked", {"chunk": 16})):
        got = np.asarray(sdtw(jnp.asarray(q), jnp.asarray(r), impl=impl,
                              **kw))
        np.testing.assert_array_equal(got, want)


def test_single_query_returns_scalar(rng):
    q = rng.integers(-40, 40, 7).astype(np.int32)
    r = rng.integers(-40, 40, 31).astype(np.int32)
    d = sdtw(jnp.asarray(q), jnp.asarray(r))
    assert d.ndim == 0
    assert float(d) == sdtw_ref(q, r)


def test_pallas_rejects_exclusion():
    with pytest.raises(ValueError, match="exclusion"):
        sdtw(jnp.zeros((2, 4), jnp.int32), jnp.zeros(8, jnp.int32),
             impl="pallas", excl_lo=1, excl_hi=3)


def test_bad_impl_rejected():
    with pytest.raises(ValueError, match="impl"):
        sdtw(jnp.zeros((1, 4), jnp.int32), jnp.zeros(8, jnp.int32),
             impl="vibes")


def test_forced_impl_contradictions_rejected():
    """Forced impls reject arguments that belong to another path instead of
    silently ignoring them (explicit precedence)."""
    q = jnp.zeros((2, 4), jnp.int32)
    r = jnp.zeros(16, jnp.int32)
    mesh = object()
    cases = [
        (dict(impl="rowscan", chunk=8), "ignore chunk"),
        (dict(impl="wavefront", chunk=8), "ignore chunk"),
        (dict(impl="rowscan", mesh=mesh), "sharded driver"),
        (dict(impl="wavefront", mesh=mesh), "sharded driver"),
        (dict(impl="pallas", mesh=mesh), "single-device"),
        (dict(impl="chunked", mesh=mesh), "single-device"),
        (dict(impl="rowscan", top_k=2), "top-K heap"),
        (dict(impl="pallas", top_k=2), "single best match"),
        (dict(top_k=0), "positive int"),
    ]
    for kw, match in cases:
        with pytest.raises(ValueError, match=match):
            sdtw(q, r, **kw)


# ---------------------------------------------------------------------------
# Top-K / match-position modes
# ---------------------------------------------------------------------------

_pos_oracle = sdtw_end


def test_return_positions_all_impls_agree(rng):
    """Every impl (incl. pallas, streamed pallas, chunked) reports the same
    leftmost end position as the oracle matrix argmin."""
    q = rng.integers(-40, 40, (4, 8)).astype(np.int32)
    r = rng.integers(-40, 40, 70).astype(np.int32)
    qj, rj = jnp.asarray(q), jnp.asarray(r)
    want_d = np.array([sdtw_ref(q[i], r) for i in range(4)])
    want_p = np.array([_pos_oracle(q[i], r) for i in range(4)])
    for impl, kw in (("rowscan", {}), ("wavefront", {}), ("pallas", {}),
                     ("pallas", {"chunk": 16}), ("chunked", {"chunk": 16})):
        d, p = sdtw(qj, rj, impl=impl, return_positions=True, **kw)
        np.testing.assert_array_equal(np.asarray(d), want_d, err_msg=impl)
        np.testing.assert_array_equal(np.asarray(p), want_p, err_msg=impl)


def test_topk_auto_routes_to_chunked_and_matches_greedy(rng):
    """engine.sdtw(top_k=) == greedy suppression on the oracle last row;
    top-1 column equals the plain-call distance bitwise."""
    q = rng.integers(-40, 40, (3, 8)).astype(np.int32)
    r = rng.integers(-40, 40, 120).astype(np.int32)
    k, zone = 3, 5
    d, p = sdtw(jnp.asarray(q), jnp.asarray(r), top_k=k, excl_zone=zone)
    d, p = np.asarray(d), np.asarray(p)
    plain = np.asarray(sdtw(jnp.asarray(q), jnp.asarray(r)))
    np.testing.assert_array_equal(d[:, 0], plain)
    for i in range(3):
        row = sdtw_matrix(q[i], r)[-1].copy()
        for kk in range(k):
            j = int(np.argmin(row))
            assert p[i, kk] == j
            assert d[i, kk] == row[j]
            row[np.abs(np.arange(len(row)) - j) <= zone] = np.inf
    # Suppressed matches are genuinely distinct.
    for i in range(3):
        ps = p[i][p[i] >= 0]
        assert all(abs(int(a) - int(b)) > zone
                   for x, a in enumerate(ps) for b in ps[x + 1:])


def test_topk_chunk_size_invariance(rng):
    """The streamed heap must not depend on the tile size."""
    q = rng.integers(-40, 40, (2, 8)).astype(np.int32)
    r = rng.integers(-40, 40, 137).astype(np.int32)
    outs = [sdtw_chunked(jnp.asarray(q), jnp.asarray(r), chunk=c, top_k=3,
                         excl_zone=4) for c in (1, 5, 8, 137, 1024)]
    for d, p in outs[1:]:
        np.testing.assert_array_equal(np.asarray(outs[0][0]), np.asarray(d))
        np.testing.assert_array_equal(np.asarray(outs[0][1]), np.asarray(p))


@pytest.mark.parametrize("excl_zone", [3, None])
def test_topk_single_query_and_ragged(rng, excl_zone):
    """Ragged bucketed top-K must equal the per-query call — including the
    *default* excl_zone, which is derived from each query's true length,
    never the padded bucket width."""
    r = rng.integers(-40, 40, 90).astype(np.int32)
    q1 = rng.integers(-40, 40, 7).astype(np.int32)
    q2 = rng.integers(-40, 40, 12).astype(np.int32)
    d, p = sdtw(jnp.asarray(q1), jnp.asarray(r), top_k=2,
                excl_zone=excl_zone)
    assert d.shape == (2,) and p.shape == (2,)
    dr, pr = sdtw([jnp.asarray(q1), jnp.asarray(q2)], jnp.asarray(r),
                  top_k=2, excl_zone=excl_zone)
    np.testing.assert_array_equal(np.asarray(dr[0]), np.asarray(d))
    np.testing.assert_array_equal(np.asarray(pr[0]), np.asarray(p))
    d2, p2 = sdtw(jnp.asarray(q2), jnp.asarray(r), top_k=2,
                  excl_zone=excl_zone)
    np.testing.assert_array_equal(np.asarray(dr[1]), np.asarray(d2))
    np.testing.assert_array_equal(np.asarray(pr[1]), np.asarray(p2))


def test_topk_default_zone_uses_true_qlen(rng):
    """A padded batch with a short qlen gets zone = qlen//2, not padded//2:
    identical to calling with the unpadded query."""
    r = rng.integers(-40, 40, 90).astype(np.int32)
    q = rng.integers(-40, 40, 7).astype(np.int32)
    qpad = np.zeros((1, 16), np.int32)
    qpad[0, :7] = q
    d_pad, p_pad = sdtw_chunked(jnp.asarray(qpad), jnp.asarray(r),
                                jnp.asarray([7], jnp.int32), top_k=3)
    d_raw, p_raw = sdtw_chunked(jnp.asarray(q)[None, :], jnp.asarray(r),
                                top_k=3)
    np.testing.assert_array_equal(np.asarray(d_pad), np.asarray(d_raw))
    np.testing.assert_array_equal(np.asarray(p_pad), np.asarray(p_raw))


def test_topk_respects_exclusion_columns(rng):
    """Banned reference columns can never be reported as match ends."""
    q = rng.integers(-40, 40, (1, 6)).astype(np.int32)
    r = rng.integers(-40, 40, 64).astype(np.int32)
    lo, hi = jnp.asarray([20]), jnp.asarray([40])
    d, p = sdtw(q, jnp.asarray(r), top_k=4, excl_zone=2,
                excl_lo=lo, excl_hi=hi)
    ps = np.asarray(p)[0]
    assert not np.any((ps >= 20) & (ps < 40))


def test_pallas_streamed_carry_positions(rng):
    """impl='pallas' + chunk= streams slices through the kernel carry and
    still reports exact global positions (slice point ∤ block_m)."""
    q = rng.integers(-40, 40, (3, 7)).astype(np.int32)
    r = rng.integers(-40, 40, 53).astype(np.int32)
    d, p = sdtw(jnp.asarray(q), jnp.asarray(r), impl="pallas", chunk=21,
                return_positions=True, block_q=2, block_m=8)
    want_d = np.array([sdtw_ref(q[i], r) for i in range(3)])
    want_p = np.array([_pos_oracle(q[i], r) for i in range(3)])
    np.testing.assert_array_equal(np.asarray(d), want_d)
    np.testing.assert_array_equal(np.asarray(p), want_p)


def test_choose_impl_topk_routes_chunked():
    assert choose_impl(8, 16, 4096, backend="cpu", top_k=5) == "chunked"
    assert choose_impl(8, 16, 4096, backend="tpu", top_k=5) == "chunked"
    assert choose_impl(8, 16, 4096, backend="cpu", mesh=object(),
                       top_k=5) == "sharded"
