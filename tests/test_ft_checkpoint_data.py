"""Fault tolerance, checkpointing, and data-pipeline determinism."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs import get_arch
from repro.data import DataConfig, SyntheticLM, TSAFilteredLM
from repro.ft import FailureInjector, RunnerConfig, TrainingRunner
from repro.models import RunConfig, init_lm
from repro.optim import OptConfig
from repro.train import TrainConfig, init_train_state, make_train_step

CFG = get_arch("llama3.2-1b").reduced()
RUN = RunConfig(remat="none")
TCFG = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=50))
KEY = jax.random.PRNGKey(0)


def _runner(tmp, steps=10, **kw):
    data = SyntheticLM(DataConfig(seed=7, seq_len=16, global_batch=4,
                                  vocab=CFG.vocab))
    state = init_train_state(CFG, init_lm(CFG, KEY), TCFG)
    step = jax.jit(make_train_step(CFG, RUN, TCFG))
    return TrainingRunner(step, data, state, tmp,
                          RunnerConfig(total_steps=steps, ckpt_every=3), **kw)


def test_recovery_bitwise_identical(tmp_path):
    out1 = _runner(str(tmp_path / "a")).run()
    out2 = _runner(str(tmp_path / "b"),
                   injector=FailureInjector(fail_at=(7,))).run()
    assert out2["restarts"] == 1
    for a, b in zip(jax.tree.leaves(out1["state"]["params"]),
                    jax.tree.leaves(out2["state"]["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_multiple_failures(tmp_path):
    out = _runner(str(tmp_path / "c"),
                  injector=FailureInjector(fail_at=(2, 5, 8))).run()
    assert out["restarts"] == 3
    assert len(out["metrics"]) >= 10


def test_straggler_watchdog(tmp_path):
    """Deterministic unit test of the EWMA watchdog (wall-clock-free — the
    shared CI box makes real timing flaky)."""
    r = _runner(str(tmp_path / "d"), steps=1)
    for step in range(10):
        r._watch(step, 0.1)
    r._watch(10, 0.5)              # > 3× EWMA → flagged
    assert 10 in r.straggler_steps
    r._watch(11, 0.12)             # recovered → not flagged
    assert 11 not in r.straggler_steps


def test_checkpoint_roundtrip_and_prune(tmp_path):
    tree = {"w": jnp.arange(6.0).reshape(2, 3), "n": {"b": jnp.ones(4)}}
    d = str(tmp_path / "ck")
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(d, s, tree, extra={"step": s}, keep_last=2)
    assert ckpt.latest_step(d) == 5
    # pruned to last 2
    kept = [f for f in os.listdir(d) if f.startswith("step_")]
    assert len(kept) == 2
    restored, extra, step = ckpt.restore(d, tree)
    assert extra["step"] == 5 and step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_checkpoint_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "nope"), {"a": jnp.zeros(1)})


def test_data_determinism_and_shards():
    cfg = DataConfig(seed=3, seq_len=8, global_batch=8, vocab=64)
    d = SyntheticLM(cfg)
    a = d.batch_at(5)
    b = d.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # shard batches are deterministic and sized global/num_shards
    s0 = d.batch_at(5, shard=0, num_shards=2)
    s1 = d.batch_at(5, shard=1, num_shards=2)
    assert s0["tokens"].shape == (4, 8)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_data_labels_are_next_tokens():
    d = SyntheticLM(DataConfig(seed=1, seq_len=12, global_batch=2, vocab=32))
    b = d.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_tsa_filter_keeps_anomalies():
    """Paper Fig. 2: the sDTW filter passes only high-distance windows."""
    cfg = DataConfig(seed=5, seq_len=64, global_batch=4, vocab=128)
    d = TSAFilteredLM(cfg, window=64)
    b = d.batch_at(0)
    assert b["tokens"].shape == (4, 64)
    assert d.filter_stats["kept"] <= d.filter_stats["seen"]
    assert d.filter_stats["kept"] >= 4
    b2 = d.batch_at(0)
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])  # deterministic
