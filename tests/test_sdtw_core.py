"""Core sDTW: production implementations vs the naive oracle + properties."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis dev dependency")
from hypothesis import given, settings, strategies as st

from oracle import dtw_ref, sdtw_matrix, sdtw_ref

from repro.core import (matsa, sdtw_batch, sdtw_rowscan, sdtw_wavefront,
                        self_join_windows)

IMPLS = {
    "rowscan": lambda q, r, **kw: sdtw_rowscan(jnp.asarray(q), jnp.asarray(r), **kw),
    "wavefront": lambda q, r, **kw: sdtw_wavefront(jnp.asarray(q), jnp.asarray(r), **kw),
}


@pytest.mark.parametrize("impl", list(IMPLS))
@pytest.mark.parametrize("metric", ["abs_diff", "square_diff"])
@pytest.mark.parametrize("dtype", [np.int32, np.int16, np.float32])
def test_matches_oracle_random(impl, metric, dtype, rng):
    for _ in range(6):
        n = int(rng.integers(1, 24))
        m = int(rng.integers(1, 48))
        q = rng.integers(-60, 60, n).astype(dtype)
        r = rng.integers(-60, 60, m).astype(dtype)
        want = sdtw_ref(q, r, metric)
        got = float(IMPLS[impl](q, r, metric=metric))
        assert np.isclose(got, want, rtol=1e-5), (n, m, got, want)


@pytest.mark.parametrize("impl", list(IMPLS))
def test_padded_qlen(impl, rng):
    q = rng.integers(-50, 50, 9).astype(np.int32)
    qpad = np.concatenate([q, rng.integers(-50, 50, 6).astype(np.int32)])
    r = rng.integers(-50, 50, 31).astype(np.int32)
    got = float(IMPLS[impl](qpad, r, qlen=9))
    assert got == sdtw_ref(q, r)


def test_exact_subsequence_gives_zero(rng):
    r = rng.integers(-50, 50, 40).astype(np.int32)
    q = r[13:29]
    assert sdtw_ref(q, r) == 0
    assert float(sdtw_rowscan(jnp.asarray(q), jnp.asarray(r))) == 0
    assert float(sdtw_wavefront(jnp.asarray(q), jnp.asarray(r))) == 0


def test_literal_init_variant(rng):
    """Paper Algorithm 1 as literally printed vs standard free-start."""
    q = rng.integers(-20, 20, 6).astype(np.int32)
    r = rng.integers(-20, 20, 15).astype(np.int32)
    lit = sdtw_matrix(q, r, literal_init=True)
    std = sdtw_matrix(q, r, literal_init=False)
    # Literal zero row-0 init can only lower scores (0 ≤ any distance).
    assert lit[-1].min() <= std[-1].min()


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(-40, 40), min_size=1, max_size=10),
       st.lists(st.integers(-40, 40), min_size=1, max_size=20))
def test_hyp_sdtw_le_dtw(qs, rs):
    """Free boundary conditions can only help: sDTW(Q,R) <= DTW(Q,R)."""
    q = np.asarray(qs, np.int32)
    r = np.asarray(rs, np.int32)
    assert sdtw_ref(q, r) <= dtw_ref(q, r) + 1e-9


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(-40, 40), min_size=1, max_size=8),
       st.lists(st.integers(-40, 40), min_size=2, max_size=16),
       st.lists(st.integers(-40, 40), min_size=1, max_size=6))
def test_hyp_appending_reference_never_hurts(qs, rs, extra):
    """Growing the reference adds alignment options (never raises the min)."""
    q = np.asarray(qs, np.int32)
    r = np.asarray(rs, np.int32)
    r2 = np.concatenate([r, np.asarray(extra, np.int32)])
    assert sdtw_ref(q, r2) <= sdtw_ref(q, r) + 1e-9


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(-40, 40), min_size=1, max_size=8),
       st.lists(st.integers(-40, 40), min_size=1, max_size=16),
       st.integers(1, 100))
def test_hyp_shift_invariance(qs, rs, shift):
    """abs_diff sDTW is invariant to a common additive offset."""
    q = np.asarray(qs, np.int32)
    r = np.asarray(rs, np.int32)
    a = float(sdtw_rowscan(jnp.asarray(q), jnp.asarray(r)))
    b = float(sdtw_rowscan(jnp.asarray(q + shift), jnp.asarray(r + shift)))
    assert a == b


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(-30, 30), min_size=2, max_size=10),
       st.lists(st.integers(-30, 30), min_size=4, max_size=20))
def test_hyp_impls_agree(qs, rs):
    q = np.asarray(qs, np.int32)
    r = np.asarray(rs, np.int32)
    a = float(sdtw_rowscan(jnp.asarray(q), jnp.asarray(r)))
    b = float(sdtw_wavefront(jnp.asarray(q), jnp.asarray(r)))
    assert a == b


def test_batch_matches_individual(rng):
    r = rng.integers(-50, 50, 37).astype(np.int32)
    qs = rng.integers(-50, 50, (5, 11)).astype(np.int32)
    batch = np.asarray(sdtw_batch(jnp.asarray(qs), jnp.asarray(r)))
    indiv = [sdtw_ref(qs[i], r) for i in range(5)]
    np.testing.assert_allclose(batch, indiv)


def test_matsa_api_query_filtering(rng):
    r = rng.integers(-50, 50, 64).astype(np.int32)
    qs = rng.integers(-50, 50, (4, 8)).astype(np.int32)
    res = matsa(r, qs, anomaly_threshold=50)
    assert res.distances.shape == (4,)
    assert res.anomalies.shape == (4,)
    np.testing.assert_array_equal(
        np.asarray(res.anomalies), np.asarray(res.distances) > 50)


def test_matsa_api_self_join_exclusion(rng):
    r = rng.integers(-50, 50, 48).astype(np.float32)
    res_x = matsa(r, mode="self_join", window=8, stride=8, exclusion=True)
    res_o = matsa(r, mode="self_join", window=8, stride=8, exclusion=False)
    # Without exclusion every window trivially matches itself → 0 distance.
    assert np.allclose(np.asarray(res_o.distances), 0.0)
    assert np.all(np.asarray(res_x.distances) > 0)


def test_self_join_windows_shapes(rng):
    r = rng.integers(-5, 5, 20).astype(np.int32)
    w, starts = self_join_windows(jnp.asarray(r), 6, 2)
    assert w.shape == (8, 6)
    np.testing.assert_array_equal(np.asarray(w[0]), r[:6])
    np.testing.assert_array_equal(np.asarray(starts),
                                  np.arange(0, 15, 2))
