"""Per-architecture smoke tests (REQUIRED: reduced config of the same family,
one forward/train step on CPU, output shapes + no NaNs) + numerics checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_arch
from repro.models import RunConfig, decode_step, forward, init_lm, prefill
from repro.optim import OptConfig
from repro.train import TrainConfig, init_train_state, make_train_step

RUN = RunConfig(remat="none", attn_mode="dense")
RUN32 = RunConfig(remat="none", attn_mode="dense",
                  compute_dtype=jnp.float32, cache_dtype=jnp.float32)
KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg, key=KEY, b=B, s=S):
    if cfg.frontend == "stub":
        return {"embeddings": jax.random.normal(key, (b, s, cfg.d_model)),
                "labels": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    return {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
            "labels": jax.random.randint(key, (b, s), 0, cfg.vocab)}


@pytest.mark.parametrize("name", sorted(all_archs()))
def test_arch_smoke_forward_and_train_step(name):
    cfg = get_arch(name).reduced()
    params = init_lm(cfg, KEY)
    batch = _batch(cfg)
    logits, aux = forward(cfg, params, batch, run=RUN)
    assert logits.shape == (B, S, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert not np.any(np.isnan(np.asarray(logits)))

    tcfg = TrainConfig(opt=OptConfig(lr=1e-3))
    state = init_train_state(cfg, params, tcfg)
    step = make_train_step(cfg, RUN, tcfg)
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(state["params"]):
        assert not np.any(np.isnan(np.asarray(leaf)))


@pytest.mark.parametrize("name", sorted(all_archs()))
def test_arch_smoke_prefill_decode(name):
    cfg = get_arch(name).reduced()
    params = init_lm(cfg, KEY)
    batch = _batch(cfg)
    logits, cache = prefill(cfg, params, batch, max_len=S + 4, run=RUN)
    assert logits.shape == (B, cfg.vocab)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = decode_step(cfg, params, tok, cache, run=RUN)
    assert logits2.shape == (B, cfg.vocab)
    assert not np.any(np.isnan(np.asarray(logits2)))
    np.testing.assert_array_equal(np.asarray(cache2["pos"]),
                                  np.asarray(cache["pos"]) + 1)


@pytest.mark.parametrize("name", ["mamba2-780m", "llama3.2-1b",
                                  "zamba2-2.7b", "qwen1.5-32b",
                                  "granite-34b"])
def test_prefill_decode_matches_forward(name):
    """Serving path == training forward at the next position."""
    cfg = get_arch(name).reduced()
    params = init_lm(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    full, _ = forward(cfg, params, {"tokens": toks}, run=RUN32)
    lg, cache = prefill(cfg, params, {"tokens": toks[:, :S]},
                        max_len=S + 8, run=RUN32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, S - 1]),
                               atol=2e-4, rtol=1e-4)
    lg2, _ = decode_step(cfg, params, toks[:, S], cache, run=RUN32)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(full[:, S]),
                               atol=2e-4, rtol=1e-4)


def test_moe_nodrop_prefill_consistency():
    """With no-drop capacity, MoE routing is causal → prefill == forward."""
    cfg = dataclasses.replace(get_arch("qwen3-moe-30b-a3b").reduced(),
                              capacity_factor=8.0)
    params = init_lm(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    full, _ = forward(cfg, params, {"tokens": toks}, run=RUN32)
    lg, cache = prefill(cfg, params, {"tokens": toks[:, :S]},
                        max_len=S + 8, run=RUN32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, S - 1]),
                               atol=2e-4, rtol=1e-4)


def test_attention_modes_equivalent():
    cfg = get_arch("llama3.2-1b").reduced()
    params = init_lm(cfg, KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 17), 0, cfg.vocab)}
    outs = {}
    for mode in ["dense", "chunked", "triangular"]:
        r = dataclasses.replace(RUN32, attn_mode=mode, attn_chunk=4)
        outs[mode], _ = forward(cfg, params, batch, run=r)
    np.testing.assert_allclose(np.asarray(outs["chunked"]),
                               np.asarray(outs["dense"]), atol=2e-5)
    np.testing.assert_allclose(np.asarray(outs["triangular"]),
                               np.asarray(outs["dense"]), atol=2e-5)


def test_ssd_chunk_invariance():
    """Chunked SSD == pure recurrence (chunk=1) — the state-space duality."""
    cfg = get_arch("mamba2-780m").reduced()
    params = init_lm(cfg, KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 17), 0, cfg.vocab)}
    a, _ = forward(cfg, params, batch, run=RUN32)
    cfg1 = dataclasses.replace(cfg, ssm_chunk=1)
    b, _ = forward(cfg1, params, batch, run=RUN32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_scan_vs_unroll_equivalence():
    cfg = get_arch("zamba2-2.7b").reduced()
    params = init_lm(cfg, KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab)}
    a, _ = forward(cfg, params, batch, run=RUN32)
    b, _ = forward(cfg, params, batch,
                   run=dataclasses.replace(RUN32, scan_layers=False))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                               rtol=1e-4)


def test_remat_matches_no_remat():
    cfg = get_arch("llama3.2-1b").reduced()
    params = init_lm(cfg, KEY)
    batch = _batch(cfg)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3))
    outs = []
    for remat in ["none", "full", "dots"]:
        run = dataclasses.replace(RUN32, remat=remat)
        state = init_train_state(cfg, params, tcfg)
        state, m = jax.jit(make_train_step(cfg, run, tcfg))(state, batch)
        outs.append(float(m["loss"]))
    assert np.allclose(outs, outs[0], rtol=1e-6)


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_arch("llama3.2-1b").reduced()
    params = init_lm(cfg, KEY)
    batch = _batch(cfg, b=4)
    t1 = TrainConfig(opt=OptConfig(lr=1e-3), microbatches=1)
    t2 = TrainConfig(opt=OptConfig(lr=1e-3), microbatches=2)
    run = RUN32
    s1, m1 = jax.jit(make_train_step(cfg, run, t1))(
        init_train_state(cfg, params, t1), batch)
    s2, m2 = jax.jit(make_train_step(cfg, run, t2))(
        init_train_state(cfg, params, t2), batch)
    # same data, same update (up to fp reassociation)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_param_count_close_to_init():
    """Analytic param_count within 2% of actual init (per arch)."""
    for name, full in all_archs().items():
        cfg = full.reduced()
        params = init_lm(cfg, KEY)
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(analytic / actual - 1) < 0.02, (name, analytic, actual)


def test_long_context_flags():
    assert get_arch("mamba2-780m").supports_long_context
    assert get_arch("zamba2-2.7b").supports_long_context
    for n in ["phi3-medium-14b", "llama3.2-1b", "qwen1.5-32b", "granite-34b",
              "qwen3-moe-30b-a3b", "granite-moe-1b-a400m", "musicgen-large",
              "internvl2-2b"]:
        assert not get_arch(n).supports_long_context, n
