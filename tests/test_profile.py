"""The matrix profile: batch and streaming self-joins against the
banned-column brute-force oracle (bitwise), the stride exclusion-unit
regression, sentinel-leak guards, and motif/discord selection
invariants. Hypothesis variants of the property sweeps run when the
library is installed; the seeded manual sweeps below cover the same
properties unconditionally."""
import jax.numpy as jnp
import numpy as np
import pytest

from oracle import sdtw_span_matrix

from repro.core.distances import big
from repro.core.matsa_api import matsa
from repro.search import search_topk
from repro.search.profile import matrix_profile
from repro.stream import StreamProfile


# ---------------------------------------------------------------------------
# Oracle: per-window nearest neighbor under banned reference columns
# ---------------------------------------------------------------------------

def oracle_profile(series, window, stride=1, zone=None, metric="abs_diff",
                   return_rows=False):
    """Brute-force matrix profile: one full banned-column DP per window.
    Returns (starts, dist, start, end) float64/int64 arrays with
    (inf, -1, -1) rows where the exclusion band admits nothing; with
    ``return_rows`` also the per-window (last row, start lane) pairs."""
    series = np.asarray(series)
    m = len(series)
    z = window // 2 if zone is None else zone
    starts = np.arange(0, m - window + 1, stride, dtype=np.int64)
    dist = np.full(starts.shape, np.inf)
    nn_s = np.full(starts.shape, -1, np.int64)
    nn_e = np.full(starts.shape, -1, np.int64)
    rows = []
    for i, s in enumerate(starts):
        q = series[s:s + window]
        S, T = sdtw_span_matrix(q, series, metric,
                                excl_lo=max(int(s) - z, 0),
                                excl_hi=int(s) + window + z)
        row = S[-1]
        rows.append((row, T[-1]))
        j = int(np.argmin(row))
        if np.isfinite(row[j]):
            dist[i], nn_s[i], nn_e[i] = row[j], int(T[-1, j]), j
    if return_rows:
        return starts, dist, nn_s, nn_e, rows
    return starts, dist, nn_s, nn_e


def assert_profile_matches_oracle(prof, series, metric="abs_diff",
                                  exact_spans=True):
    """Valid mask and distances bitwise against oracle_profile; spans
    bitwise when ``exact_spans`` (the unpruned contract: leftmost-argmin
    end, smallest-start tie-break), otherwise verified as *an* optimal
    witness — pruning is admissible on distances but may skip a chunk
    that only ties the incumbent, so an equally-optimal later span can
    win."""
    starts, dist, nn_s, nn_e, rows = oracle_profile(
        series, prof.window, prof.stride, prof.excl_zone, metric,
        return_rows=True)
    np.testing.assert_array_equal(prof.starts, starts)
    np.testing.assert_array_equal(prof.valid, np.isfinite(dist))
    v = prof.valid
    np.testing.assert_array_equal(prof.nn_dist[v].astype(np.float64),
                                  dist[v])
    if exact_spans:
        np.testing.assert_array_equal(prof.nn_start, nn_s)
        np.testing.assert_array_equal(prof.nn_end, nn_e)
    else:
        for i in np.flatnonzero(v):
            row, tlast = rows[i]
            e = prof.nn_end[i]
            assert row[e] == dist[i], (i, e)
            assert tlast[e] == prof.nn_start[i], (i, e)


# ---------------------------------------------------------------------------
# Batch profile vs oracle (the acceptance differential)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", [1, 2, 3, 5])
@pytest.mark.parametrize("prune", [False, True])
def test_profile_vs_oracle_bitwise(stride, prune, rng):
    """Every per-window (distance, start, end) bitwise-equal to the
    brute-force banned-column DP — pruned and exact, across strides."""
    series = rng.integers(-30, 30, 97).astype(np.int32)
    prof = matrix_profile(series, 8, stride=stride, prune=prune,
                          chunk=16, batch=7)
    assert_profile_matches_oracle(prof, series, exact_spans=not prune)


def test_profile_square_diff_and_default_zone(rng):
    series = rng.integers(-9, 9, 64).astype(np.int32)
    prof = matrix_profile(series, 6, metric="square_diff", prune=False,
                          chunk=16)
    assert prof.excl_zone == 3
    assert_profile_matches_oracle(prof, series, metric="square_diff")


def test_profile_custom_zone_vs_oracle(rng):
    """A wider explicit zone changes which neighbors are admissible —
    the profile must track the oracle's banned band exactly."""
    series = rng.integers(-20, 20, 80).astype(np.int32)
    prof = matrix_profile(series, 8, excl_zone=11, prune=False, chunk=16)
    assert_profile_matches_oracle(prof, series)


def test_profile_batch_size_invariant(rng):
    """The batch knob is memory-only. Unpruned, batch=3 and batch=1000
    agree bitwise on everything; pruned, distances still agree bitwise
    but the witness span may differ on exact ties (batchmates decide
    which tying chunks get dispatched at all)."""
    series = rng.integers(-30, 30, 90).astype(np.int32)
    for prune in (False, True):
        small = matrix_profile(series, 8, prune=prune, chunk=16, batch=3)
        huge = matrix_profile(series, 8, prune=prune, chunk=16,
                              batch=1000)
        np.testing.assert_array_equal(small.nn_dist, huge.nn_dist)
        if not prune:
            np.testing.assert_array_equal(small.nn_start, huge.nn_start)
            np.testing.assert_array_equal(small.nn_end, huge.nn_end)


def test_profile_validates_args():
    s = np.zeros(32, np.int32)
    with pytest.raises(ValueError, match="1-D"):
        matrix_profile(s.reshape(4, 8), 4)
    with pytest.raises(ValueError, match="window"):
        matrix_profile(s, 33)
    with pytest.raises(ValueError, match="stride"):
        matrix_profile(s, 4, stride=0)
    with pytest.raises(ValueError, match="k must"):
        matrix_profile(s, 4, k=0)
    with pytest.raises(ValueError, match="batch"):
        matrix_profile(s, 4, batch=0)
    with pytest.raises(ValueError, match="excl_zone"):
        matrix_profile(s, 4, excl_zone=-1)


# ---------------------------------------------------------------------------
# Satellite: matsa self-join exclusion stays in sample units at stride > 1
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", [1, 2, 3, 5, 8])
def test_matsa_self_join_stride_exclusion_units(stride, rng):
    """The trivial-match band is [s - w//2, s + w + w//2) in *samples*
    regardless of stride — both the profile-routed default and the
    legacy engine path must match the oracle for every stride (a
    window-unit bug would widen or shrink the band as stride grows)."""
    series = rng.integers(-25, 25, 73).astype(np.int32)
    w = 8
    starts, dist, _, _ = oracle_profile(series, w, stride)
    finite = np.where(np.isfinite(dist), dist, None)

    routed = matsa(series, mode="self_join", window=w, stride=stride)
    assert routed.profile is not None
    np.testing.assert_array_equal(np.asarray(routed.window_starts), starts)
    d = np.asarray(routed.distances).astype(np.float64)
    for i, want in enumerate(finite):
        if want is not None:
            assert d[i] == want, (stride, i)

    legacy = matsa(series, mode="self_join", window=w, stride=stride,
                   impl="chunked", chunk=16)
    assert legacy.profile is None
    dl = np.asarray(legacy.distances).astype(np.float64)
    for i, want in enumerate(finite):
        if want is not None:
            assert dl[i] == want, (stride, i)


# ---------------------------------------------------------------------------
# Satellite: sentinel padding never leaks
# ---------------------------------------------------------------------------

def test_search_topk_padding_exact_when_k_exceeds_matches(rng):
    """k greater than the number of admissible chunks' distinct matches:
    the spare heap slots must come back as the exact (BIG, -1, -1)
    padding triple — not garbage, not duplicates."""
    q = rng.integers(-10, 10, (2, 6)).astype(np.int32)
    r = rng.integers(-10, 10, 20).astype(np.int32)
    res = search_topk(jnp.asarray(q), jnp.asarray(r), k=8, chunk=16,
                      prune=False, excl_zone=50)  # one pick suppresses all
    d = np.asarray(res.distances)
    p = np.asarray(res.positions)
    s = np.asarray(res.starts)
    ceiling = big(d.dtype)
    assert (d[:, 1:] == ceiling).all()
    assert (p[:, 1:] == -1).all()
    assert (s[:, 1:] == -1).all()
    assert (d[:, 0] < ceiling).all() and (p[:, 0] >= 0).all()


def test_profile_fully_banned_windows_masked():
    """m=14, w=8, zone=4: windows starting at 2, 3, 4 ban every
    reference column. They must come back invalid with canonical
    (-1, -1, -1) span padding and never be chosen as motif or discord."""
    series = (np.arange(14, dtype=np.int32) % 5) * 3
    prof = matrix_profile(series, 8, excl_zone=4, prune=False, chunk=16,
                          k=4)
    want_valid = np.array([True, True, False, False, False, True, True])
    np.testing.assert_array_equal(prof.valid, want_valid)
    inv = ~prof.valid
    assert (prof.nn_start[inv] == -1).all()
    assert (prof.nn_end[inv] == -1).all()
    assert (prof.nn_window[inv] == -1).all()
    assert (prof.nn_dist[inv] == big(prof.nn_dist.dtype)).all()
    banned = set(np.flatnonzero(inv))
    for a, b, _ in prof.motifs:
        assert a not in banned and b not in banned
    for i, d in prof.discords:
        assert i not in banned
        assert np.isfinite(d)
    assert_profile_matches_oracle(prof, series)


# ---------------------------------------------------------------------------
# Motif / discord selection invariants (manual property sweep)
# ---------------------------------------------------------------------------

def _check_selection_invariants(prof):
    """The documented motif/discord contracts, checkable on any result."""
    dist_f = np.where(prof.valid, prof.nn_dist.astype(np.float64), np.inf)
    motifs = prof.motifs
    for a, b, d in motifs:
        assert a < b
        assert prof.nn_window[a] == b and prof.nn_window[b] == a
        assert d == min(dist_f[a], dist_f[b])
    assert [m[2] for m in motifs] == sorted(m[2] for m in motifs)
    members = [s for a, b, _ in motifs
               for s in (prof.starts[a], prof.starts[b])]
    for i in range(len(members)):
        for j in range(i + 1, len(members)):
            assert abs(members[i] - members[j]) > prof.excl_zone

    discords = prof.discords
    for idx, d in discords:
        assert prof.valid[idx] and np.isfinite(d)
        assert d == dist_f[idx]
    assert [d for _, d in discords] == sorted(
        (d for _, d in discords), reverse=True)
    picks = [prof.starts[i] for i, _ in discords]
    for i in range(len(picks)):
        for j in range(i + 1, len(picks)):
            assert abs(picks[i] - picks[j]) > prof.excl_zone
    if discords:
        # The top discord is the global max over valid windows.
        assert discords[0][1] == dist_f[prof.valid].max()


@pytest.mark.parametrize("seed", range(6))
def test_motif_discord_invariants_sweep(seed):
    rng = np.random.default_rng(seed)
    m = int(rng.integers(40, 120))
    w = int(rng.integers(4, 10))
    stride = int(rng.integers(1, 4))
    series = rng.integers(-15, 15, m).astype(np.int32)
    prof = matrix_profile(series, w, stride=stride, k=3,
                          prune=bool(seed % 2), chunk=16)
    _check_selection_invariants(prof)


def test_planted_motif_found():
    """A planted repeated pattern far apart in noise must surface as the
    top motif pair."""
    rng = np.random.default_rng(7)
    series = rng.integers(-40, 40, 120).astype(np.int32)
    pat = np.array([5, -30, 30, -30, 30, 5, 17, -17], np.int32)
    series[10:18] = pat
    series[90:98] = pat
    prof = matrix_profile(series, 8, k=2, prune=False, chunk=16)
    assert prof.motifs, "no motif reported"
    a, b, d = prof.motifs[0]
    assert {prof.starts[a], prof.starts[b]} == {10, 90}
    assert d == 0.0


# ---------------------------------------------------------------------------
# Streaming differential: StreamProfile == matrix_profile, any partition
# ---------------------------------------------------------------------------

def _feed_partitioned(sp, series, cuts, flush_at=()):
    edges = [0] + sorted(cuts) + [len(series)]
    for i, (a, b) in enumerate(zip(edges[:-1], edges[1:])):
        sp.feed(series[a:b])
        if i in flush_at:
            sp.flush()
    return sp


def assert_stream_equals_batch(sp, series, stride=1):
    got = sp.results()
    want = matrix_profile(series, sp.window, stride=stride, prune=False,
                          chunk=sp.chunk, excl_zone=sp.zone, k=sp.k)
    np.testing.assert_array_equal(got.nn_dist, want.nn_dist)
    np.testing.assert_array_equal(got.nn_start, want.nn_start)
    np.testing.assert_array_equal(got.nn_end, want.nn_end)
    np.testing.assert_array_equal(got.starts, want.starts)
    np.testing.assert_array_equal(got.motif_a, want.motif_a)
    np.testing.assert_array_equal(got.motif_b, want.motif_b)
    np.testing.assert_array_equal(got.discord_idx, want.discord_idx)


@pytest.mark.parametrize("stride", [1, 4])
def test_stream_profile_vs_batch_bitwise(stride, rng):
    series = rng.integers(-20, 20, 101).astype(np.int32)
    sp = StreamProfile(8, stride=stride, chunk=16, k=2)
    sp.feed(series)
    assert_stream_equals_batch(sp, series, stride)


@pytest.mark.parametrize("seed", range(5))
def test_stream_profile_random_partitions(seed):
    """Random feed partitions with random mid-stream flushes: the
    streamed profile is partition-invariant and bitwise-equal to the
    batch result (per-window heaps are top-1, hence exact)."""
    rng = np.random.default_rng(100 + seed)
    m = int(rng.integers(60, 140))
    series = rng.integers(-25, 25, m).astype(np.int32)
    ncuts = int(rng.integers(1, 6))
    cuts = sorted(rng.choice(np.arange(1, m), ncuts, replace=False).tolist())
    flush_at = set(rng.integers(0, ncuts + 1, 2).tolist())
    sp = StreamProfile(8, chunk=16)
    _feed_partitioned(sp, series, cuts, flush_at)
    assert_stream_equals_batch(sp, series)


def test_stream_profile_peek_is_stable(rng):
    """results() twice in a row (with a buffered tail) gives identical
    answers and does not disturb the subsequent stream."""
    series = rng.integers(-20, 20, 77).astype(np.int32)
    sp = StreamProfile(8, chunk=16)
    sp.feed(series[:50])
    a = sp.results()
    b = sp.results()
    np.testing.assert_array_equal(a.nn_dist, b.nn_dist)
    np.testing.assert_array_equal(a.nn_end, b.nn_end)
    sp.feed(series[50:])
    assert_stream_equals_batch(sp, series)


def test_stream_profile_vs_oracle(rng):
    """End-to-end: streamed per-sample feeding against the brute-force
    banned-column oracle."""
    series = rng.integers(-15, 15, 59).astype(np.int32)
    sp = StreamProfile(6, chunk=16)
    for x in series:
        sp.feed(np.asarray([x], np.int32))
    assert_profile_matches_oracle(sp.results(), series)


def test_stream_profile_validates():
    sp = StreamProfile(4, chunk=16)
    with pytest.raises(ValueError, match="1-D"):
        sp.feed(np.zeros((2, 2), np.int32))
    sp.feed(np.zeros(4, np.int32))
    with pytest.raises(ValueError, match="dtype"):
        sp.feed(np.zeros(4, np.float32))
    with pytest.raises(ValueError, match="window"):
        StreamProfile(0)
    with pytest.raises(ValueError, match="stride"):
        StreamProfile(4, stride=0)


def test_stream_profile_empty_and_short():
    """No samples / fewer than window samples: an empty but well-formed
    profile (no windows, no motifs, no discords)."""
    sp = StreamProfile(8, chunk=16)
    res = sp.results()
    assert res.starts.shape == (0,)
    assert res.motifs == [] and res.discords == []
    sp.feed(np.arange(5, dtype=np.int32))
    assert sp.results().starts.shape == (0,)
    assert sp.windows_admitted == 0


# ---------------------------------------------------------------------------
# Hypothesis variants (skipped when the library is absent)
# ---------------------------------------------------------------------------

try:
    import hypothesis
    import hypothesis.strategies as hyp_st
except ImportError:
    hypothesis = None

if hypothesis is not None:
    @hypothesis.given(
        data=hyp_st.lists(hyp_st.integers(-30, 30), min_size=20,
                          max_size=90),
        window=hyp_st.integers(3, 9),
        stride=hyp_st.integers(1, 4))
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_hyp_profile_vs_oracle(data, window, stride):
        series = np.asarray(data, np.int32)
        hypothesis.assume(window <= len(series))
        prof = matrix_profile(series, window, stride=stride, prune=False,
                              chunk=16)
        assert_profile_matches_oracle(prof, series)
        _check_selection_invariants(prof)

    @hypothesis.given(
        data=hyp_st.lists(hyp_st.integers(-20, 20), min_size=24,
                          max_size=80),
        cuts=hyp_st.lists(hyp_st.integers(1, 79), max_size=4))
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_hyp_stream_partition_invariance(data, cuts):
        series = np.asarray(data, np.int32)
        cuts = sorted({c for c in cuts if c < len(series)})
        sp = StreamProfile(6, chunk=16)
        _feed_partitioned(sp, series, cuts)
        assert_stream_equals_batch(sp, series)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hyp_profile_vs_oracle():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hyp_stream_partition_invariance():
        pass
