"""The serving tier: router coalescing, backpressure, session pool.

The load-bearing gate: for any interleaving of concurrent clients, the
router's answers are bitwise int32-identical to offline engine calls —
coalescing into shared bucket dispatches must be invisible to every
tenant.
"""
import threading

import numpy as np
import pytest

import repro.core.engine as engine
from repro.search import search_topk
from repro.serve import QueueFull, Router, RouterConfig, StreamSessionPool


def _mk(rng, nq, n, m=300):
    q = rng.integers(-40, 40, (nq, n)).astype(np.int32)
    r = rng.integers(-40, 40, m).astype(np.int32)
    return q, r


# ---------------------------------------------------------------------------
# coalescing == offline, bitwise
# ---------------------------------------------------------------------------

def test_coalesced_window_equals_offline_per_client(rng):
    """One drained window of compatible requests becomes ONE dispatch,
    and every client's slice equals its own offline call bitwise."""
    r = rng.integers(-40, 40, 300).astype(np.int32)
    clients = [rng.integers(-40, 40, (nq, 12)).astype(np.int32)
               for nq in (2, 3, 1, 4)]
    router = Router(RouterConfig(auto_dispatch=False))
    futs = [router.submit(queries=q, reference=r, top_k=2, excl_zone=4,
                          return_spans=True) for q in clients]
    assert router.drain() == len(clients)
    stats = router.stats()
    assert stats.dispatches == 1
    assert stats.mean_batch_requests == len(clients)
    for q, f in zip(clients, futs):
        want = engine.sdtw(q, r, top_k=2, excl_zone=4, return_spans=True)
        got = f.result(timeout=0)
        for w, g in zip(want, got):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g))
    router.close()


def test_concurrent_clients_bitwise_and_counted(rng):
    """Real threads through the auto-dispatching router: every client
    sees its offline answer, and the stats count every request."""
    r = rng.integers(-40, 40, 256).astype(np.int32)
    clients = [rng.integers(-40, 40, (2, 10)).astype(np.int32)
               for _ in range(6)]
    results = [None] * len(clients)
    with Router(window_ms=5.0) as router:
        def worker(i):
            results[i] = router.sdtw(clients[i], r)
        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(len(clients))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = router.stats()
    assert stats.completed == len(clients)
    assert stats.errors == 0
    assert stats.dispatches <= len(clients)
    for q, got in zip(clients, results):
        np.testing.assert_array_equal(np.asarray(engine.sdtw(q, r)),
                                      np.asarray(got))


def test_single_query_clients_unwrap_like_offline(rng):
    """1-D clients coalesce too and still get scalar-shaped answers."""
    r = rng.integers(-40, 40, 200).astype(np.int32)
    qs = [rng.integers(-40, 40, n).astype(np.int32) for n in (7, 12, 9)]
    router = Router(RouterConfig(auto_dispatch=False))
    futs = [router.submit(queries=q, reference=r) for q in qs]
    router.drain()
    assert router.stats().dispatches == 1
    for q, f in zip(qs, futs):
        want = engine.sdtw(q, r)
        got = f.result(timeout=0)
        assert np.asarray(got).shape == np.asarray(want).shape == ()
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    router.close()


def test_search_coalescing_equals_offline_batched(rng):
    """Merged search requests equal ONE offline batched search_topk over
    the concatenated queries (the LB thresholds are batch-shared by
    design — same semantics as calling the batch offline)."""
    r = rng.integers(-40, 40, 600).astype(np.int32)
    qa = [rng.integers(-40, 40, 16).astype(np.int32) for _ in range(2)]
    qb = [rng.integers(-40, 40, 16).astype(np.int32) for _ in range(3)]
    router = Router(RouterConfig(auto_dispatch=False))
    fa = router.submit(queries=qa, reference=r, op="search_topk", top_k=2,
                       ref_key="feed")
    fb = router.submit(queries=qb, reference=r, op="search_topk", top_k=2,
                       ref_key="feed")
    router.drain()
    assert router.stats().dispatches == 1
    want = search_topk(qa + qb, r, 2, ref_key="feed", cache=router.cache)
    merged_d = np.concatenate([np.asarray(fa.result(timeout=0).distances),
                               np.asarray(fb.result(timeout=0).distances)])
    np.testing.assert_array_equal(merged_d, np.asarray(want.distances))
    router.close()


def test_incompatible_requests_do_not_coalesce(rng):
    """Different semantics (metric) or different references must split
    into separate dispatches."""
    q, r = _mk(rng, 2, 8)
    r2 = rng.integers(-40, 40, 300).astype(np.int32)
    router = Router(RouterConfig(auto_dispatch=False))
    f1 = router.submit(queries=q, reference=r)
    f2 = router.submit(queries=q, reference=r, metric="square_diff")
    f3 = router.submit(queries=q, reference=r2)
    router.drain()
    assert router.stats().dispatches == 3
    np.testing.assert_array_equal(np.asarray(f1.result(timeout=0)),
                                  np.asarray(engine.sdtw(q, r)))
    np.testing.assert_array_equal(
        np.asarray(f2.result(timeout=0)),
        np.asarray(engine.sdtw(q, r, metric="square_diff")))
    np.testing.assert_array_equal(np.asarray(f3.result(timeout=0)),
                                  np.asarray(engine.sdtw(q, r2)))
    router.close()


def test_per_query_exclusion_arrays_never_coalesce(rng):
    """Array-valued exclusion zones are sized to one request's batch —
    even two clients sharing the array object must dispatch separately
    (and still match offline bitwise)."""
    r = rng.integers(-40, 40, 200).astype(np.int32)
    q1 = rng.integers(-40, 40, (2, 8)).astype(np.int32)
    q2 = rng.integers(-40, 40, (2, 8)).astype(np.int32)
    lo, hi = np.array([3, 5]), np.array([9, 12])
    router = Router(RouterConfig(auto_dispatch=False))
    f1 = router.submit(queries=q1, reference=r, excl_lo=lo, excl_hi=hi)
    f2 = router.submit(queries=q2, reference=r, excl_lo=lo, excl_hi=hi)
    router.drain()
    assert router.stats().dispatches == 2
    for q, f in ((q1, f1), (q2, f2)):
        np.testing.assert_array_equal(
            np.asarray(f.result(timeout=0)),
            np.asarray(engine.sdtw(q, r, excl_lo=lo, excl_hi=hi)))
    router.close()


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_backpressure_reject_policy(rng):
    q, r = _mk(rng, 1, 6)
    router = Router(RouterConfig(max_queue=2, admission="reject",
                                 auto_dispatch=False))
    router.submit(queries=q, reference=r)
    router.submit(queries=q, reference=r)
    with pytest.raises(QueueFull, match="full"):
        router.submit(queries=q, reference=r)
    assert router.stats().rejected == 1
    router.drain()
    assert router.stats().completed == 2
    router.close()


def test_backpressure_block_timeout(rng):
    q, r = _mk(rng, 1, 6)
    router = Router(RouterConfig(max_queue=1, admission="block",
                                 block_timeout_s=0.05, auto_dispatch=False))
    router.submit(queries=q, reference=r)
    with pytest.raises(QueueFull, match="blocking"):
        router.submit(queries=q, reference=r)
    router.drain()
    router.close()


def test_invalid_requests_refused_at_the_door(rng):
    """Validation runs at submit — the front-door message, raised
    synchronously, nothing enqueued."""
    q, r = _mk(rng, 2, 6)
    router = Router(RouterConfig(auto_dispatch=False))
    with pytest.raises(ValueError) as served:
        router.submit(queries=q, reference=r, excl_lo=5)
    with pytest.raises(ValueError) as offline:
        engine.sdtw(q, r, excl_lo=5)
    assert str(served.value) == str(offline.value)
    with pytest.raises(ValueError, match="unknown SdtwRequest argument"):
        router.submit(queries=q, reference=r, topk=2)
    assert router.drain() == 0
    router.close()


def test_execution_errors_propagate_to_every_member(rng):
    """A failure inside a merged dispatch answers every client future
    instead of hanging the window (admitted == answered)."""
    q, r = _mk(rng, 2, 8)
    router = Router(RouterConfig(auto_dispatch=False))
    bad = np.zeros((2, 2, 2), np.int32)       # 3-D queries explode in run()
    f1 = router.submit(queries=bad, reference=r)
    router.drain()
    with pytest.raises(Exception):
        f1.result(timeout=0)
    assert router.stats().errors == 1
    router.close()


# ---------------------------------------------------------------------------
# shared state across tenants
# ---------------------------------------------------------------------------

def test_envelope_cache_shared_across_tenants(rng):
    q, r = _mk(rng, 2, 16, m=600)
    router = Router(RouterConfig(auto_dispatch=False))
    for _ in range(2):
        f = router.submit(queries=q, reference=r, op="search_topk",
                          top_k=1, ref_key="shared-feed")
        router.drain()
        f.result(timeout=0)
    assert router.cache.hits >= 1
    router.close()


def test_session_pool_churn_and_snapshot_restore(rng):
    ref = rng.integers(-40, 40, 512).astype(np.int32)
    qa = rng.integers(-40, 40, (2, 16)).astype(np.int32)
    qb = rng.integers(-40, 40, (3, 16)).astype(np.int32)
    qc = rng.integers(-40, 40, (1, 16)).astype(np.int32)

    pool = StreamSessionPool()
    pool.attach("feed", "a", queries=qa, chunk=64, top_k=2)
    pool.attach("feed", "b", queries=qb, chunk=64, top_k=2)
    for i in range(0, 256, 128):
        assert pool.feed("feed", ref[i:i + 128]) == 2

    # churn: attach mid-feed → fresh start (only sees the suffix);
    # detach mid-feed → prefix-only results, feed keeps flowing.
    pool.attach("feed", "c", queries=qc, chunk=64, top_k=2)
    with pytest.raises(ValueError, match="already attached"):
        pool.attach("feed", "a", queries=qa, chunk=64)
    res_b = pool.detach("feed", "b")
    db, _ = engine.sdtw(qb, ref[:256], top_k=2, chunk=64)
    np.testing.assert_array_equal(np.asarray(res_b.distances),
                                  np.asarray(db))

    snaps = pool.snapshot("feed")
    assert sorted(snaps) == ["a", "c"]

    pool.feed("feed", ref[256:])
    live = pool.finalize("feed")

    # the restored pool continues bit-for-bit on the same suffix
    pool.restore("feed-replay", snaps)
    pool.feed("feed-replay", ref[256:])
    replay = pool.finalize("feed-replay")
    for t in ("a", "c"):
        np.testing.assert_array_equal(np.asarray(live[t].distances),
                                      np.asarray(replay[t].distances))

    da, _ = engine.sdtw(qa, ref, top_k=2, chunk=64)
    np.testing.assert_array_equal(np.asarray(live["a"].distances),
                                  np.asarray(da))
    dc, _ = engine.sdtw(qc, ref[256:], top_k=2, chunk=64)
    np.testing.assert_array_equal(np.asarray(live["c"].distances),
                                  np.asarray(dc))


def test_router_open_stream_and_stats(rng):
    ref = rng.integers(-40, 40, 256).astype(np.int32)
    q = rng.integers(-40, 40, (2, 8)).astype(np.int32)
    with Router(RouterConfig(auto_dispatch=False)) as router:
        router.open_stream("sensor", "t0", queries=q, chunk=32, top_k=2)
        assert router.feed("sensor", ref) == 1
        res = router.sessions.finalize("sensor")["t0"]
        d, _ = engine.sdtw(q, ref, top_k=2, chunk=32)
        np.testing.assert_array_equal(np.asarray(res.distances),
                                      np.asarray(d))
        snap = router.stats()
        assert snap.completed == snap.dispatches == 0
