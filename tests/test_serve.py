"""The serving tier: router coalescing, backpressure, session pool.

The load-bearing gate: for any interleaving of concurrent clients, the
router's answers are bitwise int32-identical to offline engine calls —
coalescing into shared bucket dispatches, device pooling, priority
scheduling, and in-window dedup must all be invisible to every tenant.
"""
import concurrent.futures
import threading
import time

import numpy as np
import pytest

import repro.core.engine as engine
from repro.search import search_topk
from repro.serve import (AdmissionQueue, DevicePool, QueueFull, Router,
                         RouterConfig, StreamSessionPool, Telemetry)


def _mk(rng, nq, n, m=300):
    q = rng.integers(-40, 40, (nq, n)).astype(np.int32)
    r = rng.integers(-40, 40, m).astype(np.int32)
    return q, r


# ---------------------------------------------------------------------------
# coalescing == offline, bitwise
# ---------------------------------------------------------------------------

def test_coalesced_window_equals_offline_per_client(rng):
    """One drained window of compatible requests becomes ONE dispatch,
    and every client's slice equals its own offline call bitwise."""
    r = rng.integers(-40, 40, 300).astype(np.int32)
    clients = [rng.integers(-40, 40, (nq, 12)).astype(np.int32)
               for nq in (2, 3, 1, 4)]
    router = Router(RouterConfig(auto_dispatch=False))
    futs = [router.submit(queries=q, reference=r, top_k=2, excl_zone=4,
                          return_spans=True) for q in clients]
    assert router.drain() == len(clients)
    stats = router.stats()
    assert stats.dispatches == 1
    assert stats.mean_batch_requests == len(clients)
    for q, f in zip(clients, futs):
        want = engine.sdtw(q, r, top_k=2, excl_zone=4, return_spans=True)
        got = f.result(timeout=0)
        for w, g in zip(want, got):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g))
    router.close()


def test_concurrent_clients_bitwise_and_counted(rng):
    """Real threads through the auto-dispatching router: every client
    sees its offline answer, and the stats count every request."""
    r = rng.integers(-40, 40, 256).astype(np.int32)
    clients = [rng.integers(-40, 40, (2, 10)).astype(np.int32)
               for _ in range(6)]
    results = [None] * len(clients)
    with Router(window_ms=5.0) as router:
        def worker(i):
            results[i] = router.sdtw(clients[i], r)
        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(len(clients))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = router.stats()
    assert stats.completed == len(clients)
    assert stats.errors == 0
    assert stats.dispatches <= len(clients)
    for q, got in zip(clients, results):
        np.testing.assert_array_equal(np.asarray(engine.sdtw(q, r)),
                                      np.asarray(got))


def test_single_query_clients_unwrap_like_offline(rng):
    """1-D clients coalesce too and still get scalar-shaped answers."""
    r = rng.integers(-40, 40, 200).astype(np.int32)
    qs = [rng.integers(-40, 40, n).astype(np.int32) for n in (7, 12, 9)]
    router = Router(RouterConfig(auto_dispatch=False))
    futs = [router.submit(queries=q, reference=r) for q in qs]
    router.drain()
    assert router.stats().dispatches == 1
    for q, f in zip(qs, futs):
        want = engine.sdtw(q, r)
        got = f.result(timeout=0)
        assert np.asarray(got).shape == np.asarray(want).shape == ()
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    router.close()


def test_search_coalescing_equals_offline_batched(rng):
    """Merged search requests equal ONE offline batched search_topk over
    the concatenated queries (the LB thresholds are batch-shared by
    design — same semantics as calling the batch offline)."""
    r = rng.integers(-40, 40, 600).astype(np.int32)
    qa = [rng.integers(-40, 40, 16).astype(np.int32) for _ in range(2)]
    qb = [rng.integers(-40, 40, 16).astype(np.int32) for _ in range(3)]
    router = Router(RouterConfig(auto_dispatch=False))
    fa = router.submit(queries=qa, reference=r, op="search_topk", top_k=2,
                       ref_key="feed")
    fb = router.submit(queries=qb, reference=r, op="search_topk", top_k=2,
                       ref_key="feed")
    router.drain()
    assert router.stats().dispatches == 1
    want = search_topk(qa + qb, r, 2, ref_key="feed", cache=router.cache)
    merged_d = np.concatenate([np.asarray(fa.result(timeout=0).distances),
                               np.asarray(fb.result(timeout=0).distances)])
    np.testing.assert_array_equal(merged_d, np.asarray(want.distances))
    router.close()


def test_incompatible_requests_do_not_coalesce(rng):
    """Different semantics (metric) or different references must split
    into separate dispatches."""
    q, r = _mk(rng, 2, 8)
    r2 = rng.integers(-40, 40, 300).astype(np.int32)
    router = Router(RouterConfig(auto_dispatch=False))
    f1 = router.submit(queries=q, reference=r)
    f2 = router.submit(queries=q, reference=r, metric="square_diff")
    f3 = router.submit(queries=q, reference=r2)
    router.drain()
    assert router.stats().dispatches == 3
    np.testing.assert_array_equal(np.asarray(f1.result(timeout=0)),
                                  np.asarray(engine.sdtw(q, r)))
    np.testing.assert_array_equal(
        np.asarray(f2.result(timeout=0)),
        np.asarray(engine.sdtw(q, r, metric="square_diff")))
    np.testing.assert_array_equal(np.asarray(f3.result(timeout=0)),
                                  np.asarray(engine.sdtw(q, r2)))
    router.close()


def test_per_query_exclusion_arrays_never_coalesce(rng):
    """Array-valued exclusion zones are sized to one request's batch —
    even two clients sharing the array object must dispatch separately
    (and still match offline bitwise)."""
    r = rng.integers(-40, 40, 200).astype(np.int32)
    q1 = rng.integers(-40, 40, (2, 8)).astype(np.int32)
    q2 = rng.integers(-40, 40, (2, 8)).astype(np.int32)
    lo, hi = np.array([3, 5]), np.array([9, 12])
    router = Router(RouterConfig(auto_dispatch=False))
    f1 = router.submit(queries=q1, reference=r, excl_lo=lo, excl_hi=hi)
    f2 = router.submit(queries=q2, reference=r, excl_lo=lo, excl_hi=hi)
    router.drain()
    assert router.stats().dispatches == 2
    for q, f in ((q1, f1), (q2, f2)):
        np.testing.assert_array_equal(
            np.asarray(f.result(timeout=0)),
            np.asarray(engine.sdtw(q, r, excl_lo=lo, excl_hi=hi)))
    router.close()


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_backpressure_reject_policy(rng):
    q, r = _mk(rng, 1, 6)
    router = Router(RouterConfig(max_queue=2, admission="reject",
                                 auto_dispatch=False))
    router.submit(queries=q, reference=r)
    router.submit(queries=q, reference=r)
    with pytest.raises(QueueFull, match="full"):
        router.submit(queries=q, reference=r)
    assert router.stats().rejected == 1
    router.drain()
    assert router.stats().completed == 2
    router.close()


def test_backpressure_block_timeout(rng):
    q, r = _mk(rng, 1, 6)
    router = Router(RouterConfig(max_queue=1, admission="block",
                                 block_timeout_s=0.05, auto_dispatch=False))
    router.submit(queries=q, reference=r)
    with pytest.raises(QueueFull, match="blocking"):
        router.submit(queries=q, reference=r)
    router.drain()
    router.close()


def test_invalid_requests_refused_at_the_door(rng):
    """Validation runs at submit — the front-door message, raised
    synchronously, nothing enqueued."""
    q, r = _mk(rng, 2, 6)
    router = Router(RouterConfig(auto_dispatch=False))
    with pytest.raises(ValueError) as served:
        router.submit(queries=q, reference=r, excl_lo=5)
    with pytest.raises(ValueError) as offline:
        engine.sdtw(q, r, excl_lo=5)
    assert str(served.value) == str(offline.value)
    with pytest.raises(ValueError, match="unknown SdtwRequest argument"):
        router.submit(queries=q, reference=r, topk=2)
    assert router.drain() == 0
    router.close()


def test_execution_errors_propagate_to_every_member(rng):
    """A failure inside a merged dispatch answers every client future
    instead of hanging the window (admitted == answered)."""
    q, r = _mk(rng, 2, 8)
    router = Router(RouterConfig(auto_dispatch=False))
    bad = np.zeros((2, 2, 2), np.int32)       # 3-D queries explode in run()
    f1 = router.submit(queries=bad, reference=r)
    router.drain()
    with pytest.raises(Exception):
        f1.result(timeout=0)
    assert router.stats().errors == 1
    router.close()


# ---------------------------------------------------------------------------
# shared state across tenants
# ---------------------------------------------------------------------------

def test_envelope_cache_shared_across_tenants(rng):
    q, r = _mk(rng, 2, 16, m=600)
    router = Router(RouterConfig(auto_dispatch=False))
    for _ in range(2):
        f = router.submit(queries=q, reference=r, op="search_topk",
                          top_k=1, ref_key="shared-feed")
        router.drain()
        f.result(timeout=0)
    assert router.cache.hits >= 1
    router.close()


def test_session_pool_churn_and_snapshot_restore(rng):
    ref = rng.integers(-40, 40, 512).astype(np.int32)
    qa = rng.integers(-40, 40, (2, 16)).astype(np.int32)
    qb = rng.integers(-40, 40, (3, 16)).astype(np.int32)
    qc = rng.integers(-40, 40, (1, 16)).astype(np.int32)

    pool = StreamSessionPool()
    pool.attach("feed", "a", queries=qa, chunk=64, top_k=2)
    pool.attach("feed", "b", queries=qb, chunk=64, top_k=2)
    for i in range(0, 256, 128):
        assert pool.feed("feed", ref[i:i + 128]) == 2

    # churn: attach mid-feed → fresh start (only sees the suffix);
    # detach mid-feed → prefix-only results, feed keeps flowing.
    pool.attach("feed", "c", queries=qc, chunk=64, top_k=2)
    with pytest.raises(ValueError, match="already attached"):
        pool.attach("feed", "a", queries=qa, chunk=64)
    res_b = pool.detach("feed", "b")
    db, _ = engine.sdtw(qb, ref[:256], top_k=2, chunk=64)
    np.testing.assert_array_equal(np.asarray(res_b.distances),
                                  np.asarray(db))

    snaps = pool.snapshot("feed")
    assert sorted(snaps) == ["a", "c"]

    pool.feed("feed", ref[256:])
    live = pool.finalize("feed")

    # the restored pool continues bit-for-bit on the same suffix
    pool.restore("feed-replay", snaps)
    pool.feed("feed-replay", ref[256:])
    replay = pool.finalize("feed-replay")
    for t in ("a", "c"):
        np.testing.assert_array_equal(np.asarray(live[t].distances),
                                      np.asarray(replay[t].distances))

    da, _ = engine.sdtw(qa, ref, top_k=2, chunk=64)
    np.testing.assert_array_equal(np.asarray(live["a"].distances),
                                  np.asarray(da))
    dc, _ = engine.sdtw(qc, ref[256:], top_k=2, chunk=64)
    np.testing.assert_array_equal(np.asarray(live["c"].distances),
                                  np.asarray(dc))


# ---------------------------------------------------------------------------
# lifecycle regressions: once admitted, always answered
# ---------------------------------------------------------------------------

def test_close_without_drain_fails_queued_futures(rng):
    """close(drain=False) must fail still-queued futures instead of
    orphaning them (clients blocked in .result() used to hang forever)."""
    q, r = _mk(rng, 2, 8)
    router = Router(RouterConfig(auto_dispatch=False))
    futs = [router.submit(queries=q, reference=r) for _ in range(3)]
    router.close(drain=False)
    for f in futs:
        with pytest.raises(RuntimeError,
                           match="router closed before dispatch"):
            f.result(timeout=1.0)
    stats = router.stats()
    assert stats.unserved_on_close == 3
    assert stats.completed == 0


def test_cancelled_future_does_not_poison_group(rng):
    """A client-cancelled future must not convert its groupmates'
    successes into errors (set_result on a cancelled future used to
    raise InvalidStateError out of the delivery loop)."""
    r = rng.integers(-40, 40, 300).astype(np.int32)
    clients = [rng.integers(-40, 40, (2, 10)).astype(np.int32)
               for _ in range(3)]
    router = Router(RouterConfig(auto_dispatch=False))
    futs = [router.submit(queries=q, reference=r) for q in clients]
    assert futs[1].cancel()
    router.drain()
    for i in (0, 2):
        np.testing.assert_array_equal(
            np.asarray(futs[i].result(timeout=0)),
            np.asarray(engine.sdtw(clients[i], r)))
    stats = router.stats()
    assert stats.cancelled == 1
    assert stats.errors == 0
    assert stats.completed == 2
    router.close()


def test_cancelled_mid_window_under_load(rng):
    """Cancel racing a live dispatch window: every non-cancelled future
    still resolves with its bitwise offline answer."""
    r = rng.integers(-40, 40, 256).astype(np.int32)
    clients = [rng.integers(-40, 40, (1, 8 + i)).astype(np.int32)
               for i in range(8)]
    with Router(window_ms=20.0) as router:
        futs = [router.submit(queries=q, reference=r) for q in clients]
        cancelled = [f.cancel() for f in futs[::2]]
        for i, f in enumerate(futs):
            if i % 2 == 0 and cancelled[i // 2]:
                assert f.cancelled()
                continue
            np.testing.assert_array_equal(
                np.asarray(f.result(timeout=30.0)),
                np.asarray(engine.sdtw(clients[i], r)))


def test_telemetry_bounded_ring():
    """The percentile stores are ring buffers (no unbounded growth);
    counters and means stay exact over the whole lifetime."""
    from repro.serve import RequestTrace
    tel = Telemetry(window=16)
    for _ in range(100):
        t = RequestTrace(op="sdtw", nq=2)
        t.mark_dispatch(batch_requests=1, batch_queries=2)
        t.mark_complete()
        tel.record_complete(t)
    snap = tel.snapshot()
    assert snap.completed == 100
    assert snap.queries_served == 200
    assert snap.latency_samples == 16          # bounded
    assert snap.sample_window == 16
    assert np.isfinite(snap.p50_latency_us)
    assert np.isfinite(snap.mean_latency_us)   # exact running mean
    with pytest.raises(ValueError, match="window"):
        Telemetry(window=0)


def test_submit_vs_close_race_every_future_answered(rng):
    """Stress: clients submitting while the router closes — every
    future must settle (result, QueueFull, or the close error); none
    may hang."""
    q, r = _mk(rng, 1, 6)
    want = np.asarray(engine.sdtw(q, r))
    futs, errs, lock = [], [], threading.Lock()

    router = Router(RouterConfig(window_ms=1.0, max_queue=8,
                                 admission="reject"))

    def submitter():
        for _ in range(10):
            try:
                f = router.submit(queries=q, reference=r)
                with lock:
                    futs.append(f)
            except (QueueFull, RuntimeError) as e:
                with lock:
                    errs.append(e)

    threads = [threading.Thread(target=submitter) for _ in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.02)
    router.close(drain=False)
    for t in threads:
        t.join()
    answered = 0
    for f in futs:
        try:
            got = f.result(timeout=30.0)       # never hangs
            np.testing.assert_array_equal(np.asarray(got), want)
            answered += 1
        except (QueueFull, RuntimeError):
            pass
        except concurrent.futures.CancelledError:
            pass
    stats = router.stats()
    assert answered == stats.completed
    assert stats.completed + stats.unserved_on_close \
        + stats.shed + len(errs) >= len(futs) + len(errs)


# ---------------------------------------------------------------------------
# priorities, quotas, aging, shedding
# ---------------------------------------------------------------------------

def test_priority_drain_order_strict():
    q = AdmissionQueue(8, aging_s=None)
    q.put("lo", priority=0)
    q.put("hi", priority=5)
    q.put("mid", priority=2)
    q.put("hi2", priority=5)
    assert q.drain() == ["hi", "hi2", "mid", "lo"]   # desc, FIFO ties


def test_priority_aging_admits_starved_tenants():
    """With aging, a parked low-priority request eventually outranks
    fresh high-priority traffic (starvation freedom)."""
    q = AdmissionQueue(8, aging_s=0.01)
    q.put("starved-lo", priority=0)
    time.sleep(0.06)                     # ages >= 5 effective classes
    q.put("fresh-hi", priority=3)
    assert q.drain() == ["starved-lo", "fresh-hi"]

    q2 = AdmissionQueue(8, aging_s=None)  # aging off: strict priority
    q2.put("lo", priority=0)
    time.sleep(0.02)
    q2.put("hi", priority=3)
    assert q2.drain() == ["hi", "lo"]


def test_tenant_quota_rejects_overrun(rng):
    q, r = _mk(rng, 1, 6)
    router = Router(RouterConfig(auto_dispatch=False, tenant_quota=2))
    router.submit(queries=q, reference=r, tenant="greedy")
    router.submit(queries=q, reference=r, tenant="greedy")
    with pytest.raises(QueueFull, match="quota"):
        router.submit(queries=q, reference=r, tenant="greedy")
    router.submit(queries=q, reference=r, tenant="other")  # unaffected
    assert router.stats().rejected == 1
    router.drain()
    assert router.stats().completed == 3
    router.close()


def test_reject_shed_lowest_priority_first(rng):
    """Under 'reject', a higher-priority arrival sheds the newest
    lowest-priority pending request; its future fails with QueueFull."""
    q, r = _mk(rng, 1, 6)
    router = Router(RouterConfig(max_queue=2, admission="reject",
                                 aging_s=None, auto_dispatch=False))
    f_old = router.submit(queries=q, reference=r, priority=0)
    f_new = router.submit(queries=q, reference=r, priority=0)
    f_hi = router.submit(queries=q, reference=r, priority=5)  # sheds f_new
    with pytest.raises(QueueFull, match="shed"):
        f_new.result(timeout=1.0)
    # equal priority still rejects the arrival, never sheds
    with pytest.raises(QueueFull, match="full"):
        router.submit(queries=q, reference=r, priority=0)
    router.drain()
    want = np.asarray(engine.sdtw(q, r))
    np.testing.assert_array_equal(np.asarray(f_old.result(timeout=0)), want)
    np.testing.assert_array_equal(np.asarray(f_hi.result(timeout=0)), want)
    stats = router.stats()
    assert stats.shed == 1 and stats.rejected == 1
    assert stats.completed == 2
    router.close()


def test_reject_storm_under_priority_shed_accounting(rng):
    """Storm of mixed-priority submissions against a tiny reject queue
    with a concurrent drainer: every request is accounted for exactly
    once (completed / rejected / shed), and every success is bitwise."""
    q, r = _mk(rng, 1, 6)
    want = np.asarray(engine.sdtw(q, r))
    router = Router(RouterConfig(max_queue=4, admission="reject",
                                 aging_s=None, auto_dispatch=False))
    futs, sync_rejects, lock = [], [0], threading.Lock()
    stop = threading.Event()

    def drainer():
        while not stop.is_set():
            router.drain()
            time.sleep(0.002)
        router.drain()

    def submitter(prio):
        for _ in range(12):
            try:
                f = router.submit(queries=q, reference=r, priority=prio)
                with lock:
                    futs.append(f)
            except QueueFull:
                with lock:
                    sync_rejects[0] += 1

    d = threading.Thread(target=drainer)
    d.start()
    threads = [threading.Thread(target=submitter, args=(p,))
               for p in (0, 1, 2, 0)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    d.join()
    completed = shed = 0
    for f in futs:
        try:
            np.testing.assert_array_equal(np.asarray(f.result(timeout=30.0)),
                                          want)
            completed += 1
        except QueueFull:
            shed += 1
    stats = router.stats()
    offered = 4 * 12
    assert completed + shed + sync_rejects[0] == offered
    assert stats.completed == completed
    assert stats.shed == shed
    assert stats.rejected == sync_rejects[0]
    router.close()


# ---------------------------------------------------------------------------
# in-window dedup
# ---------------------------------------------------------------------------

def test_dedup_identical_requests_share_call_and_result(rng):
    """Identical concurrent requests (equal bytes, different array
    objects) share ONE engine call and the SAME result object; a
    different request in the same window still coalesces normally."""
    r = rng.integers(-40, 40, 300).astype(np.int32)
    q = rng.integers(-40, 40, (2, 12)).astype(np.int32)
    other = rng.integers(-40, 40, (3, 12)).astype(np.int32)
    router = Router(RouterConfig(auto_dispatch=False))
    f1 = router.submit(queries=q, reference=r, ref_key="feed")
    f2 = router.submit(queries=q.copy(), reference=r, ref_key="feed")
    f3 = router.submit(queries=other, reference=r, ref_key="feed")
    router.drain()
    stats = router.stats()
    assert stats.dispatches == 1                # one merged call for all
    assert stats.deduped == 1
    assert stats.completed == 3
    g1, g2 = f1.result(timeout=0), f2.result(timeout=0)
    assert g1 is g2                             # bitwise-shared result
    np.testing.assert_array_equal(np.asarray(g1),
                                  np.asarray(engine.sdtw(q, r)))
    np.testing.assert_array_equal(np.asarray(f3.result(timeout=0)),
                                  np.asarray(engine.sdtw(other, r)))
    router.close()


def test_dedup_respects_content_and_shape(rng):
    """Same length but different bytes — or same bytes via a 1-D vs 2-D
    shape — must NOT dedup."""
    r = rng.integers(-40, 40, 200).astype(np.int32)
    q1 = rng.integers(-40, 40, (1, 8)).astype(np.int32)
    q2 = q1 + 1
    router = Router(RouterConfig(auto_dispatch=False))
    fa = router.submit(queries=q1, reference=r, ref_key="k")
    fb = router.submit(queries=q2, reference=r, ref_key="k")
    fc = router.submit(queries=q1[0], reference=r, ref_key="k")  # 1-D
    router.drain()
    assert router.stats().deduped == 0
    np.testing.assert_array_equal(np.asarray(fa.result(timeout=0)),
                                  np.asarray(engine.sdtw(q1, r)))
    np.testing.assert_array_equal(np.asarray(fb.result(timeout=0)),
                                  np.asarray(engine.sdtw(q2, r)))
    got_c = fc.result(timeout=0)
    assert np.asarray(got_c).shape == ()        # scalar unwrap preserved
    np.testing.assert_array_equal(np.asarray(got_c),
                                  np.asarray(engine.sdtw(q1[0], r)))
    router.close()


def test_dedup_can_be_disabled(rng):
    q, r = _mk(rng, 2, 8)
    router = Router(RouterConfig(auto_dispatch=False, dedup=False))
    f1 = router.submit(queries=q, reference=r)
    f2 = router.submit(queries=q.copy(), reference=r)
    router.drain()
    assert router.stats().deduped == 0
    assert f1.result(timeout=0) is not f2.result(timeout=0)
    np.testing.assert_array_equal(np.asarray(f1.result(timeout=0)),
                                  np.asarray(f2.result(timeout=0)))
    router.close()


# ---------------------------------------------------------------------------
# device pool
# ---------------------------------------------------------------------------

def test_device_pool_bitwise_equal_to_single_device_drain(rng):
    """The same request mix through a multi-worker device pool equals
    the single-device drain bitwise (and offline, transitively)."""
    import jax
    dev = jax.local_devices()[0]
    r = rng.integers(-40, 40, 300).astype(np.int32)
    clients = [rng.integers(-40, 40, (nq, 10 + nq)).astype(np.int32)
               for nq in (1, 2, 3, 4, 2)]

    def serve_all(devices):
        router = Router(RouterConfig(auto_dispatch=False, devices=devices))
        futs = [router.submit(queries=q, reference=r, metric=m)
                for q in clients for m in ("abs_diff", "square_diff")]
        router.drain()
        out = [np.asarray(f.result(timeout=0)) for f in futs]
        router.close()
        return out

    single = serve_all(None)
    pooled = serve_all([dev, dev, dev])     # 3 workers, shared device
    alldev = serve_all("all")
    for s, p, a in zip(single, pooled, alldev):
        np.testing.assert_array_equal(s, p)
        np.testing.assert_array_equal(s, a)


def test_device_pool_resolution_and_lifecycle():
    import jax
    with DevicePool(None) as pool:
        assert pool.size == 1 and pool.devices == [None]
    n = len(jax.local_devices())
    with DevicePool("all") as pool:
        assert pool.size == n
    with DevicePool(1) as pool:
        assert pool.size == 1
    with pytest.raises(ValueError, match="local device"):
        DevicePool(n + 1)
    with pytest.raises(ValueError, match="at least one"):
        DevicePool([])
    pool = DevicePool(None)
    pool.close()
    with pytest.raises(RuntimeError, match="closed"):
        pool.submit([], None)


def test_device_pool_affinity_policy():
    """Executable-affinity routing: reuse a warm device when one is
    idle, grow onto a cold idle device only under same-shape pressure,
    and queue on warm rather than compile when everything is busy."""
    from repro.serve.pool import pick_device

    # Never-seen shape: globally least-loaded, lowest index on ties.
    assert pick_device([0, 0, 0], ()) == 0
    assert pick_device([2, 1, 2], ()) == 1
    # A warm device is idle: stay on it even though device 0 is idle too
    # (free cache reuse beats spreading).
    assert pick_device([0, 0, 0], {1}) == 1
    assert pick_device([1, 0, 1], {1, 2}) == 1
    # Warm merely busy (below GROW_LOAD): still queue on it — one group
    # in flight is every burst's steady state, not a backlog.
    assert pick_device([1, 0, 0], {0}) == 0
    # A genuinely backlogged warm set + a cold idle device: pay one
    # compile to grow the warm set (lowest cold idle index).
    assert pick_device([2, 0, 0], {0}) == 1
    assert pick_device([0, 2, 2], {1, 2}) == 0
    # Everything busy: queue on the least-loaded warm device — waiting
    # milliseconds beats compiling seconds on a cold one.
    assert pick_device([3, 4, 3], {1, 2}) == 2
    assert pick_device([9, 2, 2], {1}) == 1
    # A cold landing already in flight gates further growth: the same
    # pressure that would spread the shape must queue on warm instead
    # (one compile at a time per shape — no compile avalanche).
    assert pick_device([2, 0, 0], {0}, growing=True) == 0
    assert pick_device([0, 2, 2], {1, 2}, growing=True) == 1


def test_router_warmup_primes_every_device(rng):
    """``warmup`` compiles the request's bucket on every pool device
    and marks them all warm, so serving never routes that shape to a
    cold device."""
    from repro.core.request import SdtwRequest
    from repro.serve import batcher
    from repro.serve import pool as pool_mod

    pool_mod.clear_affinity_cache()
    r = rng.integers(-40, 40, 256).astype(np.int32)
    qs = [rng.integers(-40, 40, 16).astype(np.int32) for _ in range(4)]
    with Router(devices="all", auto_dispatch=False) as router:
        assert router.warmup(queries=qs, reference=r) == router._pool.size
        req = SdtwRequest.from_kwargs(queries=qs, reference=r)
        shape = batcher.group_shape(
            [batcher.Pending(request=req, future=None, trace=None)])
        assert set(router._pool.devices) <= pool_mod._warm_devices[shape]
        fut = router.submit(queries=qs, reference=r)
        router.drain()
        np.testing.assert_array_equal(np.asarray(fut.result(timeout=60)),
                                      np.asarray(engine.sdtw(qs, r)))
    pool_mod.clear_affinity_cache()


# ---------------------------------------------------------------------------
# adaptive window
# ---------------------------------------------------------------------------

def test_adaptive_window_closes_early_when_bucket_fills(rng):
    """A filled pow-2 bucket must close the window immediately — a
    client never waits out a long base window once the batch is full."""
    r = rng.integers(-40, 40, 200).astype(np.int32)
    q = rng.integers(-40, 40, (4, 8)).astype(np.int32)   # weight 4
    expect = engine.sdtw(q, r)          # warm the jit cache: the timer
    with Router(window_ms=2000.0, window_full_queries=4) as router:
        t0 = time.monotonic()           # must see the window, not XLA
        got = router.sdtw(q, r)                # blocks until served
        elapsed = time.monotonic() - t0
        stats = router.stats()
    assert elapsed < 1.5, f"window did not close early ({elapsed:.2f}s)"
    assert stats.window_early_closes >= 1
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))


def test_queue_wait_weight_primitive():
    q = AdmissionQueue(8)
    q.put("a", weight=3)
    assert q.wait_weight(3, time.monotonic() + 5.0)      # already full
    assert not q.wait_weight(4, time.monotonic() + 0.02)  # expires
    assert q.pending_weight() == 3

    def late_put():
        time.sleep(0.02)
        q.put("b", weight=5)

    t = threading.Thread(target=late_put)
    t.start()
    assert q.wait_weight(8, time.monotonic() + 5.0)      # woken by put
    t.join()


def test_router_open_stream_and_stats(rng):
    ref = rng.integers(-40, 40, 256).astype(np.int32)
    q = rng.integers(-40, 40, (2, 8)).astype(np.int32)
    with Router(RouterConfig(auto_dispatch=False)) as router:
        router.open_stream("sensor", "t0", queries=q, chunk=32, top_k=2)
        assert router.feed("sensor", ref) == 1
        res = router.sessions.finalize("sensor")["t0"]
        d, _ = engine.sdtw(q, ref, top_k=2, chunk=32)
        np.testing.assert_array_equal(np.asarray(res.distances),
                                      np.asarray(d))
        snap = router.stats()
        assert snap.completed == snap.dispatches == 0
