"""Match spans and alignment-path traceback: the oracle-differential
harness across every engine path.

Ground truth comes exclusively from ``tests/oracle.py`` (the lexicographic
start-lane DP + pinned-window path traceback). The five single-process
execution regimes (rowscan, wavefront, pallas, streamed pallas, chunked)
are asserted bitwise against it for int32 (and for integer-valued float32,
which is exact); the 8-device sharded regime is §10 of
``_distributed_check.py`` (the ``-m slow`` lane). Golden ``.npz`` fixtures
pin the exact outputs of a fixed seed against silent cross-version drift.
"""
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from oracle import greedy_topk_spans, sdtw_path, sdtw_span

from repro.core import align, check_path, path_cost, sdtw, traceback_path
from repro.core.distances import INT_BIG
from repro.search import search_topk

GOLDEN = pathlib.Path(__file__).parent / "golden" / "sdtw_spans_v1.npz"

#: Every single-process execution regime behind ``engine.sdtw``.
SINGLE_IMPLS = [("rowscan", {}), ("wavefront", {}),
                ("pallas", {"block_q": 2, "block_m": 8}),
                ("pallas", {"chunk": 21, "block_q": 2, "block_m": 8}),
                ("chunked", {"chunk": 16})]


def _spans(q, r, impl, kw, metric="abs_diff"):
    d, s, e = sdtw(jnp.asarray(q), jnp.asarray(r), impl=impl, metric=metric,
                   return_spans=True, **kw)
    return np.asarray(d), np.asarray(s), np.asarray(e)


# ---------------------------------------------------------------------------
# Differential: spans vs the oracle on all single-process impls
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["abs_diff", "square_diff"])
@pytest.mark.parametrize("dtype", [np.int32, np.float32])
def test_spans_match_oracle_all_impls(metric, dtype, rng):
    """(dist, start, end) of every impl == the lexicographic span oracle —
    bitwise (integer-valued float32 is exact, so bitwise there too). Small
    value range forces plenty of exact ties, exercising the tie-break."""
    for _ in range(4):
        nq = int(rng.integers(1, 5))
        n = int(rng.integers(1, 10))
        m = int(rng.integers(1, 60))
        q = rng.integers(-8, 8, (nq, n)).astype(dtype)
        r = rng.integers(-8, 8, m).astype(dtype)
        want = np.array([sdtw_span(q[i], r, metric) for i in range(nq)])
        for impl, kw in SINGLE_IMPLS:
            d, s, e = _spans(q, r, impl, kw, metric)
            np.testing.assert_array_equal(d, want[:, 0], err_msg=impl)
            np.testing.assert_array_equal(s, want[:, 1], err_msg=impl)
            np.testing.assert_array_equal(e, want[:, 2], err_msg=impl)


def test_spans_chunk_and_block_invariance(rng):
    """Tiling must not change the reported span — chunk=1 (pure column
    streaming) through chunk > M, and pallas block shapes."""
    q = rng.integers(-10, 10, (3, 8)).astype(np.int32)
    r = rng.integers(-10, 10, 137).astype(np.int32)
    base = _spans(q, r, "chunked", {"chunk": 137})
    for c in (1, 5, 8, 1024):
        got = _spans(q, r, "chunked", {"chunk": c})
        for a, b in zip(base, got):
            np.testing.assert_array_equal(a, b, err_msg=f"chunk={c}")
    for bq, bm in ((1, 8), (2, 32), (4, 256)):
        got = _spans(q, r, "pallas", {"block_q": bq, "block_m": bm})
        for a, b in zip(base, got):
            np.testing.assert_array_equal(a, b, err_msg=f"block={bq},{bm}")


def test_spans_ragged_matches_per_query(rng):
    r = rng.integers(-20, 20, 90).astype(np.int32)
    ragged = [rng.integers(-20, 20, L).astype(np.int32) for L in (3, 17, 8)]
    dr, sr, er = sdtw(ragged, jnp.asarray(r), return_spans=True)
    for i, q in enumerate(ragged):
        d, s, e = sdtw(jnp.asarray(q), jnp.asarray(r), return_spans=True)
        assert (int(dr[i]), int(sr[i]), int(er[i])) == \
            (int(d), int(s), int(e))


# ---------------------------------------------------------------------------
# Top-K spans (heap start lane) + span-overlap suppression
# ---------------------------------------------------------------------------

def test_topk_spans_match_greedy_oracle(rng):
    """engine.sdtw(top_k=, return_spans=True) == greedy select-then-suppress
    on the oracle's last row with its start lane, both exclusion modes."""
    q = rng.integers(-10, 10, (2, 6)).astype(np.int32)
    r = rng.integers(-10, 10, 120).astype(np.int32)
    k, zone = 3, 4
    for mode in ("end", "span"):
        d, s, e = sdtw(jnp.asarray(q), jnp.asarray(r), top_k=k,
                       excl_zone=zone, excl_mode=mode, return_spans=True)
        d, s, e = np.asarray(d), np.asarray(s), np.asarray(e)
        for i in range(2):
            want = greedy_topk_spans(q[i], r, k, zone,
                                     excl_span=(mode == "span"))
            for kk, (wd, ws, we) in enumerate(want):
                assert e[i, kk] == we, (mode, i, kk)
                assert s[i, kk] == ws, (mode, i, kk)
                if we >= 0:
                    assert d[i, kk] == wd, (mode, i, kk)


def test_span_overlap_mode_reports_disjoint_spans(rng):
    """excl_mode='span' (default zone 0): no two reported matches of a
    query share a reference sample."""
    q = rng.integers(-40, 40, (3, 8)).astype(np.int32)
    r = rng.integers(-40, 40, 200).astype(np.int32)
    _, s, e = sdtw(jnp.asarray(q), jnp.asarray(r), top_k=4,
                   excl_mode="span", return_spans=True)
    s, e = np.asarray(s), np.asarray(e)
    for i in range(3):
        spans = [(a, b) for a, b in zip(s[i], e[i]) if a >= 0]
        assert spans, "no live matches reported"
        for x in range(len(spans)):
            for y in range(x + 1, len(spans)):
                lo, hi = sorted((spans[x], spans[y]))
                assert lo[1] < hi[0], (spans[x], spans[y])


def test_span_mode_requires_topk():
    with pytest.raises(ValueError, match="span"):
        sdtw(jnp.zeros((1, 4), jnp.int32), jnp.zeros(8, jnp.int32),
             excl_mode="span")


def test_search_topk_spans_match_engine(rng):
    """search_topk reports the same spans as the engine: exact path always,
    pruned path for in-cap spans (top-1)."""
    q = rng.integers(-40, 40, (3, 10)).astype(np.int32)
    r = rng.integers(-40, 40, 300).astype(np.int32)
    res = search_topk(jnp.asarray(q), jnp.asarray(r), k=2, prune=False,
                      chunk=32)
    d, s, e = sdtw(jnp.asarray(q), jnp.asarray(r), top_k=2,
                   return_spans=True)
    np.testing.assert_array_equal(np.asarray(res.distances), np.asarray(d))
    np.testing.assert_array_equal(np.asarray(res.starts), np.asarray(s))
    np.testing.assert_array_equal(np.asarray(res.positions), np.asarray(e))
    assert res.spans.shape == (3, 2, 2)
    pruned = search_topk(jnp.asarray(q), jnp.asarray(r), k=1, chunk=64)
    want_d, want_s, want_e = sdtw(jnp.asarray(q), jnp.asarray(r),
                                  return_spans=True)
    np.testing.assert_array_equal(np.asarray(pruned.distances)[:, 0],
                                  np.asarray(want_d))
    np.testing.assert_array_equal(np.asarray(pruned.starts)[:, 0],
                                  np.asarray(want_s))
    np.testing.assert_array_equal(np.asarray(pruned.positions)[:, 0],
                                  np.asarray(want_e))


# ---------------------------------------------------------------------------
# Alignment-path traceback
# ---------------------------------------------------------------------------

def test_align_replays_distance_bitwise(rng):
    """engine.align(): the recovered path is structurally valid, matches
    the oracle's pinned-window traceback exactly, and its accumulated
    cost reproduces the engine distance bitwise (int32 and float32)."""
    for dtype in (np.int32, np.float32):
        q = rng.integers(-10, 10, (3, 7)).astype(dtype)
        r = rng.integers(-10, 10, 80).astype(dtype)
        d, s, e = _spans(q, r, "chunked", {"chunk": 16})
        results = align(jnp.asarray(q), jnp.asarray(r), trace_chunk=5)
        for i, ar in enumerate(results):
            assert (ar.start, ar.end) == (int(s[i]), int(e[i]))
            assert check_path(ar.path, ar.start, ar.end, 7)
            assert path_cost(q[i], r, ar.path) == d[i]
            np.testing.assert_array_equal(
                ar.path, sdtw_path(q[i], r, ar.start, ar.end))


def test_traceback_chunk_invariance(rng):
    """The checkpointed block replay must produce the identical path for
    any block width (1 = column-at-a-time … ≥ window = single block)."""
    q = rng.integers(-10, 10, 9).astype(np.int32)
    r = rng.integers(-10, 10, 64).astype(np.int32)
    _, s, e = sdtw(jnp.asarray(q), jnp.asarray(r), return_spans=True)
    paths = [traceback_path(q, r, int(s), int(e), chunk=c)
             for c in (1, 3, 7, 64, 10**6)]
    assert check_path(paths[0], int(s), int(e), 9)
    for p in paths[1:]:
        np.testing.assert_array_equal(paths[0], p)


def test_traceback_chunk1_boundary_diagonal_keeps_start_cell():
    """Regression: with chunk=1 every move crosses a block boundary; a
    *diagonal* step landing on (0, start) used to terminate the outer
    block loop before block 0 replayed, silently dropping the path's
    first cell (and its distance contribution)."""
    q = np.asarray([0, 5], np.int32)
    r = np.asarray([9, 9, 0, 5, 9], np.int32)   # exact match at [2, 3]
    want = np.asarray([[0, 2], [1, 3]], np.int64)
    for c in (1, 2, 64):
        p = traceback_path(q, r, 2, 3, chunk=c)
        np.testing.assert_array_equal(p, want, err_msg=f"chunk={c}")
        assert check_path(p, 2, 3, 2)
        assert int(path_cost(q, r, p)) == 0


def test_align_exact_subsequence_is_diagonal(rng):
    """A planted exact match aligns 1:1: span == the planted window and
    the path is the pure diagonal."""
    r = rng.integers(-50, 50, 100).astype(np.int32)
    q = r[37:59]
    ar = align(jnp.asarray(q), jnp.asarray(r))
    assert (int(ar.distance), ar.start, ar.end) == (0, 37, 58)
    want = np.stack([np.arange(22), np.arange(37, 59)], axis=1)
    np.testing.assert_array_equal(ar.path, want)


def test_align_saturated_match_has_no_span(rng):
    """When every alignment saturates the int32 lattice (per-cell square
    distances fit, multi-cell paths clamp at INT_BIG — the largest regime
    the lattice supports) there is no meaningful span: align reports
    (-1, -1, None) instead of garbage."""
    q = np.full((6,), -10_000, np.int32)
    r = np.full((48,), 10_000, np.int32)
    ar = align(jnp.asarray(q), jnp.asarray(r), metric="square_diff")
    assert int(ar.distance) == INT_BIG
    assert ar.start == -1 and ar.end == -1 and ar.path is None


def test_traceback_rejects_bad_span(rng):
    q = rng.integers(-5, 5, 4).astype(np.int32)
    r = rng.integers(-5, 5, 16).astype(np.int32)
    with pytest.raises(ValueError, match="span"):
        traceback_path(q, r, 5, 3)
    with pytest.raises(ValueError, match="span"):
        traceback_path(q, r, -1, 3)


# ---------------------------------------------------------------------------
# Hypothesis property suite
# ---------------------------------------------------------------------------

def test_hyp_span_and_path_properties():
    """Property suite: across random int32 inputs and every in-core impl,
    start <= end, spans differential-match the oracle, the traced path is
    monotone/contiguous with the span endpoints, and its cost sums
    bitwise to the reported distance."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.integers(-6, 6), min_size=1, max_size=8),
           st.lists(st.integers(-6, 6), min_size=1, max_size=24),
           st.sampled_from(["abs_diff", "square_diff"]))
    def prop(qs, rs, metric):
        q = np.asarray(qs, np.int32)
        r = np.asarray(rs, np.int32)
        want = sdtw_span(q, r, metric)
        for impl, kw in (("rowscan", {}), ("wavefront", {}),
                         ("chunked", {"chunk": 8})):
            d, s, e = _spans(q[None], r, impl, kw, metric)
            assert (float(d[0]), int(s[0]), int(e[0])) == want, impl
            assert 0 <= s[0] <= e[0] < len(r)
        ar = align(jnp.asarray(q), jnp.asarray(r), metric=metric,
                   trace_chunk=4)
        assert check_path(ar.path, ar.start, ar.end, len(q))
        assert int(path_cost(q, r, ar.path, metric)) == int(want[0])
        np.testing.assert_array_equal(
            ar.path, sdtw_path(q, r, ar.start, ar.end, metric))

    prop()


def test_hyp_topk_span_mode_disjoint():
    """Property: span-overlap suppression never reports overlapping spans
    and the top-1 always equals the plain span call."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(-10, 10), min_size=2, max_size=6),
           st.lists(st.integers(-10, 10), min_size=8, max_size=40))
    def prop(qs, rs):
        q = np.asarray(qs, np.int32)
        r = np.asarray(rs, np.int32)
        d, s, e = sdtw(jnp.asarray(q), jnp.asarray(r), top_k=3,
                       excl_mode="span", return_spans=True)
        d, s, e = np.asarray(d), np.asarray(s), np.asarray(e)
        pd, ps, pe = sdtw(jnp.asarray(q), jnp.asarray(r),
                          return_spans=True)
        assert (d[0], s[0], e[0]) == (pd, ps, pe)
        live = [(int(a), int(b)) for a, b in zip(s, e) if a >= 0]
        for x in range(len(live)):
            for y in range(x + 1, len(live)):
                lo, hi = sorted((live[x], live[y]))
                assert lo[1] < hi[0]

    prop()


# ---------------------------------------------------------------------------
# Golden regression fixtures (bitwise, fixed seed)
# ---------------------------------------------------------------------------

def test_golden_spans_bitwise():
    """Recompute the committed fixture (tests/golden/make_golden.py) and
    require bitwise equality — the jax-version-drift tripwire (the PR 1
    breakage class). Regenerate *only* on an intentional semantic change:
    ``python tests/golden/make_golden.py``."""
    assert GOLDEN.exists(), "golden fixture missing — run " \
        "tests/golden/make_golden.py"
    data = np.load(GOLDEN)
    from golden.make_golden import compute  # noqa: E402
    fresh = compute()
    assert set(fresh) == set(data.files)
    for key in data.files:
        np.testing.assert_array_equal(
            np.asarray(fresh[key]), data[key],
            err_msg=f"golden drift in {key!r} — if intentional, "
                    "regenerate via tests/golden/make_golden.py")
